"""Co-simulation of the recovery control plane with the event engine.

Glue between :class:`runtime.control_plane.ControlPlane` (the online
pipeline) and :class:`core.event_sim.EventSimulator` (the data plane in
virtual time): every failure event the engine processes is played through
the control plane *at that virtual instant*, and the resulting
:class:`RecoveryDecision` — derived restart delay, rebalance capacity
factor, optional replanned program — is applied by the engine.  Failover
latency therefore *emerges* from the detect→diagnose→migrate→rebalance
pipeline instead of the alpha-beta ``R2CCL_MIGRATION_LATENCY`` constant.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.comm_sim import _strategy_program
from repro.core.event_sim import (
    EventSimReport,
    RecoveryDecision,
    simulate_program,
)
from repro.core.failures import FailureState
from repro.core.schedule import ring_program
from repro.core.topology import ClusterTopology, DEFAULT_ALPHA

from .control_plane import ControlPlane, RecoveryLedger, RecoveryState
from .scenarios import Scenario


class _EngineAdapter:
    """The controller object the event engine calls back into.

    ``offset`` rebases the engine's run-local clock onto campaign time: a
    multi-iteration campaign runs one engine per gradient sync, each
    starting at t=0, while the persistent control plane's ledger and
    transitions are stamped in campaign-global virtual time.

    Failures carry the engine's chunk map (:class:`ChunkProgress`) into the
    pipeline so a replan prices the residual collective.  Recoveries are
    two-phase: ``on_recover`` (the physical event) returns the confirmation
    time — the control plane's next scheduled probe tick — and the engine
    calls ``on_recovery_confirmed`` when that tick arrives, which is when
    the failure state actually clears.
    """

    def __init__(self, cp: ControlPlane, offset: float = 0.0):
        self.cp = cp
        self.offset = offset
        self.decisions: list[RecoveryDecision] = []

    def on_failure(self, sim, now, failure) -> RecoveryDecision | None:
        outcome = self.cp.handle_failure(
            failure, self.offset + now, progress=sim.chunk_progress())
        if outcome is None:
            return None
        self.decisions.append(outcome.decision)
        return outcome.decision

    def on_recover(self, sim, now, failure) -> float:
        return self.cp.observe_physical_recovery(
            failure, self.offset + now) - self.offset

    def on_recovery_confirmed(self, sim, now, failure) -> None:
        self.cp.handle_recovery(failure, self.offset + now)


def plan_initial_program(
    strategy: str,
    cluster: ClusterTopology,
    failures,
    *,
    g: int,
    state: FailureState | None = None,
):
    """The t=0 program: ``strategy`` planned against what the control plane
    knows before the collective starts — ``state`` (carried over from
    earlier collectives, if any) plus failures already in effect (``at_time
    <= 0`` and full severity).  Single planning rule for the one-collective
    (:func:`run_scenario`) and campaign (:mod:`runtime.campaign`) paths so
    they cannot diverge."""
    pre = state.copy() if state is not None else FailureState()
    for f in failures:
        if f.at_time <= 0.0 and f.severity >= 1.0:
            pre.apply(f)
    return _strategy_program(strategy, cluster, pre, g=g)


@dataclasses.dataclass
class CoSimReport:
    """One scenario campaign, co-simulated end to end."""

    scenario: str
    report: EventSimReport                 # the engine's view
    ledger: RecoveryLedger                 # the control plane's view
    final_state: RecoveryState
    transitions: list[tuple[float, RecoveryState]]
    stage_totals: dict[str, float]
    decisions: list[RecoveryDecision]
    healthy_time: float
    overhead: float                        # completion vs healthy ring - 1

    @property
    def failover_latency(self) -> float:
        """Ledger total of the first recovery pipeline (the paper's
        hot-repair figure for a clean single failure)."""
        return self.ledger.entries[0].total if self.ledger.entries else 0.0


def run_scenario(
    scenario: Scenario,
    cluster: ClusterTopology,
    payload_bytes: float,
    *,
    strategy: str = "ring",
    alpha: float = DEFAULT_ALPHA,
    control_plane: ControlPlane | None = None,
    rank_data: Sequence[np.ndarray] | None = None,
    healthy_time: float | None = None,
    finalize: bool = True,
) -> CoSimReport:
    """Drive one failure campaign through the co-simulated runtime.

    The initial program is planned against what the control plane knows at
    t=0 (failures with ``at_time <= 0``); later failures strike
    mid-collective and exercise the full closed loop.  ``finalize`` settles
    the state machine at campaign end (persistent degradation → REPLANNED
    for the next collective, all-healthy → HEALTHY).
    """
    n = cluster.num_nodes
    g = cluster.devices_per_node
    order = list(range(n))

    cp = control_plane or ControlPlane(cluster, payload_bytes=payload_bytes)
    prog = plan_initial_program(strategy, cluster, scenario.failures, g=g)

    if healthy_time is None:
        healthy_time = simulate_program(
            ring_program(order, n), payload_bytes, cluster=cluster,
            alpha=alpha).completion_time

    adapter = _EngineAdapter(cp)
    report = simulate_program(
        prog, payload_bytes, cluster=cluster, alpha=alpha,
        failures=scenario.failures, rank_data=rank_data, controller=adapter)
    if finalize:
        cp.finalize(report.completion_time)

    return CoSimReport(
        scenario=scenario.name,
        report=report,
        ledger=cp.ledger,
        final_state=cp.state,
        transitions=list(cp.transitions),
        stage_totals=cp.ledger.stage_totals(),
        decisions=adapter.decisions,
        healthy_time=healthy_time,
        overhead=report.completion_time / healthy_time - 1.0,
    )
