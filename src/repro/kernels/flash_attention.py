"""Flash attention Pallas kernel (TPU target).

Online-softmax attention with explicit VMEM tiling: grid
``(batch, kv_heads, q_groups, num_q_blocks, num_kv_blocks)`` with the KV
dimension sequential ("arbitrary") carrying running (m, l, acc) statistics
in VMEM scratch.  Blocks are MXU-aligned (q_block x head_dim and
kv_block x head_dim tiles, head_dim padded to a lane multiple by ops.py).

Supports the full mask menu of the model zoo: causal, sliding window,
prefix-LM (bidirectional prefix), and logit soft-capping — semantics
identical to ``ref.reference_attention`` (the pure-jnp oracle).

Validated with ``interpret=True`` on CPU; on TPU the same pallas_call
lowers to Mosaic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                          # output block
    m_scr, l_scr, acc_scr,          # VMEM scratch carried over the kv grid dim
    *,
    q_block: int,
    kv_block: int,
    kv_len: int,
    causal: bool,
    window: int | None,
    prefix_len: int | None,
    logit_cap: float | None,
    scale: float,
):
    qi = pl.program_id(3)
    ki = pl.program_id(4)
    nk = pl.num_programs(4)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, 0, :].astype(jnp.float32) * scale      # (qb, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                 # (kb, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (qb, kb)
    if logit_cap is not None:
        s = jnp.tanh(s / logit_cap) * logit_cap

    q_pos = qi * q_block + lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    k_pos = ki * kv_block + lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
    mask = k_pos < kv_len
    if causal:
        c = k_pos <= q_pos
        if prefix_len is not None:
            c = c | (k_pos < prefix_len)
        mask = mask & c
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                       # (qb,)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, 0, :] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,                    # (B, Tq, KVH, G, D)
    k: jax.Array,                    # (B, Tk, KVH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int | None = None,
    logit_cap: float | None = None,
    scale: float | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """pallas_call wrapper; expects block-multiple-padded inputs
    (``ops.flash_attention`` handles padding/unpadding)."""
    B, Tq, KVH, G, D = q.shape
    Tk = k.shape[1]
    assert Tq % q_block == 0 and Tk % kv_block == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    nq, nk = Tq // q_block, Tk // kv_block

    kernel = functools.partial(
        _flash_kernel,
        q_block=q_block, kv_block=kv_block, kv_len=Tk,
        causal=causal, window=window, prefix_len=prefix_len,
        logit_cap=logit_cap, scale=scale,
    )

    grid = (B, KVH, G, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, 1, D),
                         lambda b, h, g, i, j: (b, i, h, g, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, g, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, g, i, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, 1, D),
                               lambda b, h, g, i, j: (b, i, h, g, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),        # running max m
            pltpu.VMEM((q_block,), jnp.float32),        # running sum l
            pltpu.VMEM((q_block, D), jnp.float32),      # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
