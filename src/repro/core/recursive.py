"""Recursive R2CCL-AllReduce for concurrent failures (paper Section 6).

Under multiple failures the cluster develops a *bandwidth spectrum* rather
than a binary healthy/degraded split.  The recursive strategy:

  1. form a global ring over all nodes running at the slowest node's rate;
  2. peel the slowest node off and build a faster sub-ring from the rest;
  3. recurse while bandwidth variance persists, each sub-ring handling a
     payload fraction proportional to the *incremental* bandwidth of its
     members;
  4. apply topology-aware logical re-ranking (Algorithm 1) at every level to
     avoid rail mismatches introduced by skipping slower nodes;
  5. excluded nodes contribute via injection edges and receive results via
     delivery edges (the stage-2 broadcasts).

The builder emits a :class:`CollectiveProgram` whose segments are the
per-level rings — executable by the numpy oracle and the JAX backend — plus
an alpha-beta time estimate used by the planner.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .partition import ring_coeff
from .reranking import bridge_rerank
from .schedule import (
    ChunkSchedule,
    CollectiveProgram,
    Segment,
    Step,
    build_ring_all_gather,
    build_ring_all_reduce,
    build_ring_reduce_scatter,
)


@dataclasses.dataclass
class Level:
    members: list[int]            # nodes in this level's ring
    excluded: list[int]           # slower nodes peeled off below this level
    frac: float                   # payload fraction this level handles
    rate: float                   # bandwidth the level runs at (slowest member)


def spectrum_levels(
    bandwidths: Sequence[float],
    *,
    min_frac: float = 0.01,
    max_levels: int = 4,
    variance_threshold: float = 1.05,
) -> list[Level]:
    """Decompose a bandwidth spectrum into recursion levels.

    Level 0 spans all nodes at rate b_(1) (the minimum); level k spans the
    nodes faster than the k slowest and handles payload proportional to the
    *incremental* bandwidth (b_(k+1) - b_(k)) available once the slower
    nodes are excluded.  Recursion stops when the remaining ring is
    bandwidth-homogeneous (ratio < ``variance_threshold``), when fewer than
    3 nodes remain (a 2-node "ring" cannot beat direct exchange), or when a
    level's payload share falls under ``min_frac``.
    """
    n = len(bandwidths)
    order = sorted(range(n), key=lambda i: bandwidths[i])   # slow -> fast
    sorted_bw = [bandwidths[i] for i in order]

    raw: list[tuple[list[int], list[int], float]] = []
    prev_rate = 0.0
    for k in range(min(max_levels, n - 2 + 1)):
        members = sorted(order[k:])
        excluded = sorted(order[:k])
        rate = sorted_bw[k]
        incr = rate - prev_rate
        if k > 0 and (len(members) < 3 or incr <= 0):
            break
        raw.append((members, excluded, max(incr, 0.0)))
        prev_rate = rate
        if k + 1 < n and sorted_bw[-1] / max(sorted_bw[k + 1], 1e-30) < variance_threshold \
                and sorted_bw[k + 1] / max(rate, 1e-30) < variance_threshold:
            break
    total_incr = sum(i for _, _, i in raw) or 1.0
    levels = [
        Level(members=m, excluded=e, frac=i / total_incr, rate=sorted_bw[0] + 0.0)
        for (m, e, i) in raw
    ]
    # assign true per-level rates
    for idx, lv in enumerate(levels):
        lv.rate = sorted_bw[idx]
    # drop dust levels, renormalize
    levels = [lv for lv in levels if lv.frac >= min_frac or lv is levels[0]]
    s = sum(lv.frac for lv in levels)
    if s <= 0.0:
        # degenerate spectrum: every level has zero incremental bandwidth
        # (e.g. the minimum is 0 with ties) — fall back to an even split so
        # the program still sums to 1 instead of dividing by zero
        for lv in levels:
            lv.frac = 1.0 / len(levels)
        return levels
    for lv in levels:
        lv.frac /= s
    return levels


def _multi_bridge_ring(
    members: Sequence[int], excluded: Sequence[int], n: int
) -> ChunkSchedule:
    """Ring AllReduce over ``members`` with injection/delivery edges for every
    excluded node (generalizes ``allreduce.build_partial_all_reduce``)."""
    k = len(members)
    if k < 2:
        from repro.analysis.errors import Provenance, ScheduleError

        raise ScheduleError(
            f"bridged sub-ring needs >= 2 members, got {list(members)}",
            Provenance(schedule=f"subring_ar[{k}]"))
    order = list(members)

    def whole(src: int, dst: int, accumulate: bool) -> Step:
        send = [-1] * n
        recv = [-1] * n
        send[src] = 0
        recv[dst] = 0
        return Step(((src, dst),), tuple(send), tuple(recv),
                    accumulate=accumulate, whole_buffer=True)

    steps: list[Step] = []
    # Spread injections across distinct healthy entry points so no single
    # member becomes an ingest hotspot; one round can carry several disjoint
    # injection edges.
    entry = {ex: order[i % k] for i, ex in enumerate(excluded)}
    groups: dict[int, list[int]] = {}
    for i, ex in enumerate(excluded):
        groups.setdefault(i // k, []).append(ex)
    for _, exs in sorted(groups.items()):
        perm = tuple((ex, entry[ex]) for ex in exs)
        send = [-1] * n
        recv = [-1] * n
        for ex in exs:
            send[ex] = 0
            recv[entry[ex]] = 0
        steps.append(Step(perm, tuple(send), tuple(recv),
                          accumulate=True, whole_buffer=True))

    rs = build_ring_reduce_scatter(order, n)
    ag = build_ring_all_gather(order, n)
    steps += rs.steps + ag.steps

    exit_ = {ex: order[(i + 1) % k] for i, ex in enumerate(excluded)}
    for _, exs in sorted(groups.items()):
        perm = tuple((exit_[ex], ex) for ex in exs)
        send = [-1] * n
        recv = [-1] * n
        for ex in exs:
            send[exit_[ex]] = 0
            recv[ex] = 0
        steps.append(Step(perm, tuple(send), tuple(recv),
                          accumulate=False, whole_buffer=True))

    sched = ChunkSchedule(
        f"subring_ar[{k}]+{len(excluded)}bridges", n, k, steps,
        result_ranks=tuple(sorted(list(members) + list(excluded))),
    )
    sched.validate()
    return sched


def build_recursive_all_reduce(
    bandwidths: Sequence[float],
    *,
    rail_sets: Sequence[frozenset[int]] | None = None,
    g: int = 8,
) -> tuple[CollectiveProgram, list[Level]]:
    """Recursive decomposition over a bandwidth spectrum.

    ``bandwidths[i]`` — residual egress bandwidth of node i.  When
    ``rail_sets`` is given, each level's ring order is repaired with
    Algorithm 1 before scheduling.
    """
    n = len(bandwidths)
    levels = spectrum_levels(bandwidths)
    segments: list[Segment] = []
    for lv in levels:
        order = lv.members
        if rail_sets is not None and len(order) >= 3:
            order = bridge_rerank(order, rail_sets).ring
        if lv.excluded:
            sched = _multi_bridge_ring(order, lv.excluded, n)
        else:
            sched = build_ring_all_reduce(order, n)
        segments.append(Segment(lv.frac, sched))
    prog = CollectiveProgram("recursive_r2ccl_all_reduce", n, segments)
    prog.validate()
    return prog, levels


def predict_time(
    levels: Sequence[Level], total_bytes: float, g: int = 8,
    bandwidths: Sequence[float] | None = None,
) -> float:
    """alpha-beta completion estimate: reduction phases of all rings run in
    parallel (each level uses its members' incremental bandwidth), broadcasts
    overlap with slower levels' ongoing work (paper Section 6)."""
    t = 0.0
    for lv in levels:
        k = len(lv.members)
        d = total_bytes * lv.frac
        ring_t = ring_coeff(k * g) * d / max(lv.rate, 1e-30)
        deliver_t = (d / max(lv.rate, 1e-30)) if lv.excluded else 0.0
        t = max(t, ring_t + deliver_t)
    return t
