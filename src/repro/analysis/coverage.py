"""Static failure-coverage analysis: which single NIC/rail failures does a
schedule survive, and at what degraded rate — without simulating any of them.

For every (node, rail) in the topology, remove that rail's bandwidth from
the node's capacity and re-run the cost walk (:mod:`repro.analysis.cost`)
under the residual capacities:

* **survivable** — the transfer graph retains a live path through every
  participant rank (finite degraded prediction);
* **stranded** — some rank that must send or receive retains zero residual
  capacity; the engine would raise ``StalledError``, and here it becomes a
  typed :class:`~repro.analysis.errors.CoverageError` finding carrying the
  same :class:`~repro.analysis.errors.Provenance` the verifier's errors do.

The survivability matrix plus the degraded-time bound per failure is what
the paper's planner needs *before* committing to a schedule: a schedule
whose transfers are pinned to one rail (``devices_per_node=1``, or a
single-NIC capacity model) is provably non-survivable here, statically.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.schedule import ChunkSchedule, CollectiveProgram
from repro.core.topology import ClusterTopology, DEFAULT_ALPHA

from .cost import CostReport, analyze_program, as_program, resolve_capacities
from .errors import CoverageError, Provenance

__all__ = [
    "CoverageEntry",
    "CoverageReport",
    "analyze_coverage",
    "check_coverage",
]


@dataclasses.dataclass(frozen=True)
class CoverageEntry:
    """One cell of the survivability matrix: a single (node, rail) failure."""

    node: int
    rail: int
    #: bandwidth the failure removes from the node
    lost_bandwidth: float
    #: whether the failed node carries any of the schedule's traffic
    participates: bool
    survivable: bool
    #: static bound on the degraded completion time (inf when stranded)
    degraded_time: float
    #: degraded_time / healthy_time (1.0 for a non-participant node)
    slowdown: float
    #: participant ranks left with zero residual capacity
    stranded_ranks: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class CoverageReport:
    """Survivability matrix of one program over every single-rail failure."""

    name: str
    n: int
    total_bytes: float
    healthy: CostReport
    entries: tuple[CoverageEntry, ...]
    findings: tuple[CoverageError, ...]

    @property
    def survivable_fraction(self) -> float:
        if not self.entries:
            return 1.0
        good = sum(1 for e in self.entries if e.survivable)
        return good / len(self.entries)

    @property
    def worst_slowdown(self) -> float:
        """Largest degraded/healthy ratio among survivable failures."""
        slow = [e.slowdown for e in self.entries if e.survivable]
        return max(slow) if slow else 1.0

    def entry(self, node: int, rail: int) -> CoverageEntry:
        for e in self.entries:
            if e.node == node and e.rail == rail:
                return e
        raise KeyError(f"no coverage entry for node {node} rail {rail}")

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "n": self.n,
            "total_bytes": self.total_bytes,
            "healthy_time": self.healthy.predicted_time,
            "survivable_fraction": self.survivable_fraction,
            "worst_slowdown": self.worst_slowdown,
            "entries": [dataclasses.asdict(e) for e in self.entries],
            "findings": [str(f) for f in self.findings],
        }


def _rail_bandwidths(
    n: int,
    cluster: ClusterTopology | None,
    caps: Sequence[float],
    g: int,
) -> list[list[float]]:
    """Per-node per-rail bandwidth map — the cluster's real NICs, or the
    uniform ``g``-way split the engine's ``capacities=`` mode assumes."""
    if cluster is not None:
        return cluster.rail_bandwidths()
    return [[c / g] * g for c in caps]


def analyze_coverage(
    obj: ChunkSchedule | CollectiveProgram,
    total_bytes: float,
    *,
    cluster: ClusterTopology | None = None,
    capacities: Sequence[float] | None = None,
    g: int = 8,
    alpha: float = DEFAULT_ALPHA,
) -> CoverageReport:
    """Statically decide, for every single NIC/rail failure, whether ``obj``
    retains live paths, and bound its degraded completion time.

    Topology arguments mirror :func:`repro.core.event_sim.simulate_program`:
    one of ``cluster`` or ``capacities`` (with ``g`` equal rails per rank).
    """
    prog = as_program(obj)
    n = prog.n
    caps = resolve_capacities(n, cluster, capacities)
    rails = _rail_bandwidths(n, cluster, caps, g)
    healthy = analyze_program(prog, total_bytes, capacities=caps, alpha=alpha)

    entries: list[CoverageEntry] = []
    findings: list[CoverageError] = []
    for node in range(n):
        participates = (healthy.rank_tx_bytes[node] > 0.0
                        or healthy.rank_rx_bytes[node] > 0.0)
        for rail, lost_bw in enumerate(rails[node]):
            residual = list(caps)
            residual[node] = max(0.0, residual[node] - lost_bw)
            stranded = tuple(
                r for r in range(n)
                if residual[r] <= 0.0
                and (healthy.rank_tx_bytes[r] > 0.0
                     or healthy.rank_rx_bytes[r] > 0.0))
            degraded = analyze_program(prog, total_bytes,
                                       capacities=residual, alpha=alpha)
            survivable = degraded.completes and not stranded
            if healthy.predicted_time > 0.0 and degraded.completes:
                slowdown = degraded.predicted_time / healthy.predicted_time
            else:
                slowdown = math.inf if not degraded.completes else 1.0
            entries.append(CoverageEntry(
                node=node, rail=rail, lost_bandwidth=lost_bw,
                participates=participates, survivable=survivable,
                degraded_time=degraded.predicted_time, slowdown=slowdown,
                stranded_ranks=stranded))
            if not survivable:
                where = Provenance(
                    schedule=prog.name,
                    rank=stranded[0] if stranded else node)
                findings.append(CoverageError(
                    f"single failure (node {node}, rail {rail}) leaves "
                    f"rank(s) {list(stranded) or [node]} of {prog.name!r} "
                    f"with zero residual capacity: the transfer graph "
                    f"retains no live path", where, node=node, rail=rail))

    return CoverageReport(
        name=prog.name, n=n, total_bytes=float(total_bytes),
        healthy=healthy, entries=tuple(entries), findings=tuple(findings))


def check_coverage(
    obj: ChunkSchedule | CollectiveProgram,
    total_bytes: float,
    **kw,
) -> CoverageReport:
    """Like :func:`analyze_coverage`, but raise the first
    :class:`CoverageError` when any single-rail failure strands the
    schedule (the assert-style entry point for tests and CI)."""
    report = analyze_coverage(obj, total_bytes, **kw)
    if report.findings:
        raise report.findings[0]
    return report
