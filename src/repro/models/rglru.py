"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence (per channel):

    r_t = sigmoid(W_r x_t)                      # recurrence gate
    i_t = sigmoid(W_i x_t)                      # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)      # data-dependent decay
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in Griffin's recurrent block: linear in -> temporal conv1d(4) ->
RG-LRU -> gated linear out.  Train/prefill uses an associative scan
(log-depth, TPU-friendly); decode is a single state update.

The linear scan is also provided as a Pallas kernel target
(``kernels/lru_scan.py``); this module is its jnp oracle.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense_init


def init_rglru_block(key, d_model: int, lru_width: int, conv_width: int,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    w = lru_width
    # Lambda init so a = exp(-c*softplus(L)) is spread in (0.9, 0.999) —
    # the Griffin init.
    u = jax.random.uniform(ks[0], (w,), minval=0.9, maxval=0.999)
    c = 8.0
    lam = jnp.log(jnp.expm1(-jnp.log(u) / c))    # softplus^-1(-ln(u)/c)
    params = {
        "w_x": dense_init(ks[1], (d_model, w), d_model, dtype),     # input branch
        "w_gate": dense_init(ks[2], (d_model, w), d_model, dtype),  # mult. gate branch
        "conv_w": (jax.random.normal(ks[3], (conv_width, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_rg": dense_init(ks[4], (w, w), w, dtype),                # recurrence gate
        "b_rg": jnp.zeros((w,), jnp.float32),
        "w_ig": dense_init(ks[5], (w, w), w, dtype),                # input gate
        "b_ig": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (w, d_model), w, dtype),
    }
    axes = {
        "w_x": ("embed", "lru"), "w_gate": ("embed", "lru"),
        "conv_w": (None, "lru"), "conv_b": ("lru",),
        "w_rg": ("lru", None), "b_rg": ("lru",),
        "w_ig": ("lru", None), "b_ig": ("lru",),
        "lam": ("lru",), "w_out": ("lru", "embed"),
    }
    return params, axes


@dataclasses.dataclass
class RGLRUState:
    """Decode-time state: LRU hidden + conv tail window."""

    h: jnp.ndarray                 # (B, W)
    conv_tail: jnp.ndarray         # (B, conv_width-1, W)


jax.tree_util.register_dataclass(
    RGLRUState, data_fields=["h", "conv_tail"], meta_fields=[]
)


def init_rglru_state(batch: int, lru_width: int, conv_width: int,
                     dtype=jnp.float32) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, lru_width), dtype),
        conv_tail=jnp.zeros((batch, conv_width - 1, lru_width), dtype),
    )


C_CONST = 8.0


def _gates(params, u):
    """u: (..., W) post-conv activations -> (a, gated_input) in float32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_rg"].astype(jnp.float32) + params["b_rg"])
    i = jax.nn.sigmoid(uf @ params["w_ig"].astype(jnp.float32) + params["b_ig"])
    log_a = -C_CONST * jax.nn.softplus(params["lam"]) * r      # (..., W), <0
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, x_in


def lru_scan_ref(a: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + x_t via associative scan.  a,x: (B,T,W)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq, h_seq = lax.associative_scan(combine, (a, x), axis=1)
    # fold in h0: h_t += (prod a_{1..t}) * h0
    return h_seq + a_seq * h0[:, None, :]


def rglru_block(
    params,
    x: jnp.ndarray,                # (B, T, d)
    *,
    conv_width: int,
    state: RGLRUState | None = None,
    mode: str = "train",
) -> tuple[jnp.ndarray, RGLRUState | None]:
    B, T, d = x.shape
    u = x @ params["w_x"]                                       # (B,T,W)
    gate = jax.nn.gelu((x @ params["w_gate"]).astype(jnp.float32), approximate=True)
    W = u.shape[-1]

    if mode == "decode":
        assert state is not None and T == 1
        hist = jnp.concatenate([state.conv_tail, u.astype(state.conv_tail.dtype)], axis=1)
        win = hist[:, -conv_width:]                             # (B,cw,W)
        cu = jnp.einsum("bcw,cw->bw", win.astype(jnp.float32),
                        params["conv_w"].astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
        a, x_in = _gates(params, cu[:, None])                   # (B,1,W)
        h = a[:, 0] * state.h.astype(jnp.float32) + x_in[:, 0]
        y = (h * gate[:, 0]) @ params["w_out"].astype(jnp.float32)
        new_state = RGLRUState(h.astype(state.h.dtype),
                               hist[:, -(conv_width - 1):])
        return y[:, None].astype(x.dtype), new_state

    # causal conv1d over time
    pad = jnp.zeros((B, conv_width - 1, W), u.dtype)
    if state is not None:
        pad = state.conv_tail.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)                       # (B,T+cw-1,W)
    idx = jnp.arange(T)[:, None] + jnp.arange(conv_width)[None, :]
    windows = up[:, idx]                                         # (B,T,cw,W)
    cu = jnp.einsum("btcw,cw->btw", windows.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)

    a, x_in = _gates(params, cu)                                 # (B,T,W)
    h0 = state.h.astype(jnp.float32) if state is not None else jnp.zeros((B, W), jnp.float32)
    h = lru_scan_ref(a, x_in, h0)                                # (B,T,W)
    y = (h * gate) @ params["w_out"].astype(jnp.float32)

    new_state = None
    if mode == "prefill":
        sdt = state.h.dtype if state is not None else jnp.float32
        new_state = RGLRUState(
            h[:, -1].astype(sdt),
            up[:, -(conv_width - 1):].astype(sdt) if conv_width > 1
            else jnp.zeros((B, 0, W), sdt))
    return y.astype(x.dtype), new_state
