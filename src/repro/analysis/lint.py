"""Simulator-determinism lint: AST checks over the event engine + runtime.

The discrete-event simulator must be a pure function of its inputs — same
program, failures, and seed in, same timeline out.  Replay equality is what
the refactor-equivalence guards, the replan bit-exactness tests, and the
ledger↔trace cross-validation all assume.  This lint statically forbids
the ways that property quietly breaks:

=======  ====================================================================
rule     what it forbids
=======  ====================================================================
DET001   wall-clock reads (``time.time``/``monotonic``/``perf_counter``,
         ``datetime.now``/``utcnow``) — simulated time must come from the
         event queue, never the host clock
DET002   unseeded randomness (bare ``random.*`` module calls, legacy
         ``np.random.*`` globals, ``default_rng()`` / ``random.Random()``
         with no seed argument) — seeded generator objects are fine
DET003   iteration over a bare ``set``/``frozenset`` in event-ordering code
         (``for`` loops, comprehensions) — set order is hash-randomized
         across runs; wrap in ``sorted(...)``
DET004   float ``==``/``!=`` where either side looks like a simulated
         timestamp (named ``now``/``t``/``t0``/``dt``/...,
         contains ``time``, ends with ``_at``) — compare with a tolerance
         or restructure
DET005   mutation of frozen IR dataclasses (``object.__setattr__`` outside
         ``__post_init__``, attribute assignment through a name annotated
         with a frozen class) — the IR is immutable by contract
=======  ====================================================================

Findings carry file/line/rule and are stable across runs.  Run via
``python -m repro.analysis lint [paths...]`` or ``scripts/lint.sh``.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Iterable, Sequence

__all__ = ["LintFinding", "lint_source", "lint_paths", "DEFAULT_LINT_TARGETS"]

#: directories the CI determinism gate covers (relative to the repo root):
#: the simulator core and runtime, plus the analysis package itself (the
#: static analyses must be as replay-deterministic as what they check) and
#: the serving engine (its virtual-time request loop shares the contract)
DEFAULT_LINT_TARGETS = ("src/repro/core", "src/repro/runtime",
                        "src/repro/analysis", "src/repro/serving")

_WALL_CLOCK_TIME_ATTRS = {"time", "monotonic", "perf_counter", "time_ns",
                          "monotonic_ns", "perf_counter_ns"}
_WALL_CLOCK_DT_ATTRS = {"now", "utcnow", "today"}
_RANDOM_MODULE_FUNCS = {
    "random", "randint", "randrange", "uniform", "gauss", "normalvariate",
    "choice", "choices", "sample", "shuffle", "betavariate", "expovariate",
    "seed",
}
_TIMEY_EXACT = {"now", "t", "t0", "t1", "dt", "start", "end", "deadline",
                "eta", "when"}

# builtins whose result doesn't depend on iteration order: iterating a set
# through these cannot leak nondeterminism
_ORDER_SAFE_CALLS = {"sorted", "min", "max", "sum", "len", "any", "all",
                     "set", "frozenset"}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _is_timey(name: str) -> bool:
    low = name.lower()
    return (low in _TIMEY_EXACT or "time" in low or low.endswith("_at")
            or low.startswith("t_"))


def _name_of(node: ast.expr) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Call):
        return _name_of(node.func)
    return None


def _dotted(node: ast.expr) -> str | None:
    """'a.b.c' for nested attribute access rooted at a Name, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, frozen_classes: set[str]):
        self.path = path
        self.frozen_classes = frozen_classes
        self.findings: list[LintFinding] = []
        # names known to hold sets in the current scope(s)
        self._set_names: list[set[str]] = [set()]
        # attribute names (self.X) known to hold sets, per enclosing class
        self._set_attrs: list[set[str]] = [set()]
        # names annotated with a frozen dataclass type
        self._frozen_names: list[dict[str, str]] = [{}]
        self._in_post_init = 0

    # -- helpers ----------------------------------------------------------

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(
            LintFinding(self.path, getattr(node, "lineno", 0), rule, message))

    def _ann_is_set(self, ann: ast.expr | None) -> bool:
        if ann is None:
            return False
        base = ann.value if isinstance(ann, ast.Subscript) else ann
        name = _name_of(base)
        return name in {"set", "frozenset", "Set", "FrozenSet", "MutableSet"}

    def _ann_frozen_class(self, ann: ast.expr | None) -> str | None:
        if ann is None:
            return None
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            name = ann.value.strip().split("[")[0].split(".")[-1]
            return name if name in self.frozen_classes else None
        name = _name_of(ann.value if isinstance(ann, ast.Subscript) else ann)
        return name if name in self.frozen_classes else None

    def _expr_is_set(self, node: ast.expr) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Name):
            return any(node.id in scope for scope in self._set_names)
        if isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name) and node.value.id == "self"
                    and any(node.attr in s for s in self._set_attrs)):
                return True
            return False
        if isinstance(node, ast.Call):
            fname = _name_of(node.func)
            if fname in {"set", "frozenset"}:
                return True
            if fname in {"union", "intersection", "difference",
                         "symmetric_difference", "copy"}:
                return self._expr_is_set(node.func.value) if isinstance(
                    node.func, ast.Attribute) else False
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return self._expr_is_set(node.left) or self._expr_is_set(node.right)
        return False

    # -- scope bookkeeping ------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._set_attrs.append(set())
        # pre-scan: attribute annotations + __init__ assignments
        for sub in ast.walk(node):
            if isinstance(sub, ast.AnnAssign) and self._ann_is_set(
                    sub.annotation):
                tgt = sub.target
                if (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    self._set_attrs[-1].add(tgt.attr)
            if isinstance(sub, ast.Assign) and self._expr_is_set(sub.value):
                for tgt in sub.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        self._set_attrs[-1].add(tgt.attr)
        self.generic_visit(node)
        self._set_attrs.pop()

    def _visit_function(self, node) -> None:
        is_post_init = node.name == "__post_init__"
        self._in_post_init += is_post_init
        self._set_names.append(set())
        self._frozen_names.append({})
        for arg in list(node.args.args) + list(node.args.kwonlyargs):
            if self._ann_is_set(arg.annotation):
                self._set_names[-1].add(arg.arg)
            frozen = self._ann_frozen_class(arg.annotation)
            if frozen:
                self._frozen_names[-1][arg.arg] = frozen
        self.generic_visit(node)
        self._frozen_names.pop()
        self._set_names.pop()
        self._in_post_init -= is_post_init

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._expr_is_set(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self._set_names[-1].add(tgt.id)
        else:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self._set_names[-1].discard(tgt.id)
        self._check_frozen_target_assign(node.targets, node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if isinstance(node.target, ast.Name):
            if self._ann_is_set(node.annotation):
                self._set_names[-1].add(node.target.id)
            frozen = self._ann_frozen_class(node.annotation)
            if frozen:
                self._frozen_names[-1][node.target.id] = frozen
        self._check_frozen_target_assign([node.target], node)
        self.generic_visit(node)

    # -- DET001 / DET002: calls ------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted:
            head, _, tail = dotted.partition(".")
            if head == "time" and tail in _WALL_CLOCK_TIME_ATTRS:
                self._emit(node, "DET001",
                           f"wall-clock call {dotted}() in simulator code; "
                           f"simulated time must come from the event queue")
            if tail.split(".")[-1] in _WALL_CLOCK_DT_ATTRS and (
                    "datetime" in dotted or head == "datetime"):
                self._emit(node, "DET001",
                           f"wall-clock call {dotted}() in simulator code")
            if head == "random" and tail in _RANDOM_MODULE_FUNCS:
                self._emit(node, "DET002",
                           f"unseeded module-level {dotted}(); use a seeded "
                           f"random.Random(seed) instance")
            if dotted.endswith("np.random." + tail.split(".")[-1]) or \
                    dotted.startswith("numpy.random."):
                last = tail.split(".")[-1]
                if last not in {"Generator", "default_rng", "SeedSequence",
                                "RandomState"}:
                    self._emit(node, "DET002",
                               f"legacy global numpy RNG {dotted}(); use "
                               f"np.random.default_rng(seed)")
        fname = _name_of(node.func)
        if fname in {"default_rng", "RandomState", "Random"} and \
                not node.args and not node.keywords:
            self._emit(node, "DET002",
                       f"{fname}() constructed without a seed")
        # DET005: object.__setattr__ outside __post_init__
        if dotted == "object.__setattr__" and not self._in_post_init:
            self._emit(node, "DET005",
                       "object.__setattr__ outside __post_init__ mutates a "
                       "frozen dataclass; build a new instance instead")
        self.generic_visit(node)

    # -- DET003: iteration over bare sets --------------------------------

    def _check_iter(self, node: ast.AST, iter_expr: ast.expr) -> None:
        if self._expr_is_set(iter_expr):
            self._emit(node, "DET003",
                       "iteration over a bare set; order is "
                       "hash-randomized — wrap in sorted(...)")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node, node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        # set/frozenset comprehensions can't leak order; others can
        order_safe = isinstance(node, ast.SetComp)
        if not order_safe:
            for gen in node.generators:
                self._check_iter(node, gen.iter)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_Compare(self, node: ast.Compare) -> None:
        # DET004: float equality on time-like values
        operands = [node.left, *node.comparators]
        for op, left, right in zip(node.ops, operands, operands[1:]):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            for side, other in ((left, right), (right, left)):
                name = _name_of(side)
                if name is None or not _is_timey(name):
                    continue
                # comparisons against None/sentinel ints are fine; flag
                # only float-typed literals or other time-like operands
                if isinstance(other, ast.Constant) and (
                        other.value is None
                        or isinstance(other.value, (bool, int, str))):
                    continue
                other_name = _name_of(other)
                if (isinstance(other, ast.Constant)
                        and isinstance(other.value, float)) or (
                        other_name is not None and _is_timey(other_name)):
                    self._emit(node, "DET004",
                               f"float equality on time-like value "
                               f"{name!r}; compare with a tolerance")
                    break
        self.generic_visit(node)

    # -- DET005: frozen-instance attribute assignment ---------------------

    def _check_frozen_target_assign(self, targets, node) -> None:
        for tgt in targets:
            if not isinstance(tgt, ast.Attribute):
                continue
            base = tgt.value
            if not isinstance(base, ast.Name):
                continue
            for scope in self._frozen_names:
                if base.id in scope:
                    self._emit(
                        node, "DET005",
                        f"assignment to {base.id}.{tgt.attr} mutates frozen "
                        f"dataclass {scope[base.id]}; use dataclasses."
                        f"replace()")
                    break

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_frozen_target_assign([node.target], node)
        self.generic_visit(node)


def _frozen_classes_in(trees: Iterable[ast.AST]) -> set[str]:
    """Names of every ``@dataclass(frozen=True)`` class across the files
    being linted (frozen-mutation checks resolve annotations against
    these)."""
    found: set[str] = set()
    for tree in trees:
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                dname = _dotted(dec.func) or ""
                if dname.split(".")[-1] != "dataclass":
                    continue
                for kw in dec.keywords:
                    if (kw.arg == "frozen"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True):
                        found.add(node.name)
    return found


def lint_source(source: str, path: str = "<string>",
                frozen_classes: set[str] | None = None) -> list[LintFinding]:
    """Lint one source string; ``frozen_classes`` augments the set
    discovered in the source itself."""
    tree = ast.parse(source, filename=path)
    frozen = _frozen_classes_in([tree])
    if frozen_classes:
        frozen |= frozen_classes
    linter = _Linter(path, frozen)
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: Sequence[str | pathlib.Path]) -> list[LintFinding]:
    """Lint every ``.py`` file under ``paths`` (files or directories).

    Frozen-dataclass names are collected across *all* files first, so a
    frozen class defined in ``core/schedule.py`` is recognized when
    ``runtime/`` code annotates with it.
    """
    files: list[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    sources = {f: f.read_text() for f in files}
    trees = [ast.parse(src, filename=str(f)) for f, src in sources.items()]
    frozen = _frozen_classes_in(trees)
    findings: list[LintFinding] = []
    for f, src in sources.items():
        findings.extend(lint_source(src, str(f), frozen_classes=frozen))
    return sorted(findings, key=lambda x: (x.path, x.line, x.rule))
