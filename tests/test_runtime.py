"""Recovery runtime: state machine, ledger, co-simulation conformance.

Covers the PR-2 acceptance criteria:
  * the co-simulated clean single-NIC-down failover (ledger total) lands in
    the paper's low-millisecond hot-repair range and within 2x of the
    alpha-beta ``R2CCL_MIGRATION_LATENCY`` constant;
  * ledger stage latencies sum to the failover delay the event engine
    actually applied (``repair_events``);
  * property (via the offline hypothesis shim): arbitrary failure-injection
    campaigns always terminate in HEALTHY or REPLANNED with zero lost
    chunks (every surviving transfer completes; payload conservation is
    checked with real numpy data, including through mid-collective replans
    — chunk-exact since PR 4).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.comm_sim import R2CCL_MIGRATION_LATENCY
from repro.core.event_sim import simulate_program, simulate_streams
from repro.core.failures import Failure, FailureType, nic_down_at
from repro.core.schedule import ring_program
from repro.core.topology import make_cluster
from repro.runtime import (
    ControlPlane,
    RecoveryState,
    Scenario,
    StreamSpec,
    build_engine_streams,
    clean_nic_down,
    failure_during_recovery,
    flap_storm,
    parse_campaign,
    parse_streams,
    run_scenario,
    slow_nic_degradation,
    standard_campaigns,
    standard_parallel_streams,
)
from repro.runtime.control_plane import STAGES

NIC_BW = 25e9
PAYLOAD = 100e6


@pytest.fixture(scope="module")
def cluster():
    return make_cluster(4, 4, nic_bandwidth=NIC_BW)


@pytest.fixture(scope="module")
def t_h(cluster):
    return simulate_program(ring_program(list(range(4)), 4), PAYLOAD,
                            cluster=cluster).completion_time


def _data(n, size=64, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=size) for _ in range(n)]


# ---------------------------------------------------------------------------
# acceptance: conformance of the derived failover latency
# ---------------------------------------------------------------------------

def test_clean_nic_down_failover_within_paper_budget(cluster, t_h):
    """Single clean NIC-down: the pipeline-derived ledger total must be in
    the low-millisecond hot-repair range and within 2x of the alpha-beta
    constant it replaces."""
    rep = run_scenario(clean_nic_down(t_h), cluster, PAYLOAD, healthy_time=t_h)
    entry = rep.ledger.entries[0]
    total = entry.total
    assert 1e-4 < total < 10e-3, "not in the low-millisecond range"
    assert 0.5 <= total / R2CCL_MIGRATION_LATENCY <= 2.0
    # the pipeline ran detect -> diagnose -> migrate -> rebalance
    assert [s for s in STAGES if s in entry.stages] == \
        ["detect", "diagnose", "migrate", "rebalance"]
    assert all(v >= 0 for v in entry.stages.values())
    assert entry.backup_nic is not None
    assert entry.backup_nic != entry.failure.nic_key


def test_ledger_total_is_engine_repair_delay(cluster, t_h):
    """The ledger's stage sum must equal the restart delay the event engine
    actually applied to the rolled-back transfers — the latency is derived,
    not asserted."""
    rep = run_scenario(clean_nic_down(t_h), cluster, PAYLOAD, healthy_time=t_h)
    entry = rep.ledger.entries[0]
    assert sum(entry.stages.values()) == pytest.approx(entry.total)
    assert len(rep.report.repair_events) == 1
    ev = rep.report.repair_events[0]
    assert ev.derived
    assert ev.rollbacks >= 1
    assert ev.delay == pytest.approx(entry.hot_repair_latency)
    # no replan stage on a clean single failure, so hot-repair == total
    assert entry.hot_repair_latency == pytest.approx(entry.total)
    # the failover is visible in the makespan: at least the repair window
    assert rep.report.completion_time >= ev.at_time + ev.delay


def test_derived_latency_differs_from_constant_path(cluster, t_h):
    """Co-simulation must actually replace the closed-form constant: running
    the same campaign without a controller uses DEFAULT_REPAIR_LATENCY."""
    sc = clean_nic_down(t_h)
    plain = simulate_program(ring_program(list(range(4)), 4), PAYLOAD,
                             cluster=cluster, failures=sc.failures)
    assert not plain.repair_events[0].derived
    cosim = run_scenario(sc, cluster, PAYLOAD, healthy_time=t_h)
    assert cosim.report.repair_events[0].derived
    assert cosim.report.repair_events[0].delay != plain.repair_events[0].delay


# ---------------------------------------------------------------------------
# state machine semantics
# ---------------------------------------------------------------------------

def test_transitions_follow_pipeline_order(cluster, t_h):
    rep = run_scenario(clean_nic_down(t_h), cluster, PAYLOAD, healthy_time=t_h)
    states = [s for _, s in rep.transitions]
    assert states[0] is RecoveryState.HEALTHY
    i = states.index(RecoveryState.DETECTING)
    assert states[i:i + 4] == [
        RecoveryState.DETECTING, RecoveryState.DIAGNOSING,
        RecoveryState.MIGRATING, RecoveryState.REBALANCED]
    times = [t for t, _ in rep.transitions]
    assert times == sorted(times)
    # persistent degradation settles into REPLANNED for the next collective
    assert rep.final_state is RecoveryState.REPLANNED


def test_flap_storm_replans_after_threshold(cluster, t_h):
    """Repeated flaps of one NIC must trigger algorithm re-selection; once
    every flap has recovered the campaign ends HEALTHY (or stays REPLANNED
    if the swap happened)."""
    rep = run_scenario(flap_storm(t_h, count=4), cluster, PAYLOAD,
                       healthy_time=t_h)
    assert any("replan" in e.stages for e in rep.ledger.entries)
    assert rep.report.replans >= 1
    assert rep.final_state in (RecoveryState.HEALTHY, RecoveryState.REPLANNED)
    # flapping NIC recovered each time -> no failed NICs left at the end
    assert rep.ledger.entries[0].failure is not None


def test_slow_nic_skips_migration(cluster, t_h):
    """Fractional degradation raises no transport error: the pipeline is
    monitor-detect -> rebalance, no migrate stage, no rollbacks."""
    rep = run_scenario(slow_nic_degradation(t_h), cluster, PAYLOAD,
                       healthy_time=t_h)
    for e in rep.ledger.entries:
        assert "migrate" not in e.stages
        assert "diagnose" not in e.stages
    assert rep.report.failovers == 0
    assert rep.report.retransmitted_bytes == 0.0
    assert rep.final_state is RecoveryState.HEALTHY
    assert rep.overhead > 0          # the degradation still costs bandwidth
    # no flows were orphaned, so no detour-efficiency penalty is installed:
    # the co-simulated completion equals the controller-less run exactly
    assert all(d.capacity_scale is None for d in rep.decisions)
    plain = simulate_program(
        ring_program(list(range(4)), 4), PAYLOAD, cluster=cluster,
        failures=slow_nic_degradation(t_h).failures)
    assert rep.report.completion_time == pytest.approx(plain.completion_time)


def test_failure_during_recovery_composes(cluster, t_h):
    """A second hard failure inside the first repair window runs a second
    pipeline; with real payloads the collective still loses nothing."""
    sc = failure_during_recovery(t_h)
    data = _data(4)
    want = np.sum(np.stack(data), axis=0)
    rep = run_scenario(sc, cluster, PAYLOAD, healthy_time=t_h,
                       rank_data=data)
    hard = [e for e in rep.ledger.entries if e.failure is not None]
    assert len(hard) == 2
    assert len(rep.report.repair_events) == 2
    # second pipeline started before the first repair window elapsed
    assert hard[1].t_start < hard[0].t_start + hard[0].total
    for r in rep.report.rank_data:
        np.testing.assert_allclose(r, want, rtol=1e-12)


def test_node_loss_forces_replan():
    """When every NIC of a node dies there is nothing to migrate onto: the
    diagnosis escalates straight to algorithm re-selection.  (Driven through
    the control plane directly — a zero-bandwidth node can never finish a
    collective in the data plane, by construction.)"""
    cluster = make_cluster(4, 2, nic_bandwidth=NIC_BW)
    cp = ControlPlane(cluster, payload_bytes=PAYLOAD)
    first = cp.handle_failure(nic_down_at(1, 0, 0.0), now=0.0)
    assert first.entry.backup_nic == (1, 1)
    second = cp.handle_failure(nic_down_at(1, 1, 1e-3), now=1e-3)
    assert second.entry.backup_nic is None
    assert "replan" in second.entry.stages
    assert second.entry.state_after is RecoveryState.REPLANNED
    assert cp.state is RecoveryState.REPLANNED
    assert second.decision.replan is not None


def test_flap_threshold_uses_sliding_window():
    """Regression: flap counts used to grow monotonically forever, so one
    historical storm pushed every later failure on that NIC over the replan
    threshold.  The threshold must reflect *recent* flapping only."""
    from repro.core.failures import link_flap

    cluster = make_cluster(4, 4, nic_bandwidth=NIC_BW)
    # storm inside the window -> the 3rd flap replans
    cp = ControlPlane(cluster, payload_bytes=PAYLOAD, flap_window=10.0)
    outs = [cp.handle_failure(link_flap(1, 0, t, 0.01), now=t)
            for t in (0.0, 1.0, 2.0)]
    assert "replan" in outs[-1].entry.stages
    # same three flaps spread far beyond the window -> never replans
    cp2 = ControlPlane(cluster, payload_bytes=PAYLOAD, flap_window=10.0)
    outs2 = [cp2.handle_failure(link_flap(1, 0, t, 0.01), now=t)
             for t in (0.0, 100.0, 200.0)]
    assert all("replan" not in o.entry.stages for o in outs2)
    # all-time totals stay observable even after the window drained
    assert cp2.flap_counts[(1, 0)] == 3
    assert cp2.recent_flaps((1, 0), now=200.0) == 1


def test_reprobe_cadence_adapts_to_flap_history():
    """The control plane schedules the next re-probe from the NIC's recent
    flap history: stable links probe faster than the base constant, recent
    flappers back off — within the floor/ceiling."""
    from repro.core.detection import (
        REPROBE_PERIOD,
        REPROBE_PERIOD_MAX,
        REPROBE_PERIOD_MIN,
    )
    from repro.core.failures import link_flap, nic_down_at

    cluster = make_cluster(4, 4, nic_bandwidth=NIC_BW)
    cp = ControlPlane(cluster, payload_bytes=PAYLOAD)
    # a one-off hard failure that recovers: stable link, fast cadence
    f = nic_down_at(1, 0, 0.0)
    cp.handle_failure(f, now=0.0)
    cp.handle_recovery(f, now=0.5)
    stable_period = cp.next_reprobe[(1, 0)] - 0.5
    assert REPROBE_PERIOD_MIN <= stable_period < REPROBE_PERIOD
    # hammer a different NIC with flaps: cadence backs off
    for i in range(5):
        fl = link_flap(2, 1, float(i), 0.01)
        cp.handle_failure(fl, now=float(i))
        cp.handle_recovery(fl, now=float(i) + 0.01)
    flappy_period = cp.next_reprobe[(2, 1)] - 4.01
    assert flappy_period > stable_period
    assert flappy_period <= REPROBE_PERIOD_MAX
    assert cp.reprobe_period((2, 1), now=4.01) == pytest.approx(flappy_period)


def test_control_plane_static_score_threads_to_replans():
    """``ControlPlane(score="static")`` prices replan candidates with the
    static cost analyzer (built programs, not alpha-beta closed forms) —
    and the mode is strictly opt-in."""
    from repro.core.failures import link_flap

    cluster = make_cluster(4, 4, nic_bandwidth=NIC_BW)
    cp = ControlPlane(cluster, payload_bytes=PAYLOAD, flap_window=10.0,
                      score="static")
    seen_scores = []
    orig = cp.planner.choose_strategy

    def spy(*args, **kw):
        seen_scores.append(kw.get("score"))
        return orig(*args, **kw)

    cp.planner.choose_strategy = spy
    outs = [cp.handle_failure(link_flap(1, 0, t, 0.01), now=t)
            for t in (0.0, 1.0, 2.0)]
    assert outs[-1].decision.replan is not None, (
        "flap storm past the threshold must replan")
    assert seen_scores and set(seen_scores) == {"static"}
    assert outs[-1].entry.strategy in (
        "balance", "r2ccl_all_reduce", "recursive")
    with pytest.raises(ValueError, match="score"):
        ControlPlane(cluster, score="event")


def test_recovery_transition_back_to_healthy(cluster, t_h):
    """A single flap that recovers re-probes healthy: HEALTHY terminal."""
    sc = parse_campaign("one_flap", "flap node=1 rail=0 at=0.3 down=0.2",
                        t_scale=t_h)
    rep = run_scenario(sc, cluster, PAYLOAD, healthy_time=t_h)
    assert rep.final_state is RecoveryState.HEALTHY
    assert RecoveryState.REBALANCED in [s for _, s in rep.transitions]


def test_serving_engine_hiccup_is_ledger_total():
    """The serving engine's r2ccl hiccup must be the pipeline ledger total
    (wired through ControlPlane), not the retired constant."""
    cp = ControlPlane(make_cluster(2, 8), replan=False)
    out = cp.handle_failure(Failure(FailureType.NIC_HARDWARE, 0, 0), now=1.0)
    assert out is not None
    assert out.entry.total == pytest.approx(
        sum(out.entry.stages.values()))
    assert 1e-4 < out.entry.total < 10e-3
    assert out.decision.replan is None          # replanning disabled


def test_nic_down_rebalance_reprices_all_streams(cluster, t_h):
    """Regression (satellite of the multi-stream engine): the rebalance
    decision's detour-efficiency capacity factor lands on the NODE, so
    every stream crossing the failed rail is re-priced — not just the
    gradient sync that observed the failure.  Pinned by comparing the
    co-simulated run against a controller-less run with the SAME failure
    and the SAME derived repair delay: the only remaining difference is
    the rebalance re-pricing, and it must slow the TP/PP co-runners too."""
    specs = standard_parallel_streams(PAYLOAD)
    # inject early enough that even the small PP handoff is still in flight
    sc = clean_nic_down(t_h, frac=0.1)
    cos = run_scenario(sc, cluster, PAYLOAD, healthy_time=t_h, streams=specs)
    entry = cos.ledger.entries[0]
    assert entry.balance_efficiency < 1.0
    assert any(d.capacity_scale for d in cos.decisions)
    assert set(cos.report.streams) == {"dp", "tp", "pp"}

    # identical engine run minus the control plane, repair delay matched
    plain = simulate_streams(
        build_engine_streams(ring_program(list(range(4)), 4), PAYLOAD,
                             specs, 4),
        cluster=cluster, failures=sc.failures,
        repair_latency=entry.hot_repair_latency)
    for name in ("dp", "tp", "pp"):
        assert cos.report.streams[name].completion_time > \
            plain.streams[name].completion_time * (1 + 1e-9), name


def test_parse_streams_roundtrip():
    specs = parse_streams(
        "tp kind=allreduce frac=0.5 prio=2; pp kind=p2p frac=0.125 start=0.1 "
        "root=1",
        payload_scale=8e6, t_scale=2.0)
    assert [s.name for s in specs] == ["tp", "pp"]
    assert specs[0].kind == "allreduce"
    assert specs[0].payload_bytes == pytest.approx(4e6)
    assert specs[0].priority == 2.0
    assert specs[1].kind == "p2p"
    assert specs[1].payload_bytes == pytest.approx(1e6)
    assert specs[1].start_time == pytest.approx(0.2)
    assert specs[1].root == 1
    with pytest.raises(ValueError):
        parse_streams("tp kind=explode frac=0.5")
    with pytest.raises(ValueError):
        parse_streams("tp kind=allreduce bogus=1")
    with pytest.raises(ValueError):
        StreamSpec("tp", "allreduce", -1.0)
    with pytest.raises(ValueError):
        StreamSpec("tp", "allreduce", 1.0, priority=0.0)
    # the managed-stream name is reserved and duplicates fail at parse
    # time with a clear message, not at engine construction deep in a run
    with pytest.raises(ValueError):
        StreamSpec("dp", "allreduce", 1.0)
    with pytest.raises(ValueError):
        parse_streams("dp kind=allreduce frac=0.5")
    with pytest.raises(ValueError):
        parse_streams("tp frac=0.5; tp frac=0.25")


def test_control_plane_reusable_after_streamed_scenario(cluster, t_h):
    """Regression: a caller-provided ControlPlane must come out of a
    streams= co-simulation unmutated (stream=None), so reusing it for a
    later single-stream scenario — whose only stream is named \"main\" —
    still resolves chunk progress instead of raising on an unknown
    stream."""
    cp = ControlPlane(cluster, payload_bytes=PAYLOAD)
    run_scenario(clean_nic_down(t_h, frac=0.2), cluster, PAYLOAD,
                 healthy_time=t_h, control_plane=cp,
                 streams=standard_parallel_streams(PAYLOAD))
    assert cp.stream is None
    rep = run_scenario(clean_nic_down(t_h, node=2), cluster, PAYLOAD,
                       healthy_time=t_h, control_plane=cp)
    assert rep.report.completion_time > 0


def test_scenario_dsl_roundtrip(t_h):
    sc = parse_campaign(
        "mix",
        "nic_down node=1 rail=0 at=0.4; "
        "flaps node=2 rail=1 at=0.1 down=0.02 period=0.2 count=3; "
        "slow node=0 rail=0 at=0 lost=0.3",
        t_scale=t_h)
    assert len(sc.failures) == 5
    assert sc.failures == tuple(sorted(sc.failures, key=lambda f: f.at_time))
    kinds = {f.ftype for f in sc.failures}
    assert kinds == {FailureType.NIC_HARDWARE, FailureType.LINK_FLAPPING,
                     FailureType.SLOW_NIC}
    with pytest.raises(ValueError):
        parse_campaign("bad", "explode node=0 rail=0 at=0")
    with pytest.raises(ValueError):
        parse_campaign("bad", "nic_down node=0 rail=0 at=0 bogus=1")


def test_standard_campaigns_cover_acceptance_set(t_h):
    names = {s.name for s in standard_campaigns(t_h, num_nodes=4, rails=4)}
    assert {"clean_nic_down", "flap_storm", "slow_nic",
            "failure_during_recovery"} <= names


# ---------------------------------------------------------------------------
# property: arbitrary campaigns terminate cleanly with zero lost chunks
# ---------------------------------------------------------------------------

@st.composite
def _campaigns(draw):
    """Arbitrary mixed campaigns on a 3x2 cluster.

    Hard failures are confined to rail 0 of distinct nodes and flaps/slow
    NICs to rail 1, so no node ever reaches zero bandwidth with no future
    recovery event (which would be an unrecoverable stall by construction,
    not a control-plane property)."""
    events = []
    hard_nodes = draw(st.lists(st.integers(0, 2), max_size=2))
    for nd in set(hard_nodes):
        events.append(("hard", nd, draw(st.floats(0.05, 1.2))))
    n_flaps = draw(st.integers(0, 4))
    for _ in range(n_flaps):
        events.append(("flap", draw(st.integers(0, 2)),
                       draw(st.floats(0.05, 1.2)), draw(st.floats(0.01, 0.3))))
    if draw(st.booleans()):
        events.append(("slow", draw(st.integers(0, 2)),
                       draw(st.floats(0.0, 1.0)), draw(st.floats(0.1, 0.8))))
    return events


@given(campaign=_campaigns())
@settings(max_examples=20, deadline=None)
def test_arbitrary_campaigns_terminate_healthy_or_replanned(campaign):
    from repro.core.failures import link_flap, slow_nic

    cluster = make_cluster(3, 2, nic_bandwidth=NIC_BW)
    payload = 10e6
    t_h = simulate_program(ring_program(list(range(3)), 3), payload,
                           cluster=cluster).completion_time
    failures = []
    for ev in campaign:
        if ev[0] == "hard":
            failures.append(nic_down_at(ev[1], 0, ev[2] * t_h))
        elif ev[0] == "flap":
            failures.append(link_flap(ev[1], 1, ev[2] * t_h, ev[3] * t_h))
        else:
            failures.append(slow_nic(ev[1], 1, ev[2] * t_h,
                                     lost_fraction=ev[3]))
    data = _data(3, seed=7)
    want = np.sum(np.stack(data), axis=0)
    sc = Scenario("prop", tuple(failures))
    # real payloads ride the full closed loop: since the chunk-map replan
    # (PR 4) a mid-collective program swap is payload-conserving, so
    # conservation is asserted unconditionally — replans included.
    rep = run_scenario(sc, cluster, payload, healthy_time=t_h,
                       rank_data=data)

    # terminal state property
    assert rep.final_state in (RecoveryState.HEALTHY, RecoveryState.REPLANNED)
    # every pipeline run's stages sum to its total, stages in order
    for e in rep.ledger.entries:
        assert e.total == pytest.approx(sum(e.stages.values()))
        keys = [s for s in STAGES if s in e.stages]
        assert keys == sorted(keys, key=STAGES.index)
    # the engine applied exactly the derived delays
    derived = [ev for ev in rep.report.repair_events if ev.derived]
    hard_entries = [e for e in rep.ledger.entries
                    if e.failure is not None and e.failure.severity >= 1.0]
    assert len(derived) == len(hard_entries)
    for ev, e in zip(derived, hard_entries):
        assert ev.delay == pytest.approx(e.hot_repair_latency)
    # zero lost chunks: all surviving transfers completed (the engine's run
    # loop only returns at _remaining == 0) and the real payloads reduce to
    # exactly the right result — even when the program was swapped
    # mid-collective (the chunk-exact residual replan)
    assert rep.report.completion_time > 0
    for r in rep.report.rank_data:
        np.testing.assert_allclose(r, want, atol=1e-9)
    for ev in rep.report.replan_events:
        assert 0.0 <= ev.residual_fraction <= 1.0 + 1e-12
