"""Paper Fig. 16 (Appendix E): AllGather / ReduceScatter / SendRecv under a
single NIC failure — R2CCL-Balance vs HotRepair, large messages.

Also validates the schedule executors: the numpy oracle runs the real ring
schedules and its measured per-rank traffic must match the analytic model
Section 5.1 uses (ReduceScatter sends (n-1)/n * D, etc.).
"""

from __future__ import annotations

import numpy as np

from repro.core.comm_sim import strategy_rate
from repro.core.executor_np import ExecStats, execute_chunk_schedule
from repro.core.schedule import (
    build_ring_all_gather,
    build_ring_broadcast,
    build_ring_reduce_scatter,
)
from repro.core.topology import IB_NIC_BW

from .common import Reporter

N_NODES, G = 2, 8
NODE_BW = 8 * IB_NIC_BW
X = 1.0 / 8.0


def run() -> None:
    r = Reporter("collectives_fig16")
    n = 8
    rng = np.random.default_rng(0)
    data = [rng.normal(size=4096) for _ in range(n)]
    d_bytes = 4096 * 8.0

    # traffic accounting from the executor vs the Section-5.1 lower bounds
    for name, sched, bound in [
        ("reduce_scatter", build_ring_reduce_scatter(list(range(n)), n), (n - 1) / n),
        ("all_gather", build_ring_all_gather(list(range(n)), n), (n - 1) / n),
        ("broadcast", build_ring_broadcast(list(range(n)), n, root=0), 1.0),
    ]:
        stats = ExecStats()
        execute_chunk_schedule(sched, data, stats=stats)
        tx = max(stats.rank_tx.values()) / d_bytes
        r.row(f"{name}_max_tx_over_D", tx, f"lower bound {bound:.3f}")

    # large-message throughput fractions under one NIC failure
    for coll in ("all_gather", "reduce_scatter", "send_recv"):
        bal = strategy_rate("balance", NODE_BW, X, n_nodes=N_NODES, g=G)
        hot = strategy_rate("hot_repair", NODE_BW, X, n_nodes=N_NODES, g=G)
        r.row(f"{coll}_balance_frac", bal, "paper: 0.85-0.89")
        r.row(f"{coll}_hot_repair_frac", hot, "paper: ~0.50")
    r.save()


if __name__ == "__main__":
    run()
