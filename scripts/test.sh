#!/usr/bin/env bash
# Tier-1 test entry point: fast suite (minutes, not tens of minutes).
#
#   scripts/test.sh              # default: skip @slow (model/system/multidevice)
#   scripts/test.sh --all        # everything, including @slow
#   scripts/test.sh <pytest args...>   # passed through verbatim
#
# The fast tier includes the multi-iteration campaign path on every push:
# tests/test_campaign.py (persistent-control-plane semantics) and the tiny
# campaign bench smoke (tests/test_bench_smoke.py::test_training_bench_tiny_campaign
# and ::test_runtime_bench_tiny_campaign_sweep — 3 iterations, 1 failure).
#
# Property tests run offline via tests/_propcheck.py when hypothesis is not
# installed; install requirements-dev.txt to use the real library.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Fail loudly if the package is not importable: without this, a broken
# PYTHONPATH/src layout makes pytest silently collect zero repro tests.
if ! python -c "import repro" 2>/dev/null; then
    echo "error: cannot import 'repro' with PYTHONPATH=$PYTHONPATH" >&2
    echo "       expected the package at $(pwd)/src/repro — run this script" >&2
    echo "       from a checkout, or set PYTHONPATH=src manually." >&2
    exit 2
fi

if [[ "${1:-}" == "--all" ]]; then
    shift
    exec python -m pytest -q "$@"
fi
exec python -m pytest -q -m "not slow" "$@"
