"""Serving through NIC failures: compare the four strategies of the paper's
inference evaluation (restart / reroute / DejaVu-style replication / R2CCL
transparent migration) on a real decode loop.

  PYTHONPATH=src python examples/serve_resilient.py
"""

import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core.failures import Failure, FailureType
from repro.models import get_smoke_config, init_model
from repro.serving import Request, ServingEngine


def main() -> None:
    cfg = get_smoke_config("glm4-9b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 24) for _ in range(4)]
    failure = Failure(FailureType.NIC_HARDWARE, 0, 2)

    baseline = None
    print(f"{'strategy':10s} {'total(s)':>9s} {'ttft(ms)':>9s} "
          f"{'tpot(ms)':>9s} {'overhead':>9s}  tokens-match")
    for strategy in ("r2ccl", "dejavu", "reroute", "restart"):
        engine = ServingEngine(cfg, params, context_len=96, strategy=strategy)
        reqs = [Request(prompt=p, max_new_tokens=10) for p in prompts]
        res = engine.run_batch(reqs, fail_at_step=4, failure=failure)
        if baseline is None:
            healthy_engine = ServingEngine(cfg, params, context_len=96,
                                           strategy="r2ccl")
            healthy = healthy_engine.run_batch(
                [Request(prompt=p, max_new_tokens=10) for p in prompts])
            baseline = healthy[0]
            print(f"{'no-failure':10s} {baseline.total_latency:9.3f} "
                  f"{baseline.ttft*1e3:9.1f} {baseline.tpot*1e3:9.1f} "
                  f"{'—':>9s}  —")
        r = res[0]
        ov = r.total_latency / baseline.total_latency - 1.0
        match = all(a.tokens == b.tokens for a, b in zip(res, healthy))
        print(f"{strategy:10s} {r.total_latency:9.3f} {r.ttft*1e3:9.1f} "
              f"{r.tpot*1e3:9.1f} {ov:9.1%}  {match}")

    print("\nR2CCL keeps serving with near-zero overhead; restart pays the "
          "35 s engine relaunch plus full reprocessing (paper Fig. 11/14).")


if __name__ == "__main__":
    main()
