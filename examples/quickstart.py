"""Quickstart: train a small model for a few hundred steps with the R2CCL
collective layer, checkpoint it, then serve it.

  PYTHONPATH=src python examples/quickstart.py [--steps 200]

This is the end-to-end driver: data pipeline -> model -> train loop with
explicit R2CCL gradient sync -> checkpoint -> batched greedy serving.
"""

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core.planner import CommConfig
from repro.data import make_batch
from repro.models import get_smoke_config, init_model
from repro.optim import AdamWConfig
from repro.serving import Request, ServingEngine
from repro.training import (
    init_train_state,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"== {cfg.name}: {cfg.num_layers}L d{cfg.d_model} "
          f"vocab{cfg.vocab_size} ==")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n:,}")

    state = init_train_state(params)
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3), sync="xla",
        warmup_steps=20, total_steps=args.steps))

    t0 = time.time()
    for i in range(args.steps):
        b = make_batch(cfg, args.seq_len, args.batch, step=i)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{(i+1)*args.batch*args.seq_len/(time.time()-t0):,.0f} tok/s")

    save_checkpoint(args.ckpt, state, args.steps)
    restored, at = restore_checkpoint(args.ckpt, state)
    print(f"checkpoint roundtrip ok at step {at}")

    engine = ServingEngine(cfg, restored.params, context_len=args.seq_len + 32,
                           strategy="r2ccl")
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 16),
                    max_new_tokens=12) for _ in range(4)]
    results = engine.run_batch(reqs)
    for i, r in enumerate(results):
        print(f"req {i}: {r.tokens}  ttft={r.ttft*1e3:.0f}ms "
              f"tpot={r.tpot*1e3:.0f}ms")


if __name__ == "__main__":
    main()
