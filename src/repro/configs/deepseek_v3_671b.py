"""DeepSeek-V3-671B [moe] — MLA + 1 shared + 256 routed experts, top-8.

61L d_model=7168 128H (MLA) expert_d_ff=2048 vocab=129280  [arXiv:2412.19437]
First 3 layers use a dense FFN (d_ff=18432); the remaining 58 are MoE.
MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v=128.
MTP (multi-token prediction) is implemented as the auxiliary head of the
paper: one extra block over [h_t ; emb(t_{t+1})] predicting token t+2,
weighted 0.3 in the training loss (cfg.mtp / cfg.mtp_loss_weight).
"""

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig


CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    d_ff=18432,                     # dense FFN width (first_k_dense layers)
    vocab_size=129_280,
    attention=AttentionConfig(
        kind="mla", num_heads=128, num_kv_heads=128, head_dim=128,
        rope_theta=10_000.0,
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    ),
    moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                  expert_d_ff=2048, capacity_factor=1.25, first_k_dense=3),
    block_pattern=("attn",),
    activation="swiglu",
    norm="rmsnorm",
    mtp=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke",
        family="moe",
        source=CONFIG.source,
        num_layers=3,               # 1 dense + 2 moe
        d_model=128,
        d_ff=256,
        vocab_size=512,
        attention=AttentionConfig(
            kind="mla", num_heads=4, num_kv_heads=4, head_dim=32,
            q_lora_rank=48, kv_lora_rank=32,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        ),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      expert_d_ff=64, capacity_factor=2.0, first_k_dense=1),
        block_pattern=("attn",),
        activation="swiglu",
        norm="rmsnorm",
        remat=False,
        mtp=True,
    )
