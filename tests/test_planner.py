"""Table 1 + Section 6: alpha-beta planner strategy selection."""

import pytest

from repro.core.failures import (
    FailureState,
    concentrated_failures,
    random_failures,
    single_nic_failure,
)
from repro.core.planner import Collective, CommConfig, Planner, Strategy
from repro.core.topology import make_cluster


def _state(failures):
    st = FailureState()
    for f in failures:
        st.apply(f)
    return st


@pytest.fixture
def planner():
    return Planner(make_cluster(8, 8))


def test_no_failure_ring(planner):
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, FailureState())
    assert plan.strategy is Strategy.RING


def test_small_message_latency_bound(planner):
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 10, FailureState())
    assert plan.strategy in (Strategy.TREE, Strategy.RING)


def test_single_failure_large_allreduce_uses_decomposition(planner):
    st = _state(single_nic_failure(2, 3))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert plan.strategy is Strategy.R2CCL_ALL_REDUCE
    assert plan.degraded_node == 2
    assert 0 < plan.partition_y < 1
    assert plan.lost_fraction == pytest.approx(0.125)


def test_table1_non_allreduce_uses_balance(planner):
    st = _state(single_nic_failure(2, 3))
    for coll in (Collective.ALL_GATHER, Collective.REDUCE_SCATTER,
                 Collective.BROADCAST, Collective.ALL_TO_ALL):
        plan = planner.choose_strategy(coll, 1 << 30, st)
        assert plan.strategy is Strategy.BALANCE, coll


def test_latency_bound_allreduce_uses_balance(planner):
    st = _state(single_nic_failure(2, 3))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 12, st)
    assert plan.strategy is Strategy.BALANCE


def test_multi_failure_spectrum_recursive(planner):
    # different nodes losing different NIC counts -> bandwidth spectrum
    fails = (concentrated_failures(1, [0, 1, 2, 3]) +
             concentrated_failures(4, [0, 1]) + single_nic_failure(6, 5))
    st = _state(fails)
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert plan.strategy in (Strategy.RECURSIVE, Strategy.BALANCE)


def test_reranking_engaged_on_rail_mismatch(planner):
    from repro.core.failures import rail_mismatch_failures
    st = _state(rail_mismatch_failures(0, 1, 0, 5))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert sorted(plan.ring_order) == list(range(8))


def test_comm_config_kwargs():
    c = CommConfig(mode="r2ccl", degraded_rank=3, lost_fraction=0.5)
    kw = c.kwargs()
    assert kw["mode"] == "r2ccl" and kw["degraded"] == 3
    assert kw["bandwidths"] is None


# ---------------------------------------------------------------------------
# alpha-beta closed-form edge cases
# ---------------------------------------------------------------------------

def test_ring_time_hetero_degenerate_bandwidths():
    from repro.core.planner import ring_time_hetero

    # any dead node stalls the ring; all-dead likewise
    assert ring_time_hetero(1e6, [1e9, 0.0, 1e9], 8, 2e-6) == float("inf")
    assert ring_time_hetero(1e6, [0.0, 0.0], 8, 2e-6) == float("inf")
    # healthy vector is finite and monotone in the slowest node
    fast = ring_time_hetero(1e6, [2e9, 2e9], 8, 2e-6)
    slow = ring_time_hetero(1e6, [2e9, 1e9], 8, 2e-6)
    assert 0 < fast < slow


def test_tree_time_degenerate_bandwidths():
    from repro.core.planner import tree_time

    # a tree routes around dead nodes: priced at the slowest *live* node
    assert tree_time(1e6, [1e9, 0.0, 1e9], 8, 2e-6) == \
        tree_time(1e6, [1e9, 1e9, 1e9], 8, 2e-6)
    # every node dead: no tree can move data
    assert tree_time(1e6, [0.0, 0.0, 0.0], 8, 2e-6) == float("inf")


def test_single_node_group_times_are_latency_only():
    from repro.core.planner import ring_time_hetero, tree_time

    alpha = 2e-6
    # n=1, g=1: a "ring" of one device — zero steps, zero time
    assert ring_time_hetero(0.0, [1e9], 1, alpha) == 0.0
    t = tree_time(0.0, [1e9], 1, alpha)
    assert t == pytest.approx(2 * alpha)       # depth clamps at 1


def test_zero_payload_collectives_price_latency_term():
    from repro.core.planner import ring_time_hetero, tree_time

    alpha = 2e-6
    n, g = 4, 8
    assert ring_time_hetero(0.0, [1e9] * n, g, alpha) == \
        pytest.approx(2 * (n * g - 1) * alpha)
    assert tree_time(0.0, [1e9] * n, g, alpha) > 0
    # the planner still returns a finite plan for a zero-byte collective
    plan = Planner(make_cluster(n, g)).choose_strategy(
        Collective.ALL_REDUCE, 0.0, FailureState())
    assert plan.strategy in (Strategy.TREE, Strategy.RING)
    assert 0 < plan.predicted_time < float("inf")


# ---------------------------------------------------------------------------
# score="static": price built programs with the cost analyzer
# ---------------------------------------------------------------------------

def test_invalid_score_rejected(planner):
    with pytest.raises(ValueError, match="score"):
        planner.choose_strategy(Collective.ALL_REDUCE, 1 << 20,
                                FailureState(), score="event")


def test_static_score_is_opt_in(planner):
    # default path must be byte-identical to the original alpha-beta plan
    st = _state(single_nic_failure(2, 3))
    explicit = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st,
                                       score="alpha_beta")
    default = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert default == explicit


def test_static_score_healthy_ring():
    planner = Planner(make_cluster(4, 8))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 28,
                                   FailureState(), score="static")
    assert plan.strategy is Strategy.RING
    assert 0 < plan.predicted_time < float("inf")
    assert "static" in plan.notes


def test_static_score_prices_real_program():
    # the static plan's time is the cost analyzer's price of the built
    # ring program over the healthy node bandwidths — check it end-to-end
    from repro.analysis.cost import analyze_program
    from repro.core.schedule import ring_program

    cluster = make_cluster(4, 8)
    planner = Planner(cluster)
    payload = float(1 << 28)
    plan = planner.choose_strategy(Collective.ALL_REDUCE, payload,
                                   FailureState(), score="static")
    rep = analyze_program(ring_program(list(range(4)), 4), payload,
                          capacities=cluster.bandwidths(),
                          alpha=planner.alpha)
    assert plan.predicted_time == rep.predicted_time


def test_static_score_single_failure_candidates():
    planner = Planner(make_cluster(4, 8))
    st = _state(single_nic_failure(2, 3))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st,
                                   score="static")
    assert plan.strategy in (Strategy.BALANCE, Strategy.R2CCL_ALL_REDUCE,
                             Strategy.RECURSIVE)
    assert plan.degraded_node == 2
    assert 0 < plan.predicted_time < float("inf")
    assert plan.bandwidths[2] < plan.bandwidths[0]


def test_static_score_small_payload_under_failure_uses_balance():
    planner = Planner(make_cluster(4, 8))
    st = _state(single_nic_failure(1, 0))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 12, st,
                                   score="static")
    assert plan.strategy is Strategy.BALANCE
