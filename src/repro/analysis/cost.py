"""Static cost analysis: price a collective program without simulating it.

Abstract interpretation over the same per-rank lockstep-round structure the
verifier (:mod:`repro.analysis.verify`) walks, using the event engine's own
arithmetic so the two cannot drift:

* every round's transfers are rated by the engine's weighted max-min
  water-fill (:func:`repro.core.event_sim.fair_share_fast` — the engine's
  vectorized kernel, pinned bit-identical to the exported reference
  ``fair_share`` by the property suite in ``tests/test_fill_equiv.py`` —
  called on the same flow ordering);
* a transfer's finish is ``(start + alpha) + size / rate`` — the same float
  operations, in the same order, the engine's event loop performs (release,
  activate at ``+alpha``, drain at the fair rate);
* per-rank readiness follows the engine's dependency rule: a transfer waits
  on all transfers of both endpoints' previous participating step.

For **uncontended lockstep** schedules — a single live segment whose rounds
each begin and finish in unison (every builder ring/tree schedule on uniform
capacities) — the engine's active flow set at any instant is exactly one
round, so the walk reproduces the engine's healthy completion time
*bit-exactly*.  :attr:`CostReport.lockstep_uniform` reports when that
guarantee applied; ``tests/test_analysis.py`` and the ``python -m
repro.analysis cost --corpus`` CI gate enforce it.  Skewed rounds or
concurrent segments break the round=flow-set identity; there the prediction
is ``max(per-segment lockstep time, per-rank byte-load bound)`` and
corpus-wide conformance is held to :data:`CORPUS_COST_TOLERANCE`.

The planner's ``score="static"`` mode (:meth:`repro.core.planner.Planner.
choose_strategy`) prices *built* programs through :func:`analyze_program`
instead of the alpha-beta closed forms, and the failure-coverage analysis
(:mod:`repro.analysis.coverage`) reuses the same walk under residual
capacities.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.event_sim import fair_share_fast
from repro.core.schedule import ChunkSchedule, CollectiveProgram, Segment
from repro.core.topology import ClusterTopology, DEFAULT_ALPHA

__all__ = [
    "CONFORMANCE_CAPACITY",
    "CONFORMANCE_PAYLOAD",
    "CORPUS_COST_TOLERANCE",
    "CostReport",
    "Hotspot",
    "LinkLoad",
    "analyze_program",
    "analyze_schedule",
    "as_program",
]

#: corpus-wide relative-error ceiling of the static prediction vs the event
#: engine's healthy completion, over every builder schedule/program
#: (``python -m repro.analysis cost --corpus``).  Single-live-segment
#: lockstep schedules are bit-exact (error 0.0); the slack is consumed by
#: multi-segment programs (R2CCL / recursive decompositions), whose
#: concurrent segments contend in the engine but are priced independently
#: here.  Measured max across the seed-0 corpus is ~0.25 (a recursive
#: decomposition whose level programs overlap in the engine); pinned with
#: margin.
CORPUS_COST_TOLERANCE = 0.40

#: payload and per-rank capacity the conformance gate prices at (uniform
#: capacities keep the builder schedules in the bit-exact lockstep class)
CONFORMANCE_PAYLOAD = float(1 << 26)
CONFORMANCE_CAPACITY = 25e9


@dataclasses.dataclass(frozen=True)
class _Flow:
    """Duck-typed flow for the engine's water-fill (tid/src/dst/weight)."""

    tid: int
    src: int
    dst: int
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class LinkLoad:
    """Total bytes a directed (src, dst) rank pair carries."""

    src: int
    dst: int
    load_bytes: float
    transfers: int


@dataclasses.dataclass(frozen=True)
class Hotspot:
    """One rank-direction's share of the predicted makespan.

    ``utilization`` is the fraction of the predicted completion time this
    NIC direction spends busy (bytes / (capacity * predicted_time)); the
    report ranks these descending, so ``hotspots[0]`` is the contention
    bottleneck the schedule's bytes actually hit.
    """

    rank: int
    direction: str          # "tx" | "rx"
    load_bytes: float
    capacity: float
    utilization: float


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Closed-form performance profile of one collective program.

    ``predicted_time`` is ``max(lockstep_time, bandwidth_time)``:
    the lockstep chain time (per segment, engine-arithmetic exact) and the
    per-rank byte-load lower bound (no rank can move its bytes faster than
    its capacity).  ``math.inf`` means some transfer's endpoints retain no
    capacity — the program cannot complete (the engine would stall).
    """

    name: str
    n: int
    total_bytes: float
    alpha: float
    predicted_time: float
    lockstep_time: float
    bandwidth_time: float
    segment_times: tuple[float, ...]
    rounds: int
    transfers: int
    #: bytes per directed (src, dst) rank pair — the static analogue of
    #: ``EventSimReport.link_bytes`` (identical for failure-free runs)
    link_bytes: dict[tuple[int, int], float]
    link_transfers: dict[tuple[int, int], int]
    rank_tx_bytes: tuple[float, ...]
    rank_rx_bytes: tuple[float, ...]
    #: rank-direction loads ranked by utilization, descending
    hotspots: tuple[Hotspot, ...]
    #: True when the bit-exactness guarantee applied: one live segment and
    #: every round began and finished in unison (the prediction then equals
    #: the event engine's healthy completion exactly)
    lockstep_uniform: bool

    @property
    def completes(self) -> bool:
        """Whether every transfer retains a live path (finite prediction)."""
        return math.isfinite(self.predicted_time)

    def top_links(self, k: int = 8) -> tuple[LinkLoad, ...]:
        """The ``k`` heaviest directed links, by bytes carried."""
        ranked = sorted(self.link_bytes.items(),
                        key=lambda kv: (-kv[1], kv[0]))
        return tuple(
            LinkLoad(src, dst, load, self.link_transfers[(src, dst)])
            for (src, dst), load in ranked[:k])

    def to_dict(self) -> dict:
        """JSON-serializable form (link keys flattened to ``"src->dst"``)."""
        return {
            "name": self.name,
            "n": self.n,
            "total_bytes": self.total_bytes,
            "alpha": self.alpha,
            "predicted_time": self.predicted_time,
            "lockstep_time": self.lockstep_time,
            "bandwidth_time": self.bandwidth_time,
            "segment_times": list(self.segment_times),
            "rounds": self.rounds,
            "transfers": self.transfers,
            "lockstep_uniform": self.lockstep_uniform,
            "link_bytes": {f"{s}->{d}": v
                           for (s, d), v in sorted(self.link_bytes.items())},
            "rank_tx_bytes": list(self.rank_tx_bytes),
            "rank_rx_bytes": list(self.rank_rx_bytes),
            "hotspots": [dataclasses.asdict(h) for h in self.hotspots],
        }


def as_program(obj: ChunkSchedule | CollectiveProgram) -> CollectiveProgram:
    """Wrap a bare schedule into a single-segment program (the same wrap
    :func:`repro.core.event_sim.simulate_schedule` performs)."""
    if isinstance(obj, CollectiveProgram):
        return obj
    return CollectiveProgram(obj.name, obj.n, [Segment(1.0, obj)])


def resolve_capacities(
    n: int,
    cluster: ClusterTopology | None,
    capacities: Sequence[float] | None,
) -> list[float]:
    """Per-rank capacity vector, mirroring the engine's cluster/capacities
    constructor contract (rank i = node i, capacity = node egress)."""
    if cluster is not None:
        if capacities is not None:
            raise ValueError("pass either cluster= or capacities=, not both")
        if cluster.num_nodes != n:
            raise ValueError(
                f"program has {n} ranks but cluster has "
                f"{cluster.num_nodes} nodes")
        return cluster.bandwidths()
    if capacities is None:
        raise ValueError("need either cluster= or capacities=")
    if len(capacities) != n:
        raise ValueError(
            f"capacities must have one entry per rank: got "
            f"{len(capacities)} for {n} ranks")
    return [float(c) for c in capacities]


def _walk(
    prog: CollectiveProgram,
    total_bytes: float,
    caps: Sequence[float],
    alpha: float,
) -> CostReport:
    """The lockstep-round abstract interpretation (module docstring)."""
    n = prog.n
    caps = list(caps)

    def cap(rank: int) -> float:
        return caps[rank]

    link_bytes: dict[tuple[int, int], float] = {}
    link_transfers: dict[tuple[int, int], int] = {}
    tx = [0.0] * n
    rx = [0.0] * n
    segment_times: list[float] = []
    rounds = 0
    transfers = 0
    uniform = True
    live_segments = 0

    for seg in prog.segments:
        sched = seg.schedule
        # same float expressions, same order, as EventSimulator._instantiate
        seg_bytes = float(total_bytes) * seg.frac
        chunk_bytes = seg_bytes / sched.num_chunks
        ready = [0.0] * n
        seg_done = 0.0
        seg_live = False
        for st in sched.steps:
            size = seg_bytes if st.whole_buffer else chunk_bytes
            flows = [_Flow(i, src, dst)
                     for i, (src, dst) in enumerate(st.perm)]
            if not flows:
                continue
            seg_live = True
            rounds += 1
            transfers += len(flows)
            rates = fair_share_fast(flows, cap)
            begins: list[float] = []
            finish: dict[int, float] = {}
            for f in flows:
                rs, rd = ready[f.src], ready[f.dst]
                begin = rs if rs >= rd else rd
                rate = rates.get(f.tid, 0.0)
                if rate <= 0.0 and size > 0.0:
                    # no residual capacity at an endpoint: the engine would
                    # raise StalledError — statically, no live path
                    fin = math.inf
                elif size <= max(1e-9, 1e-9 * size):
                    # below the engine's completion epsilon: the transfer
                    # completes at its activation instant
                    fin = begin + alpha
                else:
                    # release at `begin`, activate at +alpha, stream at the
                    # fair rate — the engine's exact float fold
                    fin = (begin + alpha) + size / rate
                begins.append(begin)
                finish[f.tid] = fin
                link = (f.src, f.dst)
                link_bytes[link] = link_bytes.get(link, 0.0) + size
                link_transfers[link] = link_transfers.get(link, 0) + 1
                tx[f.src] += size
                rx[f.dst] += size
            if len(set(begins)) > 1 or len(set(finish.values())) > 1:
                uniform = False
            # engine dependency rule: a rank's next participating step waits
            # on ALL its transfers of this step (fin >= begin + alpha, so
            # this replaces the rank's readiness with its latest finish)
            for f in flows:
                fin = finish[f.tid]
                if fin > ready[f.src]:
                    ready[f.src] = fin
                if fin > ready[f.dst]:
                    ready[f.dst] = fin
                if fin > seg_done:
                    seg_done = fin
        segment_times.append(seg_done)
        if seg_live:
            live_segments += 1

    if live_segments > 1:
        # concurrent segments share the NICs in the engine; the independent
        # per-segment walk no longer tracks the true flow set
        uniform = False
    lockstep = max(segment_times) if segment_times else 0.0

    bandwidth_time = 0.0
    for rank in range(n):
        for load in (tx[rank], rx[rank]):
            if load <= 0.0:
                continue
            if caps[rank] <= 0.0:
                bandwidth_time = math.inf
            else:
                dir_time = load / caps[rank]
                if dir_time > bandwidth_time:
                    bandwidth_time = dir_time

    predicted = lockstep if lockstep >= bandwidth_time else bandwidth_time

    hotspots: list[Hotspot] = []
    for rank in range(n):
        for direction, load in (("tx", tx[rank]), ("rx", rx[rank])):
            if load <= 0.0:
                continue
            if caps[rank] <= 0.0:
                util = math.inf
            elif predicted > 0.0 and math.isfinite(predicted):
                util = load / (caps[rank] * predicted)
            else:
                util = 0.0
            hotspots.append(Hotspot(rank=rank, direction=direction,
                                    load_bytes=load, capacity=caps[rank],
                                    utilization=util))
    hotspots.sort(key=lambda h: (-h.utilization, -h.load_bytes,
                                 h.rank, h.direction))

    return CostReport(
        name=prog.name,
        n=n,
        total_bytes=float(total_bytes),
        alpha=alpha,
        predicted_time=predicted,
        lockstep_time=lockstep,
        bandwidth_time=bandwidth_time,
        segment_times=tuple(segment_times),
        rounds=rounds,
        transfers=transfers,
        link_bytes=link_bytes,
        link_transfers=link_transfers,
        rank_tx_bytes=tuple(tx),
        rank_rx_bytes=tuple(rx),
        hotspots=tuple(hotspots),
        lockstep_uniform=uniform,
    )


def analyze_program(
    prog: CollectiveProgram,
    total_bytes: float,
    *,
    cluster: ClusterTopology | None = None,
    capacities: Sequence[float] | None = None,
    alpha: float = DEFAULT_ALPHA,
) -> CostReport:
    """Statically price ``prog`` over ``total_bytes`` on a topology.

    Exactly one of ``cluster`` (rank i = node i, capacity = node egress) or
    ``capacities`` (explicit per-rank bytes/s — pass the *residual*
    bandwidths to price a degraded fabric) must be given, mirroring
    :func:`repro.core.event_sim.simulate_program`.
    """
    caps = resolve_capacities(prog.n, cluster, capacities)
    return _walk(prog, total_bytes, caps, alpha)


def analyze_schedule(
    sched: ChunkSchedule,
    total_bytes: float,
    **kw,
) -> CostReport:
    """Convenience wrapper for a single-segment schedule."""
    return analyze_program(as_program(sched), total_bytes, **kw)
