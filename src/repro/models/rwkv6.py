"""RWKV-6 "Finch" time-mix + channel-mix (arXiv:2404.05892).

Attention-free: the WKV recurrence maintains a matrix-valued state
S in R^{H x K x V} per head with *data-dependent* per-channel decay w_t
(the Finch innovation over RWKV-5's static decay):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = (r_t (S_{t-1} + diag(u) k_t^t v_t))        # bonus u on current token

Token-shift mixes each input with the previous token through learned,
data-dependent interpolation (low-rank, per Finch).

Train/prefill: lax.scan over time (chunked formulation is the Pallas kernel
target, ``kernels/rwkv_scan.py``).  Decode: single state update.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense_init


def init_rwkv_block(key, d_model: int, head_size: int, decay_lora: int,
                    tokenshift_lora: int, dtype=jnp.float32):
    ks = jax.random.split(key, 16)
    H = d_model // head_size
    params = {
        # time-mix projections
        "w_r": dense_init(ks[0], (d_model, d_model), d_model, dtype),
        "w_k": dense_init(ks[1], (d_model, d_model), d_model, dtype),
        "w_v": dense_init(ks[2], (d_model, d_model), d_model, dtype),
        "w_g": dense_init(ks[3], (d_model, d_model), d_model, dtype),
        "w_o": dense_init(ks[4], (d_model, d_model), d_model, dtype),
        # data-dependent decay (low-rank): w_t = exp(-exp(base + lora(x)))
        "decay_base": jnp.full((d_model,), -6.0, jnp.float32),
        "decay_a": dense_init(ks[5], (d_model, decay_lora), d_model, dtype),
        "decay_b": dense_init(ks[6], (decay_lora, d_model), decay_lora, dtype),
        # per-channel bonus for the current token
        "u": (jax.random.normal(ks[7], (d_model,)) * 0.1).astype(jnp.float32),
        # token-shift interpolation (one mu per projection role + lora)
        "mu": (jax.random.uniform(ks[8], (5, d_model))).astype(jnp.float32),
        "ts_a": dense_init(ks[9], (d_model, tokenshift_lora), d_model, dtype),
        "ts_b": dense_init(ks[10], (tokenshift_lora, 5 * d_model), tokenshift_lora, dtype),
        "ln_x_scale": jnp.ones((d_model,), jnp.float32),
        # channel-mix
        "cm_k": dense_init(ks[11], (d_model, d_model * 7 // 2), d_model, dtype),
        "cm_v": dense_init(ks[12], (d_model * 7 // 2, d_model), d_model * 7 // 2, dtype),
        "cm_mu": (jax.random.uniform(ks[13], (d_model,))).astype(jnp.float32),
    }
    axes = {
        "w_r": ("embed", "heads"), "w_k": ("embed", "heads"),
        "w_v": ("embed", "heads"), "w_g": ("embed", "heads"),
        "w_o": ("heads", "embed"),
        "decay_base": (None,), "decay_a": ("embed", None), "decay_b": (None, "heads"),
        "u": (None,), "mu": (None, None),
        "ts_a": ("embed", None), "ts_b": (None, None),
        "ln_x_scale": (None,),
        "cm_k": ("embed", "mlp"), "cm_v": ("mlp", "embed"),
        "cm_mu": (None,),
    }
    return params, axes


@dataclasses.dataclass
class RWKVState:
    s: jnp.ndarray                  # (B, H, K, V) wkv state
    shift_tm: jnp.ndarray           # (B, d) previous token (time-mix)
    shift_cm: jnp.ndarray           # (B, d) previous token (channel-mix)


jax.tree_util.register_dataclass(
    RWKVState, data_fields=["s", "shift_tm", "shift_cm"], meta_fields=[]
)


def init_rwkv_state(batch: int, d_model: int, head_size: int,
                    dtype=jnp.float32) -> RWKVState:
    H = d_model // head_size
    return RWKVState(
        s=jnp.zeros((batch, H, head_size, head_size), dtype),
        shift_tm=jnp.zeros((batch, d_model), dtype),
        shift_cm=jnp.zeros((batch, d_model), dtype),
    )


def _token_shift(x, prev):
    """x: (B,T,d); prev: (B,d) last token of the previous segment."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def wkv_scan_ref(r, k, v, w, u, s0):
    """WKV recurrence oracle.

    r,k,v,w: (B,T,H,K); u: (H,K); s0: (B,H,K,V=K).  Returns (out, sT):
      out_t = r_t @ (S_{t-1} + u * k_t^T v_t);  S_t = w_t * S_{t-1} + k_t^T v_t
    """
    B, T, H, K = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp                               # (B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)           # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, out

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    sT, outs = lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3), sT                  # (B,T,H,V), (B,H,K,V)


def rwkv_block(
    params,
    x: jnp.ndarray,                # (B,T,d)
    *,
    head_size: int,
    state: RWKVState | None = None,
    mode: str = "train",
) -> tuple[jnp.ndarray, RWKVState | None]:
    B, T, d = x.shape
    H = d // head_size
    xf = x.astype(jnp.float32)

    prev_tm = state.shift_tm.astype(jnp.float32) if state is not None \
        else jnp.zeros((B, d), jnp.float32)
    xs = _token_shift(xf, prev_tm)                          # (B,T,d)

    # Finch data-dependent token shift: per-role interpolation factors.
    lora = jnp.tanh(xf @ params["ts_a"].astype(jnp.float32)) @ \
        params["ts_b"].astype(jnp.float32)                  # (B,T,5d)
    lora = lora.reshape(B, T, 5, d)
    mix = jax.nn.sigmoid(params["mu"][None, None] + lora)   # (B,T,5,d)
    xr, xk, xv, xw, xg = [
        xf + mix[:, :, i] * (xs - xf) for i in range(5)
    ]

    r = (xr @ params["w_r"].astype(jnp.float32)).reshape(B, T, H, head_size)
    k = (xk @ params["w_k"].astype(jnp.float32)).reshape(B, T, H, head_size)
    v = (xv @ params["w_v"].astype(jnp.float32)).reshape(B, T, H, head_size)
    g = jax.nn.silu(xg @ params["w_g"].astype(jnp.float32))

    dec = params["decay_base"] + \
        (jnp.tanh(xw @ params["decay_a"].astype(jnp.float32)) @
         params["decay_b"].astype(jnp.float32))             # (B,T,d)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, T, H, head_size)  # in (0,1)
    u = params["u"].reshape(H, head_size)

    s0 = state.s.astype(jnp.float32) if state is not None \
        else jnp.zeros((B, H, head_size, head_size), jnp.float32)
    out, sT = wkv_scan_ref(r, k, v, w, u, s0)               # (B,T,H,K)

    # group-norm per head (RWKV's ln_x), then gate and project out
    o = out.reshape(B, T, H, head_size)
    mu_ = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mu_) * lax.rsqrt(var + 64e-5)
    o = o.reshape(B, T, d) * params["ln_x_scale"]
    y_tm = (o * g) @ params["w_o"].astype(jnp.float32)

    # channel-mix sublayer (with its own token shift)
    h_in = xf + y_tm
    prev_cm = state.shift_cm.astype(jnp.float32) if state is not None \
        else jnp.zeros((B, d), jnp.float32)
    hs = _token_shift(h_in, prev_cm)
    cmix = params["cm_mu"][None, None]
    hk = h_in + cmix * (hs - h_in)
    cm = jnp.square(jax.nn.relu(hk @ params["cm_k"].astype(jnp.float32)))
    y = y_tm + cm @ params["cm_v"].astype(jnp.float32)

    new_state = None
    if mode in ("prefill", "decode"):
        sdt = state.s.dtype if state is not None else jnp.float32
        new_state = RWKVState(
            s=sT.astype(sdt),
            shift_tm=xf[:, -1].astype(sdt),
            shift_cm=h_in[:, -1].astype(sdt),
        )
    return y.astype(x.dtype), new_state
