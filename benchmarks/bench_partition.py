"""Appendix A validation: optimal split Y*, threshold ng/(3ng-2), and the
predicted bottleneck-traffic reduction of Figure 5 (2D -> 1.75D)."""

from __future__ import annotations

import numpy as np

from repro.core.allreduce import bottleneck_traffic, build_r2ccl_all_reduce
from repro.core.partition import (
    brute_force_y,
    plan_partition,
    ring_coeff,
    total_time,
    x_threshold,
    y_star,
    y_star_overlapped,
    total_time_overlapped,
)
from repro.core.schedule import ring_program

from .common import Reporter


def run() -> None:
    r = Reporter("partition_appendix_a")
    # closed-form Y* vs brute force across the X grid
    worst = 0.0
    for n, g in [(2, 8), (4, 8), (8, 8), (16, 4)]:
        thr = x_threshold(n, g)
        r.row(f"x_threshold_n{n}_g{g}", thr, "ng/(3ng-2)")
        for x in np.linspace(0.05, 0.95, 19):
            ys = y_star(x, n, g)
            yb = brute_force_y(x, n, g, grid=20_000)
            worst = max(worst, abs(ys - yb))
    r.row("y_star_vs_bruteforce_maxerr", worst, "closed form == grid min")

    # Figure 5 bottleneck-traffic reduction at the degraded rank (n=4, X=.5)
    n = 4
    prog_ring = ring_program(list(range(n)), n)
    prog_r2, plan = build_r2ccl_all_reduce(list(range(n)), 1, x=0.5, g=8)
    d = 1.0
    t_ring = bottleneck_traffic(prog_ring, d, 1)
    t_r2 = bottleneck_traffic(prog_r2, d, 1)
    r.row("degraded_rank_traffic_ring", t_ring, "x D (tx+rx)")
    r.row("degraded_rank_traffic_r2ccl", t_r2, "x D (tx+rx)")
    r.row("traffic_reduction", t_ring / t_r2, "paper Fig.5: 2D -> 1.75D regime")

    # predicted completion-time speedup at X=0.5 (serialized, faithful)
    r.row("speedup_x0.5_serialized", plan.speedup, "Appendix A model")
    # beyond-paper: overlapped stage-2 model
    y_ov = y_star_overlapped(0.5, n, 8)
    t_ov = total_time_overlapped(y_ov, 0.5, n, 8)
    r.row("speedup_x0.5_overlapped", plan.t_ring / t_ov, "stage-2 overlap")
    # the paper's measured regime: X = 0.125 (one of 8 NICs)
    y_ov = y_star_overlapped(0.125, 2, 8)
    t_ov = total_time_overlapped(y_ov, 0.125, 2, 8)
    frac = ring_coeff(16) / t_ov
    r.row("throughput_frac_x0.125_overlapped", frac,
          "paper Fig.15 measures 0.93")
    r.save()


if __name__ == "__main__":
    run()
