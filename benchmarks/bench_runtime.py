"""Closed-loop recovery runtime: per-stage failover breakdown (Sections 4-6).

Co-simulates the detect→diagnose→migrate→rebalance→replan control plane
with the discrete-event engine over the standard scenario campaigns (clean
NIC-down, correlated NIC-down, flap storm, slow-NIC spectrum,
failure-during-recovery) plus a seeded random multi-failure campaign.  For
each campaign it emits completion time/overhead, the recovery ledger total,
and one row per pipeline stage — the stage budget the paper's low-ms
hot-repair figure decomposes into.  The clean single-NIC-down ledger total
is checked against the alpha-beta ``R2CCL_MIGRATION_LATENCY`` constant
(conformance row: ratio must be within 2x).

Contention rows (``multi_stream_*``, ``nic_down_contended_*``,
``stream_priority_*``) co-simulate the DP gradient sync with concurrent
TP/PP streams on the shared NICs — healthy multi-stream conformance,
NIC-down with/without co-running traffic, and a stream-priority sweep.
"""

from __future__ import annotations

import numpy as np

from repro.core.comm_sim import NIC_200G, R2CCL_MIGRATION_LATENCY
from repro.core.event_sim import simulate_program
from repro.core.failures import random_failures
from repro.core.schedule import ring_program
from repro.core.telemetry import (
    ledger_entries_from_trace,
    ledger_total_from_trace,
)
from repro.core.topology import make_cluster
from repro.runtime import (
    Scenario,
    clean_nic_down,
    flap_storm,
    run_campaign,
    run_scenario,
    score_detections,
    slow_nic_degradation,
    standard_campaigns,
    standard_parallel_streams,
    standard_training_campaigns,
)

from .common import Reporter


def run(tiny: bool = False, seed: int = 0, trace: str | None = None) -> None:
    r = Reporter("runtime_recovery")
    servers, devices = (2, 4) if tiny else (4, 8)
    payload = 2e6 if tiny else 100e6
    r.data["seed"] = seed
    r.data["cluster"] = f"{servers}x{devices}"

    cluster = make_cluster(servers, devices, nic_bandwidth=NIC_200G)
    t_h = simulate_program(
        ring_program(list(range(servers)), servers), payload,
        cluster=cluster).completion_time
    r.row("healthy_ring_time", t_h, f"{servers}x{devices}, {payload:.3g}B")

    campaigns = standard_campaigns(t_h, num_nodes=servers, rails=devices)
    campaigns.append(Scenario(
        "random_multi", tuple(random_failures(
            2, servers, devices, seed=seed, at_time=0.3 * t_h)),
        note=f"seeded random 2-failure pattern (seed={seed})"))

    reps = {}
    for sc in campaigns:
        rep = reps[sc.name] = run_scenario(sc, cluster, payload,
                                           healthy_time=t_h)
        r.row(f"{sc.name}_completion_time", rep.report.completion_time,
              f"overhead={rep.overhead:.3%} "
              f"retrans={rep.report.retransmitted_bytes:.3g}B "
              f"replans={rep.report.replans} state={rep.final_state.value}")
        r.row(f"{sc.name}_ledger_total", rep.ledger.total_latency(),
              f"{len(rep.ledger.entries)} pipeline runs")
        for stage, v in rep.stage_totals.items():
            if v > 0:
                r.row(f"{sc.name}_stage_{stage}", v,
                      f"of {rep.ledger.total_latency():.3g}s ledger")

    # Conformance: the co-simulated clean-NIC-down pipeline vs the closed
    # form the alpha-beta mode still uses.
    clean = reps["clean_nic_down"]
    ratio = clean.failover_latency / R2CCL_MIGRATION_LATENCY
    r.row("clean_failover_vs_alpha_beta_constant", ratio,
          f"{clean.failover_latency * 1e3:.3f}ms vs "
          f"{R2CCL_MIGRATION_LATENCY * 1e3:.1f}ms; must be within 2x")

    # --- mid-collective replan: payload-conserving program swap -------------
    # A flap storm crosses the replan threshold while real payloads are in
    # flight; the chunk-map residual replan (PR 4) retains completed chunks
    # and resumes the rest, so the AllReduce stays exact through the swap.
    # The payload is scaled up so the collective outlives the ~1.7 ms replan
    # broadcast latency even at --tiny scale.
    replan_payload = 4e8
    t_r = simulate_program(
        ring_program(list(range(servers)), servers), replan_payload,
        cluster=cluster).completion_time
    rng = np.random.default_rng(seed)
    rank_data = [rng.normal(size=256) for _ in range(servers)]
    want = np.sum(np.stack(rank_data), axis=0)
    rrep = run_scenario(
        flap_storm(t_r, node=min(1, servers - 1), count=4), cluster,
        replan_payload, healthy_time=t_r, rank_data=rank_data)
    err = max(float(np.max(np.abs(np.asarray(d) - want)))
              for d in rrep.report.rank_data)
    evs = rrep.report.replan_events
    r.row("mid_replan_count", float(rrep.report.replans),
          f"program swaps while payload in flight ({replan_payload:.3g}B)")
    r.row("mid_replan_retrans_bytes", rrep.report.retransmitted_bytes,
          f"cancelled/rolled-back stream waste over {len(evs)} swap(s)")
    r.row("mid_replan_residual_fraction",
          evs[0].residual_fraction if evs else 0.0,
          "payload genuinely missing at the first swap (chunk map)")
    r.row("mid_replan_payload_max_error", err,
          "max |allreduce - oracle| through the swap; ~0 = lossless")

    # --- verified replans: static-analysis cost on the hot swap path --------
    # verify_replans=True routes every planner program and residual resume
    # program through repro.analysis.verify (abstract-interpretation
    # AllReduce proof + deadlock check) before instantiation.  Acceptance:
    # < 10% wall overhead on the mid-replan campaign.  Interleaved
    # min-over-reps so one-off scheduler noise cannot fake an overhead.
    import time as _time

    def _replan_wall(verify: bool):
        t0 = _time.perf_counter()
        rep = run_scenario(
            flap_storm(t_r, node=min(1, servers - 1), count=4), cluster,
            replan_payload, healthy_time=t_r, verify_replans=verify)
        return _time.perf_counter() - t0, rep

    walls = {False: [], True: []}
    brep = vrep = None
    for _ in range(5):
        for mode in (False, True):
            w, rep = _replan_wall(mode)
            walls[mode].append(w)
            if mode:
                vrep = rep
            else:
                brep = rep
    base_w, ver_w = min(walls[False]), min(walls[True])
    overhead = ver_w / base_w - 1.0
    r.row("mid_replan_verify_overhead", overhead,
          f"verified {ver_w * 1e3:.3g}ms vs base {base_w * 1e3:.3g}ms over "
          f"{vrep.report.replans} swap(s); acceptance < 10%")
    r.row("mid_replan_verified_equal",
          float(vrep.report.completion_time
                == brep.report.completion_time),
          "verification is observation-only: identical swap timeline")

    # --- concurrent TP/PP/DP streams sharing NICs (contention rows) ---------
    # Real training parallelism runs three collective streams at once over
    # the same fabric: the DP gradient sync, the TP activation AllReduce,
    # and the PP activation handoff.  The multi-stream engine co-simulates
    # them under weighted max-min fairness, so every row below prices the
    # recovery machinery in a *loaded* network instead of an empty one.
    specs = standard_parallel_streams(payload)
    sdata = [rng.normal(size=128) for _ in range(servers)]
    want_sum = np.sum(np.stack(sdata), axis=0)

    healthy_multi = run_scenario(
        Scenario("multi_stream_healthy", ()), cluster, payload,
        healthy_time=t_h, rank_data=sdata, streams=specs)
    dp_contended = healthy_multi.report.streams["dp"].completion_time
    r.row("multi_stream_healthy_dp_slowdown", dp_contended / t_h,
          "DP sync finish under TP+PP contention vs alone; >=1 by fairness")
    serr = max(
        max(float(np.max(np.abs(np.asarray(d) - want_sum)))
            for d in healthy_multi.report.streams[name].rank_data)
        for name in ("dp", "tp"))
    serr = max(serr, max(
        float(np.max(np.abs(np.asarray(d) - sdata[0])))
        for d in healthy_multi.report.streams["pp"].rank_data))
    r.row("multi_stream_payload_max_error", serr,
          "max per-stream |result - oracle| across DP/TP/PP; ~0 = exact")

    # NIC-down with vs without co-running streams: the same failure costs
    # more when the rebalanced capacity is shared with live TP/PP traffic.
    solo_fail = reps["clean_nic_down"].report.completion_time
    cont = run_scenario(clean_nic_down(t_h, node=min(1, servers - 1)),
                        cluster, payload, healthy_time=t_h, streams=specs)
    cont_dp = cont.report.streams["dp"].completion_time
    r.row("nic_down_contended_dp_time", cont_dp,
          f"DP sync under TP+PP contention; solo={solo_fail:.3g}s")
    r.row("nic_down_contention_ratio", cont_dp / solo_fail,
          "contended / solo NIC-down completion of the DP sync; >=1")

    # Stream-priority sweep: weighting the DP sync up must buy it back
    # bandwidth from the co-runners (weighted max-min fair share).
    hi = run_scenario(Scenario("multi_stream_prio", ()), cluster, payload,
                      healthy_time=t_h, streams=specs, priority=4.0)
    hi_dp = hi.report.streams["dp"].completion_time
    r.row("stream_priority_dp_speedup", dp_contended / hi_dp,
          "DP finish at priority 1x / priority 4x under contention; >1")

    # --- multi-iteration campaign sweep (paper Figs. 7-10 unit) -------------
    # N gradient syncs back-to-back through ONE persistent control plane:
    # flap counts, capacity factors, and replanned programs carry across
    # iterations, and the per-campaign recovery cost is the ledger total.
    iters = 3 if tiny else 8
    for tc in standard_training_campaigns(t_h, iterations=iters,
                                          num_nodes=servers):
        crep = run_campaign(tc, cluster, payload, healthy_time=t_h)
        r.row(f"{tc.name}_overhead", crep.overhead,
              f"{iters} iterations; ledger={crep.recovery_cost:.3g}s "
              f"replans={crep.replans} state={crep.final_state.value}")
        r.row(f"{tc.name}_ledger_total", crep.recovery_cost,
              f"{len(crep.ledger.entries)} pipeline runs across the campaign")

    # --- telemetry-inferred detection (oracle-free closed loop) -------------
    # The same campaigns with the oracle stripped: failures are silenced and
    # a TelemetryDetector must infer them from sampled counters + probe
    # bursts, feeding the identical pipeline with detected_by="monitor".
    # Payload is scaled so the 64-tick sampling period exceeds the oracle's
    # CQE detect latency — the monitor's cadence, not the clock resolution,
    # bounds its detection latency.  Rows report detection quality
    # (TP/FP/FN + latency) per scenario and the ledger<->trace
    # cross-validation bit.
    det_payload = 4e8 if tiny else 4e9
    t_d = simulate_program(
        ring_program(list(range(servers)), servers), det_payload,
        cluster=cluster).completion_time
    node = min(1, servers - 1)
    oracle = run_scenario(clean_nic_down(t_d, node=node), cluster,
                          det_payload, healthy_time=t_d)
    det_scens = [
        clean_nic_down(t_d, node=node),
        slow_nic_degradation(t_d, nodes=tuple(range(min(2, servers)))),
        flap_storm(t_d, node=node),
    ]
    clean_rep = None
    for sc in det_scens:
        rep = run_scenario(sc, cluster, det_payload, healthy_time=t_d,
                           detect="telemetry")
        if sc.name == "clean_nic_down":
            clean_rep = rep
        score = score_detections(rep.telemetry.trace.records)
        r.row(f"{sc.name}_detect_latency", score.mean_latency,
              f"tp={score.true_positives} fp={score.false_positives} "
              f"fn={score.false_negatives} max={score.max_latency:.3g}s "
              f"(sample period {t_d / 64:.3g}s)")
        r.row(f"{sc.name}_monitor_ledger_total", rep.ledger.total_latency(),
              f"{len(rep.ledger.entries)} monitor-detected pipeline runs; "
              f"state={rep.final_state.value}")

    records = clean_rep.telemetry.trace.records
    recon = ledger_entries_from_trace(records)
    match = (recon == [e.stages for e in clean_rep.ledger.entries]
             and abs(ledger_total_from_trace(records)
                     - clean_rep.ledger.total_latency()) < 1e-12)
    r.row("telemetry_trace_ledger_match", float(match),
          "every LedgerEntry stage reconstructed from the exported trace")
    mon_detect = clean_rep.ledger.entries[0].stages.get("detect", 0.0)
    orc_detect = oracle.ledger.entries[0].stages.get("detect", 0.0)
    r.row("monitor_vs_oracle_detect", mon_detect / orc_detect,
          f"monitor detect stage {mon_detect * 1e3:.3g}ms vs oracle "
          f"{orc_detect * 1e3:.3g}ms; >= 1 (no CQE shortcut)")

    if trace:
        clean_rep.telemetry.trace.write_jsonl(trace)
        clean_rep.telemetry.trace.write_chrome_trace(f"{trace}.chrome.json")
        r.row("trace_records", float(len(records)),
              f"JSONL at {trace}, Chrome trace at {trace}.chrome.json")
    r.save()


if __name__ == "__main__":
    run()
