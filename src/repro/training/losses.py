"""Per-modality training objectives."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cross_entropy


def task_loss(cfg: ModelConfig, logits: jnp.ndarray, batch) -> jnp.ndarray:
    """Next-token CE for text, prefix-offset CE for VLM, masked-unit
    prediction for audio encoders."""
    if cfg.modality.kind == "vision_text":
        p = cfg.modality.num_prefix_tokens
        t = batch["labels"].shape[1]
        # position P+i predicts text token i+1 (= labels[i])
        return cross_entropy(logits[:, p:p + t], batch["labels"])
    if cfg.modality.kind == "audio_frames":
        return cross_entropy(logits, batch["labels"],
                             mask=batch.get("loss_mask"))
    return cross_entropy(logits, batch["labels"])
