"""Gemma2-27B [dense] — local/global alternating attention + logit softcap.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000  [arXiv:2408.00118]
Sliding window 4096 on local layers; attention logit softcap 50, final
logit softcap 30.  (Gemma2's extra post-norms are folded into the pre-norm
formulation — noted in DESIGN.md.)
"""

from repro.configs.base import AttentionConfig, ModelConfig


CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=46,
    d_model=4608,
    d_ff=36864,
    vocab_size=256_000,
    attention=AttentionConfig(
        kind="gqa", num_heads=32, num_kv_heads=16, head_dim=128,
        rope_theta=10_000.0, sliding_window=4096, logit_softcap=50.0,
    ),
    block_pattern=("local_attn", "global_attn"),
    activation="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embedding_scale=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=384,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                                  head_dim=32, sliding_window=16,
                                  logit_softcap=50.0),
        block_pattern=("local_attn", "global_attn"),
        activation="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        embedding_scale=True,
        remat=False,
    )
