"""DeepSeek-67B [dense] — llama-architecture.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400  [arXiv:2401.02954]
"""

from repro.configs.base import AttentionConfig, ModelConfig


CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954",
    num_layers=95,
    d_model=8192,
    d_ff=22016,
    vocab_size=102_400,
    attention=AttentionConfig(
        kind="gqa", num_heads=64, num_kv_heads=8, head_dim=128,
        rope_theta=10_000.0,
    ),
    block_pattern=("attn",),
    activation="swiglu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=352,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=8, num_kv_heads=2,
                                  head_dim=16),
        block_pattern=("attn",),
        activation="swiglu",
        norm="rmsnorm",
        remat=False,
    )
