"""JAX execution of collective schedules (the data plane).

Executes the schedule IR from ``core.schedule`` inside ``shard_map`` using
``lax.ppermute`` — one ppermute per schedule round, with per-rank chunk
selection done via static index maps.  This is the TPU-native analogue of
the paper's NCCL channel execution: a ring "channel" becomes a chunked
ppermute pipeline over the mesh axis, and switching schedules (ring vs
R2CCL-AllReduce vs recursive) is a compile-time decision made by the
planner from the failure state — the analogue of pre-established backup
connections: every failure class's program is built (and jit-cached) ahead
of time, so nothing is re-planned on the failure path.

Public entry points:
  * ``execute_schedule`` / ``execute_program`` — run an IR program on a flat
    array inside an active shard_map context;
  * ``all_reduce``      — dispatching wrapper (xla | ring | r2ccl | recursive);
  * ``sync_gradients``  — pytree gradient synchronization used by
    ``training.train_step`` with ``sync="r2ccl"``.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .allreduce import build_r2ccl_all_reduce
from .recursive import build_recursive_all_reduce
from .schedule import (
    ChunkSchedule,
    CollectiveProgram,
    Segment,
    Step,
    build_ring_all_reduce,
    build_tree_all_reduce,
)


def _axis_size(axis_name: str) -> int:
    return lax.axis_size(axis_name)


def _dst_mask(step: Step, n: int) -> np.ndarray:
    m = np.zeros((n,), dtype=np.bool_)
    for _, d in step.perm:
        m[d] = True
    return m


def execute_schedule(x: jax.Array, sched: ChunkSchedule, axis_name: str) -> jax.Array:
    """Run one ChunkSchedule on a flat per-rank array ``x`` (inside shard_map).

    Returns the per-rank result (same shape as ``x``).
    """
    n = sched.n
    rank = lax.axis_index(axis_name)
    orig = x.shape[0]
    pad = (-orig) % sched.num_chunks
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    chunks = x.reshape(sched.num_chunks, -1)

    for step in sched.steps:
        dst_mask = jnp.asarray(_dst_mask(step, n))[rank]
        if step.whole_buffer:
            recv = lax.ppermute(chunks, axis_name, step.perm)
            if step.accumulate:
                # non-destinations receive zeros -> adding is a no-op
                chunks = chunks + recv
            else:
                chunks = jnp.where(dst_mask, recv, chunks)
        else:
            send_map = jnp.asarray(np.maximum(np.array(step.send_chunk), 0))
            recv_map = jnp.asarray(np.maximum(np.array(step.recv_chunk), 0))
            payload = jnp.take(chunks, send_map[rank], axis=0)
            recv = lax.ppermute(payload, axis_name, step.perm)
            ridx = recv_map[rank]
            cur = jnp.take(chunks, ridx, axis=0)
            new = cur + recv if step.accumulate else recv
            upd = jnp.where(dst_mask, new, cur)
            chunks = lax.dynamic_update_index_in_dim(chunks, upd, ridx, axis=0)

    out = chunks.reshape(-1)
    return out[:orig] if pad else out


def execute_program(x: jax.Array, prog: CollectiveProgram, axis_name: str) -> jax.Array:
    """Run a multi-segment program on a flat per-rank array."""
    total = x.shape[0]
    outs = []
    start = 0
    for i, seg in enumerate(prog.segments):
        end = total if i == len(prog.segments) - 1 else start + int(round(seg.frac * total))
        end = min(max(end, start), total)
        outs.append(execute_schedule(x[start:end], seg.schedule, axis_name))
        start = end
    return jnp.concatenate(outs) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
# Program cache + dispatching all_reduce
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _ring_program_cached(n: int) -> CollectiveProgram:
    return CollectiveProgram(
        "ring_all_reduce", n,
        [Segment(1.0, build_ring_all_reduce(list(range(n)), n))],
    )


@functools.lru_cache(maxsize=256)
def _tree_program_cached(n: int) -> CollectiveProgram:
    return CollectiveProgram(
        "tree_all_reduce", n,
        [Segment(1.0, build_tree_all_reduce(list(range(n)), n))],
    )


@functools.lru_cache(maxsize=256)
def _r2ccl_program_cached(n: int, degraded: int, x_pct: int, g: int) -> CollectiveProgram:
    prog, _ = build_r2ccl_all_reduce(
        list(range(n)), degraded, x=x_pct / 100.0, g=g)
    return prog


@functools.lru_cache(maxsize=64)
def _recursive_program_cached(bw_key: tuple[int, ...], g: int) -> CollectiveProgram:
    prog, _ = build_recursive_all_reduce([b / 100.0 for b in bw_key], g=g)
    return prog


def all_reduce(
    x: jax.Array,
    axis_name: str,
    *,
    mode: str = "xla",
    degraded: int | None = None,
    lost_fraction: float = 0.0,
    bandwidths: Sequence[float] | None = None,
    g: int = 8,
) -> jax.Array:
    """AllReduce over ``axis_name`` (must be a manual shard_map axis).

    mode:
      "xla"       — ``lax.psum`` (XLA's native collective; baseline);
      "ring"      — explicit chunked ring (the NCCL-equivalent schedule);
      "r2ccl"     — R2CCL-AllReduce for a single degraded node
                    (``degraded``, ``lost_fraction``);
      "recursive" — recursive decomposition over a ``bandwidths`` spectrum.

    Works on arrays of any shape (flattened internally).
    """
    n = _axis_size(axis_name)
    if mode == "xla" or n == 1:
        return lax.psum(x, axis_name)
    shape = x.shape
    flat = x.reshape(-1)
    if mode == "ring":
        prog = _ring_program_cached(n)
    elif mode == "tree":
        prog = _tree_program_cached(n)
    elif mode == "r2ccl":
        assert degraded is not None
        prog = _r2ccl_program_cached(n, degraded, int(round(lost_fraction * 100)), g)
    elif mode == "recursive":
        assert bandwidths is not None
        key = tuple(int(round(b * 100)) for b in bandwidths)
        prog = _recursive_program_cached(key, g)
    else:
        raise ValueError(f"unknown all_reduce mode {mode!r}")
    out = execute_program(flat, prog, axis_name)
    return out.reshape(shape)


def all_reduce_mean(x: jax.Array, axis_name: str, **kw) -> jax.Array:
    return all_reduce(x, axis_name, **kw) / _axis_size(axis_name)


def sync_gradients(grads, axis_name: str, *, mode: str = "ring",
                   degraded: int | None = None, lost_fraction: float = 0.0,
                   bandwidths: Sequence[float] | None = None, g: int = 8,
                   mean: bool = True):
    """Synchronize a gradient pytree across the data axis.

    Each leaf is flattened and run through the selected schedule.  With
    ``mode="xla"`` this is exactly ``psum``-mean; the other modes are the
    paper's explicit schedules — identical results (property-tested), but
    an explicit, failure-aware communication plan.
    """
    n = _axis_size(axis_name)

    def sync_leaf(leaf):
        out = all_reduce(leaf, axis_name, mode=mode, degraded=degraded,
                         lost_fraction=lost_fraction, bandwidths=bandwidths, g=g)
        return out / n if mean else out

    return jax.tree_util.tree_map(sync_leaf, grads)
