"""Telemetry-inferred detection: the oracle-free recovery loop.

The acceptance scenario: a NIC dies *silently* (the engine applies the
physics but never notifies the controller), and the TelemetryDetector —
consuming only sampled counters and active probes — must localize it and
drive the existing ControlPlane pipeline to a completed recovery, with a
measured detection latency no better than the oracle path's charged
detection and the whole ledger reconstructible from the exported trace.
"""

import pytest

from repro.core.detection import CQE_ERROR_DELAY
from repro.core.event_sim import simulate_program
from repro.core.failures import FailureType, silenced
from repro.core.schedule import ring_program
from repro.core.telemetry import (
    ledger_entries_from_trace,
    ledger_total_from_trace,
)
from repro.core.topology import make_cluster
from repro.runtime import (
    DetectorConfig,
    RecoveryState,
    Scenario,
    clean_nic_down,
    flap_storm,
    run_scenario,
    score_detections,
)
from repro.runtime.control_plane import SLOW_NIC_DETECT_LATENCY

#: payload sized so the 64-tick sampling period (~t_h/64) exceeds the
#: oracle's CQE detect latency: the monitor's cadence, not the virtual
#: clock, bounds how fast it can possibly notice anything
PAYLOAD = 4e9


@pytest.fixture(scope="module")
def cluster():
    return make_cluster(4, 8)


@pytest.fixture(scope="module")
def t_h(cluster):
    return simulate_program(ring_program(list(range(4)), 4), PAYLOAD,
                            cluster=cluster).completion_time


def test_detector_config_validation():
    with pytest.raises(ValueError, match="drop_threshold"):
        DetectorConfig(drop_threshold=0.0)
    with pytest.raises(ValueError, match="drop_threshold"):
        DetectorConfig(drop_threshold=1.0)
    with pytest.raises(ValueError, match="consecutive"):
        DetectorConfig(consecutive=0)
    with pytest.raises(ValueError, match="warmup_samples"):
        DetectorConfig(warmup_samples=0)
    with pytest.raises(ValueError, match="recover_threshold"):
        DetectorConfig(recover_threshold=1.5)
    DetectorConfig()   # defaults valid


def test_silenced_failures_skip_controller(cluster, t_h):
    """A silent failure reaches the physics but never the control plane."""
    rep = run_scenario(
        Scenario("silent", tuple(silenced(clean_nic_down(t_h).failures))),
        cluster, PAYLOAD, healthy_time=t_h)
    assert rep.ledger.entries == []          # oracle adapter never consulted
    assert rep.report.failovers > 0          # but the rollback physics ran
    assert rep.overhead > 0.0


def test_oracle_free_nic_down_completes_recovery(cluster, t_h):
    """THE acceptance scenario: no oracle failure event, recovery completes
    through the existing ControlPlane pipeline, detection latency is no
    better than the oracle's, and the ledger is trace-reconstructible."""
    oracle = run_scenario(clean_nic_down(t_h), cluster, PAYLOAD,
                          healthy_time=t_h)
    rep = run_scenario(clean_nic_down(t_h), cluster, PAYLOAD,
                       healthy_time=t_h, detect="telemetry")

    # the detector inferred the failure and the pipeline ran to completion
    assert len(rep.detections) >= 1
    det = rep.detections[0]
    assert det.failure.ftype is FailureType.NIC_HARDWARE
    assert det.outcome is not None
    entry = det.outcome.entry
    assert entry.detected_by == "monitor"
    assert entry.total == pytest.approx(sum(entry.stages.values()))
    assert rep.report.completion_time > t_h    # degraded but finished
    assert rep.final_state in (RecoveryState.REPLANNED, RecoveryState.HEALTHY)

    # detection quality scored from the trace alone
    score = score_detections(rep.telemetry.trace.records)
    assert score.true_positives >= 1
    assert score.false_positives == 0

    # detection latency >= the oracle path's: the sampling cadence bounds
    # the trace-measured latency, and the pipeline's charged detect stage
    # has no CQE shortcut
    assert entry.stages["detect"] >= SLOW_NIC_DETECT_LATENCY
    assert entry.stages["detect"] > oracle.ledger.entries[0].stages["detect"]
    end_to_end = score.mean_latency + entry.stages["detect"]
    oracle_detect = oracle.ledger.entries[0].stages["detect"]
    assert end_to_end >= oracle_detect >= CQE_ERROR_DELAY

    # ledger <-> trace cross-validation on the full monitor-driven run
    records = rep.telemetry.trace.records
    assert ledger_entries_from_trace(records) == [
        e.stages for e in rep.ledger.entries]
    assert ledger_total_from_trace(records) == pytest.approx(
        rep.ledger.total_latency())


def test_healthy_run_no_false_positives(cluster, t_h):
    rep = run_scenario(Scenario("healthy", ()), cluster, PAYLOAD,
                       healthy_time=t_h, detect="telemetry")
    assert rep.detections == []
    score = score_detections(rep.telemetry.trace.records)
    assert score.false_positives == 0
    assert score.true_positives == 0
    assert rep.overhead == pytest.approx(0.0, abs=1e-9)


def test_flap_storm_detect_and_clear(cluster, t_h):
    """Silent flaps: the stream-stall trigger catches the hard down windows
    and the recovery watch clears each inference when probes measure the
    bandwidth back — the run must end HEALTHY, not stuck degraded."""
    rep = run_scenario(flap_storm(t_h), cluster, PAYLOAD,
                       healthy_time=t_h, detect="telemetry")
    score = score_detections(rep.telemetry.trace.records)
    assert score.true_positives >= 1
    assert score.false_positives == 0
    assert any(ev.cleared for ev in rep.detections)
    assert rep.final_state is RecoveryState.HEALTHY
    assert all(lat >= 0.0 for lat in score.latencies)


def test_detect_mode_rejects_unknown_channel(cluster, t_h):
    with pytest.raises(ValueError, match="detect"):
        run_scenario(Scenario("x", ()), cluster, PAYLOAD,
                     healthy_time=t_h, detect="psychic")


def test_score_detections_synthetic():
    records = [
        {"type": "failure", "t": 1.0, "node": 0, "rail": 0},
        {"type": "detection", "t": 1.5, "node": 0, "rail": 0},   # match
        {"type": "detection", "t": 2.0, "node": 3, "rail": 1},   # FP
        {"type": "failure", "t": 4.0, "node": 2, "rail": 0},     # FN
        {"type": "recovery", "t": 5.0, "node": 2, "rail": 0},
    ]
    score = score_detections(records)
    assert score.true_positives == 1
    assert score.false_positives == 1
    assert score.false_negatives == 1
    assert score.latencies == [pytest.approx(0.5)]
    assert score.mean_latency == pytest.approx(0.5)
    assert score.max_latency == pytest.approx(0.5)
