from .adamw import AdamWConfig, adamw_update, clip_by_global_norm, global_norm, init_opt_state  # noqa: F401
from .schedules import constant, cosine_with_warmup  # noqa: F401
