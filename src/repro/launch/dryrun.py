import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: for each
assigned architecture and input shape, ``train_step`` / ``serve_step`` is
jit-lowered with production shardings on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh, compiled, and the compiled artifact's
memory/cost/collective analysis is written to ``experiments/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only|--single-only]
  python -m repro.launch.dryrun --arch X --shape Y --sync r2ccl
"""

import argparse
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.core.planner import CommConfig
from repro.launch import sharding as SH
from repro.launch.hlo_analysis import (
    HBM_PER_CHIP,
    model_flops,
    parse_collectives,
    roofline_terms,
)
from repro.launch.mesh import data_axis_names, make_production_mesh, rules_for
from repro.models import apply_model, get_config, init_caches, init_model
from repro.models.registry import list_architectures
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step
from repro.training.train_step import TrainState

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# skip rules (recorded in DESIGN.md)
# ---------------------------------------------------------------------------

def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    if cfg.encoder_only and shape.mode == "decode":
        return "encoder-only architecture has no decode step"
    return None


def long_context_window(cfg: ModelConfig, shape: InputShape) -> int | None:
    """Sliding-window substitution for dense archs at 500k (sub-quadratic
    requirement); native-state archs (ssm/hybrid/MLA) need no override."""
    if shape.name != "long_500k":
        return None
    if cfg.family in ("ssm", "hybrid"):
        return None                      # recurrent state / local attn native
    if cfg.attention is not None and cfg.attention.kind == "mla":
        return None                      # latent cache is linear in context
    return cfg.long_context_window


def cache_context_len(cfg: ModelConfig, shape: InputShape) -> int:
    w = long_context_window(cfg, shape)
    if w is not None:
        return w
    if cfg.attention is not None and cfg.attention.kind == "mla":
        return shape.seq_len
    if cfg.family in ("ssm",):
        return 1                         # state caches ignore this
    return shape.seq_len


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, jax.ShapeDtypeStruct]:
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if cfg.modality.kind == "audio_frames":
        batch = {
            "frames": jax.ShapeDtypeStruct((B, T, cfg.modality.frontend_dim), f32),
            "labels": jax.ShapeDtypeStruct((B, T), i32),
            "loss_mask": jax.ShapeDtypeStruct((B, T), f32),
        }
    elif cfg.modality.kind == "vision_text":
        Ppre = cfg.modality.num_prefix_tokens
        tlen = max(T - Ppre, 1)
        batch = {
            "patches": jax.ShapeDtypeStruct((B, Ppre, cfg.modality.frontend_dim), f32),
            "tokens": jax.ShapeDtypeStruct((B, tlen), i32),
            "labels": jax.ShapeDtypeStruct((B, tlen), i32),
        }
    else:
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
            "labels": jax.ShapeDtypeStruct((B, T), i32),
        }
    if shape.mode == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if shape.mode == "prefill":
        batch.pop("labels", None)
        batch.pop("loss_mask", None)
    return batch


def abstract_state(cfg: ModelConfig):
    def init():
        params, _ = init_model(jax.random.PRNGKey(0), cfg)
        return init_train_state(params)
    return jax.eval_shape(init)


def abstract_caches(cfg: ModelConfig, shape: InputShape):
    ctx = cache_context_len(cfg, shape)
    w = long_context_window(cfg, shape)
    return jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, ctx, window_override=w))


# ---------------------------------------------------------------------------
# one dry-run
# ---------------------------------------------------------------------------

def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               sync: str = "xla", comm: CommConfig | None = None,
               sharding_mode: str = "auto", verbose: bool = True,
               correct_scan: bool = True,
               cfg_override: ModelConfig | None = None) -> dict[str, Any]:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    result: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "sync": sync if shape.mode == "train" else "n/a",
    }
    if reason:
        result["skipped"] = reason
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_for(cfg, sharding_mode)
    baxes = data_axis_names(mesh)

    t0 = time.time()
    params_shape, axes = _eval_init(cfg)
    state_shape = jax.eval_shape(lambda: init_train_state(params_shape))
    pspecs = SH.param_pspecs(mesh, rules, axes, params_shape)
    state_specs = TrainState(
        params=pspecs,
        opt_state={"mu": pspecs, "nu": pspecs, "count": P()},
        step=P(),
    )
    batch = input_specs(cfg, shape)
    bspecs = SH.batch_pspecs(mesh, batch, baxes)

    if shape.mode == "train":
        step_fn = make_train_step(
            cfg, AdamWConfig(), sync=sync, comm=comm, mesh=mesh,
            data_axes=baxes)
        jitted = jax.jit(
            step_fn,
            in_shardings=(SH.named(mesh, state_specs), SH.named(mesh, bspecs)),
            out_shardings=(SH.named(mesh, state_specs), None),
        )
        args = (state_shape, batch)
        tokens = shape.global_batch * shape.seq_len
        mode = "train"
    else:
        caches = abstract_caches(cfg, shape)
        cspecs = SH.cache_pspecs(mesh, caches, baxes)
        w = long_context_window(cfg, shape)

        if shape.mode == "prefill":
            def serve_step(params, batch, caches):
                logits, caches, _ = apply_model(params, cfg, batch,
                                                mode="prefill", caches=caches,
                                                window_override=w)
                return jnp.argmax(logits[:, -1], -1), caches
            jitted = jax.jit(
                serve_step,
                in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, bspecs),
                              SH.named(mesh, cspecs)),
                out_shardings=(None, SH.named(mesh, cspecs)),
            )
            args = (params_shape, batch, caches)
            tokens = shape.global_batch * shape.seq_len
            mode = "prefill"
        else:
            def serve_step(params, tokens_in, caches):
                logits, caches, _ = apply_model(params, cfg,
                                                {"tokens": tokens_in},
                                                mode="decode", caches=caches,
                                                window_override=w)
                return jnp.argmax(logits[:, -1], -1), caches
            jitted = jax.jit(
                serve_step,
                in_shardings=(SH.named(mesh, pspecs),
                              SH.named(mesh, bspecs["tokens"]),
                              SH.named(mesh, cspecs)),
                out_shardings=(None, SH.named(mesh, cspecs)),
            )
            args = (params_shape, batch["tokens"], caches)
            tokens = shape.global_batch          # one token per sequence
            mode = "decode"

    with jax.set_mesh(mesh):          # with_sharding_constraint(P) support
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    wire_dev = coll.wire_bytes
    coll_op_bytes = dict(coll.op_bytes)

    # --- scan-trip-count correction -------------------------------------
    # XLA's cost_analysis counts a while-loop (lax.scan) body ONCE, not
    # x trip_count.  We recover the per-group body cost from two reduced
    # compiles (1 group and 2 groups of the layer pattern) and extrapolate:
    #   cost(G groups) = cost_raw + (G - 1) * (cost_2g - cost_1g).
    from repro.models.transformer import _pattern_split
    import dataclasses as _dc
    n_groups, pattern, _rem = _pattern_split(cfg)
    scan_corrected = False
    if correct_scan and n_groups > 1:
        lead = cfg.moe.first_k_dense if (cfg.moe and cfg.moe.first_k_dense) else 0
        plen = len(pattern)
        sub = {}
        for gname, groups in (("g1", 1), ("g2", 2)):
            # unrolled (scan_layers=False) so cost_analysis sees each group
            sub_cfg = _dc.replace(cfg, num_layers=lead + groups * plen,
                                  scan_layers=False,
                                  name=f"{cfg.name}-{gname}")
            sub[gname] = dryrun_one(
                arch, shape_name, multi_pod=multi_pod, sync=sync, comm=comm,
                sharding_mode=sharding_mode, verbose=False,
                correct_scan=False, cfg_override=sub_cfg)
        def _body(metric):
            return max(sub["g2"][metric] - sub["g1"][metric], 0.0)
        flops_dev += (n_groups - 1) * _body("flops_per_device")
        bytes_dev += (n_groups - 1) * _body("hbm_bytes_per_device")
        wire_dev += (n_groups - 1) * _body("wire_bytes_per_device")
        for k in coll_op_bytes:
            delta = max(sub["g2"]["collective_op_bytes"].get(k, 0.0)
                        - sub["g1"]["collective_op_bytes"].get(k, 0.0), 0.0)
            coll_op_bytes[k] += (n_groups - 1) * delta
        scan_corrected = True
    terms = roofline_terms(
        flops_per_device=flops_dev,
        hbm_bytes_per_device=bytes_dev,
        wire_bytes_per_device=wire_dev,
        chips=chips,
    )
    mflops = model_flops(cfg, tokens, "train" if mode == "train" else "infer")

    result.update({
        "chips": chips,
        "mode": mode,
        "scan_corrected": scan_corrected,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": bytes_dev,
        "collective_op_bytes": coll_op_bytes,
        "collective_op_counts": coll.op_counts,
        "wire_bytes_per_device": wire_dev,
        "roofline": terms,
        "model_flops_global": mflops,
        "useful_flops_ratio": (mflops / (flops_dev * chips)) if flops_dev else None,
        "memory_analysis": _mem_dict(mem),
        "fits_hbm": (_mem_dict(mem).get("total_bytes", 0) <= HBM_PER_CHIP
                     if mem else None),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    })
    if verbose:
        r = terms
        print(f"[{arch} x {shape_name} x {result['mesh']}] mode={mode} "
              f"compile={t_compile:.0f}s compute={r['compute_s']*1e3:.2f}ms "
              f"mem={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
              f"-> {r['bottleneck']}")
    return result


_EVAL_CACHE: dict[str, Any] = {}


def _eval_init(cfg):
    """(params ShapeDtypeStructs, logical-axes pytree) without allocation."""
    if cfg.name in _EVAL_CACHE:
        return _EVAL_CACHE[cfg.name]
    holder = {}

    def capture():
        params, axes = init_model(jax.random.PRNGKey(0), cfg)
        holder["axes"] = axes            # static strings, safe to capture
        return params

    params_shape = jax.eval_shape(capture)
    _EVAL_CACHE[cfg.name] = (params_shape, holder["axes"])
    return _EVAL_CACHE[cfg.name]


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    args = out.get("argument_size_in_bytes", 0)
    out["total_bytes"] = (args + out.get("temp_size_in_bytes", 0)
                          + out.get("output_size_in_bytes", 0)
                          - out.get("alias_size_in_bytes", 0))
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sync", default="xla", choices=["xla", "r2ccl"])
    ap.add_argument("--comm-mode", default="ring",
                    choices=["xla", "ring", "r2ccl", "recursive"])
    ap.add_argument("--degraded-rank", type=int, default=None)
    ap.add_argument("--lost-fraction", type=float, default=0.0)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="perf-iteration config variant, e.g. "
                         "'expert_axis=model' or 'sharding=fsdp_tp' or "
                         "'remat=false' (comma-separated)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [a for a in list_architectures() if a != "paper-7b"] \
        if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    comm = None
    if args.sync == "r2ccl":
        comm = CommConfig(mode=args.comm_mode, degraded_rank=args.degraded_rank,
                          lost_fraction=args.lost_fraction)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}__{args.sync}"
                sharding_mode = "auto"
                cfg_override = None
                if args.variant:
                    import dataclasses as _dc
                    cfg_override = get_config(arch)
                    for kv in args.variant.split(","):
                        k, v = kv.split("=")
                        if k == "expert_axis" and cfg_override.moe:
                            cfg_override = _dc.replace(
                                cfg_override,
                                moe=_dc.replace(cfg_override.moe, expert_axis=v))
                        elif k == "sharding":
                            sharding_mode = v
                        elif k == "remat":
                            cfg_override = _dc.replace(
                                cfg_override, remat=v.lower() == "true")
                        else:
                            raise SystemExit(f"unknown variant key {k}")
                    tag += "__" + args.variant.replace("=", "-").replace(",", "_")
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                try:
                    res = dryrun_one(arch, shape, multi_pod=mp,
                                     sync=args.sync, comm=comm,
                                     sharding_mode=sharding_mode,
                                     cfg_override=cfg_override)
                    res["variant"] = args.variant
                except Exception as e:  # noqa: BLE001 — record and continue
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    failures.append(tag)
                    print(f"[FAIL] {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
