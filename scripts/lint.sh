#!/usr/bin/env bash
# Static-analysis gate: determinism lint + schedule verifier.
#
#   scripts/lint.sh              # lint src/repro/{core,runtime,analysis,
#                                # serving}, then verify the full builder
#                                # corpus
#   scripts/lint.sh <paths...>   # lint only the given files/dirs (the
#                                # verifier still runs over the corpus)
#
# The lint (repro.analysis.lint) forbids nondeterminism in simulator code:
# wall-clock reads, unseeded RNGs, bare-set iteration, float == on
# timestamps, frozen-dataclass mutation (rules DET001–DET005).  The
# verifier (repro.analysis.verify) proves every builder schedule computes
# its collective and cannot deadlock.  Both exit non-zero on any finding.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m repro.analysis lint "$@"
python -m repro.analysis verify
