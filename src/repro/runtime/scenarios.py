"""Timed multi-failure campaign DSL for the recovery runtime.

A :class:`Scenario` is a named list of timed :class:`core.failures.Failure`
events to inject into one co-simulated collective.  Campaign builders take
the healthy collective time ``t_h`` so injection points land mid-collective
regardless of payload/cluster scale, and :func:`parse_campaign` accepts a
compact textual spec for ad-hoc campaigns from benchmark CLIs and tests::

    nic_down node=1 rail=0 at=0.4; flap node=2 rail=1 at=0.2 down=0.05

Event kinds: ``nic_down`` (hard NIC death), ``flap`` (down then recovers
after ``down``), ``flaps`` (a storm: ``count`` flaps ``period`` apart),
``slow`` (bandwidth spectrum point, ``lost`` fraction).  All times are
fractions of ``t_scale`` (pass the healthy time to express campaign timing
relative to the collective).
"""

from __future__ import annotations

import dataclasses

from repro.core.failures import (
    Failure,
    flap_sequence,
    link_flap,
    nic_down_at,
    slow_nic,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named failure-injection campaign."""

    name: str
    failures: tuple[Failure, ...]
    note: str = ""

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "failures",
            tuple(sorted(self.failures, key=lambda f: f.at_time)))


# ---------------------------------------------------------------------------
# campaign builders
# ---------------------------------------------------------------------------

def clean_nic_down(t_h: float, *, node: int = 1, rail: int = 0,
                   frac: float = 0.4) -> Scenario:
    """The paper's headline case: one NIC dies mid-collective, hot repair
    lands it on the backup NIC within the low-millisecond budget."""
    return Scenario(
        "clean_nic_down",
        (nic_down_at(node, rail, frac * t_h),),
        note="single hard NIC death mid-collective (conformance target)")


def correlated_nic_down(t_h: float, *, node: int = 1, rails: tuple[int, ...] = (0, 1),
                        frac: float = 0.35, stagger: float = 0.01) -> Scenario:
    """Several NICs of one node die almost together (shared PCIe riser /
    firmware bug): each loss re-runs the pipeline against a shrinking
    backup chain."""
    fails = tuple(
        nic_down_at(node, r, (frac + i * stagger) * t_h)
        for i, r in enumerate(rails))
    return Scenario("correlated_nic_down", fails,
                    note=f"{len(rails)} rails of node {node} die {stagger:.0%} apart")


def flap_storm(t_h: float, *, node: int = 1, rail: int = 0, count: int = 4,
               start_frac: float = 0.15, period_frac: float = 0.18,
               down_frac: float = 0.06) -> Scenario:
    """Repeated link flaps of one NIC; past the flap threshold the control
    plane stops re-migrating and re-plans the algorithm instead."""
    fails = tuple(flap_sequence(
        node, rail, start=start_frac * t_h, period=period_frac * t_h,
        down_for=down_frac * t_h, count=count))
    return Scenario("flap_storm", fails,
                    note=f"{count} flaps, replan after the threshold")


def slow_nic_degradation(t_h: float, *, nodes: tuple[int, ...] = (0, 1),
                         base_lost: float = 0.2, step: float = 0.15,
                         frac: float = 0.1) -> Scenario:
    """A bandwidth spectrum: NICs on several nodes degrade (no transport
    error) — caught by monitoring, handled by rebalance alone."""
    fails = tuple(
        slow_nic(nd, 0, frac * t_h, lost_fraction=min(0.9, base_lost + i * step))
        for i, nd in enumerate(nodes))
    return Scenario("slow_nic", fails,
                    note="fractional degradation, monitor-detected")


def failure_during_recovery(t_h: float, *, first_node: int = 1,
                            second_node: int = 2, rail: int = 0,
                            frac: float = 0.3, gap: float = 0.7e-3) -> Scenario:
    """A second hard failure strikes while the first one's hot repair is
    still in flight (rolled-back transfers not yet restarted) — the pipeline
    must compose, not serialize."""
    t1 = frac * t_h
    return Scenario(
        "failure_during_recovery",
        (nic_down_at(first_node, rail, t1),
         nic_down_at(second_node, rail, t1 + gap)),
        note=f"second failure {gap * 1e3:.1f} ms into the first repair window")


def standard_campaigns(t_h: float, *, num_nodes: int, rails: int) -> list[Scenario]:
    """The benchmark/acceptance campaign set, scaled to the cluster shape."""
    second = 2 if num_nodes > 2 else 0     # distinct from the first node
    campaigns = [
        clean_nic_down(t_h, node=min(1, num_nodes - 1)),
        flap_storm(t_h, node=min(1, num_nodes - 1)),
        slow_nic_degradation(t_h, nodes=tuple(range(min(2, num_nodes)))),
        failure_during_recovery(t_h, first_node=min(1, num_nodes - 1),
                                second_node=second),
    ]
    if rails >= 2:
        campaigns.insert(1, correlated_nic_down(
            t_h, node=min(1, num_nodes - 1), rails=(0, 1)))
    return campaigns


# ---------------------------------------------------------------------------
# textual campaign spec
# ---------------------------------------------------------------------------

_EVENT_KINDS = ("nic_down", "flap", "flaps", "slow")


def parse_campaign(name: str, spec: str, *, t_scale: float = 1.0) -> Scenario:
    """Parse ``spec`` into a Scenario.

    ``spec`` is ';'-separated events, each ``kind k=v k=v ...``; time-like
    fields (``at``, ``down``, ``period``) are multiplied by ``t_scale``::

        parse_campaign("mix", "nic_down node=1 rail=0 at=0.4; "
                              "flaps node=2 rail=1 at=0.1 down=0.05 "
                              "period=0.2 count=3; "
                              "slow node=0 rail=0 at=0 lost=0.3", t_scale=t_h)
    """
    failures: list[Failure] = []
    for raw in spec.split(";"):
        raw = raw.strip()
        if not raw:
            continue
        parts = raw.split()
        kind, kv = parts[0], {}
        if kind not in _EVENT_KINDS:
            raise ValueError(
                f"unknown event kind {kind!r} (expected one of {_EVENT_KINDS})")
        for tok in parts[1:]:
            if "=" not in tok:
                raise ValueError(f"malformed field {tok!r} in event {raw!r}")
            k, v = tok.split("=", 1)
            kv[k] = float(v)
        node, rail = int(kv.pop("node")), int(kv.pop("rail"))
        at = kv.pop("at", 0.0) * t_scale
        if kind == "nic_down":
            failures.append(nic_down_at(node, rail, at))
        elif kind == "flap":
            failures.append(link_flap(node, rail, at, kv.pop("down") * t_scale))
        elif kind == "flaps":
            failures.extend(flap_sequence(
                node, rail, start=at, period=kv.pop("period") * t_scale,
                down_for=kv.pop("down") * t_scale, count=int(kv.pop("count"))))
        elif kind == "slow":
            failures.append(slow_nic(node, rail, at, lost_fraction=kv.pop("lost")))
        if kv:
            raise ValueError(f"unexpected fields {sorted(kv)} in event {raw!r}")
    return Scenario(name, tuple(failures), note=spec)
