"""Static analysis over the collective-schedule IR and the simulator.

Four passes:

* :mod:`repro.analysis.verify` — schedule verifier: legality, abstract
  interpretation over contribution multisets (AllReduce / Reduce /
  ReduceScatter / AllGather / Broadcast proofs), and deadlock-freedom of
  the per-rank lockstep dependency graph.
* :mod:`repro.analysis.lint` — AST determinism lint over ``core/``,
  ``runtime/``, ``analysis/`` and ``serving/`` (rules DET001–DET005).
* :mod:`repro.analysis.cost` — static cost analysis: per-round per-link
  byte loads folded through the engine's own max-min fair share into a
  closed-form completion time, bit-exact against the event simulator for
  uncontended lockstep schedules.
* :mod:`repro.analysis.coverage` — static failure coverage: for every
  single NIC/rail failure, decide survivability and bound the degraded
  completion time without simulating the failure.

Run the CI gate from the command line: ``python -m repro.analysis``
(verify + lint), or ``python -m repro.analysis cost --corpus`` /
``coverage`` for the conformance and survivability sweeps.
"""

from .errors import (
    CoverageError,
    DataflowError,
    DeadlockError,
    DoubleReduceError,
    ProgramError,
    Provenance,
    ResultError,
    ResultRanksError,
    ScheduleError,
    StaleReadError,
    StepLegalityError,
)
from .verify import (
    Semantics,
    VerifyReport,
    check_deadlock_free,
    check_program,
    check_schedule,
    check_step,
    clear_memos,
    infer_semantics,
    memo_stats,
    verify_program,
    verify_schedule,
)
from .lint import DEFAULT_LINT_TARGETS, LintFinding, lint_paths, lint_source
from .cost import (
    CORPUS_COST_TOLERANCE,
    CostReport,
    Hotspot,
    LinkLoad,
    analyze_program,
    analyze_schedule,
    as_program,
)
from .coverage import (
    CoverageEntry,
    CoverageReport,
    analyze_coverage,
    check_coverage,
)

__all__ = [
    "CoverageError",
    "DataflowError",
    "DeadlockError",
    "DoubleReduceError",
    "ProgramError",
    "Provenance",
    "ResultError",
    "ResultRanksError",
    "ScheduleError",
    "StaleReadError",
    "StepLegalityError",
    "Semantics",
    "VerifyReport",
    "check_deadlock_free",
    "check_program",
    "check_schedule",
    "check_step",
    "clear_memos",
    "infer_semantics",
    "memo_stats",
    "verify_program",
    "verify_schedule",
    "DEFAULT_LINT_TARGETS",
    "LintFinding",
    "lint_paths",
    "lint_source",
    "CORPUS_COST_TOLERANCE",
    "CostReport",
    "Hotspot",
    "LinkLoad",
    "analyze_program",
    "analyze_schedule",
    "as_program",
    "CoverageEntry",
    "CoverageReport",
    "analyze_coverage",
    "check_coverage",
]
