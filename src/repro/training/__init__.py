from .checkpoint import latest_step, restore_checkpoint, save_checkpoint  # noqa: F401
from .losses import task_loss  # noqa: F401
from .train_step import (  # noqa: F401
    TrainState,
    compute_loss,
    init_train_state,
    make_eval_step,
    make_train_step,
)
