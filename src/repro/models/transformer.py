"""Model assembly: pattern-based block stacks for all 6 architecture types.

A model is ``embedding -> [pattern block groups] -> final norm -> unembed``
where the repeating pattern (e.g. ``("rglru","rglru","local_attn")`` for
RecurrentGemma, ``("local_attn","global_attn")`` for Gemma-2) is scanned
over ``num_groups`` repeats with stacked parameters — keeping the lowered
HLO one-pattern-group sized regardless of depth.  Remainder layers (depth
not divisible by the pattern) run unscanned.

Modes: ``train`` (full sequence, logits everywhere), ``prefill`` (build
caches, logits at last position), ``decode`` (one token + caches).
Caches are pytrees compatible with ``lax.scan`` slicing.

[vlm]/[audio] frontends are stubs per the task carve-out: the model
consumes precomputed patch/frame embeddings via a linear projector.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import rglru as RG
from . import rwkv6 as RW


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_ffn(key, cfg: ModelConfig, layer_idx: int):
    """Dense MLP or MoE depending on config + first_k_dense."""
    use_moe = (cfg.moe is not None and cfg.moe.num_experts > 0
               and layer_idx >= (cfg.moe.first_k_dense if cfg.moe else 0))
    if use_moe:
        p, a = MOE.init_moe(key, cfg.d_model, cfg.moe.expert_d_ff or cfg.d_ff,
                            cfg.moe.num_experts, cfg.moe.num_shared_experts,
                            cfg.activation)
        return ("moe", p, a)
    p, a = L.init_mlp(key, cfg.d_model, cfg.d_ff, cfg.activation)
    return ("mlp", p, a)


def _init_layer(key, cfg: ModelConfig, kind: str, layer_idx: int):
    ks = jax.random.split(key, 4)
    norm_init, _ = L.make_norm(cfg.norm, cfg.d_model)
    params: dict[str, Any] = {"norm1": norm_init[0]}
    axes: dict[str, Any] = {"norm1": norm_init[1]}

    if kind in ("attn", "local_attn", "global_attn"):
        a = cfg.attention
        if a.kind == "mla":
            p, ax = MLA.init_mla(ks[0], cfg.d_model, a.num_heads,
                                 q_lora_rank=a.q_lora_rank,
                                 kv_lora_rank=a.kv_lora_rank,
                                 qk_nope_head_dim=a.qk_nope_head_dim,
                                 qk_rope_head_dim=a.qk_rope_head_dim,
                                 v_head_dim=a.v_head_dim)
        else:
            p, ax = L.init_gqa(ks[0], cfg.d_model, a.num_heads,
                               a.num_kv_heads, a.head_dim)
        params["attn"], axes["attn"] = p, ax
    elif kind == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        p, ax = RG.init_rglru_block(ks[0], cfg.d_model, w, cfg.rglru.conv_width)
        params["rglru"], axes["rglru"] = p, ax
    elif kind == "rwkv":
        p, ax = RW.init_rwkv_block(ks[0], cfg.d_model, cfg.rwkv.head_size,
                                   cfg.rwkv.decay_lora, cfg.rwkv.tokenshift_lora)
        params["rwkv"], axes["rwkv"] = p, ax
        return params, axes          # rwkv block includes channel-mix
    else:
        raise ValueError(kind)

    n2, _ = L.make_norm(cfg.norm, cfg.d_model)
    params["norm2"], axes["norm2"] = n2
    ftype, fp, fa = _init_ffn(ks[1], cfg, layer_idx)
    params[ftype], axes[ftype] = fp, fa
    return params, axes


def _apply_layer(params, cfg: ModelConfig, kind: str, x, *, cache, mode,
                 prefix_len=None, window_override=None):
    """Returns (x_out, new_cache, aux_loss)."""
    _, norm_fn = L.make_norm(cfg.norm, cfg.d_model)
    aux = jnp.zeros((), jnp.float32)
    h = norm_fn(params["norm1"], x)

    if kind in ("attn", "local_attn", "global_attn"):
        a = cfg.attention
        if kind == "local_attn":
            window = a.sliding_window
        elif kind == "global_attn":
            window = window_override
        else:
            window = window_override or a.sliding_window
        if a.kind == "mla":
            y, new_cache = MLA.mla_attention(
                params["attn"], h, num_heads=a.num_heads,
                qk_nope_head_dim=a.qk_nope_head_dim,
                qk_rope_head_dim=a.qk_rope_head_dim,
                v_head_dim=a.v_head_dim, rope_theta=a.rope_theta,
                cache=cache, mode=mode)
        else:
            y, new_cache = L.gqa_attention(
                params["attn"], h, num_heads=a.num_heads,
                num_kv_heads=a.num_kv_heads, head_dim=a.head_dim,
                rope_theta=a.rope_theta, use_rope=a.use_rope,
                causal=a.causal, window=window, prefix_len=prefix_len,
                logit_cap=a.logit_softcap, cache=cache, mode=mode)
        x = x + y.astype(x.dtype)
    elif kind == "rglru":
        y, new_cache = RG.rglru_block(params["rglru"], h,
                                      conv_width=cfg.rglru.conv_width,
                                      state=cache, mode=mode)
        x = x + y.astype(x.dtype)
    elif kind == "rwkv":
        y, new_cache = RW.rwkv_block(params["rwkv"], h,
                                     head_size=cfg.rwkv.head_size,
                                     state=cache, mode=mode)
        return x + y.astype(x.dtype), new_cache, aux
    else:
        raise ValueError(kind)

    h2 = norm_fn(params["norm2"], x)
    if "moe" in params:
        y2, aux = MOE.moe_ffn(params["moe"], h2,
                              num_experts=cfg.moe.num_experts,
                              top_k=cfg.moe.top_k,
                              capacity_factor=cfg.moe.capacity_factor,
                              activation=cfg.activation,
                              router_aux_weight=cfg.moe.router_aux_weight,
                              expert_sharding=cfg.moe.expert_axis)
    else:
        y2 = L.mlp(params["mlp"], h2, cfg.activation)
    return x + y2.astype(x.dtype), new_cache, aux


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, context_len: int,
                 window_override=None, dtype=jnp.bfloat16):
    if kind in ("attn", "local_attn", "global_attn"):
        a = cfg.attention
        if a.kind == "mla":
            return MLA.init_mla_cache(batch, context_len, a.kv_lora_rank,
                                      a.qk_rope_head_dim, dtype)
        if kind == "local_attn" and a.sliding_window:
            size = min(a.sliding_window, context_len)
        elif window_override:
            size = min(window_override, context_len)
        else:
            size = context_len
        return L.init_kv_cache(batch, size, a.num_kv_heads, a.head_dim, dtype)
    if kind == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        return RG.init_rglru_state(batch, w, cfg.rglru.conv_width, dtype)
    if kind == "rwkv":
        return RW.init_rwkv_state(batch, cfg.d_model, cfg.rwkv.head_size, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model init / apply
# ---------------------------------------------------------------------------

def _pattern_split(cfg: ModelConfig) -> tuple[int, list[str], list[str]]:
    """(num_groups, pattern, remainder_kinds).

    Leading ``first_k_dense`` layers run unscanned (they have a different
    FFN), so the scan covers ``num_layers - first_k_dense``.
    """
    p = list(cfg.block_pattern)
    lead = cfg.moe.first_k_dense if (cfg.moe and cfg.moe.first_k_dense) else 0
    if not cfg.scan_layers:
        return 0, p, cfg.pattern_layers[lead:]
    effective = cfg.num_layers - lead
    n_groups = effective // len(p)
    remainder = cfg.pattern_layers[lead + n_groups * len(p):]
    return n_groups, p, remainder


def init_model(key, cfg: ModelConfig):
    """Returns (params, axes) pytrees."""
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    emb_p, emb_a = L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings)
    params["embed"], axes["embed"] = emb_p, emb_a

    if cfg.modality.kind in ("audio_frames", "vision_text"):
        params["frontend_proj"] = L.dense_init(
            keys[1], (cfg.modality.frontend_dim, cfg.d_model),
            cfg.modality.frontend_dim)
        axes["frontend_proj"] = (None, "embed")

    n_groups, pattern, remainder = _pattern_split(cfg)

    if n_groups > 0:
        def init_group(gkey):
            gks = jax.random.split(gkey, len(pattern))
            ps, as_ = [], []
            for i, kind in enumerate(pattern):
                # layer_idx for first_k_dense: use pattern position of group 0;
                # per-group idx handled by initializing group 0 separately if
                # first_k_dense is inside the scanned region (see below).
                p_, a_ = _init_layer(gks[i], cfg, kind, layer_idx=10**6)
                ps.append(p_)
                as_.append(a_)
            return tuple(ps), tuple(as_)

        gkeys = jax.random.split(keys[2], n_groups)
        sample_p, sample_a = init_group(gkeys[0])
        stacked = jax.vmap(lambda k: init_group(k)[0])(gkeys)
        params["blocks"] = stacked
        axes["blocks"] = jax.tree_util.tree_map(
            lambda ax: (None,) + tuple(ax), sample_a,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    # Leading dense layers (first_k_dense) and remainder layers run unscanned.
    lead = cfg.moe.first_k_dense if (cfg.moe and cfg.moe.first_k_dense) else 0
    if lead:
        lead_ps, lead_as = [], []
        lks = jax.random.split(keys[3], lead)
        for i in range(lead):
            p_, a_ = _init_layer(lks[i], cfg, cfg.pattern_layers[i], layer_idx=i)
            lead_ps.append(p_)
            lead_as.append(a_)
        params["lead"] = lead_ps
        axes["lead"] = lead_as

    if remainder:
        rks = jax.random.split(keys[4], len(remainder))
        rem_ps, rem_as = [], []
        for i, kind in enumerate(remainder):
            p_, a_ = _init_layer(rks[i], cfg, kind, layer_idx=10**6)
            rem_ps.append(p_)
            rem_as.append(a_)
        params["tail"] = rem_ps
        axes["tail"] = rem_as

    fn, _ = L.make_norm(cfg.norm, cfg.d_model)
    params["final_norm"], axes["final_norm"] = fn

    if cfg.mtp:
        # DeepSeek-V3 MTP module: project [h_t ; emb(t_{t+1})] -> d, one
        # extra block, shared unembedding.
        mk = jax.random.split(keys[5], 3)
        params["mtp_proj"] = L.dense_init(mk[0], (2 * cfg.d_model, cfg.d_model),
                                          2 * cfg.d_model)
        axes["mtp_proj"] = (None, "embed")
        p_, a_ = _init_layer(mk[1], cfg, cfg.block_pattern[-1], layer_idx=10**6)
        params["mtp_block"], axes["mtp_block"] = p_, a_
        n_, _ = L.make_norm(cfg.norm, cfg.d_model)
        params["mtp_norm"], axes["mtp_norm"] = n_
    return params, axes


def init_caches(cfg: ModelConfig, batch: int, context_len: int,
                window_override=None, dtype=jnp.bfloat16):
    """Cache pytree matching the model structure (None in train mode)."""
    n_groups, pattern, remainder = _pattern_split(cfg)
    caches: dict[str, Any] = {}
    if n_groups > 0:
        def one(kind):
            c = _layer_cache(cfg, kind, batch, context_len, window_override, dtype)
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), c)
        caches["blocks"] = tuple(one(k) for k in pattern)
    lead = cfg.moe.first_k_dense if (cfg.moe and cfg.moe.first_k_dense) else 0
    if lead:
        caches["lead"] = [
            _layer_cache(cfg, cfg.pattern_layers[i], batch, context_len,
                         window_override, dtype) for i in range(lead)]
    if remainder:
        caches["tail"] = [
            _layer_cache(cfg, k, batch, context_len, window_override, dtype)
            for k in remainder]
    return caches


def apply_model(
    params,
    cfg: ModelConfig,
    batch: dict[str, jnp.ndarray],
    *,
    mode: str = "train",            # train | prefill | decode
    caches=None,
    window_override=None,
) -> tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Forward pass.  ``batch`` keys by modality:

      text:          tokens (B,T)
      vision_text:   patches (B,P,frontend_dim) + tokens (B,T_text)
      audio_frames:  frames (B,T,frontend_dim)

    Returns (logits, new_caches, aux_loss).
    """
    prefix_len = None
    if cfg.modality.kind == "vision_text" and mode != "decode":
        patches = batch["patches"]
        x_img = patches @ params["frontend_proj"]
        x_txt = L.embed(params["embed"], batch["tokens"],
                        scale_by_dim=cfg.embedding_scale)
        x = jnp.concatenate([x_img.astype(x_txt.dtype), x_txt], axis=1)
        prefix_len = patches.shape[1]
    elif cfg.modality.kind == "audio_frames":
        x = batch["frames"] @ params["frontend_proj"]
    else:
        x = L.embed(params["embed"], batch["tokens"],
                    scale_by_dim=cfg.embedding_scale)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    n_groups, pattern, remainder = _pattern_split(cfg)
    total_aux = jnp.zeros((), jnp.float32)

    lead = cfg.moe.first_k_dense if (cfg.moe and cfg.moe.first_k_dense) else 0
    new_caches: dict[str, Any] = {}
    if lead:
        lead_caches = []
        for i in range(lead):
            c = caches["lead"][i] if caches else None
            x, c2, aux = _apply_layer(params["lead"][i], cfg,
                                      cfg.pattern_layers[i], x,
                                      cache=c, mode=mode,
                                      prefix_len=prefix_len,
                                      window_override=window_override)
            total_aux += aux
            lead_caches.append(c2)
        new_caches["lead"] = lead_caches

    if n_groups > 0:
        group_params = params["blocks"]
        group_caches = caches["blocks"] if caches else tuple(None for _ in pattern)

        def group_step(carry, scanned):
            x, aux_acc = carry
            gp, gc = scanned

            def body(x, aux_acc, gp, gc):
                new_gc = []
                for i, kind in enumerate(pattern):
                    c = gc[i] if gc is not None else None
                    x, c2, aux = _apply_layer(gp[i], cfg, kind, x, cache=c,
                                              mode=mode, prefix_len=prefix_len,
                                              window_override=window_override)
                    aux_acc = aux_acc + aux
                    new_gc.append(c2)
                return x, aux_acc, tuple(new_gc)

            if cfg.remat and mode == "train":
                x, aux_acc, new_gc = jax.checkpoint(
                    lambda x_, a_, p_: body(x_, a_, p_, gc))(x, aux_acc, gp)
            else:
                x, aux_acc, new_gc = body(x, aux_acc, gp, gc)
            out_caches = new_gc if mode != "train" else None
            return (x, aux_acc), out_caches

        scanned_caches = group_caches if mode != "train" else None
        if mode == "train":
            (x, total_aux), _ = lax.scan(
                lambda c, gp: group_step(c, (gp, None)),
                (x, total_aux), group_params)
        else:
            (x, total_aux), block_caches = lax.scan(
                group_step, (x, total_aux), (group_params, group_caches))
            new_caches["blocks"] = block_caches

    if remainder:
        tail_caches = []
        for i, kind in enumerate(remainder):
            c = caches["tail"][i] if caches else None
            x, c2, aux = _apply_layer(params["tail"][i], cfg, kind, x,
                                      cache=c, mode=mode,
                                      prefix_len=prefix_len,
                                      window_override=window_override)
            total_aux += aux
            tail_caches.append(c2)
        new_caches["tail"] = tail_caches

    _, norm_fn = L.make_norm(cfg.norm, cfg.d_model)
    xn = norm_fn(params["final_norm"], x)
    if mode == "prefill":
        xn = xn[:, -1:]                   # only the last position's logits
    cap = 30.0 if cfg.attention and cfg.attention.logit_softcap else None
    logits = L.unembed(params["embed"], xn, logit_cap=cap)

    # -- MTP auxiliary head (train only): predict token t+2 from
    #    [h_t ; emb(token_{t+1})] through one extra block -----------------
    if cfg.mtp and mode == "train" and cfg.modality.kind == "text":
        emb_next = L.embed(params["embed"], batch["tokens"],
                           scale_by_dim=cfg.embedding_scale).astype(xn.dtype)
        # align: position t pairs with the embedding of token t+1
        emb_shift = jnp.concatenate(
            [emb_next[:, 1:], jnp.zeros_like(emb_next[:, :1])], axis=1)
        h = jnp.concatenate([xn, emb_shift], axis=-1) @ params["mtp_proj"]
        h = h.astype(xn.dtype)
        h, _, mtp_aux = _apply_layer(params["mtp_block"], cfg,
                                     cfg.block_pattern[-1], h,
                                     cache=None, mode="train")
        total_aux += mtp_aux
        h = norm_fn(params["mtp_norm"], h)
        mtp_logits = L.unembed(params["embed"], h, logit_cap=cap)
        return logits, None, (total_aux, mtp_logits)

    return logits, (new_caches if mode != "train" else None), total_aux
