"""GLM4-9B [dense] — RoPE + GQA.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552  [hf:THUDM/glm-4-9b]
"""

from repro.configs.base import AttentionConfig, ModelConfig


CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    source="hf:THUDM/glm-4-9b",
    num_layers=40,
    d_model=4096,
    d_ff=13696,
    vocab_size=151_552,
    attention=AttentionConfig(
        kind="gqa", num_heads=32, num_kv_heads=2, head_dim=128,
        rope_theta=10_000.0,
    ),
    block_pattern=("attn",),
    activation="swiglu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=448,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=8, num_kv_heads=2,
                                  head_dim=16),
        block_pattern=("attn",),
        activation="swiglu",
        norm="rmsnorm",
        remat=False,
    )
