"""Table 1 + Section 6: alpha-beta planner strategy selection."""

import pytest

from repro.core.failures import (
    FailureState,
    concentrated_failures,
    random_failures,
    single_nic_failure,
)
from repro.core.planner import Collective, CommConfig, Planner, Strategy
from repro.core.topology import make_cluster


def _state(failures):
    st = FailureState()
    for f in failures:
        st.apply(f)
    return st


@pytest.fixture
def planner():
    return Planner(make_cluster(8, 8))


def test_no_failure_ring(planner):
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, FailureState())
    assert plan.strategy is Strategy.RING


def test_small_message_latency_bound(planner):
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 10, FailureState())
    assert plan.strategy in (Strategy.TREE, Strategy.RING)


def test_single_failure_large_allreduce_uses_decomposition(planner):
    st = _state(single_nic_failure(2, 3))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert plan.strategy is Strategy.R2CCL_ALL_REDUCE
    assert plan.degraded_node == 2
    assert 0 < plan.partition_y < 1
    assert plan.lost_fraction == pytest.approx(0.125)


def test_table1_non_allreduce_uses_balance(planner):
    st = _state(single_nic_failure(2, 3))
    for coll in (Collective.ALL_GATHER, Collective.REDUCE_SCATTER,
                 Collective.BROADCAST, Collective.ALL_TO_ALL):
        plan = planner.choose_strategy(coll, 1 << 30, st)
        assert plan.strategy is Strategy.BALANCE, coll


def test_latency_bound_allreduce_uses_balance(planner):
    st = _state(single_nic_failure(2, 3))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 12, st)
    assert plan.strategy is Strategy.BALANCE


def test_multi_failure_spectrum_recursive(planner):
    # different nodes losing different NIC counts -> bandwidth spectrum
    fails = (concentrated_failures(1, [0, 1, 2, 3]) +
             concentrated_failures(4, [0, 1]) + single_nic_failure(6, 5))
    st = _state(fails)
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert plan.strategy in (Strategy.RECURSIVE, Strategy.BALANCE)


def test_reranking_engaged_on_rail_mismatch(planner):
    from repro.core.failures import rail_mismatch_failures
    st = _state(rail_mismatch_failures(0, 1, 0, 5))
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 30, st)
    assert sorted(plan.ring_order) == list(range(8))


def test_comm_config_kwargs():
    c = CommConfig(mode="r2ccl", degraded_rank=3, lost_fraction=0.5)
    kw = c.kwargs()
    assert kw["mode"] == "r2ccl" and kw["degraded"] == 3
    assert kw["bandwidths"] is None
