"""Training substrate: optimizer math, loss descent, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# minutes of model compiles (loss-descent runs): excluded from the fast tier
pytestmark = pytest.mark.slow

from repro.data import make_batch
from repro.models import get_smoke_config, init_model
from repro.optim import AdamWConfig, adamw_update, global_norm, init_opt_state
from repro.optim.schedules import cosine_with_warmup
from repro.training import (
    init_train_state,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                      grad_clip_norm=None)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    st = init_opt_state(p)
    new_p, st2, _ = adamw_update(cfg, p, g, st)

    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.square(np.asarray(g["w"]))
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.asarray(p["w"]) - 1e-2 * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(st2["count"]) == 1


def test_grad_clipping():
    cfg = AdamWConfig(grad_clip_norm=1.0)
    p = {"w": jnp.ones((10,))}
    g = {"w": jnp.full((10,), 100.0)}
    st = init_opt_state(p)
    _, _, gnorm = adamw_update(cfg, p, g, st)
    assert float(gnorm) == pytest.approx(float(global_norm(g)))


def test_cosine_schedule():
    assert float(cosine_with_warmup(jnp.asarray(0), warmup_steps=10,
                                    total_steps=100)) == 0.0
    assert float(cosine_with_warmup(jnp.asarray(10), warmup_steps=10,
                                    total_steps=100)) == pytest.approx(1.0)
    end = float(cosine_with_warmup(jnp.asarray(100), warmup_steps=10,
                                   total_steps=100))
    assert end == pytest.approx(0.1, abs=1e-6)


def test_loss_decreases_smollm():
    cfg = get_smoke_config("smollm-360m")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3), sync="xla",
                                   warmup_steps=5, total_steps=200))
    losses = []
    for i in range(40):
        b = make_batch(cfg, seq_len=32, batch_size=8, step=i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("glm4-9b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, step=7)
    restored, step = restore_checkpoint(path, state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    cfg = get_smoke_config("glm4-9b")
    b1 = make_batch(cfg, 16, 4, step=3, seed=9)
    b2 = make_batch(cfg, 16, 4, step=3, seed=9)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, 16, 4, step=4, seed=9)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # bigram structure is learnable: labels mostly among the successor set
    from repro.data import SyntheticConfig, SyntheticTokens
    gen = SyntheticTokens(SyntheticConfig(64, 8, cfg.vocab_size))
    b = gen.batch(0)
    hits = 0
    total = 0
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            hits += l in gen.successors[t]
            total += 1
    assert hits / total > 0.8
