"""Optimal data partition for R2CCL-AllReduce (paper Section 5.2 + Appendix A).

Notation (paper):
  D : total AllReduce payload per rank (bytes)
  B : per-node egress bandwidth when healthy (bytes/s)
  n : number of server nodes
  g : devices per node                     (ring size = n*g)
  X : fraction of the degraded node's bandwidth that was lost, 0 < X < 1
  Y : fraction of D assigned to the *partial* AllReduce (excludes the
      degraded node); the remaining (1-Y) runs the global AllReduce.

Stage 1 (concurrent):
  T1(Y) = a * (1-Y) D / ((1-X) B)   global ring AllReduce, a = 2(ng-1)/(ng)
  T2(Y) = b * Y D / (X B)           partial ring AllReduce, b = 2((n-1)g-1)/((n-1)g)
Stage 2:
  T3(Y) = Y D / (X B)               broadcast completing the partial path

T(Y) = max(T1, T2) + T3.  Appendix A shows T is minimized at Y=0 when
X <= ng/(3ng-2) (plain ring wins) and otherwise at
Y* = X + X(1-X) / (X + (g(n-1)-1) n).
"""

from __future__ import annotations

import dataclasses
import math


def ring_coeff(k: int) -> float:
    """2(k-1)/k — the classic ring-AllReduce traffic factor over k ranks."""
    if k <= 1:
        return 0.0
    return 2.0 * (k - 1) / k


def stage_times(
    y: float, x: float, n: int, g: int, d: float = 1.0, b: float = 1.0
) -> tuple[float, float, float]:
    """(T1, T2, T3) for a given partition fraction Y."""
    a = ring_coeff(n * g)
    bb = ring_coeff((n - 1) * g)
    t1 = a * (1.0 - y) * d / ((1.0 - x) * b)
    t2 = (bb * y * d / (x * b)) if x > 0 else (math.inf if y > 0 else 0.0)
    t3 = (y * d / (x * b)) if x > 0 else (math.inf if y > 0 else 0.0)
    return t1, t2, t3


def total_time(y: float, x: float, n: int, g: int, d: float = 1.0, b: float = 1.0) -> float:
    t1, t2, t3 = stage_times(y, x, n, g, d, b)
    return max(t1, t2) + t3


def ring_time(x: float, n: int, g: int, d: float = 1.0, b: float = 1.0) -> float:
    """Completion time of the *standard* ring AllReduce, throttled by the
    degraded node's residual bandwidth (1-X)B."""
    return ring_coeff(n * g) * d / ((1.0 - x) * b)


def x_threshold(n: int, g: int) -> float:
    """Lost-bandwidth fraction above which R2CCL-AllReduce beats plain ring.

    Appendix A, step 2: T'(Y) on [0, Y*] changes sign at X = ng / (3ng - 2).
    """
    ng = n * g
    return ng / (3.0 * ng - 2.0)


def y_star(x: float, n: int, g: int) -> float:
    """Optimal partial-AllReduce fraction Y* (Appendix A, step 3)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        raise ValueError("X must be < 1 (some bandwidth must survive)")
    if x <= x_threshold(n, g):
        return 0.0
    return x + x * (1.0 - x) / (x + (g * (n - 1) - 1) * n)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Resolved R2CCL-AllReduce plan for one degraded node."""

    n: int                   # number of nodes in the ring
    g: int                   # devices per node
    x: float                 # lost bandwidth fraction of the degraded node
    y: float                 # fraction of payload on the partial path
    use_r2ccl: bool          # False => plain ring is optimal
    t_ring: float            # predicted plain-ring time (D=B=1 units)
    t_r2ccl: float           # predicted decomposed time (D=B=1 units)

    @property
    def speedup(self) -> float:
        return self.t_ring / self.t_r2ccl if self.t_r2ccl > 0 else 1.0


def plan_partition(
    x: float, n: int, g: int, *, practice_threshold: bool = True
) -> PartitionPlan:
    """Compute the R2CCL-AllReduce plan for a single degraded node.

    ``practice_threshold`` follows the paper's deployed rule (Section 5.2):
    use plain ring for X < 1/3 and the decomposition for X >= 1/3; with it
    disabled, the exact Appendix-A threshold ng/(3ng-2) is used.
    """
    if not 0.0 <= x < 1.0:
        raise ValueError(f"X must be in [0,1), got {x}")
    if n < 3:
        # The partial AllReduce needs >=2 healthy nodes; with n<3 fall back.
        y = 0.0
    else:
        thr = (1.0 / 3.0) if practice_threshold else x_threshold(n, g)
        y = y_star(x, n, g) if x >= thr and x > 0 else 0.0
    t_ring = ring_time(x, n, g) if x < 1.0 else math.inf
    t_dec = total_time(y, x, n, g) if y > 0 else t_ring
    return PartitionPlan(
        n=n, g=g, x=x, y=y, use_r2ccl=y > 0.0, t_ring=t_ring, t_r2ccl=min(t_dec, t_ring)
    )


# ---------------------------------------------------------------------------
# Overlapped-broadcast variant (beyond-paper optimization; see EXPERIMENTS.md)
# ---------------------------------------------------------------------------
# The Appendix-A model serializes the stage-2 broadcast after stage 1:
# T = max(T1, T2) + T3.  But the broadcast only involves the *healthy* ring
# and the degraded node's ingress, which are exactly the links the partial
# AllReduce used — while the *global* ring (throttled by the degraded node's
# residual egress) is still running.  Overlapping stage 2 with the tail of
# stage 1 gives T = max(T1, T2 + T3), which is minimized where
# T1(Y) = (T2+T3)(Y):
#
#   Y*_ov = aX / ((b+1)(1-X) + aX),      T_ov = T1(Y*_ov)
#
# and — unlike the serialized form — beats plain ring for *every* X > 0.
# This matches the paper's own measurements (93% of healthy throughput at
# X = 0.125, above the 87.5% residual-bandwidth cap of any schedule that
# routes the full payload through the degraded node), even though their
# analytic model would pick Y = 0 there.

def y_star_overlapped(x: float, n: int, g: int) -> float:
    if x <= 0.0:
        return 0.0
    a = ring_coeff(n * g)
    b = ring_coeff((n - 1) * g)
    return a * x / ((b + 1.0) * (1.0 - x) + a * x)


def total_time_overlapped(y: float, x: float, n: int, g: int,
                          d: float = 1.0, b: float = 1.0) -> float:
    t1, t2, t3 = stage_times(y, x, n, g, d, b)
    return max(t1, t2 + t3)


def plan_partition_overlapped(x: float, n: int, g: int) -> PartitionPlan:
    """Plan using the overlapped-broadcast model (beats ring for all X>0)."""
    if not 0.0 <= x < 1.0:
        raise ValueError(f"X must be in [0,1), got {x}")
    if n < 3 or x == 0.0:
        t = ring_time(x, n, g)
        return PartitionPlan(n=n, g=g, x=x, y=0.0, use_r2ccl=False,
                             t_ring=t, t_r2ccl=t)
    y = y_star_overlapped(x, n, g)
    t_ring = ring_time(x, n, g)
    t_ov = total_time_overlapped(y, x, n, g)
    use = t_ov < t_ring
    return PartitionPlan(n=n, g=g, x=x, y=y if use else 0.0, use_r2ccl=use,
                         t_ring=t_ring, t_r2ccl=min(t_ov, t_ring))


def brute_force_y(x: float, n: int, g: int, grid: int = 200_000) -> float:
    """Grid minimizer of T(Y) — test oracle for ``y_star``."""
    best_y, best_t = 0.0, total_time(0.0, x, n, g)
    for i in range(1, grid + 1):
        y = i / grid
        t = total_time(y, x, n, g)
        if t < best_t:
            best_t, best_y = t, y
    return best_y
