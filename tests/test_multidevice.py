"""Multi-device parity tests (subprocess with 8 virtual host devices so the
main pytest process keeps its single CPU device)."""

import pytest

pytestmark = pytest.mark.slow


def test_jax_collectives_match_oracle(multidevice):
    out = multidevice("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.collectives import all_reduce
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
x = rng.normal(size=(8, 53)).astype(np.float32)
want = x.sum(0)
for mode, kw in [("xla", {}), ("ring", {}),
                 ("r2ccl", dict(degraded=3, lost_fraction=0.5)),
                 ("r2ccl", dict(degraded=0, lost_fraction=0.9)),
                 ("recursive", dict(bandwidths=(4,4,2,4,3,4,4,4.0)))]:
    f = jax.shard_map(lambda v: all_reduce(v[0], "data", mode=mode, **kw)[None],
                      mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None), check_vma=False)
    got = np.asarray(jax.jit(f)(x))
    assert np.allclose(got, np.tile(want, (8, 1)), atol=1e-4), mode
print("COLLECTIVES_OK")
""")
    assert "COLLECTIVES_OK" in out


def test_r2ccl_training_parity(multidevice):
    """xla-psum vs explicit ring vs failure-aware r2ccl gradient sync must
    train identically (within bf16 numerics)."""
    out = multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import get_smoke_config, init_model
from repro.training import make_train_step, init_train_state
from repro.optim import AdamWConfig
from repro.data import make_batch
from repro.core.planner import CommConfig

cfg = get_smoke_config("paper-7b")
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
params, _ = init_model(jax.random.PRNGKey(0), cfg)

def run(sync, comm=None, steps=3):
    state = init_train_state(params)
    fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), sync=sync,
                                 comm=comm, mesh=mesh))
    out = []
    for i in range(steps):
        b = make_batch(cfg, seq_len=32, batch_size=8, step=i)
        batch = {k: jax.device_put(jnp.asarray(v),
                                   NamedSharding(mesh, P("data")))
                 for k, v in b.items()}
        state, m = fn(state, batch)
        out.append(float(m["loss"]))
    return out, state

l_xla, s_xla = run("xla")
l_r2, s_r2 = run("r2ccl", CommConfig(mode="r2ccl", degraded_rank=1,
                                     lost_fraction=0.5, devices_per_node=2))
d = max(abs(a - b) for a, b in zip(l_xla, l_r2))
assert d < 5e-3, f"loss diff {d}"
import jax.tree_util as jtu
pd = max(jtu.tree_leaves(jtu.tree_map(
    lambda a, b: float(jnp.abs(a - b).max()), s_xla.params, s_r2.params)))
assert pd < 5e-3, f"param diff {pd}"
print("TRAIN_PARITY_OK", d, pd)
""")
    assert "TRAIN_PARITY_OK" in out


def test_failover_mid_training(multidevice):
    """Switch the gradient-sync schedule mid-run (hot repair) — training
    continues with the same data and converging loss."""
    out = multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import get_smoke_config, init_model
from repro.training import make_train_step, init_train_state
from repro.optim import AdamWConfig
from repro.data import make_batch
from repro.core.planner import CommConfig

cfg = get_smoke_config("smollm-360m")
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
params, _ = init_model(jax.random.PRNGKey(0), cfg)
state = init_train_state(params)
healthy = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3), sync="r2ccl",
                                  comm=CommConfig(mode="ring"), mesh=mesh))
degraded = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3), sync="r2ccl",
                                   comm=CommConfig(mode="r2ccl",
                                                   degraded_rank=2,
                                                   lost_fraction=0.5),
                                   mesh=mesh))
losses = []
for i in range(16):
    fn = healthy if i < 8 else degraded        # NIC fails at step 8
    b = make_batch(cfg, seq_len=32, batch_size=8, step=i)
    batch = {k: jax.device_put(jnp.asarray(v), NamedSharding(mesh, P("data")))
             for k, v in b.items()}
    state, m = fn(state, batch)
    losses.append(float(m["loss"]))
import numpy as np
assert np.isfinite(losses).all()
assert np.mean(losses[-4:]) < np.mean(losses[:4])   # still converging
print("FAILOVER_OK", losses[0], losses[-1])
""")
    assert "FAILOVER_OK" in out


def test_dryrun_smoke_64dev(multidevice):
    """A reduced dry-run on a 8x8 virtual mesh: lower+compile+roofline for a
    small arch, exercising the full dryrun path without the 512-dev cost."""
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
import repro.launch.sharding as SH
from repro.launch.mesh import rules_for
from repro.models import get_smoke_config, init_model, init_caches, apply_model
from repro.launch.hlo_analysis import parse_collectives, roofline_terms

cfg = get_smoke_config("glm4-9b")
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
holder = {}
def capture():
    p, a = init_model(jax.random.PRNGKey(0), cfg)
    holder["axes"] = a
    return p
pshape = jax.eval_shape(capture)
pspecs = SH.param_pspecs(mesh, rules_for(cfg, "tp"), holder["axes"], pshape)
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
caches = jax.eval_shape(lambda: init_caches(cfg, 8, 96))
cspecs = SH.cache_pspecs(mesh, caches, ("data",))

def serve(params, tokens, caches):
    logits, caches, _ = apply_model(params, cfg, {"tokens": tokens},
                                    mode="decode", caches=caches)
    return jnp.argmax(logits[:, -1], -1), caches

jitted = jax.jit(serve, in_shardings=(SH.named(mesh, pspecs),
                                      SH.named(mesh, P("data", None)),
                                      SH.named(mesh, cspecs)),
                 out_shardings=(None, SH.named(mesh, cspecs)))
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
lowered = jitted.lower(pshape, tok, caches)
compiled = lowered.compile()
cost = compiled.cost_analysis()
coll = parse_collectives(compiled.as_text())
terms = roofline_terms(flops_per_device=float(cost.get("flops", 0)),
                       hbm_bytes_per_device=float(cost.get("bytes accessed", 0)),
                       wire_bytes_per_device=coll.wire_bytes, chips=8)
mem = compiled.memory_analysis()
assert terms["bound_s"] > 0
print("DRYRUN_OK", terms["bottleneck"], mem is not None)
""")
    assert "DRYRUN_OK" in out


def test_tree_allreduce_jax_backend(multidevice):
    out = multidevice("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collectives import all_reduce
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
x = np.random.default_rng(0).normal(size=(8, 37)).astype(np.float32)
f = jax.shard_map(lambda v: all_reduce(v[0], "data", mode="tree")[None],
                  mesh=mesh, in_specs=P("data", None),
                  out_specs=P("data", None), check_vma=False)
got = np.asarray(jax.jit(f)(x))
assert np.allclose(got, np.tile(x.sum(0), (8, 1)), atol=1e-4)
print("TREE_OK")
""")
    assert "TREE_OK" in out
