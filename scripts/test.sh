#!/usr/bin/env bash
# Tier-1 test entry point: fast suite (minutes, not tens of minutes).
#
#   scripts/test.sh              # default: skip @slow (model/system/multidevice)
#   scripts/test.sh --all        # everything, including @slow
#   scripts/test.sh <pytest args...>   # passed through verbatim
#
# Property tests run offline via tests/_propcheck.py when hypothesis is not
# installed; install requirements-dev.txt to use the real library.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
    shift
    exec python -m pytest -q "$@"
fi
exec python -m pytest -q -m "not slow" "$@"
