"""CLI: ``python -m repro.analysis [verify|lint] ...``.

* ``verify [--seed S] [--max-n N]`` — run the schedule verifier over the
  full builder corpus; prints one line per entry, exits non-zero on the
  first schedule that fails to prove.
* ``lint [paths...]`` — run the determinism lint (defaults to
  ``src/repro/core`` and ``src/repro/runtime``); exits non-zero if any
  finding is emitted.

With no subcommand, runs both with defaults (the CI gate).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from .corpus import builder_corpus
from .errors import ScheduleError
from .lint import DEFAULT_LINT_TARGETS, lint_paths
from .verify import verify_program, verify_schedule
from repro.core.schedule import CollectiveProgram


def _run_verify(seed: int, max_n: int) -> int:
    n_sched = n_transfers = 0
    for label, obj in builder_corpus(seed=seed, max_n=max_n):
        try:
            if isinstance(obj, CollectiveProgram):
                reports = verify_program(obj)
            else:
                reports = [verify_schedule(obj)]
        except ScheduleError as e:
            print(f"FAIL {label}: {type(e).__name__}: {e}")
            return 1
        n_sched += len(reports)
        n_transfers += sum(r.transfers for r in reports)
        proved = ", ".join(f"{r.schedule}:{r.semantics.value}"
                           for r in reports)
        print(f"ok   {label}  [{proved}]")
    print(f"verified {n_sched} schedules ({n_transfers} transfers) clean")
    return 0


def _resolve_targets(paths: list[str]) -> list[pathlib.Path]:
    if paths:
        return [pathlib.Path(p) for p in paths]
    # default targets are repo-relative; resolve against this package's
    # location so the CLI works from any cwd
    src_root = pathlib.Path(__file__).resolve().parents[2]   # .../src
    repo_root = src_root.parent
    return [repo_root / t for t in DEFAULT_LINT_TARGETS]


def _run_lint(paths: list[str]) -> int:
    targets = _resolve_targets(paths)
    findings = lint_paths(targets)
    for f in findings:
        print(f)
    label = ", ".join(str(t) for t in targets)
    if findings:
        print(f"lint: {len(findings)} finding(s) in {label}")
        return 1
    print(f"lint clean: {label}")
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="cmd")
    pv = sub.add_parser("verify", help="verify the builder corpus")
    pv.add_argument("--seed", type=int, default=0)
    pv.add_argument("--max-n", type=int, default=8)
    pl = sub.add_parser("lint", help="run the determinism lint")
    pl.add_argument("paths", nargs="*", help="files/dirs (default: "
                    + ", ".join(DEFAULT_LINT_TARGETS) + ")")
    args = parser.parse_args(argv)

    if args.cmd == "verify":
        return _run_verify(args.seed, args.max_n)
    if args.cmd == "lint":
        return _run_lint(args.paths)
    rc = _run_verify(seed=0, max_n=8)
    return rc or _run_lint([])


if __name__ == "__main__":
    sys.exit(main())
