"""Topology-aware logical re-ranking (paper Section 6 + Appendix D, Alg. 1).

When adjacent ring nodes lose *different* rails, their shared bandwidth
collapses to the intersection of surviving rails.  Most collective
algorithms are symmetric in node order, so R2CCL repairs only the
problematic edges by relocating "bridge" nodes (nodes with broad rail
connectivity) between incompatible neighbours, preserving most established
connections.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


def edge_capacity(s_u: frozenset[int], s_v: frozenset[int]) -> int:
    """|S_u ∩ S_v| — surviving shared rails between ring neighbours."""
    return len(s_u & s_v)


def ring_bottleneck(ring: Sequence[int], rail_sets: Sequence[frozenset[int]]) -> int:
    """Minimum edge capacity around the ring."""
    k = len(ring)
    return min(
        edge_capacity(rail_sets[ring[i]], rail_sets[ring[(i + 1) % k]])
        for i in range(k)
    )


@dataclasses.dataclass
class RerankResult:
    ring: list[int]
    moved: list[int]                  # bridge nodes that were relocated
    bottleneck_before: int
    bottleneck_after: int


def bridge_rerank(ring: Sequence[int], rail_sets: Sequence[frozenset[int]]) -> RerankResult:
    """Algorithm 1: bridge-based re-ranking.

    ``rail_sets[n]`` is the set of healthy rail indices of node ``n`` (S_n).
    Returns a repaired ring where every edge meets the global target
    B_global = min_n |S_n| when a suitable bridge exists.
    """
    ring = list(ring)
    n = len(ring)
    if n < 3:
        return RerankResult(ring, [], ring_bottleneck(ring, rail_sets) if n > 1 else 0,
                            ring_bottleneck(ring, rail_sets) if n > 1 else 0)
    b_global = min(len(rail_sets[node]) for node in ring)
    before = ring_bottleneck(ring, rail_sets)

    # Collect deficient edges, sorted by severity (gap size) descending.
    def deficient_edges(r: list[int]) -> list[tuple[int, int, int]]:
        out = []
        for i in range(len(r)):
            u, v = r[i], r[(i + 1) % len(r)]
            cap = edge_capacity(rail_sets[u], rail_sets[v])
            if cap < b_global:
                out.append((b_global - cap, u, v))
        out.sort(key=lambda t: -t[0])
        return out

    moved: list[int] = []
    for _gap, u, v in deficient_edges(ring):
        # Edge may have been fixed (or nodes moved) by an earlier relocation.
        iu = ring.index(u)
        if ring[(iu + 1) % len(ring)] != v:
            continue
        if edge_capacity(rail_sets[u], rail_sets[v]) >= b_global:
            continue
        best_bridge = None
        for w in ring:
            if w in (u, v):
                continue
            iw = ring.index(w)
            x = ring[(iw - 1) % len(ring)]      # PrevNode(w)
            y = ring[(iw + 1) % len(ring)]      # NextNode(w)
            if x in (u, v) or y in (u, v):
                continue   # removing w would touch the edge under repair
            new_cap = min(
                edge_capacity(rail_sets[u], rail_sets[w]),
                edge_capacity(rail_sets[w], rail_sets[v]),
            )
            removal_cap = edge_capacity(rail_sets[x], rail_sets[y])
            if new_cap >= b_global and removal_cap >= b_global:
                best_bridge = w
                break
        if best_bridge is not None:
            ring.remove(best_bridge)
            ring.insert(ring.index(u) + 1, best_bridge)
            moved.append(best_bridge)

    return RerankResult(
        ring=ring,
        moved=moved,
        bottleneck_before=before,
        bottleneck_after=ring_bottleneck(ring, rail_sets),
    )


def is_valid_ring(ring: Sequence[int], nodes: Sequence[int]) -> bool:
    """Re-ranking must be a permutation of the original membership."""
    return sorted(ring) == sorted(nodes)
