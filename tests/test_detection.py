"""Section 4.1-4.2: bilateral awareness + probe triangulation."""

import itertools

import pytest

from repro.core.detection import (
    FailureDetector,
    FaultLocation,
    NCCL_DEFAULT_TIMEOUT,
    ProbeOutcome,
    probe_outcome,
    triangulate,
)
from repro.core.failures import Failure, FailureState, FailureType


def test_triangulation_truth_table():
    ok, to, le = ProbeOutcome.OK, ProbeOutcome.TIMEOUT, ProbeOutcome.LOCAL_ERROR
    # local NIC dead
    assert triangulate(le, to, to, ok) is FaultLocation.LOCAL_NIC
    # remote NIC dead
    assert triangulate(to, le, ok, to) is FaultLocation.REMOTE_NIC
    # link broken: both time out, aux reaches both endpoints
    assert triangulate(to, to, ok, ok) is FaultLocation.LINK
    # aux distinguishes single-endpoint impairment
    assert triangulate(to, to, to, ok) is FaultLocation.LOCAL_NIC
    assert triangulate(to, to, ok, to) is FaultLocation.REMOTE_NIC


def test_probe_outcomes():
    assert probe_outcome(True, False, False) is ProbeOutcome.LOCAL_ERROR
    assert probe_outcome(False, True, False) is ProbeOutcome.TIMEOUT
    assert probe_outcome(False, False, True) is ProbeOutcome.TIMEOUT
    assert probe_outcome(False, False, False) is ProbeOutcome.OK


@pytest.mark.parametrize("ftype,expected", [
    (FailureType.NIC_HARDWARE, FaultLocation.LOCAL_NIC),
    (FailureType.LINK_DOWN, FaultLocation.LINK),
])
def test_end_to_end_detection(ftype, expected):
    det = FailureDetector(FailureState())
    f = Failure(ftype, 0, 0)
    diag = det.detect(f, (0, 0), (1, 0), aux=(2, 0))
    assert diag.location is expected
    # milliseconds, not the minutes of an NCCL timeout
    assert diag.detect_latency < 1e-2
    assert diag.localize_latency < 1e-2
    assert diag.localize_latency >= diag.detect_latency
    assert NCCL_DEFAULT_TIMEOUT / diag.detect_latency > 1e4


def test_bilateral_vs_unilateral():
    det_uni = FailureDetector(FailureState(), bilateral=False)
    f = Failure(FailureType.NIC_HARDWARE, 0, 0)
    diag = det_uni.detect(f, (0, 0), (1, 0), aux=(2, 0))
    assert diag.detect_latency >= NCCL_DEFAULT_TIMEOUT  # peer spins to timeout


def test_event_log_ordering():
    det = FailureDetector(FailureState())
    det.detect(Failure(FailureType.NIC_HARDWARE, 0, 1), (0, 1), (1, 1), aux=(2, 0))
    times = [e.time for e in det.log]
    assert times == sorted(times)
    kinds = [e.kind for e in det.log]
    assert kinds[0] == "failure" and kinds[-1] == "diagnosis_broadcast"


def test_reprobe_recovery():
    st = FailureState()
    st.apply(Failure(FailureType.NIC_HARDWARE, 0, 0))
    det = FailureDetector(st)
    healthy, nxt = det.reprobe((0, 0), now=5.0, recovered=True)
    assert healthy and (0, 0) not in st.failed_nics
    assert nxt > 5.0


def test_adaptive_reprobe_period():
    from repro.core.detection import (
        REPROBE_PERIOD,
        REPROBE_PERIOD_MAX,
        REPROBE_PERIOD_MIN,
        adaptive_reprobe_period,
    )

    # stable link: faster than the base constant (recovery detection
    # latency shrinks), but never below the floor
    assert adaptive_reprobe_period(0) < REPROBE_PERIOD
    assert adaptive_reprobe_period(0) >= REPROBE_PERIOD_MIN
    # each recent flap backs the cadence off, monotonically
    periods = [adaptive_reprobe_period(k) for k in range(6)]
    assert periods == sorted(periods)
    # ceiling holds for any storm size
    assert adaptive_reprobe_period(50) == REPROBE_PERIOD_MAX
    with pytest.raises(ValueError):
        adaptive_reprobe_period(-1)


def test_adaptive_reprobe_period_edge_cases():
    from repro.core.detection import adaptive_reprobe_period

    # zero flaps with base already below the floor: clamp up, exactly
    assert adaptive_reprobe_period(0, base=0.1, floor=0.25, ceiling=8.0) \
        == 0.25
    # storm saturating the ceiling: 2**k growth must not overflow past it
    assert adaptive_reprobe_period(500, base=1.0, floor=0.25, ceiling=8.0) \
        == 8.0
    # degenerate clamp floor == ceiling: every flap count maps to the point
    for k in (0, 1, 3, 10):
        assert adaptive_reprobe_period(k, base=1.0, floor=2.0, ceiling=2.0) \
            == 2.0


def test_reprobe_cadence_feeds_flap_count():
    det = FailureDetector(FailureState())
    _, stable = det.reprobe((0, 0), now=0.0, recovered=False, flap_count=0)
    _, flappy = det.reprobe((0, 0), now=0.0, recovered=False, flap_count=4)
    assert stable < flappy


def test_failure_scope_table2():
    st = FailureState()
    assert st.apply(Failure(FailureType.NIC_HARDWARE, 0, 0))
    assert st.apply(Failure(FailureType.QP_ERROR, 0, 1))
    # partial types depend on escalation
    assert st.apply(Failure(FailureType.LINK_FLAPPING, 1, 0, escalates=True))
    assert not st.apply(Failure(FailureType.CRC_ERROR, 1, 1, escalates=False))
    # out of scope
    assert not st.apply(Failure(FailureType.NVLINK, 2, 0))
    assert not st.apply(Failure(FailureType.SWITCH_OUTAGE, 2, -1))
    assert len(st.unsupported) == 3
    assert st.failed_on_node(0) == {0, 1}
