"""Static-analysis subsystem: schedule verifier (dataflow + deadlock),
mutation-rejection tests, determinism lint, cost/coverage analyzers, and
verified-replan wiring."""

import dataclasses
import json
import math
import pathlib
import random
import subprocess
import sys

import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import (
    CORPUS_COST_TOLERANCE,
    CoverageError,
    DeadlockError,
    DoubleReduceError,
    ProgramError,
    ResultError,
    ResultRanksError,
    ScheduleError,
    Semantics,
    StaleReadError,
    StepLegalityError,
    analyze_coverage,
    analyze_program,
    analyze_schedule,
    as_program,
    check_coverage,
    check_deadlock_free,
    infer_semantics,
    lint_paths,
    lint_source,
    verify_program,
    verify_schedule,
)
from repro.analysis.corpus import builder_corpus
from repro.analysis.cost import CONFORMANCE_CAPACITY, CONFORMANCE_PAYLOAD
from repro.core.allreduce import build_partial_all_reduce, build_r2ccl_all_reduce
from repro.core.event_sim import EventSimulator, healthy_completion
from repro.core.recursive import build_recursive_all_reduce
from repro.core.schedule import (
    ChunkSchedule,
    CollectiveProgram,
    Segment,
    Step,
    build_ring_all_gather,
    build_ring_all_reduce,
    build_ring_broadcast,
    build_ring_reduce_scatter,
    build_tree_all_reduce,
    build_tree_broadcast,
    build_tree_reduce,
    ring_program,
)
from repro.core.topology import ClusterTopology, make_cluster
from repro.runtime.cosim import run_scenario
from repro.runtime.scenarios import clean_nic_down, flap_storm

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# the verifier proves every builder clean
# ---------------------------------------------------------------------------

def test_builder_corpus_verifies_clean():
    count = 0
    for label, obj in builder_corpus(seed=3, max_n=7):
        if isinstance(obj, CollectiveProgram):
            reports = verify_program(obj)
        else:
            reports = [verify_schedule(obj)]
        assert reports, label
        for r in reports:
            assert r.transfers > 0
            assert r.semantics is not Semantics.OPAQUE, (
                f"{label}: builder output must claim a semantics")
        count += 1
    assert count > 100


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 999))
def test_prop_ring_and_tree_builders_verify(n, seed):
    rng = random.Random(seed)
    order = list(range(n))
    rng.shuffle(order)
    root = order[rng.randrange(n)]
    assert verify_schedule(
        build_ring_all_reduce(order, n)).semantics is Semantics.ALL_REDUCE
    assert verify_schedule(
        build_ring_broadcast(order, n, root)).root == root
    rep = verify_schedule(build_tree_reduce(order, n, root))
    assert rep.semantics is Semantics.REDUCE and rep.result_ranks == (root,)
    verify_schedule(build_tree_broadcast(order, n, root))
    verify_schedule(build_tree_all_reduce(order, n, root=root))
    verify_schedule(build_ring_reduce_scatter(order, n))
    verify_schedule(build_ring_all_gather(order, n))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 10), seed=st.integers(0, 999),
       x=st.floats(0.05, 0.95))
def test_prop_degraded_builders_verify(n, seed, x):
    rng = random.Random(seed)
    order = list(range(n))
    rng.shuffle(order)
    degraded = order[rng.randrange(n)]
    healthy = [r for r in order if r != degraded]
    verify_schedule(build_partial_all_reduce(healthy, degraded, n))
    prog, _plan = build_r2ccl_all_reduce(order, degraded, x=x)
    verify_program(prog)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999))
def test_prop_recursive_builder_verifies(seed):
    rng = random.Random(seed)
    n = rng.randrange(3, 9)
    bw = [rng.choice([1.0, 1.0, 0.7, 0.4, 0.0]) for _ in range(n)]
    if sum(1 for b in bw if b > 0) < 2:
        bw[0] = bw[1] = 1.0
    prog, _levels = build_recursive_all_reduce(bw)
    verify_program(prog)


# ---------------------------------------------------------------------------
# mutation tests: corrupt known-good schedules, expect typed rejections
# ---------------------------------------------------------------------------

def _swap_step(sched, i, **changes):
    steps = list(sched.steps)
    steps[i] = dataclasses.replace(steps[i], **changes)
    return dataclasses.replace(sched, steps=steps)


def test_mutation_swapped_perm_edge_rejected():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    st0 = sched.steps[0]
    (s0, d0), (s1, d1), *rest = st0.perm
    bad = _swap_step(sched, 0, perm=((s0, d1), (s1, d0), *rest))
    with pytest.raises((DoubleReduceError, ResultError)) as ei:
        verify_schedule(bad)
    assert ei.value.where.schedule == sched.name


def test_mutation_offbyone_chunk_rejected():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    st0 = sched.steps[0]
    send = list(st0.send_chunk)
    src = st0.perm[0][0]
    send[src] = (send[src] + 1) % sched.num_chunks
    bad = _swap_step(sched, 0, send_chunk=tuple(send))
    with pytest.raises((DoubleReduceError, ResultError)):
        verify_schedule(bad)


def test_mutation_chunk_out_of_range_is_legality_error():
    sched = build_ring_all_reduce([0, 1, 2], 3)
    send = list(sched.steps[0].send_chunk)
    src = sched.steps[0].perm[0][0]
    send[src] = sched.num_chunks          # one past the end
    bad = _swap_step(sched, 0, send_chunk=tuple(send))
    with pytest.raises(StepLegalityError) as ei:
        verify_schedule(bad)
    assert ei.value.where.step == 0 and ei.value.where.rank == src


def test_mutation_dropped_accumulate_rejected():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    assert sched.steps[0].accumulate
    bad = _swap_step(sched, 0, accumulate=False)
    with pytest.raises(ResultError) as ei:
        verify_schedule(bad)
    assert "missing" in str(ei.value)


def test_mutation_reordered_broadcast_steps_stale_read():
    sched = build_ring_broadcast([0, 1, 2, 3], 4, root=0)
    # forward a chunk before the round that delivers it has run
    steps = list(sched.steps)
    steps[0], steps[-1] = steps[-1], steps[0]
    bad = dataclasses.replace(sched, steps=steps)
    with pytest.raises(StaleReadError) as ei:
        verify_schedule(bad)
    assert ei.value.where.rank is not None


def test_mutation_duplicate_source_is_legality_error():
    sched = build_ring_all_reduce([0, 1, 2], 3)
    st0 = sched.steps[0]
    (s0, d0), (_s1, d1), *rest = st0.perm
    bad = _swap_step(sched, 0, perm=((s0, d0), (s0, d1), *rest))
    with pytest.raises(StepLegalityError) as ei:
        verify_schedule(bad)
    assert "duplicate source" in str(ei.value)


def test_mutation_double_reduce_detected_at_offending_step():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    # replay the first reduce round verbatim: every contribution it moved
    # is accumulated a second time
    steps = list(sched.steps)
    steps.insert(1, steps[0])
    bad = dataclasses.replace(sched, steps=steps)
    with pytest.raises(DoubleReduceError) as ei:
        verify_schedule(bad)
    assert ei.value.where.step == 1


def test_empty_result_ranks_rejected_for_semantic_names():
    sched = build_ring_all_reduce([0, 1, 2], 3)
    bad = dataclasses.replace(sched, result_ranks=())
    with pytest.raises(ResultRanksError):
        verify_schedule(bad)
    # but an opaque name with no claim passes legality-only verification
    opaque = dataclasses.replace(bad, name="scratch")
    rep = verify_schedule(opaque)
    assert rep.semantics is Semantics.OPAQUE


def test_result_rank_out_of_range_rejected():
    sched = build_ring_all_reduce([0, 1, 2], 3)
    bad = dataclasses.replace(sched, result_ranks=(0, 1, 2, 7))
    with pytest.raises(ResultRanksError) as ei:
        verify_schedule(bad)
    assert ei.value.where.rank == 7


def test_program_fraction_error_is_typed():
    prog = ring_program([0, 1, 2], 3)
    bad = CollectiveProgram(prog.name, 3, [
        Segment(0.7, prog.segments[0].schedule)])
    with pytest.raises(ProgramError):
        verify_program(bad)


def test_all_builders_populate_result_ranks():
    for label, obj in builder_corpus(seed=0, max_n=5):
        scheds = ([s.schedule for s in obj.segments]
                  if isinstance(obj, CollectiveProgram) else [obj])
        for s in scheds:
            assert s.result_ranks, f"{label}: {s.name} has empty result_ranks"


# ---------------------------------------------------------------------------
# deadlock analysis
# ---------------------------------------------------------------------------

def test_deadlock_free_counts_transfers():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    assert check_deadlock_free(sched) == sum(
        len(s.perm) for s in sched.steps)


def test_cross_segment_wait_cycle_is_deadlock():
    a = build_ring_broadcast([0, 1, 2], 3, root=0)
    b = build_ring_broadcast([2, 1, 0], 3, root=2)
    prog = CollectiveProgram("scratch", 3,
                             [Segment(0.5, a), Segment(0.5, b)])
    # acyclic cross-segment barrier: fine
    assert check_deadlock_free(prog, cross_segment_deps={1: [0]}) > 0
    # mutual wait: every transfer of each segment waits on the other
    with pytest.raises(DeadlockError) as ei:
        check_deadlock_free(prog, cross_segment_deps={0: [1], 1: [0]})
    assert len(ei.value.cycle) >= 2
    segs = {c[0] for c in ei.value.cycle}
    assert segs == {0, 1}


def test_infer_semantics_builder_names():
    assert infer_semantics("ring_ar[8]") is Semantics.ALL_REDUCE
    assert infer_semantics("partial_ar[7]+bridge") is Semantics.ALL_REDUCE
    assert infer_semantics("ring_rs[4]") is Semantics.REDUCE_SCATTER
    assert infer_semantics("ring_ag[4]") is Semantics.ALL_GATHER
    assert infer_semantics("ring_bcast[4]") is Semantics.BROADCAST
    assert infer_semantics("tree_reduce[4]") is Semantics.REDUCE
    assert infer_semantics("pp_chain[4]") is Semantics.BROADCAST
    assert infer_semantics("residual[r2ccl_all_reduce]") is \
        Semantics.ALL_REDUCE
    assert infer_semantics("scratch") is Semantics.OPAQUE


# ---------------------------------------------------------------------------
# typed errors survive python -O (the old bare asserts did not)
# ---------------------------------------------------------------------------

def test_validate_raises_under_python_O():
    code = (
        "from repro.core.schedule import Step\n"
        "from repro.analysis.errors import ScheduleError\n"
        "bad = Step(((0, 1), (0, 2)), (0, -1, -1), (-1, 0, 0))\n"
        "try:\n"
        "    bad.validate(3, 1)\n"
        "except ScheduleError:\n"
        "    print('caught')\n"
    )
    out = subprocess.run(
        [sys.executable, "-O", "-c", code],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "caught"


# ---------------------------------------------------------------------------
# determinism lint
# ---------------------------------------------------------------------------

def _rules(src):
    return [f.rule for f in lint_source(src)]


def test_lint_wall_clock():
    assert "DET001" in _rules("import time\nnow = time.time()\n")
    assert "DET001" in _rules(
        "import datetime\nd = datetime.datetime.now()\n")
    assert _rules("now = sim.clock()\n") == []


def test_lint_unseeded_random():
    assert "DET002" in _rules("import random\nx = random.random()\n")
    assert "DET002" in _rules(
        "import numpy as np\nx = np.random.uniform()\n")
    assert "DET002" in _rules("import random\nr = random.Random()\n")
    assert "DET002" in _rules(
        "import numpy as np\nr = np.random.default_rng()\n")
    # seeded constructions are deterministic
    assert _rules("import random\nr = random.Random(7)\nx = r.random()\n") \
        == []
    assert _rules(
        "import numpy as np\nr = np.random.default_rng(0)\n"
        "x = r.uniform()\n") == []


def test_lint_set_iteration():
    assert "DET003" in _rules("s = {1, 2}\nfor x in s:\n    print(x)\n")
    assert "DET003" in _rules(
        "def f(active: set[int]):\n    return [x for x in active]\n")
    assert "DET003" in _rules(
        "class A:\n"
        "    def __init__(self):\n"
        "        self.live = set()\n"
        "    def go(self):\n"
        "        for x in self.live:\n"
        "            print(x)\n")
    # sorted() wrapping and set-comprehension results are order-safe
    assert _rules("s = {1, 2}\nfor x in sorted(s):\n    print(x)\n") == []
    assert _rules("s = {1, 2}\nt = {x + 1 for x in s}\n") == []
    # rebinding to a non-set clears the inference
    assert _rules("s = {1}\ns = [1]\nfor x in s:\n    print(x)\n") == []


def test_lint_float_time_equality():
    assert "DET004" in _rules("def f(now, t_end):\n    return now == t_end\n")
    assert "DET004" in _rules("def f(now):\n    return now == 0.5\n")
    # int sentinels and None guards are fine
    assert _rules("def f(now):\n    return now == 0\n") == []
    assert _rules("def f(now):\n    return now == None\n") == []
    assert _rules("def f(count, total):\n    return count == total\n") == []


def test_lint_frozen_mutation():
    frozen = (
        "import dataclasses\n"
        "@dataclasses.dataclass(frozen=True)\n"
        "class P:\n"
        "    x: int\n"
    )
    assert "DET005" in _rules(
        frozen + "def f(p: P):\n    p.x = 3\n")
    assert "DET005" in _rules(
        frozen + "def f(p):\n    object.__setattr__(p, 'x', 3)\n")
    # __post_init__ is the blessed frozen-init escape hatch
    assert _rules(
        "import dataclasses\n"
        "@dataclasses.dataclass(frozen=True)\n"
        "class P:\n"
        "    x: int\n"
        "    def __post_init__(self):\n"
        "        object.__setattr__(self, 'x', abs(self.x))\n") == []
    # mutating a non-frozen instance is fine
    assert _rules(
        "import dataclasses\n"
        "@dataclasses.dataclass\n"
        "class Q:\n"
        "    x: int\n"
        "def f(q: Q):\n    q.x = 3\n") == []


def test_lint_clean_on_all_default_targets():
    # the gate covers core, runtime, analysis, AND serving (the analyzer
    # must satisfy its own determinism contract; serving reads the host
    # clock only through its injected seam)
    findings = lint_paths([REPO / "src/repro/core",
                           REPO / "src/repro/runtime",
                           REPO / "src/repro/analysis",
                           REPO / "src/repro/serving"])
    assert findings == [], "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# verified replans in the engine
# ---------------------------------------------------------------------------

def _cluster():
    return ClusterTopology(num_nodes=4, devices_per_node=4)


def test_verify_replans_passes_on_replanning_campaign():
    scen = flap_storm(0.004, node=1, count=4)
    base = run_scenario(scen, _cluster(), 4e8)
    checked = run_scenario(scen, _cluster(), 4e8, verify_replans=True)
    assert checked.report.replans > 0, "scenario must actually replan"
    # verification is observation-only: identical timeline
    assert checked.report.completion_time == base.report.completion_time
    assert checked.report.replans == base.report.replans


def test_verify_replans_passes_on_clean_nic_down():
    scen = clean_nic_down(0.004, node=1)
    rep = run_scenario(scen, _cluster(), 4e8, verify_replans=True)
    assert rep.report.completion_time > 0


def test_verify_replans_rejects_corrupt_program():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    bad = _swap_step(sched, 0, accumulate=False)
    prog = CollectiveProgram("ring_all_reduce", 4, [Segment(1.0, bad)])
    caps = [1e9] * 4
    # legality-only validate() lets the semantic corruption through
    EventSimulator(prog, 1e6, capacities=caps, g=2)
    with pytest.raises(ResultError):
        EventSimulator(prog, 1e6, capacities=caps, g=2, verify_replans=True)


def test_analysis_cli_verify_and_lint():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "verify", "--max-n", "3"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stderr
    assert "clean" in out.stdout


# ---------------------------------------------------------------------------
# static cost analysis: engine conformance
# ---------------------------------------------------------------------------

def _uniform_caps(n):
    return [CONFORMANCE_CAPACITY] * n


def test_static_cost_bit_exact_on_lockstep_uniform_corpus():
    """The tentpole guarantee: for every corpus entry in the uncontended
    lockstep class, the static prediction equals the event engine's healthy
    completion *bit-exactly*; everything else stays within the pinned
    corpus tolerance."""
    uniform = total = 0
    for label, obj in builder_corpus(seed=0, max_n=6):
        prog = as_program(obj)
        caps = _uniform_caps(prog.n)
        rep = analyze_program(prog, CONFORMANCE_PAYLOAD, capacities=caps)
        engine = healthy_completion(prog, CONFORMANCE_PAYLOAD,
                                    capacities=caps, g=2)
        total += 1
        if rep.lockstep_uniform:
            uniform += 1
            assert rep.predicted_time == engine, (
                f"{label}: lockstep-uniform entry must be bit-exact "
                f"(static={rep.predicted_time!r} engine={engine!r})")
        rel = abs(rep.predicted_time - engine) / engine
        assert rel <= CORPUS_COST_TOLERANCE, (
            f"{label}: rel error {rel:.4g} exceeds {CORPUS_COST_TOLERANCE}")
    assert uniform > 50, "the bit-exact class must dominate the corpus"
    assert uniform < total, "multi-segment entries must also be exercised"


def test_static_cost_bit_exact_under_heterogeneous_capacities():
    # the guarantee is about lockstep uniformity, not uniform capacity:
    # a ring on skewed-but-positive capacities loses uniformity (rounds
    # skew), but the prediction must still track the engine within the
    # corpus tolerance
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    caps = [25e9, 25e9, 12.5e9, 25e9]
    rep = analyze_schedule(sched, CONFORMANCE_PAYLOAD, capacities=caps)
    engine = healthy_completion(as_program(sched), CONFORMANCE_PAYLOAD,
                                capacities=caps, g=2)
    rel = abs(rep.predicted_time - engine) / engine
    assert rel <= CORPUS_COST_TOLERANCE


def test_cost_report_structure():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    rep = analyze_schedule(sched, CONFORMANCE_PAYLOAD,
                           capacities=_uniform_caps(4))
    assert rep.completes and rep.lockstep_uniform
    assert rep.rounds == len(sched.steps)
    assert rep.transfers == sum(len(s.perm) for s in sched.steps)
    # a ring moves every byte it sends: per-link and per-rank loads agree
    assert sum(rep.link_bytes.values()) == pytest.approx(
        sum(rep.rank_tx_bytes))
    assert sum(rep.rank_tx_bytes) == pytest.approx(sum(rep.rank_rx_bytes))
    # hotspots ranked by utilization, densest first, all finite
    utils = [h.utilization for h in rep.hotspots]
    assert utils == sorted(utils, reverse=True)
    assert all(0.0 < u <= 1.0 for u in utils)
    # uniform ring: every direction equally hot
    assert len(set(utils)) == 1
    top = rep.top_links(3)
    assert len(top) == 3
    assert top[0].load_bytes >= top[-1].load_bytes
    json.dumps(rep.to_dict())            # must be JSON-serializable


def test_cost_prediction_infinite_without_live_path():
    sched = build_ring_all_reduce([0, 1, 2], 3)
    rep = analyze_schedule(sched, CONFORMANCE_PAYLOAD,
                           capacities=[25e9, 0.0, 25e9])
    assert not rep.completes
    assert rep.predicted_time == math.inf


def test_cost_zero_payload_is_pure_latency():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    rep = analyze_schedule(sched, 0.0, capacities=_uniform_caps(4))
    # every transfer hits the completion-epsilon branch: alpha per round
    assert rep.predicted_time == pytest.approx(rep.alpha * rep.rounds)
    engine = healthy_completion(as_program(sched), 0.0,
                                capacities=_uniform_caps(4), g=2)
    assert rep.predicted_time == engine


def test_cost_topology_argument_contract():
    sched = build_ring_all_reduce([0, 1, 2], 3)
    with pytest.raises(ValueError):
        analyze_schedule(sched, 1e6)                       # neither
    with pytest.raises(ValueError):
        analyze_schedule(sched, 1e6, capacities=[1e9] * 2)  # wrong arity
    cluster = make_cluster(3, 4)
    with pytest.raises(ValueError):
        analyze_schedule(sched, 1e6, cluster=cluster,
                         capacities=[1e9] * 3)             # both


# ---------------------------------------------------------------------------
# failure-coverage analysis
# ---------------------------------------------------------------------------

def test_coverage_multi_rail_survivable():
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    rep = check_coverage(sched, CONFORMANCE_PAYLOAD,
                         capacities=_uniform_caps(4), g=2)
    assert rep.survivable_fraction == 1.0
    assert rep.findings == ()
    assert len(rep.entries) == 4 * 2
    # losing one of two rails halves the slowest rank's capacity
    e = rep.entry(1, 0)
    assert e.participates and e.survivable
    assert e.slowdown > 1.0 and math.isfinite(e.degraded_time)
    assert rep.worst_slowdown >= e.slowdown
    json.dumps(rep.to_dict())


def test_coverage_single_rail_pinned_is_non_survivable():
    """Mutation guard: pin all transfers to one rail per rank (g=1) and the
    analyzer must statically flag every participant failure as fatal, with
    typed provenance."""
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    rep = analyze_coverage(sched, CONFORMANCE_PAYLOAD,
                           capacities=_uniform_caps(4), g=1)
    assert rep.survivable_fraction == 0.0
    assert len(rep.findings) == 4
    f = rep.findings[0]
    assert isinstance(f, CoverageError)
    assert isinstance(f, ScheduleError)          # typed like the verifier's
    assert f.node == 0 and f.rail == 0
    assert f.where is not None and f.where.schedule == sched.name
    assert rep.entry(0, 0).stranded_ranks == (0,)
    assert rep.entry(0, 0).degraded_time == math.inf
    with pytest.raises(CoverageError):
        check_coverage(sched, CONFORMANCE_PAYLOAD,
                       capacities=_uniform_caps(4), g=1)


def test_coverage_non_participant_failure_is_survivable():
    # rank 3 carries no traffic in a 3-rank ring embedded in 4 capacities
    sched = build_ring_all_reduce([0, 1, 2], 3)
    prog = CollectiveProgram(sched.name, 4, [Segment(1.0, sched)])
    rep = analyze_coverage(prog, CONFORMANCE_PAYLOAD,
                           capacities=_uniform_caps(4), g=1)
    e = rep.entry(3, 0)
    assert not e.participates and e.survivable
    assert e.slowdown == 1.0
    # the participants are still flagged
    assert not rep.entry(0, 0).survivable


def test_coverage_matches_event_engine_on_degraded_capacity():
    # the static degraded bound under a half-capacity rank conforms to the
    # engine run on the same residual capacities
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    rep = analyze_coverage(sched, CONFORMANCE_PAYLOAD,
                           capacities=_uniform_caps(4), g=2)
    e = rep.entry(2, 1)
    residual = _uniform_caps(4)
    residual[2] /= 2
    engine = healthy_completion(as_program(sched), CONFORMANCE_PAYLOAD,
                                capacities=residual, g=2)
    rel = abs(e.degraded_time - engine) / engine
    assert rel <= CORPUS_COST_TOLERANCE


# ---------------------------------------------------------------------------
# proof-memo LRU: cache pressure never changes verification results
# ---------------------------------------------------------------------------

def _report_key(r):
    return (r.schedule, r.semantics, r.contributors, r.result_ranks,
            r.steps, r.transfers, r.root)


def test_proof_memo_pressure_never_changes_results(monkeypatch):
    from repro.analysis import verify as V

    entries = list(builder_corpus(seed=2, max_n=5))

    def run_all():
        out = {}
        for label, obj in entries:
            if isinstance(obj, CollectiveProgram):
                reps = verify_program(obj)
            else:
                reps = [verify_schedule(obj)]
            out[label] = tuple(_report_key(r) for r in reps)
        return out

    V.clear_memos()
    baseline = run_all()

    # tiny caps: every put evicts something, both passes thrash
    monkeypatch.setattr(V, "_SCHED_MEMO", V._ProofMemo(cap=2))
    monkeypatch.setattr(V, "_PROG_MEMO", V._ProofMemo(cap=2))
    first = run_all()
    second = run_all()
    stats = V.memo_stats()
    assert stats["schedule"]["evictions"] > 0, (
        "cap-2 memo over the corpus must actually evict")
    assert stats["schedule"]["size"] <= 2
    assert first == baseline
    assert second == baseline


def test_proof_memo_lru_recency_and_counters():
    from repro.analysis.verify import _ProofMemo

    memo = _ProofMemo(cap=2)
    memo.put("a", 1)
    memo.put("b", 2)
    assert memo.get("a") == 1           # refreshes "a" to most-recent
    memo.put("c", 3)                    # evicts "b", the LRU entry
    assert memo.get("b") is None
    assert memo.get("a") == 1 and memo.get("c") == 3
    s = memo.stats()
    assert s["evictions"] == 1 and s["size"] == 2 and s["cap"] == 2
    assert s["hits"] == 3 and s["misses"] == 1
    memo.clear()
    assert len(memo) == 0 and memo.stats()["hits"] == 0


def test_memoized_verify_hits_on_repeat():
    from repro.analysis import verify as V

    V.clear_memos()
    sched = build_ring_all_reduce([0, 1, 2, 3], 4)
    verify_schedule(sched)
    misses = V.memo_stats()["schedule"]["misses"]
    verify_schedule(sched)
    after = V.memo_stats()["schedule"]
    assert after["hits"] >= 1
    assert after["misses"] == misses    # second call never re-proves


# ---------------------------------------------------------------------------
# cost / coverage CLI (the CI artifact path)
# ---------------------------------------------------------------------------

def _run_cli(*argv, cwd=None):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, cwd=cwd,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_analysis_cli_cost_corpus(tmp_path):
    out_path = tmp_path / "cost.json"
    out = _run_cli("cost", "--corpus", "--max-n", "3",
                   "--out", str(out_path))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "bit-exact" in out.stdout
    doc = json.loads(out_path.read_text())
    assert doc["conformance_ran"] is True
    assert doc["max_rel_error"] <= doc["tolerance"]
    assert doc["bit_exact"] == doc["lockstep_uniform"]
    assert len(doc["entries"]) == doc["entries_total"] > 0


def test_analysis_cli_coverage(tmp_path):
    out_path = tmp_path / "coverage.json"
    out = _run_cli("coverage", "--max-n", "3", "--out", str(out_path))
    assert out.returncode == 0, out.stdout + out.stderr
    doc = json.loads(out_path.read_text())
    assert doc["survivable_fraction"] == 1.0
    assert doc["failure_cells"] > 0
