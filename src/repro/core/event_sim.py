"""Discrete-event cluster simulator executing real collective schedules.

The alpha-beta model in :mod:`core.comm_sim` predicts a collective's time
from a closed-form formula; it cannot represent mid-collective failures,
contention between concurrent transfers, or straggler dynamics.  This
module is the SimAI-style counterpart: an absolute-time event engine that
*executes* the actual :class:`core.schedule.CollectiveProgram` emitted by
``recursive.py`` / ``planner.py`` / ``allreduce.py`` — the same IR the
numpy oracle and the JAX backend run — transfer by transfer.

Model
-----
* Each program rank is a node with full-duplex egress/ingress capacity
  (the sum of its healthy NICs, or an explicit per-rank capacity).
* All transfers concurrently in flight share bandwidth by **max-min
  fairness** subject to per-rank tx and rx capacities (progressive
  filling), recomputed at every event — the flow-level network model used
  by SimAI's analytical backend.
* A transfer of step ``i`` is released once both its endpoints finished
  their transfers of their previous participating step (per-rank lockstep;
  no global barrier).  Segments of a program run concurrently and compete
  for bandwidth, so the stage-overlap of the R2CCL decomposition *emerges*
  instead of being assumed.
* Each released transfer pays the per-hop latency ``alpha``, then streams
  its bytes at the fair-share rate.
* Failures are injected at absolute simulated timestamps from
  :class:`core.failures.Failure`: a hard NIC/link failure removes that
  NIC's bandwidth and **rolls back** every in-flight transfer riding it
  (chunk-granularity DMA rollback — bytes already streamed are counted as
  retransmitted and the transfer restarts after ``repair_latency``); a
  ``recovers_at`` timestamp restores the bandwidth (link flap); a
  fractional ``severity`` (slow NIC) only rescales bandwidth and triggers
  no rollback.
* When ``rank_data`` is given the engine also moves real numpy payloads
  (snapshot at transfer start, write/accumulate at completion), so
  conservation under failure is *checked*, not presumed.
* The engine tracks a **per-rank, per-chunk completion map**: a chunk is
  durably complete at a rank once every write the schedule directs at it
  has landed — by the per-rank lockstep dependency order that is exactly
  when the chunk holds its end-of-schedule value.  The map is what makes
  a mid-collective program swap payload-conserving (see below) and is
  exported to the control plane as :class:`ChunkProgress` so the planner
  prices the *residual* collective, not the whole payload.
* An optional ``controller`` (the online recovery control plane in
  :mod:`repro.runtime`) is consulted at every failure/recovery event in
  virtual time.  Its :class:`RecoveryDecision` *derives* the restart delay
  from the detect→diagnose→migrate→rebalance pipeline instead of the
  closed-form ``repair_latency`` constant, rescales residual capacity by
  the rebalance detour efficiency, and may swap in a freshly planned
  :class:`CollectiveProgram` mid-collective at chunk granularity.  The
  swap resumes from the exact chunk map: *settled* chunks (final at every
  rank that needs them) are retained verbatim, chunks final at *some*
  ranks are broadcast from a holder to the ranks still missing them, and
  only chunks final **nowhere** are rolled back to their pristine
  contributions and re-reduced under the new program — so real payloads
  survive the swap and conservation stays checkable end-to-end.
* A recovery event (flap back up) is *physical*; when a controller is
  attached, the capacity is only restored once the controller confirms it
  — at its next scheduled re-probe tick — so the probe cadence shapes
  recovery latency in the simulated timeline.
* The engine is **multi-stream**: one instance co-simulates a set of named
  :class:`Stream`\\ s (e.g. the TP activation AllReduce, the PP activation
  handoff, and the DP gradient sync of one training iteration), every
  stream's transfers sharing the same max-min fair per-link bandwidth
  model — cross-stream contention emerges from exactly the fairness code
  path that a single program's concurrent segments already use.  Streams
  carry a ``priority`` (weighted max-min fair share), a ``start_time``,
  and their own ``rank_data``; completion, rollback/retransmit, and
  replan accounting are kept per stream (:class:`StreamReport`) with the
  report's original scalars preserved as the cross-stream sums.  A
  failure rolls back in-flight transfers of *every* stream riding the
  dead rail, and a control-plane ``capacity_scale`` (rebalance detour
  efficiency) re-prices every stream crossing the rank — the shared-NIC
  physics, not a per-collective view.  A mid-collective replan is
  stream-scoped (:attr:`RecoveryDecision.replan_stream`): only the target
  stream's program is swapped while co-running streams keep flowing.

The engine reports per-collective completion time, per-link bytes,
per-rank egress utilization, and retransmitted bytes after failover.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import sys
from typing import Mapping, Sequence

import numpy as np

from .failures import Failure, OUT_OF_SCOPE
from .telemetry import Telemetry
from .schedule import (
    ChunkSchedule,
    CollectiveProgram,
    Segment,
    build_ring_broadcast,
)
from .topology import ClusterTopology, DEFAULT_ALPHA

#: restart delay after a rollback (matches the paper's low-millisecond
#: hot-repair figure; see core.migration.migration_latency for the breakdown)
DEFAULT_REPAIR_LATENCY = 1.5e-3

_BLOCKED, _LATENT, _ACTIVE, _DONE, _CANCELLED = range(5)

_FLOAT_EPS = sys.float_info.epsilon


def _time_tol(now: float) -> float:
    """Same-timestamp bucket tolerance at clock value ``now``.

    Co-timestamped events reach the queue through different float
    expressions (``(t + a) + b`` vs ``t + (a + b)``) and so land a few
    ulps apart.  One ulp grows with the clock — at ``now`` ≈ 16384 s it
    is ~3.6e-12, far above a fixed 1e-15 — so the tolerance must scale
    with ``now`` or long campaigns silently split one logical bucket
    across loop iterations.  Four ulps of slack covers the association
    noise while staying ~3 orders of magnitude below ``alpha``, the
    smallest genuine gap between distinct rounds.
    """
    return 1e-15 + 4.0 * _FLOAT_EPS * now


class EventSimError(RuntimeError):
    pass


class StalledError(EventSimError):
    """No transfer can make progress and no future event can unblock one."""


@dataclasses.dataclass
class _Transfer:
    tid: int
    seg: int
    step: int
    src: int
    dst: int
    size: float                  # bytes
    accumulate: bool
    whole_buffer: bool
    send_chunk: int
    recv_chunk: int
    deps: int = 0                # unfinished prerequisite transfers
    state: int = _BLOCKED
    payload: np.ndarray | None = None
    dependents: list[int] = dataclasses.field(default_factory=list)
    stream: int = 0              # owning stream index
    weight: float = 1.0          # stream priority (weighted fair share)


@dataclasses.dataclass
class _SegState:
    """Chunk-completion bookkeeping for one instantiated segment.

    ``writers_left[r, c]`` counts the writes the segment's schedule still
    owes chunk ``c`` at rank ``r``; zero means the chunk holds its
    end-of-schedule value there (per-rank lockstep orders the writes, so
    the last one landing *is* the final value).  ``needed`` is the rank set
    that must end with the final value — the schedule's ``result_ranks``,
    falling back to its participants.
    """

    schedule: ChunkSchedule
    seg_bytes: float                      # timing bytes of this segment
    needed: tuple[int, ...]
    writers_left: np.ndarray              # (n, num_chunks) int
    retired: bool = False                 # superseded by a replan
    stream: int = 0                       # owning stream index


@dataclasses.dataclass
class _SegData:
    """Real-payload buffers of one segment, remappable across replans.

    ``dest`` maps the first ``len(dest)`` elements of the flattened chunk
    buffer back to positions in the original flat input (trailing elements
    are chunk padding).  ``write_ranks`` limits which ranks' buffers are
    meaningful at write-back time (a residual delivery broadcast only
    covers the holder and the missing ranks); None = all ranks.
    """

    bufs: list[np.ndarray]                # [rank] -> (num_chunks, chunk_len)
    dest: np.ndarray                      # original flat positions
    write_ranks: tuple[int, ...] | None = None


@dataclasses.dataclass
class RecoveryDecision:
    """What the online control plane tells the engine to do about one failure.

    Returned by ``controller.on_failure``; every field is optional-by-default
    so a controller can intervene as little or as much as it likes.
    """

    #: restart delay for transfers rolled back by this failure — derived from
    #: the detect→diagnose→migrate→rebalance pipeline, replacing the engine's
    #: closed-form ``repair_latency`` constant
    repair_latency: float
    #: per-rank multiplicative factor on residual capacity (rebalance detour
    #: efficiency); removed again when the failure recovers
    capacity_scale: Mapping[int, float] | None = None
    #: new collective program to swap in mid-collective (algorithm
    #: re-selection); completed chunk work is retained
    replan: "CollectiveProgram | None" = None
    #: virtual time from the failure until the new program is live (the full
    #: pipeline latency including the replan stage)
    replan_delay: float = 0.0
    #: payload the planner priced when choosing ``replan`` — the engine's
    #: residual (not-yet-settled) bytes at the failure instant, when the
    #: chunk map was threaded through; None = planned for the full payload
    replan_payload: float | None = None
    #: name of the stream ``replan`` swaps the program of (a control plane
    #: manages one collective; co-running streams keep flowing); None = the
    #: engine's primary (first) stream
    replan_stream: str | None = None


@dataclasses.dataclass(frozen=True)
class ChunkProgress:
    """The engine's chunk-map summary at one instant, planner-facing.

    ``rereduce_bytes`` is payload final at *no* rank (must be re-reduced
    from pristine contributions), ``deliver_bytes`` is payload final at
    some rank but still missing elsewhere (a broadcast completes it).
    Everything else is settled — durably complete at every rank that
    needs it — and survives a program swap untouched.
    """

    total_bytes: float
    rereduce_bytes: float
    deliver_bytes: float

    @property
    def residual_bytes(self) -> float:
        return self.rereduce_bytes + self.deliver_bytes

    @property
    def settled_bytes(self) -> float:
        return max(0.0, self.total_bytes - self.residual_bytes)

    @property
    def residual_fraction(self) -> float:
        return (self.residual_bytes / self.total_bytes
                if self.total_bytes > 0 else 0.0)


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One mid-collective program swap, as the engine executed it."""

    at_time: float
    #: payload the residual program was instantiated over (timing bytes)
    residual_bytes: float
    #: residual as a fraction of the collective's original payload
    residual_fraction: float
    #: residual final at no rank — rolled back to pristine and re-reduced
    rereduce_bytes: float
    #: residual final at a holder rank — broadcast to the missing ranks
    deliver_bytes: float
    #: the superseded (active) program's completed transfer bytes at the
    #: swap — its durable progress; earlier retired programs not included
    done_bytes: float
    #: unfinished transfers of the superseded program cancelled at the swap
    cancelled: int
    #: name of the stream whose program was swapped
    stream: str = "main"


@dataclasses.dataclass(frozen=True)
class RepairEvent:
    """One hard failure's hot-repair as the engine observed it."""

    at_time: float
    delay: float                 # restart delay applied to rolled-back flows
    rollbacks: int               # in-flight transfers rewound by this failure
    derived: bool                # True = delay came from a controller pipeline


@dataclasses.dataclass(frozen=True, eq=False)
class Stream:
    """One named collective stream co-scheduled on the shared fabric.

    A training iteration's concurrent parallelism traffic is a set of
    streams — e.g. the TP activation AllReduce, the PP activation handoff,
    and the DP gradient sync — each with its own
    :class:`~repro.core.schedule.CollectiveProgram`, payload, optional real
    ``rank_data``, a ``priority`` weight in the max-min fair bandwidth
    share, and a ``start_time`` offsetting its release into the timeline.
    All streams of one engine must have the same rank count (they share
    the NICs of the same nodes).
    """

    name: str
    program: CollectiveProgram
    payload_bytes: float
    priority: float = 1.0
    start_time: float = 0.0
    rank_data: Sequence[np.ndarray] | None = None


@dataclasses.dataclass
class StreamReport:
    """One stream's view of a multi-stream run.

    The parent :class:`EventSimReport`'s scalar aggregates
    (``retransmitted_bytes``, ``failovers``, ``replans``,
    ``cancelled_transfers``) are exactly the sums of these per-stream
    breakdowns.
    """

    name: str
    payload_bytes: float
    priority: float
    start_time: float
    #: absolute finish time of the stream's last completed transfer
    completion_time: float
    transfers: int
    #: bytes this stream put on the wire, retransmission waste included —
    #: equals completed-transfer bytes + retransmitted_bytes, and the
    #: cross-stream sum equals sum(report.link_bytes.values())
    moved_bytes: float
    retransmitted_bytes: float
    failovers: int
    replans: int
    cancelled_transfers: int
    replan_events: list[ReplanEvent]
    #: final per-rank buffers when the stream carried ``rank_data``
    rank_data: list[np.ndarray] | None = None


@dataclasses.dataclass
class _StreamState:
    """Engine-internal mutable state of one stream."""

    index: int
    spec: Stream
    prog: CollectiveProgram               # active (possibly residual) program
    #: absolute segment indices owned by this stream, in creation order
    seg_ids: list[int] = dataclasses.field(default_factory=list)
    #: index into ``seg_ids`` of the active program's first segment
    #: (advances at every replan of this stream)
    active_seg_start: int = 0
    remaining: int = 0                    # unfinished transfers
    finish_time: float = 0.0
    transfers: int = 0
    moved_bytes: float = 0.0
    retransmitted_bytes: float = 0.0
    failovers: int = 0
    replans: int = 0
    cancelled: int = 0
    replan_events: list[ReplanEvent] = dataclasses.field(default_factory=list)
    has_data: bool = False
    #: pristine per-rank contributions (replan rollback target)
    pristine: list[np.ndarray] | None = None
    orig_total: int = 0


@dataclasses.dataclass
class EventSimReport:
    """What one simulated collective (or set of concurrent streams) did."""

    completion_time: float
    #: absolute finish time of each segment's last transfer, cumulative
    #: across streams and program swaps: every stream's initial program
    #: segments first (stream declaration order), then each replanned
    #: residual program's, in instantiation order.  Timestamps of segments
    #: that finished before a replan are preserved, not reset.
    segment_finish: list[float]
    #: bytes moved per directed (src, dst) rank pair, retransmissions included
    link_bytes: dict[tuple[int, int], float]
    rank_tx_bytes: dict[int, float]
    rank_rx_bytes: dict[int, float]
    #: egress busy fraction per rank: bytes sent / (healthy capacity * makespan)
    link_utilization: dict[int, float]
    retransmitted_bytes: float
    failovers: int
    transfers: int
    events: int
    #: final per-rank buffers when ``rank_data`` was supplied, else None
    rank_data: list[np.ndarray] | None = None
    #: mid-collective program swaps performed by the control plane
    replans: int = 0
    #: transfers of a superseded program cancelled at a replan point
    cancelled_transfers: int = 0
    #: per-hard-failure hot-repair record, in virtual-time order
    repair_events: list[RepairEvent] = dataclasses.field(default_factory=list)
    #: per-swap chunk-exact residual accounting, in virtual-time order
    #: (all streams; each event names its stream)
    replan_events: list[ReplanEvent] = dataclasses.field(default_factory=list)
    #: per-stream breakdown, in stream declaration order; the scalar
    #: aggregates above are the sums across these.  A single-program run
    #: has exactly one entry named "main", and the report-level
    #: ``rank_data`` is the primary (first) stream's
    streams: dict[str, StreamReport] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# capacity bookkeeping
# ---------------------------------------------------------------------------

class _Capacities:
    """Per-rank egress/ingress capacity under timed NIC-level degradation."""

    def __init__(self, base: Sequence[float], rail_bw: Sequence[Sequence[float]]):
        self.base = list(base)
        self.rail_bw = [list(r) for r in rail_bw]          # per rank, per rail
        # active degradations keyed by the *failure event itself* so a
        # flap's recovery can never resurrect a rail a different failure
        # killed: per rank, failure -> (rail, severity)
        self._lost: list[dict[Failure, tuple[int, float]]] = [{} for _ in base]
        # multiplicative residual-capacity factors installed by the control
        # plane (rebalance detour efficiency), keyed by failure for the same
        # recovery-safety reason: per rank, failure -> factor
        self._scale: list[dict[Failure, float]] = [{} for _ in base]

    @classmethod
    def from_cluster(cls, cluster: ClusterTopology) -> "_Capacities":
        return cls(cluster.bandwidths(), cluster.rail_bandwidths())

    @classmethod
    def uniform(cls, capacities: Sequence[float], g: int) -> "_Capacities":
        rails = [[c / g] * g for c in capacities]
        return cls(capacities, rails)

    def num_rails(self, rank: int) -> int:
        return len(self.rail_bw[rank])

    def fail(self, rank: int, failure: Failure) -> None:
        self._lost[rank][failure] = (failure.rail, failure.severity)

    def rail_dead(self, rank: int, rail: int) -> bool:
        """True while any active hard failure still holds ``rail`` down."""
        return any(r == rail and sev >= 1.0
                   for r, sev in self._lost[rank].values())

    def rail_loss(self, rank: int, rail: int) -> float:
        """Worst active lost-bandwidth fraction on one rail (0.0 = healthy,
        1.0 = dead) — what an active probe of the rail would measure."""
        return max((sev for r, sev in self._lost[rank].values() if r == rail),
                   default=0.0)

    def recover(self, rank: int, failure: Failure) -> list[int]:
        """Lift ``failure``'s degradation.  Returns every rank whose
        capacity this changed — the failed rank plus any rank carrying a
        control-plane factor keyed by the failure — so the engine can
        invalidate exactly those cached capacities."""
        affected = [rank]
        self._lost[rank].pop(failure, None)
        for r, scales in enumerate(self._scale):
            if scales.pop(failure, None) is not None and r != rank:
                affected.append(r)
        return affected

    def scale(self, rank: int, failure: Failure, factor: float) -> None:
        """Install a residual-capacity factor tied to ``failure``'s lifetime."""
        self._scale[rank][failure] = factor

    def active(self) -> dict[Failure, dict[int, float]]:
        """Degradations still installed: failure -> {rank: scale factor}.

        A failure with no control-plane capacity factor maps to an empty
        dict.  This is what a campaign runner carries into the next
        collective's engine so persistent failures keep degrading capacity
        across run boundaries.
        """
        out: dict[Failure, dict[int, float]] = {}
        for lost in self._lost:
            for f in lost:
                out.setdefault(f, {})
        for rank, scales in enumerate(self._scale):
            for f, factor in scales.items():
                out.setdefault(f, {})[rank] = factor
        return out

    def capacity(self, rank: int) -> float:
        # a rail's loss is the worst active degradation on it (a dead NIC is
        # dead; a concurrent slow-NIC event on the same rail adds nothing)
        worst: dict[int, float] = {}
        for rail, sev in self._lost[rank].values():
            worst[rail] = max(worst.get(rail, 0.0), sev)
        lost = sum(self.rail_bw[rank][rail] * sev for rail, sev in worst.items())
        cap = max(0.0, self.base[rank] - lost)
        for factor in self._scale[rank].values():
            cap *= factor
        return cap


def _fair_share(flows: Sequence[_Transfer], cap) -> dict[int, float]:
    """Weighted max-min fair rates under per-rank tx and rx capacity
    (water-filling).  A flow's weight is its stream's priority; with all
    weights 1.0 this is bit-identical to the unweighted progressive fill
    (weight sums equal flow counts, and ``1.0 * share == share``), so the
    single-stream engine's timings are unchanged by the weighting."""
    rates: dict[int, float] = {}
    remaining = list(flows)
    avail: dict[tuple[str, int], float] = {}
    for f in remaining:
        avail.setdefault(("tx", f.src), cap(f.src))
        avail.setdefault(("rx", f.dst), cap(f.dst))
    while remaining:
        weights: dict[tuple[str, int], float] = {}
        for f in remaining:
            weights[("tx", f.src)] = weights.get(("tx", f.src), 0.0) + f.weight
            weights[("rx", f.dst)] = weights.get(("rx", f.dst), 0.0) + f.weight
        bottleneck = min(weights, key=lambda k: avail[k] / weights[k])
        share = max(0.0, avail[bottleneck] / weights[bottleneck])
        frozen = [f for f in remaining
                  if (bottleneck[0] == "tx" and f.src == bottleneck[1])
                  or (bottleneck[0] == "rx" and f.dst == bottleneck[1])]
        for f in frozen:
            r = f.weight * share
            rates[f.tid] = r
            avail[("tx", f.src)] -= r
            avail[("rx", f.dst)] -= r
        remaining = [f for f in remaining if f.tid not in rates]
    return rates


#: conformance hook: the static cost analyzer (repro.analysis.cost) prices
#: each lockstep round with the engine's own water-fill, through this public
#: name, so the two rate models cannot drift
fair_share = _fair_share


def _fill_vec(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
              avail_tx: np.ndarray, avail_rx: np.ndarray,
              n: int) -> np.ndarray:
    """Vectorized progressive fill, bit-identical to :func:`_fair_share`.

    ``src``/``dst``/``w`` are per-flow arrays in the reference's flow
    order; ``avail_tx``/``avail_rx`` are length-``n`` per-rank capacity
    vectors (mutated in place, exactly as the reference drains its
    ``avail`` dict).  Bit-identity holds because every float operation of
    the reference is replayed in the same per-key order:

    * ``np.bincount(..., weights=...)`` accumulates sequentially in array
      order — the same adds, in the same order, as the reference's
      per-flow dict sums (and a single-flow endpoint's ``0.0 + w == w``).
    * The bottleneck tie-break replays dict insertion order: endpoint
      first-occurrence positions in the interleaved (tx of flow 0, rx of
      flow 0, tx of flow 1, ...) stream, minimized over equal-ratio
      candidates.
    * Freezing decrements ``avail`` with unbuffered ``np.subtract.at`` —
      tx and rx live in disjoint arrays, so splitting the reference's
      interleaved decrements into two sequential passes preserves the
      per-key operation order.
    * When no endpoint carries more than one remaining flow (every
      lockstep matching round), the loop collapses to one vectorized
      expression: each flow's rate is ``w * max(0, min(tx, rx ratio))``
      — the freeze its own bottleneck endpoint would have applied, and
      no other flow's freeze can touch its endpoints.
    """
    F = src.shape[0]
    rates = np.zeros(F)
    alive = np.ones(F, dtype=bool)
    while True:
        idx = np.flatnonzero(alive)
        if idx.size == 0:
            break
        s, d, ww = src[idx], dst[idx], w[idx]
        cnt_tx = np.bincount(s, minlength=n)
        cnt_rx = np.bincount(d, minlength=n)
        if cnt_tx.max(initial=0) <= 1 and cnt_rx.max(initial=0) <= 1:
            rates[idx] = ww * np.maximum(
                0.0, np.minimum(avail_tx[s] / ww, avail_rx[d] / ww))
            break
        wt = np.bincount(s, weights=ww, minlength=n)
        wr = np.bincount(d, weights=ww, minlength=n)
        safe_t = np.where(cnt_tx > 0, wt, 1.0)
        safe_r = np.where(cnt_rx > 0, wr, 1.0)
        ratio_tx = np.where(cnt_tx > 0, avail_tx / safe_t, math.inf)
        ratio_rx = np.where(cnt_rx > 0, avail_rx / safe_r, math.inf)
        m = min(ratio_tx.min(), ratio_rx.min())
        # first-occurrence position of each endpoint in the reference's
        # interleaved insertion stream (tx of flow i at 2i, rx at 2i+1)
        pos = np.arange(idx.size, dtype=np.int64)
        post = np.full(n, 2 * idx.size, dtype=np.int64)
        posr = np.full(n, 2 * idx.size, dtype=np.int64)
        np.minimum.at(post, s, 2 * pos)
        np.minimum.at(posr, d, 2 * pos + 1)
        cand_t = np.flatnonzero(ratio_tx == m)
        cand_r = np.flatnonzero(ratio_rx == m)
        best_t = int(post[cand_t].min()) if cand_t.size else 2 * idx.size
        best_r = int(posr[cand_r].min()) if cand_r.size else 2 * idx.size
        if best_t < best_r:
            b = int(cand_t[np.argmin(post[cand_t])])
            share = max(0.0, float(avail_tx[b]) / float(wt[b]))
            frozen = s == b
        else:
            b = int(cand_r[np.argmin(posr[cand_r])])
            share = max(0.0, float(avail_rx[b]) / float(wr[b]))
            frozen = d == b
        fi = idx[frozen]
        r = w[fi] * share
        rates[fi] = r
        np.subtract.at(avail_tx, src[fi], r)
        np.subtract.at(avail_rx, dst[fi], r)
        alive[fi] = False
    return rates


def fair_share_fast(flows: Sequence[_Transfer], cap) -> dict[int, float]:
    """Vectorized drop-in for :func:`fair_share`: same flows-and-capacity
    interface (anything with ``.tid/.src/.dst/.weight`` duck-types), same
    dict result, bit-identical rates (pinned by the property suite in
    ``tests/test_fill_equiv.py``).  The engine's incremental path and the
    static cost analyzer both go through the same kernel."""
    if not flows:
        return {}
    F = len(flows)
    tids = np.fromiter((f.tid for f in flows), np.int64, F)
    src = np.fromiter((f.src for f in flows), np.int64, F)
    dst = np.fromiter((f.dst for f in flows), np.int64, F)
    w = np.fromiter((f.weight for f in flows), np.float64, F)
    n = int(max(src.max(), dst.max())) + 1
    avail_tx = np.zeros(n)
    avail_rx = np.zeros(n)
    for r in np.unique(src).tolist():
        avail_tx[r] = cap(r)
    for r in np.unique(dst).tolist():
        avail_rx[r] = cap(r)
    rates = _fill_vec(src, dst, w, avail_tx, avail_rx, n)
    return dict(zip(tids.tolist(), rates.tolist()))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class EventSimulator:
    """A set of collective streams, executed on one absolute-time event queue.

    Constructed either from a single ``(prog, total_bytes[, rank_data])``
    — wrapped into one stream named ``"main"``, behaviorally identical to
    the pre-multi-stream engine — or from ``streams=`` (a sequence of
    :class:`Stream`), all sharing the cluster's NICs under weighted
    max-min fairness.
    """

    def __init__(
        self,
        prog: CollectiveProgram | None = None,
        total_bytes: float | None = None,
        *,
        streams: Sequence[Stream] | None = None,
        cluster: ClusterTopology | None = None,
        capacities: Sequence[float] | None = None,
        g: int = 8,
        alpha: float = DEFAULT_ALPHA,
        failures: Sequence[Failure] = (),
        rank_data: Sequence[np.ndarray] | None = None,
        repair_latency: float = DEFAULT_REPAIR_LATENCY,
        controller: object | None = None,
        initial_failures: Sequence[
            tuple[Failure, Mapping[int, float] | None]] = (),
        telemetry: Telemetry | None = None,
        verify_replans: bool = False,
        fill: str = "fast",
    ):
        if fill not in ("fast", "reference"):
            raise EventSimError(
                f"fill must be 'fast' or 'reference', got {fill!r}")
        #: water-fill backend: "fast" = incremental vectorized fill
        #: (bit-identical to the reference, pinned by tests/test_fill_equiv),
        #: "reference" = the exported _fair_share on every epoch
        self.fill = fill
        if streams is None:
            if prog is None or total_bytes is None:
                raise EventSimError(
                    "need either (prog, total_bytes) or streams=")
            streams = (Stream("main", prog, float(total_bytes),
                              rank_data=rank_data),)
        else:
            if prog is not None or total_bytes is not None \
                    or rank_data is not None:
                raise EventSimError(
                    "pass either (prog, total_bytes[, rank_data]) or "
                    "streams=, not both")
            streams = tuple(streams)
            if not streams:
                raise EventSimError("streams= must hold at least one stream")
        names = [s.name for s in streams]
        if len(set(names)) != len(names):
            raise EventSimError(f"stream names must be unique: {names}")
        # Statically verify every dynamically generated replan resume
        # program (and the initial stream programs) before instantiation:
        # abstract-interpretation AllReduce/broadcast proof + deadlock
        # check from repro.analysis.verify, not just legality.
        self.verify_replans = verify_replans
        n = streams[0].program.n
        for s in streams:
            s.program.validate()
            if verify_replans:
                from repro.analysis.verify import verify_program

                verify_program(s.program)
            if s.program.n != n:
                raise EventSimError(
                    f"stream {s.name!r} has {s.program.n} ranks but stream "
                    f"{streams[0].name!r} has {n}: all streams share one "
                    f"cluster")
            if not s.priority > 0.0:
                raise EventSimError(
                    f"stream {s.name!r} priority must be > 0, got "
                    f"{s.priority!r}")
            if s.start_time < 0.0:
                raise EventSimError(
                    f"stream {s.name!r} start_time must be >= 0, got "
                    f"{s.start_time!r}")
        self.n = n
        self.prog = streams[0].program    # primary stream's initial program
        #: summed payload across streams (a single-program run's total)
        self.total_bytes = float(sum(s.payload_bytes for s in streams))
        self.alpha = alpha
        self.repair_latency = repair_latency
        # duck-typed recovery control plane: on_failure(sim, now, failure) ->
        # RecoveryDecision | None, on_recover(sim, now, failure) -> None
        self.controller = controller
        if cluster is not None:
            if cluster.num_nodes != n:
                raise EventSimError(
                    f"programs have {n} ranks but cluster has "
                    f"{cluster.num_nodes} nodes")
            self.caps = _Capacities.from_cluster(cluster)
        elif capacities is not None:
            if len(capacities) != n:
                raise EventSimError("capacities must have one entry per rank")
            self.caps = _Capacities.uniform(capacities, g)
        else:
            raise EventSimError("need either cluster= or capacities=")
        self.healthy_caps = [self.caps.capacity(r) for r in range(n)]

        self.transfers: list[_Transfer] = []
        # structure-of-arrays mirror of the per-transfer hot fields, indexed
        # by tid; extended in _instantiate so the run loop and the fill can
        # stay vectorized (the dataclass keeps the cold control-plane state)
        self._t_src = np.empty(0, np.int64)
        self._t_dst = np.empty(0, np.int64)
        self._t_w = np.empty(0, np.float64)
        self._t_size = np.empty(0, np.float64)
        self._t_eps = np.empty(0, np.float64)   # completion epsilon per tid
        self._rem = np.empty(0, np.float64)     # remaining bytes per tid
        self._rates_full = np.empty(0, np.float64)  # last fair share per tid
        self._segstate: list[_SegState] = []
        self.segment_finish: list[float] = []
        #: per-segment payload buffers, parallel to ``_segstate`` (None for
        #: segments of streams without rank_data)
        self._data: list[_SegData | None] = []
        self._streams: list[_StreamState] = []
        self._stream_index: dict[str, int] = {}
        for spec in streams:
            st = _StreamState(index=len(self._streams), spec=spec,
                              prog=spec.program)
            self._streams.append(st)
            self._stream_index[spec.name] = st.index
            new = self._instantiate(spec.program, spec.payload_bytes, st)
            st.remaining = st.transfers = len(new)
            self._init_stream_data(st, spec.rank_data)
        assert len(self._data) == len(self._segstate)
        self._remaining = len(self.transfers)
        self._max_iters = 50 * len(self.transfers) + 10_000

        # event queue: (time, seq, kind, arg)
        self._events: list[tuple[float, int, str, object]] = []
        self._seq = 0
        # queued events that are not sampling ticks — the stall guard in
        # _sample() reads this instead of rescanning the whole queue
        self._pending_nonsample = 0
        # Degradations carried over from a previous collective (a training
        # campaign's earlier iteration): installed before t=0 with their
        # control-plane capacity factors, WITHOUT consulting the controller
        # again (the pipeline already ran when the failure first struck) and
        # without rollback (nothing is in flight yet).  A pending recovery
        # (``recovers_at``, already rebased to this run's clock) is scheduled
        # so a flap spanning the boundary still comes back up.
        for f, scales in initial_failures:
            self._check_target(f)
            self.caps.fail(f.node, f)
            if scales:
                for r, factor in scales.items():
                    self.caps.scale(r, f, factor)
            if f.recovers_at is not None:
                self._push(f.recovers_at, "recover", f)
        for f in failures:
            # NIC-level events only: hard failures R2CCL can see (supported /
            # escalated) or fractional degradations (slow NIC).  Out-of-scope
            # types (switch outage, process crash) are not transport events,
            # whatever their severity.
            if f.ftype in OUT_OF_SCOPE:
                continue
            if not (f.supported or f.severity < 1.0):
                continue
            self._check_target(f)
            self._push(f.at_time, "fail", f)
            if f.recovers_at is not None:
                self._push(f.recovers_at, "recover", f)

        self._active: set[int] = set()
        self.link_bytes: dict[tuple[int, int], float] = {}
        self.rank_tx: dict[int, float] = {r: 0.0 for r in range(self.n)}
        self.rank_rx: dict[int, float] = {r: 0.0 for r in range(self.n)}
        self.rank_retrans: dict[int, float] = {r: 0.0 for r in range(self.n)}
        self.retransmitted_bytes = 0.0
        self.failovers = 0
        self.replans = 0
        self.cancelled_transfers = 0
        self.repair_events: list[RepairEvent] = []
        self.replan_events: list[ReplanEvent] = []
        self.events_processed = 0

        # observability plane: counters are snapshotted into the registry at
        # the telemetry cadence (the monitoring plane's polling interval),
        # and every engine event lands in the structured trace
        self.telemetry = telemetry
        self._sample_seq = 0
        # water-fill memo: the run loop recomputes the fair share only when
        # the flow set or link capacities changed since the last iteration
        # (sampling ticks in particular leave both untouched), and the fast
        # fill recomputes only the connected components holding a dirtied
        # (tx/rx, rank) endpoint — untouched flows keep their cached rates
        self._flows_epoch = 0
        self._rates_epoch = -1
        # endpoint codes (rank << 1 | is_rx) whose flow set or capacity
        # changed since the last fill; _dirty_all forces a full refill
        self._dirty_eps: set[int] = set()
        self._dirty_all = True
        self._cap_dirty: set[int] = set()
        # active-set membership epoch: bumping it invalidates the sorted
        # tid array without forcing capacity-only epochs to re-sort
        self._members_epoch = 0
        self._act_built_epoch = -1
        self._act_tids = np.empty(0, np.int64)
        self._act_src = np.empty(0, np.int64)
        self._act_dst = np.empty(0, np.int64)
        self._act_w = np.empty(0, np.float64)
        self._act_rates = np.empty(0, np.float64)
        self._rates_dict_cache: dict[int, float] = {}
        self._rates_dict_epoch = -2
        #: refill counters (diagnostics + perf tests): full recomputes vs
        #: incremental component-scoped ones
        self.fill_full_recomputes = 0
        self.fill_partial_recomputes = 0
        # per-rank capacity vector mirroring caps.capacity, refreshed only
        # for capacity-dirty ranks (post initial_failures state)
        self._cap_vec = np.array(
            [self.caps.capacity(r) for r in range(self.n)], np.float64)
        self._last_sample_t = 0.0
        self._last_tx = {r: 0.0 for r in range(self.n)}
        self._last_good = [0.0] * len(self._streams)
        if telemetry is not None:
            # pre-resolved series handles: the sampler appends straight into
            # the ring buffers instead of going through registry.record
            reg = telemetry.registry
            self._rank_series = [
                (reg.handle("rank.tx_rate", (r,)),
                 reg.handle("rank.fair_share", (r,)),
                 reg.handle("rank.inflight", (r,)),
                 reg.handle("rank.retrans_bytes", (r,)))
                for r in range(self.n)]
            self._stream_series = [
                (reg.handle("stream.goodput", (st.spec.name,)),
                 reg.handle("stream.moved_bytes", (st.spec.name,)),
                 reg.handle("stream.remaining", (st.spec.name,)))
                for st in self._streams]
        if telemetry is not None:
            self._push(telemetry.sample_period, "sample", None)

    # -- construction --------------------------------------------------------
    def _check_target(self, f: Failure) -> None:
        if not 0 <= f.node < self.n:
            raise EventSimError(
                f"failure targets node {f.node} but the programs have "
                f"ranks 0..{self.n - 1}: {f}")
        if not 0 <= f.rail < self.caps.num_rails(f.node):
            raise EventSimError(
                f"failure targets rail {f.rail} but node {f.node} has "
                f"rails 0..{self.caps.num_rails(f.node) - 1}: {f}")

    def _push(self, t: float, kind: str, arg: object) -> None:
        heapq.heappush(self._events, (t, self._seq, kind, arg))
        self._seq += 1
        if kind != "sample":
            self._pending_nonsample += 1

    def _instantiate(self, prog: CollectiveProgram, total_bytes: float,
                     stream: _StreamState) -> list[_Transfer]:
        """Build + dependency-wire ``prog``'s transfers over ``total_bytes``.

        Appends to ``self.transfers`` (tids continue after existing ones),
        registers one :class:`_SegState` per segment (segment indices are
        *absolute* across streams and program swaps — ``segment_finish``
        and the chunk map grow, never reset; ``stream.seg_ids`` records
        which belong to ``stream``), and returns the new transfers.
        Dependency rule: transfer (seg, step i, {s,d}) waits on all
        transfers of s's and d's previous participating step in the same
        segment.  Used at init and when the control plane swaps in a
        replanned program mid-collective.
        """
        base = len(self.transfers)
        seg_base = len(self._segstate)
        for si, seg in enumerate(prog.segments):
            sched = seg.schedule
            seg_bytes = total_bytes * seg.frac
            chunk_bytes = seg_bytes / sched.num_chunks
            writers = np.zeros((prog.n, sched.num_chunks), dtype=np.int64)
            participants: set[int] = set()
            for step_i, st in enumerate(sched.steps):
                size = seg_bytes if st.whole_buffer else chunk_bytes
                for src, dst in st.perm:
                    participants.update((src, dst))
                    if st.whole_buffer:
                        writers[dst, :] += 1
                    else:
                        writers[dst, st.recv_chunk[dst]] += 1
                    self.transfers.append(_Transfer(
                        tid=len(self.transfers), seg=seg_base + si,
                        step=step_i,
                        src=src, dst=dst, size=size,
                        accumulate=st.accumulate,
                        whole_buffer=st.whole_buffer,
                        send_chunk=st.send_chunk[src],
                        recv_chunk=st.recv_chunk[dst],
                        stream=stream.index,
                        weight=stream.spec.priority,
                    ))
            needed = (tuple(sched.result_ranks) if sched.result_ranks
                      else tuple(sorted(participants)))
            self._segstate.append(_SegState(
                schedule=sched, seg_bytes=seg_bytes, needed=needed,
                writers_left=writers, stream=stream.index))
            stream.seg_ids.append(seg_base + si)
            self.segment_finish.append(0.0)
        new = self.transfers[base:]
        by_seg_step_rank: dict[tuple[int, int, int], list[_Transfer]] = {}
        for t in new:
            for r in (t.src, t.dst):
                by_seg_step_rank.setdefault((t.seg, t.step, r), []).append(t)
        for si, seg in enumerate(prog.segments):
            rank_steps = seg.schedule.rank_steps()
            for t in new:
                if t.seg != seg_base + si:
                    continue
                prereqs: set[int] = set()
                for r in sorted({t.src, t.dst}):
                    steps = rank_steps[r]
                    pos = steps.index(t.step)
                    if pos > 0:
                        prev = steps[pos - 1]
                        for p in by_seg_step_rank.get(
                                (seg_base + si, prev, r), []):
                            prereqs.add(p.tid)
                prereqs.discard(t.tid)
                t.deps = len(prereqs)
                for p in sorted(prereqs):
                    self.transfers[p].dependents.append(t.tid)
        if new:
            m = len(new)
            sizes = np.fromiter((t.size for t in new), np.float64, m)
            self._t_src = np.concatenate(
                [self._t_src, np.fromiter((t.src for t in new), np.int64, m)])
            self._t_dst = np.concatenate(
                [self._t_dst, np.fromiter((t.dst for t in new), np.int64, m)])
            self._t_w = np.concatenate(
                [self._t_w,
                 np.fromiter((t.weight for t in new), np.float64, m)])
            self._t_size = np.concatenate([self._t_size, sizes])
            # size-relative completion epsilon: float residue in the
            # remaining bytes must not stall the clock
            self._t_eps = np.concatenate(
                [self._t_eps, np.maximum(1e-9, 1e-9 * sizes)])
            self._rem = np.concatenate([self._rem, np.zeros(m)])
            self._rates_full = np.concatenate([self._rates_full, np.zeros(m)])
        return new

    def _init_stream_data(
        self, stream: _StreamState,
        rank_data: Sequence[np.ndarray] | None,
    ) -> None:
        """Per-rank, per-segment chunked float64 buffers (as executor_np)
        for one stream; a stream without data registers None per segment so
        absolute segment indices keep addressing ``_data``."""
        if rank_data is None:
            self._data.extend([None] * len(stream.prog.segments))
            return
        n = self.n
        assert len(rank_data) == n
        data = [np.asarray(d, dtype=np.float64) for d in rank_data]
        total = data[0].shape[-1]
        stream.has_data = True
        stream.orig_total = total
        #: pristine per-rank contributions — what a chunk rolls back to when
        #: a replan finds it durably complete at no rank
        stream.pristine = [d.copy() for d in data]
        # segment boundaries mirror executor_np.execute_program
        bounds = []
        start = 0
        for i, seg in enumerate(stream.prog.segments):
            end = total if i == len(stream.prog.segments) - 1 else \
                start + int(round(seg.frac * total))
            bounds.append((start, end))
            start = end
        for si, seg in enumerate(stream.prog.segments):
            s, e = bounds[si]
            self._append_seg_data(
                [data[r][s:e] for r in range(n)],
                np.arange(s, e), None, seg.schedule.num_chunks)

    def _append_seg_data(
        self,
        flat: Sequence[np.ndarray],
        dest: np.ndarray,
        write_ranks: tuple[int, ...] | None,
        num_chunks: int,
    ) -> None:
        """Register one segment's payload buffers (chunk-padded, as
        executor_np pads).  Must be called once per segment, in the same
        order ``_instantiate`` registers segments, so absolute segment
        indices address both ``_segstate`` and ``_data``."""
        orig = len(dest)
        pad = (-orig) % num_chunks
        bufs = []
        for b in flat:
            b = np.asarray(b, dtype=np.float64)
            if pad:
                b = np.concatenate([b, np.zeros(pad, np.float64)])
            bufs.append(b.reshape(num_chunks, -1).copy())
        self._data.append(_SegData(bufs=bufs, dest=dest,
                                   write_ranks=write_ranks))

    def _chunk_dest(self, si: int, c: int) -> np.ndarray:
        """Original flat positions of chunk ``c`` of segment ``si`` (the
        valid, non-padding elements only)."""
        sd = self._data[si]
        clen = sd.bufs[0].shape[1]
        return sd.dest[c * clen:min((c + 1) * clen, len(sd.dest))]

    def _chunk_values(self, si: int, c: int, rank: int) -> np.ndarray:
        sd = self._data[si]
        clen = sd.bufs[0].shape[1]
        lo = c * clen
        hi = min((c + 1) * clen, len(sd.dest))
        return sd.bufs[rank].reshape(-1)[lo:hi]

    # -- data plane ----------------------------------------------------------
    def _snapshot(self, t: _Transfer) -> None:
        sd = self._data[t.seg]
        if sd is None:
            return
        src_buf = sd.bufs[t.src]
        t.payload = src_buf.copy() if t.whole_buffer else src_buf[t.send_chunk].copy()

    def _deliver(self, t: _Transfer) -> None:
        sd = self._data[t.seg]
        if sd is None or t.payload is None:
            return
        bufs = sd.bufs
        if t.whole_buffer:
            bufs[t.dst] = bufs[t.dst] + t.payload if t.accumulate \
                else t.payload.copy()
        else:
            c = t.recv_chunk
            if t.accumulate:
                bufs[t.dst][c] = bufs[t.dst][c] + t.payload
            else:
                bufs[t.dst][c] = t.payload
        t.payload = None

    def _final_data(self, stream: _StreamState) -> list[np.ndarray] | None:
        if not stream.has_data:
            return None
        n = self.n
        out = [np.empty(stream.orig_total, np.float64) for _ in range(n)]
        # Creation order: the initial program's segments cover every position
        # at every rank; each residual program's segments then overwrite
        # exactly the positions (and ranks) they re-covered.  Settled chunks
        # keep their retired segment's values — that is the conservation.
        for seg_id in stream.seg_ids:
            sd = self._data[seg_id]
            ranks = range(n) if sd.write_ranks is None else sd.write_ranks
            for r in ranks:
                out[r][sd.dest] = sd.bufs[r].reshape(-1)[:len(sd.dest)]
        return out

    # -- scheduling ----------------------------------------------------------
    def _trace(self, rtype: str, t: float, **fields) -> None:
        if self.telemetry is not None:
            self.telemetry.trace.add(rtype, t, **fields)

    def _stream_name(self, idx: int) -> str:
        return self._streams[idx].spec.name

    def _release(self, now: float, t: _Transfer, extra_delay: float = 0.0) -> None:
        t.state = _LATENT
        self._push(now + self.alpha + extra_delay, "activate", t.tid)

    def _touch_flow(self, t: _Transfer) -> None:
        """Mark a flow's endpoints dirty: its component must be refilled."""
        self._dirty_eps.add(t.src << 1)
        self._dirty_eps.add((t.dst << 1) | 1)

    def _touch_cap(self, rank: int) -> None:
        """Mark a rank's capacity dirty: both its endpoints refill, and the
        cached capacity vector refreshes at the next fill."""
        self._cap_dirty.add(rank)
        self._dirty_eps.add(rank << 1)
        self._dirty_eps.add((rank << 1) | 1)

    def _activate(self, now: float, t: _Transfer) -> None:
        t.state = _ACTIVE
        self._rem[t.tid] = t.size
        self._active.add(t.tid)
        self._flows_epoch += 1
        self._members_epoch += 1
        self._touch_flow(t)
        self._snapshot(t)
        self._trace("transfer_start", now, tid=t.tid, seg=t.seg,
                    stream=self._stream_name(t.stream), src=t.src, dst=t.dst,
                    bytes=t.size)

    def _complete(self, now: float, t: _Transfer) -> None:
        t.state = _DONE
        self._rem[t.tid] = 0.0
        self._active.discard(t.tid)
        self._flows_epoch += 1
        self._members_epoch += 1
        self._touch_flow(t)
        self._deliver(t)
        e = (t.src, t.dst)
        self.link_bytes[e] = self.link_bytes.get(e, 0.0) + t.size
        self.rank_tx[t.src] += t.size
        self.rank_rx[t.dst] += t.size
        self.segment_finish[t.seg] = max(self.segment_finish[t.seg], now)
        st = self._streams[t.stream]
        st.moved_bytes += t.size
        st.remaining -= 1
        st.finish_time = max(st.finish_time, now)
        self._trace("transfer_finish", now, tid=t.tid, seg=t.seg,
                    stream=st.spec.name, src=t.src, dst=t.dst, bytes=t.size)
        # chunk map: one write owed to the destination chunk(s) has landed
        writers = self._segstate[t.seg].writers_left
        if t.whole_buffer:
            writers[t.dst, :] -= 1
        else:
            writers[t.dst, t.recv_chunk] -= 1
        for d in t.dependents:
            dep = self.transfers[d]
            dep.deps -= 1
            if dep.deps == 0 and dep.state == _BLOCKED:
                self._release(now, dep)

    def _rollback(self, now: float, t: _Transfer,
                  delay: float | None = None) -> None:
        """DMA rollback: bytes already streamed are retransmitted; the
        transfer restarts (on a healthy rail) after the repair latency —
        the closed-form constant, or the control plane's derived delay."""
        sent = t.size - float(self._rem[t.tid])
        self.retransmitted_bytes += sent
        self.rank_tx[t.src] += sent          # wasted egress really happened
        self.rank_retrans[t.src] += sent
        e = (t.src, t.dst)
        self.link_bytes[e] = self.link_bytes.get(e, 0.0) + sent
        self.failovers += 1
        st = self._streams[t.stream]
        st.retransmitted_bytes += sent
        st.moved_bytes += sent
        st.failovers += 1
        t.payload = None
        t.state = _LATENT
        self._active.discard(t.tid)
        self._flows_epoch += 1
        self._members_epoch += 1
        self._touch_flow(t)
        d = self.repair_latency if delay is None else delay
        self._trace("rollback", now, tid=t.tid, stream=st.spec.name,
                    src=t.src, dst=t.dst, sent_bytes=sent, delay=d)
        self._push(now + d + self.alpha, "activate", t.tid)

    def _apply_failure(self, now: float, f: Failure, recovering: bool) -> None:
        rank = f.node
        if recovering:
            # Physical recovery.  A co-simulated control plane only *observes*
            # it at its next scheduled re-probe tick (on_recover returns that
            # confirmation time); capacity is restored — and the failure state
            # cleared — at the tick, so the probe cadence shapes recovery
            # latency in the simulated timeline.  No controller (or an
            # immediate/legacy-None return) keeps the instant restore.  A
            # *silent* failure's recovery is silent too: the controller never
            # learned of the failure, so only a telemetry-driven detector can
            # notice the capacity coming back.
            self._trace("recovery", now, node=f.node, rail=f.rail)
            confirm_at = None
            if self.controller is not None and not f.silent:
                confirm_at = self.controller.on_recover(self, now, f)
            if confirm_at is not None and confirm_at > now + _time_tol(now):
                self._push(confirm_at, "confirm", f)
            else:
                self._confirm_recovery(now, f)
            return
        self.caps.fail(rank, f)
        self._flows_epoch += 1
        self._touch_cap(rank)
        self._trace("failure", now, node=f.node, rail=f.rail,
                    kind=f.ftype.value, severity=f.severity, silent=f.silent)
        # Consult the co-simulated control plane *at the failure instant*:
        # the pipeline it runs (detect → diagnose → migrate → rebalance →
        # replan) determines the restart delay, the post-rebalance residual
        # efficiency, and whether a new program is swapped in.  Silent
        # failures skip the consult — no CQE / OOB notification fires; the
        # transport still rolls back (DMA errors are physics, not
        # orchestration) at the closed-form repair latency.
        decision: RecoveryDecision | None = None
        if self.controller is not None and not f.silent:
            decision = self.controller.on_failure(self, now, f)
        if decision is not None and decision.capacity_scale:
            for r, factor in decision.capacity_scale.items():
                self.caps.scale(r, f, factor)
                self._touch_cap(r)
            self._flows_epoch += 1
        if f.severity >= 1.0 and f.escalates:
            # A hard NIC death interrupts the node's striped channels: every
            # in-flight transfer touching the node rewinds to its last
            # completed chunk (DMA rollback) and restarts after the hot-repair
            # latency.
            delay = decision.repair_latency if decision is not None else None
            rollbacks = 0
            for tid in sorted(self._active):
                t = self.transfers[tid]
                if t.src == rank or t.dst == rank:
                    self._rollback(now, t, delay)
                    rollbacks += 1
            self.repair_events.append(RepairEvent(
                at_time=now,
                delay=self.repair_latency if delay is None else delay,
                rollbacks=rollbacks,
                derived=decision is not None,
            ))
        if decision is not None and decision.replan is not None:
            target = self._resolve_stream(decision.replan_stream)
            self._push(now + decision.replan_delay, "replan",
                       (decision.replan, target))

    def _confirm_recovery(self, now: float, f: Failure) -> None:
        """The re-probe confirming ``f``'s recovery: restore the capacity
        (and any control-plane capacity factors tied to the failure) and let
        the controller clear its failure state.  The probe observes the
        rail's *current* state: if a different failure struck the same rail
        while this confirmation was pending (flap down again before the
        tick), the probe finds it down and must NOT clear the controller's
        failure state — that later failure's own recovery will."""
        for r in self.caps.recover(f.node, f):
            self._touch_cap(r)
        self._flows_epoch += 1
        if self.caps.rail_dead(f.node, f.rail):
            return
        self._trace("recovery_confirmed", now, node=f.node, rail=f.rail)
        confirmed = getattr(self.controller, "on_recovery_confirmed", None)
        if confirmed is not None and not f.silent:
            confirmed(self, now, f)

    # -- chunk map / residual ------------------------------------------------
    def _resolve_stream(self, name: str | None) -> int:
        """Stream index for ``name``; None = the primary (first) stream."""
        if name is None:
            return 0
        try:
            return self._stream_index[name]
        except KeyError:
            raise EventSimError(
                f"unknown stream {name!r} (have "
                f"{sorted(self._stream_index)})") from None

    def _classify_residual(self, stream: _StreamState):
        """Classify ``stream``'s active program's chunks by durable
        completion.

        Returns ``(rereduce, deliver, rereduce_bytes, deliver_bytes)`` where
        ``rereduce`` is ``[(abs_seg, [chunk, ...]), ...]`` — chunks final at
        *no* needed rank (their partial sums are unusable under a different
        algorithm: they roll back to pristine contributions and re-reduce) —
        and ``deliver`` is ``[(abs_seg, holder, missing, [chunk, ...]), ...]``
        — chunks some rank already holds the final value of, grouped by
        (holder, missing-set): a broadcast from the holder completes them.
        Chunks durably complete at every needed rank are settled and appear
        in neither list.  Deterministic ordering throughout.
        """
        rereduce: list[tuple[int, list[int]]] = []
        deliver: list[tuple[int, int, tuple[int, ...], list[int]]] = []
        rereduce_bytes = 0.0
        deliver_bytes = 0.0
        for si in stream.seg_ids[stream.active_seg_start:]:
            ss = self._segstate[si]
            if ss.retired or not ss.needed:
                continue
            nc = ss.schedule.num_chunks
            chunk_bytes = ss.seg_bytes / nc
            rr: list[int] = []
            groups: dict[tuple[int, tuple[int, ...]], list[int]] = {}
            for c in range(nc):
                missing = tuple(r for r in ss.needed
                                if ss.writers_left[r, c] > 0)
                if not missing:
                    continue                      # settled everywhere needed
                done = [r for r in ss.needed if ss.writers_left[r, c] <= 0]
                if done:
                    groups.setdefault((done[0], missing), []).append(c)
                    deliver_bytes += chunk_bytes
                else:
                    rr.append(c)
                    rereduce_bytes += chunk_bytes
            if rr:
                rereduce.append((si, rr))
            for (holder, missing), chunks in sorted(groups.items()):
                deliver.append((si, holder, missing, chunks))
        return rereduce, deliver, rereduce_bytes, deliver_bytes

    def chunk_progress(self, stream: str | None = None) -> ChunkProgress:
        """The chunk map summarized for the control plane: how much of one
        stream's payload is still genuinely missing (vs durably settled)
        right now.  ``stream`` names the stream (None = the primary one —
        the collective a stream-scoped control plane manages)."""
        st = self._streams[self._resolve_stream(stream)]
        _, _, rereduce_bytes, deliver_bytes = self._classify_residual(st)
        return ChunkProgress(total_bytes=st.spec.payload_bytes,
                             rereduce_bytes=rereduce_bytes,
                             deliver_bytes=deliver_bytes)

    def _do_replan(self, now: float, prog: CollectiveProgram,
                   stream_idx: int) -> None:
        """Swap a freshly planned program into ONE stream, resuming from
        that stream's chunk map.

        Payload-conserving at chunk granularity: every unfinished transfer
        of the stream's superseded program is cancelled
        (streamed-but-unacked bytes count as retransmitted), then the chunk
        map decides what remains — settled chunks are retained verbatim,
        chunks final at some rank are broadcast from a holder to the ranks
        missing them (the surviving payloads ride along), and only chunks
        final nowhere roll back to pristine contributions and re-reduce
        under ``prog``.  The residual program is instantiated over exactly
        the missing chunk bytes, so partial progress is never
        simultaneously charged as retransmitted *and* re-included in the
        remaining payload (the old scalar ``frac_done`` approximation did
        both).  Co-running streams are untouched: their transfers keep
        flowing through the swap.
        """
        prog.validate()
        if self.verify_replans:
            from repro.analysis.verify import verify_program

            verify_program(prog)
        strm = self._streams[stream_idx]
        if prog.n != self.n:
            raise EventSimError(
                f"replanned program has {prog.n} ranks, expected {self.n}")
        n = self.n
        active_segs = set(strm.seg_ids[strm.active_seg_start:])
        done_bytes = sum(t.size for t in self.transfers
                         if t.state == _DONE and t.seg in active_segs)
        cancelled = 0
        for t in self.transfers:
            if t.stream == stream_idx and t.state in (_BLOCKED, _LATENT,
                                                      _ACTIVE):
                if t.state == _ACTIVE:
                    sent = t.size - float(self._rem[t.tid])
                    self.retransmitted_bytes += sent
                    strm.retransmitted_bytes += sent
                    strm.moved_bytes += sent
                    self.rank_tx[t.src] += sent
                    self.rank_retrans[t.src] += sent
                    e = (t.src, t.dst)
                    self.link_bytes[e] = self.link_bytes.get(e, 0.0) + sent
                    self._members_epoch += 1
                    self._touch_flow(t)
                t.state = _CANCELLED
                t.payload = None
                self._active.discard(t.tid)
                self._flows_epoch += 1
                cancelled += 1
        self.cancelled_transfers += cancelled
        strm.cancelled += cancelled
        strm.remaining -= cancelled
        self._remaining -= cancelled

        rereduce, deliver, rereduce_bytes, deliver_bytes = \
            self._classify_residual(strm)
        residual_bytes = rereduce_bytes + deliver_bytes
        self.replans += 1
        strm.replans += 1
        payload_bytes = strm.spec.payload_bytes
        ev = ReplanEvent(
            at_time=now, residual_bytes=residual_bytes,
            residual_fraction=(residual_bytes / payload_bytes
                               if payload_bytes > 0 else 0.0),
            rereduce_bytes=rereduce_bytes, deliver_bytes=deliver_bytes,
            done_bytes=done_bytes, cancelled=cancelled,
            stream=strm.spec.name)
        self.replan_events.append(ev)
        strm.replan_events.append(ev)
        self._trace("replan", now, stream=strm.spec.name,
                    residual_bytes=residual_bytes,
                    rereduce_bytes=rereduce_bytes,
                    deliver_bytes=deliver_bytes, done_bytes=done_bytes,
                    cancelled=cancelled)
        for si in strm.seg_ids[strm.active_seg_start:]:
            self._segstate[si].retired = True
        if residual_bytes <= 0.0:
            # The swap arrived after the last chunk settled: nothing to
            # resume — the cancelled redundant sends were all that was left.
            return

        # Residual program: the planner's program over the re-reduce bytes
        # (its own segment fractions preserved), plus one delivery-broadcast
        # segment per (holder, missing-set) group.
        segments: list[Segment] = []
        if rereduce_bytes > 0.0:
            for seg in prog.segments:
                segments.append(Segment(
                    seg.frac * rereduce_bytes / residual_bytes, seg.schedule))
        bcast_orders: list[tuple[int, ...]] = []
        for si, holder, missing, chunks in deliver:
            ss = self._segstate[si]
            group_bytes = ss.seg_bytes / ss.schedule.num_chunks * len(chunks)
            order = (holder,) + missing
            bcast_orders.append(order)
            segments.append(Segment(
                group_bytes / residual_bytes,
                build_ring_broadcast(list(order), n, root=holder)))
        residual_prog = CollectiveProgram(
            f"residual[{prog.name}]", n, segments)
        residual_prog.validate()
        if self.verify_replans:
            from repro.analysis.verify import verify_program

            verify_program(residual_prog)

        if strm.has_data:
            # Re-reduce region: pristine contributions of every chunk final
            # nowhere, partitioned across the new program's segments the
            # same way _init_stream_data partitions the initial payload.
            dest_parts = [self._chunk_dest(si, c)
                          for si, chunks in rereduce for c in chunks]
            rr_dest = (np.concatenate(dest_parts) if dest_parts
                       else np.empty(0, dtype=np.int64))
            total = len(rr_dest)
            start = 0
            if rereduce_bytes > 0.0:
                for i, seg in enumerate(prog.segments):
                    end = total if i == len(prog.segments) - 1 else \
                        start + int(round(seg.frac * total))
                    d = rr_dest[start:end]
                    self._append_seg_data(
                        [strm.pristine[r][d] for r in range(n)],
                        d, None, seg.schedule.num_chunks)
                    start = end
            # Delivery groups: the holder's surviving final values ride the
            # broadcast; only the group's ranks are written back.
            for (si, holder, missing, chunks), order in zip(
                    deliver, bcast_orders):
                d = np.concatenate([self._chunk_dest(si, c) for c in chunks])
                self._append_seg_data(
                    [np.concatenate([self._chunk_values(si, c, r)
                                     for c in chunks]) for r in range(n)],
                    d, order, len(order))
        else:
            self._data.extend([None] * len(residual_prog.segments))
        assert len(self._data) == len(self._segstate) + \
            len(residual_prog.segments)

        strm.prog = residual_prog
        strm.active_seg_start = len(strm.seg_ids)
        new = self._instantiate(residual_prog, residual_bytes, strm)
        strm.remaining += len(new)
        strm.transfers += len(new)
        self._remaining += len(new)
        self._max_iters += 50 * len(new) + 1_000
        for t in new:
            if t.deps == 0:
                self._release(now, t)

    # -- telemetry plane -----------------------------------------------------
    def _sample(self, now: float) -> None:
        """One monitoring-plane tick: snapshot counters into the registry,
        notify the observer (the telemetry-driven detector), schedule the
        next tick.  Runs as an engine event so sampling advances in virtual
        time interleaved with the transfers it measures."""
        tm = self.telemetry
        dt = now - self._last_sample_t
        active = [self.transfers[i] for i in sorted(self._active)]
        # reuse the run loop's water-fill from the interval that just
        # elapsed — exactly what a monitoring snapshot of that window saw;
        # recomputing here would double the fair-share cost per tick
        rates = self._rates_dict()
        inflight = [0] * self.n
        share = [0.0] * self.n
        for t in active:
            inflight[t.src] += 1
            share[t.src] += rates.get(t.tid, 0.0)
        for r in range(self.n):
            tx_rate = ((self.rank_tx[r] - self._last_tx[r]) / dt
                       if dt > 0 else 0.0)
            s_tx, s_fs, s_if, s_rt = self._rank_series[r]
            s_tx.append(now, tx_rate)
            s_fs.append(now, share[r])
            s_if.append(now, inflight[r])
            s_rt.append(now, self.rank_retrans[r])
            self._last_tx[r] = self.rank_tx[r]
        for st in self._streams:
            good = st.moved_bytes - st.retransmitted_bytes
            goodput = ((good - self._last_good[st.index]) / dt
                       if dt > 0 else 0.0)
            s_gp, s_mv, s_rm = self._stream_series[st.index]
            s_gp.append(now, goodput)
            s_mv.append(now, st.moved_bytes)
            # outstanding work queue depth: the runtime issued these
            # operations, so their incompleteness is observable — zero
            # goodput with a non-empty queue is a stall, not idleness
            s_rm.append(now, st.remaining)
            self._last_good[st.index] = good
        self._trace("sample", now, seq=self._sample_seq)
        self._sample_seq += 1
        self._last_sample_t = now
        if tm.observer is not None:
            tm.observer.on_sample(self, now)
        elif (self._remaining > 0
              and self._pending_nonsample == 0
              and not any(rates.get(t.tid, 0.0) > 0 for t in active)):
            # With no detector attached, a fully stalled fabric must still
            # raise: the sampling ticks alone would keep the event clock
            # alive forever (the pre-telemetry engine raised when the event
            # queue emptied — preserve that contract).
            raise StalledError(
                f"simulation stalled at t={now:.6g}s: zero bandwidth, no "
                f"future recovery event, and no telemetry observer to "
                f"infer a repair")
        if self._remaining > 0:
            self._push(now + tm.sample_period, "sample", None)

    def probe_rank(self, now: float, node: int) -> list[tuple[int, float]]:
        """Active probe burst over every rail of ``node``: the localization
        step a telemetry-driven detector runs once passive counters flag a
        rank.  Returns ``[(rail, lost_fraction), ...]`` — what per-rail RTT
        / bandwidth probes measure — and logs one ``probe`` trace record per
        rail (outcome ``timeout`` = dead, ``degraded`` = partial loss,
        ``ok`` = healthy)."""
        out = []
        for rail in range(self.caps.num_rails(node)):
            loss = self.caps.rail_loss(node, rail)
            outcome = ("timeout" if loss >= 1.0
                       else "degraded" if loss > 0.0 else "ok")
            self._trace("probe", now, node=node, rail=rail, outcome=outcome,
                        bw_fraction=1.0 - loss)
            out.append((rail, loss))
        return out

    def apply_inferred_decision(
        self, now: float, failure: Failure, decision: RecoveryDecision,
    ) -> None:
        """Install a control-plane decision for a failure the detector
        *inferred* from telemetry (no oracle event reached the controller).
        The physical capacity loss already happened at injection; what the
        decision adds is the orchestration — rebalance capacity factors
        (keyed by the inferred failure so :meth:`revoke_inferred` can lift
        them) and an optional mid-collective replan."""
        if decision.capacity_scale:
            for r, factor in decision.capacity_scale.items():
                self.caps.scale(r, failure, factor)
                self._touch_cap(r)
            self._flows_epoch += 1
        if decision.replan is not None:
            target = self._resolve_stream(decision.replan_stream)
            self._push(now + decision.replan_delay, "replan",
                       (decision.replan, target))

    def revoke_inferred(self, failure: Failure) -> None:
        """Lift every capacity factor installed for an inferred failure —
        the detector observed the rank's measured bandwidth recover."""
        for r in self.caps.recover(failure.node, failure):
            self._touch_cap(r)
        self._flows_epoch += 1

    # -- cross-run state -----------------------------------------------------
    def active_degradations(self) -> list[tuple[Failure, dict[int, float]]]:
        """Failures still degrading capacity when the run ended, with the
        control-plane capacity factors installed for each: what a campaign
        runner must carry into the next collective's ``initial_failures``.
        Deterministically ordered by (at_time, node, rail)."""
        return sorted(self.caps.active().items(),
                      key=lambda kv: (kv[0].at_time, kv[0].node, kv[0].rail))

    # -- water-fill ----------------------------------------------------------
    def _rates_dict(self) -> dict[int, float]:
        """Per-tid view of the last computed fair share (the sampler's
        stale-by-design window view), built lazily per fill epoch."""
        if self._rates_dict_epoch != self._rates_epoch:
            self._rates_dict_cache = dict(
                zip(self._act_tids.tolist(), self._act_rates.tolist()))
            self._rates_dict_epoch = self._rates_epoch
        return self._rates_dict_cache

    #: bounded component-closure expansion rounds; a component whose
    #: endpoint-sharing chain is deeper than this falls back to a full
    #: refill (always correct: refilling a superset of the affected
    #: components reproduces the reference exactly)
    _BFS_ROUNDS = 16

    def _affected(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray | None:
        """Boolean mask over the active flows of every connected component
        containing a dirty endpoint, or None if the closure did not
        converge within _BFS_ROUNDS.  Flows never span components, so
        refilling exactly these (with fresh endpoint capacity) while the
        rest keep their cached rates is bit-identical to a full fill."""
        dirty = self._dirty_eps
        if not dirty:
            return np.zeros(src.shape[0], dtype=bool)
        mark = np.zeros(2 * self.n, dtype=bool)
        mark[np.fromiter(dirty, np.int64, len(dirty))] = True
        cs = src << 1
        cd = (dst << 1) | 1
        aff = mark[cs] | mark[cd]
        grown_count = int(aff.sum())
        for _ in range(self._BFS_ROUNDS):
            mark[cs[aff]] = True
            mark[cd[aff]] = True
            grown = mark[cs] | mark[cd]
            count = int(grown.sum())
            if count == grown_count:
                return grown
            aff = grown
            grown_count = count
        return None

    def _refill(self) -> None:
        """Recompute the weighted max-min fair share for the current epoch.

        The sorted active-tid array is rebuilt only when membership
        changed (capacity-only epochs reuse it), and the fast fill
        recomputes only the components reached from dirty endpoints —
        everything else keeps its cached rate from ``_rates_full``.
        """
        if self._members_epoch != self._act_built_epoch:
            self._act_tids = np.fromiter(
                sorted(self._active), np.int64, len(self._active))
            self._act_built_epoch = self._members_epoch
            self._act_src = self._t_src[self._act_tids]
            self._act_dst = self._t_dst[self._act_tids]
            self._act_w = self._t_w[self._act_tids]
        if self._cap_dirty:
            for r in sorted(self._cap_dirty):
                self._cap_vec[r] = self.caps.capacity(r)
            self._cap_dirty.clear()
        tids = self._act_tids
        if self.fill == "reference":
            active = [self.transfers[i] for i in tids.tolist()]
            rates = _fair_share(active, self.caps.capacity) if active else {}
            out = np.fromiter(
                (rates[i] for i in tids.tolist()), np.float64, tids.size)
            self._rates_full[tids] = out
            self._act_rates = out
        else:
            src, dst, w = self._act_src, self._act_dst, self._act_w
            sel = (None if self._dirty_all or not tids.size
                   else self._affected(src, dst))
            if sel is None:
                if tids.size:
                    self._rates_full[tids] = _fill_vec(
                        src, dst, w, self._cap_vec.copy(),
                        self._cap_vec.copy(), self.n)
                self.fill_full_recomputes += 1
            else:
                if sel.any():
                    k = np.flatnonzero(sel)
                    self._rates_full[tids[k]] = _fill_vec(
                        src[k], dst[k], w[k], self._cap_vec.copy(),
                        self._cap_vec.copy(), self.n)
                self.fill_partial_recomputes += 1
            self._act_rates = self._rates_full[tids]
        self._dirty_eps.clear()
        self._dirty_all = False
        self._rates_epoch = self._flows_epoch

    # -- main loop -----------------------------------------------------------
    def _start_stream(self, now: float, stream_idx: int) -> None:
        """Release a stream's prerequisite-free transfers into the fabric."""
        for t in self.transfers:
            if t.stream == stream_idx and t.deps == 0 \
                    and t.state == _BLOCKED:
                self._release(now, t)

    def run(self) -> EventSimReport:
        now = 0.0
        # release every stream starting at t=0 directly (identical event
        # accounting to the single-program engine); later streams enter via
        # a timed start event
        for st in self._streams:
            if st.spec.start_time <= 0.0:
                self._start_stream(now, st.index)
            else:
                self._push(st.spec.start_time, "start", st.index)

        guard = 0
        events = self._events
        while self._remaining > 0:
            guard += 1
            if guard > self._max_iters:
                raise EventSimError("event loop not converging")
            if self._rates_epoch != self._flows_epoch:
                self._refill()
            tids = self._act_tids
            rates = self._act_rates

            # earliest completion among active flows: a flow is a candidate
            # when it has bandwidth (or zero bytes); its finish is now for
            # sub-epsilon residue, now + remaining/rate otherwise
            if tids.size:
                rem = self._rem[tids]
                eps = self._t_eps[tids]
                cand = (rates > 0.0) | (self._t_size[tids] <= 0.0)
                if cand.any():
                    dur = np.divide(rem, rates, out=np.zeros_like(rem),
                                    where=rates > 0.0)
                    dur[rem <= eps] = 0.0
                    t_complete = float(np.min(now + dur[cand]))
                else:
                    t_complete = math.inf
            else:
                t_complete = math.inf
            t_event = events[0][0] if events else math.inf
            t_next = min(t_complete, t_event)
            if math.isinf(t_next):
                stalled = tids.tolist()
                blocked = [t.tid for t in self.transfers
                           if t.state in (_BLOCKED, _LATENT)]
                raise StalledError(
                    f"simulation stalled at t={now:.6g}s: active={stalled} "
                    f"have zero bandwidth and no future recovery event "
                    f"(blocked/latent: {len(blocked)})")

            # stream bytes until t_next
            dt = t_next - now
            if dt > 0 and tids.size:
                self._rem[tids] = np.maximum(0.0, rem - rates * dt)
            now = t_next

            # completions strictly before/at events at the same timestamp:
            # finish flows first so dependents can react to the event epoch
            if tids.size:
                done = (self._rem[tids] <= eps) & cand
                for tid in tids[done].tolist():
                    self._complete(now, self.transfers[tid])
                    self._remaining -= 1
                    self.events_processed += 1

            # pop the whole same-timestamp bucket in one pass (tolerance
            # relative to the clock: see _time_tol)
            horizon = now + _time_tol(now)
            while events and events[0][0] <= horizon:
                _, _, kind, arg = heapq.heappop(events)
                if kind != "sample":
                    self._pending_nonsample -= 1
                self.events_processed += 1
                if kind == "activate":
                    t = self.transfers[arg]
                    if t.state == _LATENT:
                        self._activate(now, t)
                elif kind == "fail":
                    self._apply_failure(now, arg, recovering=False)
                elif kind == "recover":
                    self._apply_failure(now, arg, recovering=True)
                elif kind == "confirm":
                    self._confirm_recovery(now, arg)
                elif kind == "start":
                    self._start_stream(now, arg)
                elif kind == "replan":
                    new_prog, target = arg
                    self._do_replan(now, new_prog, target)
                elif kind == "sample":
                    self._sample(now)

        makespan = now
        util = {}
        for r in range(self.n):
            denom = self.healthy_caps[r] * makespan
            util[r] = (self.rank_tx[r] / denom) if denom > 0 else 0.0
        stream_reports: dict[str, StreamReport] = {}
        for st in self._streams:
            stream_reports[st.spec.name] = StreamReport(
                name=st.spec.name,
                payload_bytes=st.spec.payload_bytes,
                priority=st.spec.priority,
                start_time=st.spec.start_time,
                completion_time=st.finish_time,
                transfers=st.transfers,
                moved_bytes=st.moved_bytes,
                retransmitted_bytes=st.retransmitted_bytes,
                failovers=st.failovers,
                replans=st.replans,
                cancelled_transfers=st.cancelled,
                replan_events=list(st.replan_events),
                rank_data=self._final_data(st),
            )
        primary = stream_reports[self._streams[0].spec.name]
        return EventSimReport(
            completion_time=makespan,
            segment_finish=list(self.segment_finish),
            link_bytes=dict(self.link_bytes),
            rank_tx_bytes=dict(self.rank_tx),
            rank_rx_bytes=dict(self.rank_rx),
            link_utilization=util,
            retransmitted_bytes=self.retransmitted_bytes,
            failovers=self.failovers,
            transfers=len(self.transfers),
            events=self.events_processed,
            rank_data=primary.rank_data,
            replans=self.replans,
            cancelled_transfers=self.cancelled_transfers,
            repair_events=list(self.repair_events),
            replan_events=list(self.replan_events),
            streams=stream_reports,
        )


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def simulate_program(
    prog: CollectiveProgram,
    total_bytes: float,
    *,
    cluster: ClusterTopology | None = None,
    capacities: Sequence[float] | None = None,
    g: int = 8,
    alpha: float = DEFAULT_ALPHA,
    failures: Sequence[Failure] = (),
    rank_data: Sequence[np.ndarray] | None = None,
    repair_latency: float = DEFAULT_REPAIR_LATENCY,
    controller: object | None = None,
    initial_failures: Sequence[tuple[Failure, Mapping[int, float] | None]] = (),
    telemetry: Telemetry | None = None,
    verify_replans: bool = False,
    fill: str = "fast",
) -> EventSimReport:
    """Execute ``prog`` on the discrete-event engine.

    Exactly one of ``cluster`` (rank i = node i, capacity = node egress)
    or ``capacities`` (explicit per-rank bytes/s, split over ``g`` equal
    rails for failure mapping) must be given.  ``failures`` are applied at
    their ``at_time`` timestamps; fractional ``severity`` rescales
    bandwidth only, full severity also rolls back in-flight transfers on
    the failed rail.  ``controller`` co-simulates an online recovery
    control plane (see :mod:`repro.runtime`): its per-failure pipeline
    replaces ``repair_latency`` and may replan mid-collective.
    ``initial_failures`` installs degradations carried over from a previous
    collective (with their control-plane capacity factors) before t=0,
    without re-running the pipeline — the campaign-runner handoff.
    """
    return EventSimulator(
        prog, total_bytes, cluster=cluster, capacities=capacities, g=g,
        alpha=alpha, failures=failures, rank_data=rank_data,
        repair_latency=repair_latency, controller=controller,
        initial_failures=initial_failures, telemetry=telemetry,
        verify_replans=verify_replans, fill=fill,
    ).run()


def simulate_streams(
    streams: Sequence[Stream],
    *,
    cluster: ClusterTopology | None = None,
    capacities: Sequence[float] | None = None,
    g: int = 8,
    alpha: float = DEFAULT_ALPHA,
    failures: Sequence[Failure] = (),
    repair_latency: float = DEFAULT_REPAIR_LATENCY,
    controller: object | None = None,
    initial_failures: Sequence[tuple[Failure, Mapping[int, float] | None]] = (),
    telemetry: Telemetry | None = None,
    verify_replans: bool = False,
    fill: str = "fast",
) -> EventSimReport:
    """Co-simulate a set of concurrent collective streams on one fabric.

    Every stream's transfers share the per-rank tx/rx capacities under
    weighted max-min fairness (weights = stream priorities), so
    cross-stream contention — TP vs PP vs DP traffic on the same NICs —
    emerges from the same fairness code path that a single program's
    concurrent segments use.  Failures hit every stream riding the dead
    rail; a controller's ``capacity_scale`` re-prices every stream crossing
    the rank, and its ``replan`` swaps only ``replan_stream``'s program.
    Per-stream accounting lands in ``report.streams``; the report's scalar
    aggregates are the cross-stream sums.  A single-stream call is
    behaviorally identical to :func:`simulate_program`.
    """
    return EventSimulator(
        streams=streams, cluster=cluster, capacities=capacities, g=g,
        alpha=alpha, failures=failures, repair_latency=repair_latency,
        controller=controller, initial_failures=initial_failures,
        telemetry=telemetry, verify_replans=verify_replans, fill=fill,
    ).run()


def simulate_schedule(
    sched: ChunkSchedule,
    total_bytes: float,
    **kw,
) -> EventSimReport:
    """Convenience wrapper for a single-segment schedule."""
    from .schedule import CollectiveProgram, Segment

    prog = CollectiveProgram(sched.name, sched.n, [Segment(1.0, sched)])
    return simulate_program(prog, total_bytes, **kw)


def healthy_completion(
    prog: CollectiveProgram,
    total_bytes: float,
    *,
    cluster: ClusterTopology | None = None,
    capacities: Sequence[float] | None = None,
    g: int = 8,
    alpha: float = DEFAULT_ALPHA,
) -> float:
    """Failure-free completion time of ``prog`` — the conformance target of
    the static cost analyzer (:mod:`repro.analysis.cost`): for uncontended
    lockstep schedules ``analyze_program(...).predicted_time`` must equal
    this bit-exactly, and within ``CORPUS_COST_TOLERANCE`` corpus-wide."""
    return simulate_program(
        prog, total_bytes, cluster=cluster, capacities=capacities, g=g,
        alpha=alpha).completion_time


def predict_ring_all_reduce(n: int, payload: float, bandwidth: float,
                            alpha: float = DEFAULT_ALPHA) -> float:
    """The closed-form healthy baseline the event engine must reproduce:
    2(n-1) rounds of (alpha + (payload/n)/B)."""
    from .partition import ring_coeff

    return 2 * (n - 1) * alpha + ring_coeff(n) * payload / bandwidth
