from .engine import (  # noqa: F401
    Request,
    RequestResult,
    ServingEngine,
    TraceResult,
    make_decode_fn,
    make_prefill_fn,
    serve_trace,
)
