"""Mixture-of-Experts layer (DBRX 16e/top-4; DeepSeek-V3 256e/top-8+shared).

Capacity-based scatter/gather dispatch (no dense (T, E, C) one-hot tensor):
tokens are routed with ``top_k``, each token's position within its expert is
computed by a cumulative count, tokens beyond the expert capacity are
dropped (contributing zero, standard Switch-style), and expert FFNs run as
one batched einsum over the (E, C, d) buffer.  Experts shard over the
"experts" logical axis (expert parallelism on the mesh "model" axis).

The router aux loss is the usual load-balance term (mean fraction * mean
probability per expert), returned so the train step can add it.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense_init


def init_moe(key, d_model: int, expert_d_ff: int, num_experts: int,
             num_shared: int, activation: str, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    gates = activation in ("swiglu", "geglu")
    params: dict[str, Any] = {
        "router": dense_init(ks[0], (d_model, num_experts), d_model, jnp.float32),
        "wu": dense_init(ks[2], (num_experts, d_model, expert_d_ff), d_model, dtype),
        "wd": dense_init(ks[3], (num_experts, expert_d_ff, d_model), expert_d_ff, dtype),
    }
    # NOTE: expert weights get their own logical axes ("expert_embed",
    # "expert_mlp") instead of the dense "embed": FSDP-sharding the embed
    # dim of expert tensors conflicts with the dispatch-buffer layout and
    # makes GSPMD replicate the whole expert einsum (EXPERIMENTS.md §Perf,
    # dbrx iteration 2).  FSDP rules shard "expert_mlp" over data instead
    # (TP-within-experts), keeping the contraction dim replicated.
    axes = {
        "router": ("embed", "experts"),
        "wu": ("experts", "expert_embed", "expert_mlp"),
        "wd": ("experts", "expert_mlp", "expert_embed"),
    }
    if gates:
        params["wg"] = dense_init(ks[1], (num_experts, d_model, expert_d_ff), d_model, dtype)
        axes["wg"] = ("experts", "expert_embed", "expert_mlp")
    if num_shared:
        params["shared_wu"] = dense_init(ks[5], (d_model, num_shared * expert_d_ff), d_model, dtype)
        params["shared_wd"] = dense_init(ks[6], (num_shared * expert_d_ff, d_model), expert_d_ff, dtype)
        axes["shared_wu"] = ("embed", "mlp")
        axes["shared_wd"] = ("mlp", "embed")
        if gates:
            params["shared_wg"] = dense_init(ks[4], (d_model, num_shared * expert_d_ff), d_model, dtype)
            axes["shared_wg"] = ("embed", "mlp")
    return params, axes


def _expert_ffn(params, x, activation: str):
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, params["wg"])) * \
            jnp.einsum("ecd,edf->ecf", x, params["wu"])
    elif activation == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, params["wg"]), approximate=True) * \
            jnp.einsum("ecd,edf->ecf", x, params["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, params["wu"]), approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, params["wd"])


def moe_ffn(
    params,
    x: jnp.ndarray,                  # (B, T, d)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "swiglu",
    router_aux_weight: float = 0.01,
    expert_sharding: str | None = None,
    per_example_dispatch: bool = True,
    dispatch: str = "einsum",            # "einsum" | "scatter"
    dispatch_group: int = 512,           # token-chunk size for einsum dispatch
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).

    ``per_example_dispatch`` (default): capacity is allocated per batch
    row and the dispatch buffers keep the batch dimension —
    (B, E, C_row, d).  This is what lets the expert einsum parallelize
    over BOTH the data axis (batch) and the expert axis: a flat global
    dispatch folds the data-sharded token dim into the capacity dim, and
    GSPMD then all-gathers the tokens and replicates the whole expert
    computation across the data axis (measured 16-17x FLOP inflation —
    EXPERIMENTS.md §Perf, dbrx iterations 1-4).

    ``expert_sharding``: mesh axis for the expert dim of the dispatch
    buffers (usually "model"); propagated from the expert weights when
    None, but an explicit constraint makes the intent robust.
    """
    B, T, d = x.shape

    def _scatter_dispatch(xt, top_p, top_i, capacity):
        """xt (S, d); top (S, k) -> (buf (E,C,d), keep, slot, flat_e)."""
        S = xt.shape[0]
        flat_e = top_i.reshape(-1)                              # (S*k,)
        onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot               # rank within expert
        pos = pos.sum(-1) - 1                                   # 0-based
        keep = (pos < capacity) & (pos >= 0)
        slot = jnp.clip(pos, 0, capacity - 1)
        xk = jnp.repeat(xt, top_k, axis=0)
        contrib = jnp.where(keep[:, None], xk, 0.0)
        buf = jnp.zeros((num_experts, capacity, d), x.dtype)
        buf = buf.at[flat_e, slot].add(contrib.astype(x.dtype))
        return buf, keep, slot, flat_e

    xt_all = x.reshape(B * T, d)
    logits = (xt_all.astype(jnp.float32) @ params["router"])    # (B*T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = lax.top_k(probs, top_k)                      # (B*T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    from jax.sharding import PartitionSpec as _P

    if dispatch == "einsum" and per_example_dispatch:
        # One-hot einsum dispatch (Switch/MeshTF formulation): no scatter,
        # so GSPMD partitions the whole pipeline over (batch=data,
        # experts=model).  The dispatch-tensor build costs ~G/(6*ff) of the
        # expert FFN where G is the token-group size — chunking long
        # sequences into groups of ``dispatch_group`` keeps it at a few
        # percent regardless of T (EXPERIMENTS.md §Perf, v3 prefill iter 2;
        # with G=T the cost is T/(6*ff), 2.7x the FFN for v3's 32k prefill).
        G = max(1, min(dispatch_group, T))
        pad_t = (-T) % G
        ng = (T + pad_t) // G
        xg = x
        tpg = top_p.reshape(B, T, top_k)
        tig = top_i.reshape(B, T, top_k)
        if pad_t:
            xg = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
            tpg = jnp.pad(tpg, ((0, 0), (0, pad_t), (0, 0)))
            tig = jnp.pad(tig, ((0, 0), (0, pad_t), (0, 0)))
        Bg, Tg = B * ng, G
        xg = xg.reshape(Bg, Tg, d)
        capacity = max(1, int(math.ceil(Tg * top_k / num_experts
                                        * capacity_factor)))
        tp = tpg.reshape(Bg, Tg, top_k)
        ti = tig.reshape(Bg, Tg, top_k)
        onehot_e = jax.nn.one_hot(ti, num_experts, dtype=jnp.float32)
        # rank of each (t, k) slot within its expert, per group
        flat = onehot_e.reshape(Bg, Tg * top_k, num_experts)
        pos = jnp.cumsum(flat, axis=1) * flat                   # (Bg, Tg*k, E)
        pos = (pos.sum(-1) - 1.0).reshape(Bg, Tg, top_k)        # 0-based ranks
        keep = (pos < capacity) & (pos >= 0)
        onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                                  dtype=jnp.float32) * keep[..., None]
        # (Bg,Tg,k,E) x (Bg,Tg,k,C) -> (Bg,Tg,E,C) one-hot dispatch mask
        disp = jnp.einsum("btke,btkc->btec", onehot_e, onehot_c)
        buf = jnp.einsum("btec,btd->becd", disp.astype(x.dtype), xg)
        out_buf = jax.vmap(lambda b: _expert_ffn(params, b, activation))(buf)
        comb = jnp.einsum("btke,btkc,btk->btec", onehot_e, onehot_c,
                          tp.astype(jnp.float32)).astype(x.dtype)
        # NOTE: constraining comb/out_buf on the expert axis here was
        # measured 4x WORSE (forces materialization of the (B,T,E,C)
        # mask; EXPERIMENTS.md §Perf pair-1 iteration 8, refuted).
        # Propagation from the expert weights is the right layout source.
        y = jnp.einsum("btec,becd->btd", comb, out_buf)
        y = y.reshape(B, ng * G, d)[:, :T].reshape(B * T, d)
    elif per_example_dispatch:
        capacity = max(1, int(math.ceil(T * top_k / num_experts
                                        * capacity_factor)))
        buf, keep, slot, flat_e = jax.vmap(
            lambda xr, pr, ir: _scatter_dispatch(xr, pr, ir, capacity)
        )(x, top_p.reshape(B, T, top_k), top_i.reshape(B, T, top_k))
        # buf: (B, E, C, d) — batch stays on the data axis
        if expert_sharding is not None:
            buf = jax.lax.with_sharding_constraint(
                buf, _P(None, expert_sharding, None, None))
        out_buf = jax.vmap(lambda b: _expert_ffn(params, b, activation))(buf)
        if expert_sharding is not None:
            out_buf = jax.lax.with_sharding_constraint(
                out_buf, _P(None, expert_sharding, None, None))
        gathered = jax.vmap(lambda ob, fe, sl: ob[fe, sl])(out_buf, flat_e, slot)
        gathered = jnp.where(keep[..., None], gathered, 0.0)    # (B, T*k, d)
        w = top_p.reshape(B, T * top_k, 1).astype(gathered.dtype)
        y = (gathered * w).reshape(B, T, top_k, d).sum(axis=2).reshape(B * T, d)
    else:
        S = B * T
        capacity = max(1, int(math.ceil(S * top_k / num_experts
                                        * capacity_factor)))
        buf, keep, slot, flat_e = _scatter_dispatch(xt_all, top_p, top_i, capacity)
        if expert_sharding is not None:
            buf = jax.lax.with_sharding_constraint(
                buf, _P(expert_sharding, None, None))
        out_buf = _expert_ffn(params, buf, activation)          # (E, C, d)
        if expert_sharding is not None:
            out_buf = jax.lax.with_sharding_constraint(
                out_buf, _P(expert_sharding, None, None))
        gathered = out_buf[flat_e, slot]                        # (S*k, d)
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        w = top_p.reshape(-1)[:, None].astype(gathered.dtype)
        y = (gathered * w).reshape(S, top_k, d).sum(axis=1)
    xt = xt_all

    if "shared_wu" in params:
        if "shared_wg" in params:
            act = jax.nn.silu if activation == "swiglu" else (
                lambda a: jax.nn.gelu(a, approximate=True))
            h = act(xt @ params["shared_wg"]) * (xt @ params["shared_wu"])
        else:
            h = jax.nn.gelu(xt @ params["shared_wu"], approximate=True)
        y = y + h @ params["shared_wd"]

    # Switch-style load-balance aux loss.
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], num_experts, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = router_aux_weight * num_experts * jnp.sum(frac * mean_p)

    return y.reshape(B, T, d), aux


def moe_ffn_dense_reference(params, x, *, num_experts: int, top_k: int,
                            activation: str = "swiglu"):
    """Droppedless dense oracle: every token computed by its top-k experts
    via full (S, E) weighting.  O(S*E*ff) — tests only."""
    B, T, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs)
    w = jnp.take_along_axis(w, top_i, axis=-1)
    weights = jnp.zeros((xt.shape[0], num_experts), probs.dtype)
    weights = weights.at[jnp.arange(xt.shape[0])[:, None], top_i].set(top_p)
    per_expert = _expert_ffn(params, jnp.broadcast_to(xt, (num_experts,) + xt.shape),
                             activation)                        # (E, S, d)
    y = jnp.einsum("se,esd->sd", weights.astype(x.dtype), per_expert)
    if "shared_wu" in params:
        if "shared_wg" in params:
            act = jax.nn.silu if activation == "swiglu" else (
                lambda a: jax.nn.gelu(a, approximate=True))
            h = act(xt @ params["shared_wg"]) * (xt @ params["shared_wu"])
        else:
            h = jax.nn.gelu(xt @ params["shared_wu"], approximate=True)
        y = y + h @ params["shared_wd"]
    return y.reshape(B, T, d)
