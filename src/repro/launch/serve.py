"""Serving launcher: batched requests with failure-aware strategies.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 4 --max-new 16 --strategy r2ccl --fail-at-step 5
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core.failures import Failure, FailureType
from repro.models import get_config, get_smoke_config, init_model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--context-len", type=int, default=128)
    ap.add_argument("--strategy", default="r2ccl",
                    choices=["r2ccl", "restart", "reroute", "dejavu"])
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--fail-node", type=int, default=0)
    ap.add_argument("--fail-rail", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving "
                         "(see DESIGN.md skip notes)")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, context_len=args.context_len,
                           strategy=args.strategy)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    failure = None
    if args.fail_at_step is not None:
        failure = Failure(FailureType.NIC_HARDWARE, args.fail_node, args.fail_rail)

    results = engine.run_batch(reqs, fail_at_step=args.fail_at_step,
                               failure=failure)
    for i, r in enumerate(results):
        print(f"req {i}: ttft={r.ttft*1e3:.1f}ms tpot={r.tpot*1e3:.1f}ms "
              f"total={r.total_latency:.3f}s failovers={r.failovers} "
              f"tokens={r.tokens[:8]}...")
    print(json.dumps({
        "strategy": args.strategy,
        "mean_ttft_ms": float(np.mean([r.ttft for r in results]) * 1e3),
        "mean_tpot_ms": float(np.mean([r.tpot for r in results]) * 1e3),
        "total_s": results[0].total_latency,
    }))


if __name__ == "__main__":
    main()
