"""Training launcher.

Runs real training on the locally available devices (CPU smoke / TPU
slice); the production 256/512-chip configuration is exercised by
``dryrun.py``.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --smoke --steps 50 --sync r2ccl --comm-mode r2ccl \
      --fail-at-step 20 --fail-node 0 --fail-rail 0
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.detection import FailureDetector
from repro.core.failures import Failure, FailureState, FailureType
from repro.core.planner import CommConfig, Planner, Collective
from repro.core.topology import make_cluster
from repro.data import make_batch
from repro.launch.mesh import data_axis_names, make_host_mesh
from repro.models import get_config, get_smoke_config, init_model
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step, save_checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sync", default="xla", choices=["xla", "r2ccl"])
    ap.add_argument("--comm-mode", default="ring",
                    choices=["xla", "ring", "r2ccl", "recursive"])
    ap.add_argument("--data-par", type=int, default=0,
                    help="data-parallel degree (0 = all local devices)")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--fail-node", type=int, default=0)
    ap.add_argument("--fail-rail", type=int, default=0)
    ap.add_argument("--nics-per-node", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ndev = len(jax.devices())
    dp = args.data_par or ndev
    mesh = make_host_mesh(data=dp, model=max(ndev // dp, 1))
    baxes = data_axis_names(mesh)
    print(f"arch={cfg.name} devices={ndev} mesh={dict(mesh.shape)} sync={args.sync}")

    key = jax.random.PRNGKey(0)
    params, _ = init_model(key, cfg)
    state = init_train_state(params)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params:,}")

    # Two pre-built steps: healthy and degraded — the analogue of the
    # paper's pre-established backup connections (nothing is planned or
    # compiled on the failure path).
    comm_healthy = CommConfig(mode=args.comm_mode if args.sync == "r2ccl" else "xla")
    steps = {
        "healthy": jax.jit(make_train_step(
            cfg, AdamWConfig(lr=args.lr), sync=args.sync, comm=comm_healthy,
            mesh=mesh, data_axes=baxes)),
    }
    if args.fail_at_step is not None and args.sync == "r2ccl":
        x = 1.0 / args.nics_per_node
        comm_deg = CommConfig(mode="r2ccl", degraded_rank=args.fail_node,
                              lost_fraction=max(x, 0.34),
                              devices_per_node=args.nics_per_node)
        steps["degraded"] = jax.jit(make_train_step(
            cfg, AdamWConfig(lr=args.lr), sync="r2ccl", comm=comm_deg,
            mesh=mesh, data_axes=baxes))

    detector = FailureDetector(FailureState())
    cluster = make_cluster(max(mesh.shape.get("data", 1), 2),
                           args.nics_per_node)
    active = "healthy"
    history = []
    bspec = NamedSharding(mesh, P(tuple(baxes)))
    t_start = time.time()
    for step in range(args.steps):
        if args.fail_at_step is not None and step == args.fail_at_step:
            failure = Failure(FailureType.NIC_HARDWARE, args.fail_node,
                              args.fail_rail, at_time=time.time() - t_start)
            diag = detector.detect(failure, (args.fail_node, args.fail_rail),
                                   ((args.fail_node + 1) % cluster.num_nodes, args.fail_rail),
                                   aux=((args.fail_node + 2) % cluster.num_nodes, 0))
            print(f"step {step}: NIC failure injected -> located {diag.location.value} "
                  f"in {diag.localize_latency*1e3:.2f}ms; "
                  f"switching to degraded schedule" if "degraded" in steps else
                  f"step {step}: failure injected (xla sync cannot adapt)")
            if "degraded" in steps:
                active = "degraded"
        b = make_batch(cfg, seq_len=args.seq_len, batch_size=args.batch, step=step)
        batch = {k: jax.device_put(jnp.asarray(v), bspec) for k, v in b.items()}
        state, metrics = steps[active](state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {loss:.4f} gnorm "
                  f"{float(metrics['grad_norm']):.3f} sched={active}")

    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, state, args.steps)
        print(f"checkpoint saved to {args.checkpoint_dir}")
    print(json.dumps({"first_loss": history[0], "last_loss": history[-1],
                      "decreased": history[-1] < history[0]}))


if __name__ == "__main__":
    main()
