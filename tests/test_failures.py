"""Failure model: severity domain validation (regression).

Severities outside (0, 1] used to be silently accepted and then
misinterpreted by the slow-NIC bandwidth spectrum (a severity of 1.5 would
subtract more than the rail's bandwidth; 0 or negative meant "failure that
removes nothing").  Construction now rejects them.

Same pattern for the control-plane tuning knobs: a zero/negative
``flap_window`` or ``reprobe_base`` used to propagate into division and
scheduling arithmetic before blowing up far from the bad argument.
"""

import pytest

from repro.core.failures import Failure, FailureType, nic_down_at, silenced, slow_nic
from repro.core.topology import make_cluster
from repro.runtime import ControlPlane


def test_severity_one_and_fractional_accepted():
    assert Failure(FailureType.NIC_HARDWARE, 0, 0).severity == 1.0
    f = Failure(FailureType.SLOW_NIC, 1, 2, escalates=False, severity=0.25)
    assert f.severity == 0.25
    assert nic_down_at(0, 0, 1.0).severity == 1.0
    assert slow_nic(0, 0, 0.0, lost_fraction=0.5).severity == 0.5


@pytest.mark.parametrize("bad", [0.0, -0.5, 1.0001, 2.0, float("inf")])
def test_severity_out_of_domain_rejected(bad):
    with pytest.raises(ValueError, match="severity"):
        Failure(FailureType.SLOW_NIC, 0, 0, escalates=False, severity=bad)


def test_nan_severity_rejected():
    with pytest.raises(ValueError, match="severity"):
        Failure(FailureType.NIC_HARDWARE, 0, 0, severity=float("nan"))


def test_silenced_preserves_everything_but_the_oracle_bit():
    fs = [Failure(FailureType.NIC_HARDWARE, 0, 0, at_time=1.0),
          slow_nic(1, 2, 2.0, lost_fraction=0.5)]
    quiet = silenced(fs)
    assert all(f.silent for f in quiet)
    assert not any(f.silent for f in fs)        # originals untouched
    assert [(f.ftype, f.node, f.rail, f.at_time, f.severity) for f in quiet] \
        == [(f.ftype, f.node, f.rail, f.at_time, f.severity) for f in fs]


@pytest.mark.parametrize("kw", [{"flap_window": 0.0}, {"flap_window": -1.0},
                                {"reprobe_base": 0.0}, {"reprobe_base": -0.5}])
def test_control_plane_rejects_nonpositive_tuning(kw):
    with pytest.raises(ValueError, match=next(iter(kw))):
        ControlPlane(make_cluster(2, 4), **kw)


def test_control_plane_accepts_positive_tuning():
    cp = ControlPlane(make_cluster(2, 4), flap_window=5.0, reprobe_base=0.5)
    assert cp.flap_window == 5.0
    assert cp.reprobe_base == 0.5
