"""Production mesh construction + logical-axis sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16x16 = 256 chips ("data", "model");
multi-pod: 2x16x16 = 512 chips ("pod", "data", "model").
"""

from __future__ import annotations

import jax

from repro.configs.base import FSDP_TP_RULES, ShardingConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axis_names(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def rules_for(cfg, mode: str = "auto") -> dict:
    """Logical-axis -> mesh-axis rules; big models get FSDP+TP.

    ``ep2d`` variant: shard the expert dim over (model, data) — viable when
    num_experts divides the whole mesh (deepseek-v3: 256 = 16x16), which
    makes expert gradients fully sharded (no data-axis all-reduce for the
    654B expert params).  Archs whose expert count doesn't divide fall back
    to model-only sharding automatically (divisibility rule).
    """
    if mode in ("fsdp_tp", "ep2d") or (mode == "auto" and cfg.param_count() > 30e9):
        rules = dict(FSDP_TP_RULES)
        if mode == "ep2d":
            rules["experts"] = ("model", "pod", "data")
        return rules
    return ShardingConfig().lookup()
