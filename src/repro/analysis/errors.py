"""Typed schedule-verification errors with step/rank/chunk provenance.

Every check the static verifier (:mod:`repro.analysis.verify`) performs —
and every legality check :meth:`repro.core.schedule.Step.validate` /
``ChunkSchedule.validate`` / ``CollectiveProgram.validate`` delegates to it
— raises one of these instead of a bare ``assert``.  Unlike asserts they
survive ``python -O``, and they carry enough provenance (schedule name,
segment, step index, rank, chunk) to point at the exact IR location that
is wrong.

This module must stay import-light (stdlib only): the core IR imports it
from inside ``validate()`` and must never pull the full analysis package
into its import graph at module-load time.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Provenance:
    """Where in the IR a verification error points.

    ``None`` fields mean "not applicable / unknown at this level" — e.g. a
    program-level fraction error has no step, a bare ``Step.validate`` call
    has no step index.
    """

    schedule: str | None = None     # ChunkSchedule.name
    segment: int | None = None      # segment index within a CollectiveProgram
    step: int | None = None         # step index within the schedule
    rank: int | None = None
    chunk: int | None = None

    def __str__(self) -> str:
        parts = []
        if self.schedule is not None:
            parts.append(f"schedule={self.schedule!r}")
        if self.segment is not None:
            parts.append(f"segment={self.segment}")
        if self.step is not None:
            parts.append(f"step={self.step}")
        if self.rank is not None:
            parts.append(f"rank={self.rank}")
        if self.chunk is not None:
            parts.append(f"chunk={self.chunk}")
        return ", ".join(parts) if parts else "<no provenance>"


class ScheduleError(ValueError):
    """Base class: a collective schedule is malformed or provably wrong.

    Subclasses partition the failure modes; ``where`` locates the offending
    IR element.  Raised (never asserted) so the checks survive ``python -O``.
    """

    def __init__(self, message: str, where: Provenance | None = None):
        self.where = where if where is not None else Provenance()
        super().__init__(f"{message} [{self.where}]")
        self.message = message


class StepLegalityError(ScheduleError):
    """A Step violates ppermute legality (duplicate src/dst, rank or chunk
    index out of range, malformed send/recv vectors)."""


class ProgramError(ScheduleError):
    """A CollectiveProgram is structurally inconsistent (segment fractions
    don't sum to 1, segment rank-count mismatch, empty segment list)."""


class DataflowError(ScheduleError):
    """The symbolic execution found an illegal data movement."""


class StaleReadError(DataflowError):
    """A rank sends a chunk that was never written (read-before-write):
    the value on the wire would be stale/uninitialized garbage."""


class DoubleReduceError(DataflowError):
    """An accumulate lands a contribution the destination chunk already
    holds — the reduction would double-count that rank's data."""


class ResultError(ScheduleError):
    """A result rank does not end holding the collective's result (missing
    or extra contributions, value bound to the wrong chunk region, or a
    broadcast/gather delivering inconsistent values)."""


class ResultRanksError(ScheduleError):
    """A schedule whose name claims a semantic result (AllReduce, Reduce,
    Broadcast, ...) declares no ``result_ranks``, or declares ranks outside
    the rank space — the verifier would have nothing to prove."""


class CoverageError(ScheduleError):
    """A statically provable survivability hole: some single NIC/rail
    failure leaves the schedule's transfer graph with no live path — a
    participant rank would retain zero residual capacity, so the engine
    would stall rather than complete (see :mod:`repro.analysis.coverage`).
    """

    def __init__(self, message: str, where: Provenance | None = None,
                 *, node: int | None = None, rail: int | None = None):
        #: the single failure (node, rail) that strands the schedule
        self.node = node
        self.rail = rail
        super().__init__(message, where)


class DeadlockError(ScheduleError):
    """The per-rank lockstep dependency graph has a cycle: some set of
    transfers each wait on one another and none can ever be released."""

    def __init__(self, message: str, where: Provenance | None = None,
                 cycle: tuple[tuple[int, int, int, int], ...] = ()):
        #: the offending cycle as (segment, step, src, dst) transfer nodes
        self.cycle = cycle
        super().__init__(message, where)
