"""Co-simulation of the recovery control plane with the event engine.

Glue between :class:`runtime.control_plane.ControlPlane` (the online
pipeline) and :class:`core.event_sim.EventSimulator` (the data plane in
virtual time): every failure event the engine processes is played through
the control plane *at that virtual instant*, and the resulting
:class:`RecoveryDecision` — derived restart delay, rebalance capacity
factor, optional replanned program — is applied by the engine.  Failover
latency therefore *emerges* from the detect→diagnose→migrate→rebalance
pipeline instead of the alpha-beta ``R2CCL_MIGRATION_LATENCY`` constant.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.comm_sim import _strategy_program
from repro.core.event_sim import (
    EventSimReport,
    RecoveryDecision,
    Stream,
    simulate_program,
    simulate_streams,
)
from repro.core.failures import FailureState, silenced
from repro.core.schedule import CollectiveProgram, ring_program
from repro.core.telemetry import Telemetry
from repro.core.topology import ClusterTopology, DEFAULT_ALPHA

from .control_plane import ControlPlane, RecoveryLedger, RecoveryState
from .inference import DetectionEvent, DetectorConfig, TelemetryDetector
from .scenarios import (
    MANAGED_STREAM,
    Scenario,
    StreamSpec,
    build_stream_program,
)


class _EngineAdapter:
    """The controller object the event engine calls back into.

    ``offset`` rebases the engine's run-local clock onto campaign time: a
    multi-iteration campaign runs one engine per gradient sync, each
    starting at t=0, while the persistent control plane's ledger and
    transitions are stamped in campaign-global virtual time.

    Failures carry the engine's chunk map (:class:`ChunkProgress`) into the
    pipeline so a replan prices the residual collective.  Recoveries are
    two-phase: ``on_recover`` (the physical event) returns the confirmation
    time — the control plane's next scheduled probe tick — and the engine
    calls ``on_recovery_confirmed`` when that tick arrives, which is when
    the failure state actually clears.
    """

    def __init__(self, cp: ControlPlane, offset: float = 0.0):
        self.cp = cp
        self.offset = offset
        self.decisions: list[RecoveryDecision] = []

    def on_failure(self, sim, now, failure) -> RecoveryDecision | None:
        # chunk progress of the MANAGED stream only: replans are priced on
        # (and swap) the control plane's collective; co-running streams'
        # progress is theirs alone
        outcome = self.cp.handle_failure(
            failure, self.offset + now,
            progress=sim.chunk_progress(self.cp.stream))
        if outcome is None:
            return None
        self.decisions.append(outcome.decision)
        return outcome.decision

    def on_recover(self, sim, now, failure) -> float:
        return self.cp.observe_physical_recovery(
            failure, self.offset + now) - self.offset

    def on_recovery_confirmed(self, sim, now, failure) -> None:
        self.cp.handle_recovery(failure, self.offset + now)


def plan_initial_program(
    strategy: str,
    cluster: ClusterTopology,
    failures,
    *,
    g: int,
    state: FailureState | None = None,
):
    """The t=0 program: ``strategy`` planned against what the control plane
    knows before the collective starts — ``state`` (carried over from
    earlier collectives, if any) plus failures already in effect (``at_time
    <= 0`` and full severity).  Single planning rule for the one-collective
    (:func:`run_scenario`) and campaign (:mod:`runtime.campaign`) paths so
    they cannot diverge."""
    pre = state.copy() if state is not None else FailureState()
    for f in failures:
        if f.at_time <= 0.0 and f.severity >= 1.0:
            pre.apply(f)
    return _strategy_program(strategy, cluster, pre, g=g)


def build_engine_streams(
    prog: CollectiveProgram,
    payload_bytes: float,
    specs: Sequence[StreamSpec],
    n: int,
    *,
    priority: float = 1.0,
    rank_data: Sequence[np.ndarray] | None = None,
) -> list[Stream]:
    """The engine stream set for one co-simulated collective: the managed
    gradient sync (``prog``, placed first and named ``"dp"`` so a
    stream-scoped replan targets it) plus one co-running stream per
    :class:`StreamSpec`.  When ``rank_data`` is given every stream moves
    its own copy of the real payloads so conservation is checkable per
    stream (the engine never mutates the caller's arrays)."""
    streams = [Stream(MANAGED_STREAM, prog, payload_bytes,
                      priority=priority, rank_data=rank_data)]
    for spec in specs:
        streams.append(Stream(
            spec.name, build_stream_program(spec, n), spec.payload_bytes,
            priority=spec.priority, start_time=spec.start_time,
            rank_data=rank_data))
    return streams


@dataclasses.dataclass
class CoSimReport:
    """One scenario campaign, co-simulated end to end."""

    scenario: str
    report: EventSimReport                 # the engine's view
    ledger: RecoveryLedger                 # the control plane's view
    final_state: RecoveryState
    transitions: list[tuple[float, RecoveryState]]
    stage_totals: dict[str, float]
    decisions: list[RecoveryDecision]
    healthy_time: float
    overhead: float                        # completion vs healthy ring - 1
    #: the observability plane of the run, when one was attached (always in
    #: ``detect="telemetry"`` mode)
    telemetry: Telemetry | None = None
    #: failures the telemetry detector inferred (empty in oracle mode)
    detections: list[DetectionEvent] = dataclasses.field(default_factory=list)

    @property
    def failover_latency(self) -> float:
        """Ledger total of the first recovery pipeline (the paper's
        hot-repair figure for a clean single failure)."""
        return self.ledger.entries[0].total if self.ledger.entries else 0.0


def run_scenario(
    scenario: Scenario,
    cluster: ClusterTopology,
    payload_bytes: float,
    *,
    strategy: str = "ring",
    alpha: float = DEFAULT_ALPHA,
    control_plane: ControlPlane | None = None,
    rank_data: Sequence[np.ndarray] | None = None,
    healthy_time: float | None = None,
    finalize: bool = True,
    streams: Sequence[StreamSpec] = (),
    priority: float = 1.0,
    telemetry: Telemetry | None = None,
    detect: str = "oracle",
    detector_config: DetectorConfig | None = None,
    verify_replans: bool = False,
) -> CoSimReport:
    """Drive one failure campaign through the co-simulated runtime.

    The initial program is planned against what the control plane knows at
    t=0 (failures with ``at_time <= 0``); later failures strike
    mid-collective and exercise the full closed loop.  ``finalize`` settles
    the state machine at campaign end (persistent degradation → REPLANNED
    for the next collective, all-healthy → HEALTHY).

    ``streams`` adds co-running parallelism collectives (TP/PP traffic)
    contending with the managed collective on the shared NICs: the engine
    runs them all under weighted max-min fairness (the managed stream's
    weight is ``priority``), a NIC failure rolls back and re-prices every
    stream crossing the rail, and a control-plane replan swaps only the
    managed stream's program.  ``healthy_time`` and ``overhead`` stay
    relative to the managed collective alone, so the reported overhead
    *includes* the contention cost.

    ``detect`` selects the detection channel.  ``"oracle"`` (default) hands
    every failure event to the control plane at its injection instant, as
    before.  ``"telemetry"`` strips the oracle: the scenario's failures are
    *silenced* (the engine applies their physics but never notifies the
    controller — not even at t=0, so the initial program is planned blind),
    and a :class:`TelemetryDetector` riding the sampling tick must infer
    them from measured counters and probes, feeding the same pipeline with
    ``detected_by="monitor"``.  A ``telemetry`` plane is auto-built at 64
    samples per healthy collective when not supplied; either way the
    control plane mirrors its ledger into the shared trace so every entry
    is reconstructible from the export.
    """
    if detect not in ("oracle", "telemetry"):
        raise ValueError(
            f"detect must be 'oracle' or 'telemetry', got {detect!r}")
    n = cluster.num_nodes
    g = cluster.devices_per_node
    order = list(range(n))

    cp = control_plane or ControlPlane(cluster, payload_bytes=payload_bytes)
    failures = scenario.failures
    if detect == "telemetry":
        failures = tuple(silenced(failures))
        known_at_t0 = ()     # silent failures: the planner starts blind
    else:
        known_at_t0 = failures
    prog = plan_initial_program(strategy, cluster, known_at_t0, g=g)

    if healthy_time is None:
        healthy_time = simulate_program(
            ring_program(order, n), payload_bytes, cluster=cluster,
            alpha=alpha).completion_time

    detector: TelemetryDetector | None = None
    if detect == "telemetry":
        if telemetry is None:
            telemetry = Telemetry.for_duration(healthy_time, samples=64)
        if telemetry.observer is None:
            telemetry.observer = TelemetryDetector(cp, detector_config)
        detector = telemetry.observer
    if telemetry is not None and cp.trace is None:
        cp.trace = telemetry.trace

    adapter = _EngineAdapter(cp)
    if streams:
        # the managed stream is placed first, so a control plane with the
        # default stream=None targets it as the engine's primary stream —
        # no need to (permanently) rebind a caller-provided control plane
        report = simulate_streams(
            build_engine_streams(prog, payload_bytes, streams, n,
                                 priority=priority, rank_data=rank_data),
            cluster=cluster, alpha=alpha, failures=failures,
            controller=adapter, telemetry=telemetry,
            verify_replans=verify_replans)
    else:
        report = simulate_program(
            prog, payload_bytes, cluster=cluster, alpha=alpha,
            failures=failures, rank_data=rank_data,
            controller=adapter, telemetry=telemetry,
            verify_replans=verify_replans)
    if finalize:
        cp.finalize(report.completion_time)

    return CoSimReport(
        scenario=scenario.name,
        report=report,
        ledger=cp.ledger,
        final_state=cp.state,
        transitions=list(cp.transitions),
        stage_totals=cp.ledger.stage_totals(),
        decisions=(adapter.decisions
                   + [ev.outcome.decision for ev in
                      (detector.detections if detector else [])
                      if ev.outcome is not None]),
        healthy_time=healthy_time,
        overhead=report.completion_time / healthy_time - 1.0,
        telemetry=telemetry,
        detections=list(detector.detections) if detector else [],
    )
