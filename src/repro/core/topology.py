"""Cluster topology model for R2CCL.

The paper's hardware unit is a *server* ("node") with ``g`` GPUs and ``g`` NICs
(one rail per GPU) behind a PCIe/NUMA topology, connected by a rail-optimized
fabric.  On TPU the analogous unit is a "super-node" of chips whose egress is a
set of ICI link groups; we keep the paper's vocabulary (node / NIC / rail) and
map NIC -> egress link group.

Everything here is plain Python (no jax) so it can be used by the planner, the
discrete-event simulator, and the schedule builders alike.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e target, per task spec)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per ICI link ("NIC" analogue)

# Paper testbed constants, used by the paper-figure benchmarks.
IB_NIC_BW = 400e9 / 8         # 400 Gb/s ConnectX-7 -> 50 GB/s  (per NIC)
NVLINK_BW = 900e9 / 2         # 900 GB/s bidirectional -> 450 GB/s per direction
PCIE_GEN5_X16 = 63e9          # bytes/s usable
UPI_BW = 40e9                 # cross-socket interconnect
DEFAULT_ALPHA = 2e-6          # per-hop latency (s) for the alpha-beta model


@dataclasses.dataclass(frozen=True)
class Nic:
    """One egress interface (IB NIC on GPU clusters, ICI link group on TPU)."""

    node: int
    rail: int                  # rail index within the node (0..g-1)
    bandwidth: float = ICI_LINK_BW   # bytes/s
    numa: int = 0              # NUMA domain (rail < g/2 -> 0 else 1 by default)
    pcie_switch: int = 0       # PCIe switch id, used for distance ordering

    @property
    def key(self) -> tuple[int, int]:
        return (self.node, self.rail)


@dataclasses.dataclass
class NodeTopology:
    """A single server: ``g`` accelerators, a set of NICs, intra-node fabric."""

    node_id: int
    num_devices: int = 8
    nics: list[Nic] = dataclasses.field(default_factory=list)
    nvlink_bw: float = NVLINK_BW
    pcie_bw: float = PCIE_GEN5_X16
    upi_bw: float = UPI_BW

    def __post_init__(self) -> None:
        if not self.nics:
            half = max(1, self.num_devices // 2)
            self.nics = [
                Nic(
                    node=self.node_id,
                    rail=r,
                    numa=0 if r < half else 1,
                    pcie_switch=r // 2,
                )
                for r in range(self.num_devices)
            ]

    # -- failure bookkeeping -------------------------------------------------
    def healthy_nics(self, failed: Iterable[tuple[int, int]] = ()) -> list[Nic]:
        failed = set(failed)
        return [n for n in self.nics if n.key not in failed]

    def total_bandwidth(self, failed: Iterable[tuple[int, int]] = ()) -> float:
        return sum(n.bandwidth for n in self.healthy_nics(failed))

    def lost_fraction(self, failed: Iterable[tuple[int, int]] = ()) -> float:
        """X in the paper: fraction of this node's egress bandwidth lost."""
        total = sum(n.bandwidth for n in self.nics)
        if total == 0:
            return 1.0
        return 1.0 - self.total_bandwidth(failed) / total

    # -- locality ------------------------------------------------------------
    def pcie_distance(self, device: int, nic: Nic) -> int:
        """Hop metric used to order the failover chain (paper 4.3/7).

        0: same PCIe switch (affinity NIC), 1: same NUMA, 2: cross NUMA (UPI),
        3: PXN detour via a proxy device.
        """
        dev_switch = device // 2
        dev_numa = 0 if device < max(1, self.num_devices // 2) else 1
        if nic.pcie_switch == dev_switch:
            return 0
        if nic.numa == dev_numa:
            return 1
        return 2

    def failover_chain(
        self, device: int, failed: Iterable[tuple[int, int]] = ()
    ) -> list[Nic]:
        """Healthy NICs ordered by PCIe distance then rail — the backup chain.

        Mirrors the paper's "per-channel failover list ordered by PCIe
        distance to the source GPU".
        """
        healthy = self.healthy_nics(failed)
        return sorted(healthy, key=lambda n: (self.pcie_distance(device, n), n.rail))


@dataclasses.dataclass
class ClusterTopology:
    """A rail-optimized cluster of ``n`` nodes with ``g`` devices each."""

    num_nodes: int
    devices_per_node: int = 8
    nic_bandwidth: float = ICI_LINK_BW
    nodes: list[NodeTopology] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.nodes:
            self.nodes = [
                NodeTopology(
                    node_id=i,
                    num_devices=self.devices_per_node,
                    nics=[
                        Nic(
                            node=i,
                            rail=r,
                            bandwidth=self.nic_bandwidth,
                            numa=0 if r < max(1, self.devices_per_node // 2) else 1,
                            pcie_switch=r // 2,
                        )
                        for r in range(self.devices_per_node)
                    ],
                )
                for i in range(self.num_nodes)
            ]

    # -- rail sets (Section 6 / Algorithm 1 input) -----------------------------
    def rail_set(self, node: int, failed: Iterable[tuple[int, int]] = ()) -> frozenset[int]:
        """Set of healthy rail indices on ``node`` (S_n in Algorithm 1)."""
        return frozenset(n.rail for n in self.nodes[node].healthy_nics(failed))

    def rail_sets(self, failed: Iterable[tuple[int, int]] = ()) -> list[frozenset[int]]:
        return [self.rail_set(i, failed) for i in range(self.num_nodes)]

    def node_bandwidth(self, node: int, failed: Iterable[tuple[int, int]] = ()) -> float:
        return self.nodes[node].total_bandwidth(failed)

    def bandwidths(self, failed: Iterable[tuple[int, int]] = ()) -> list[float]:
        return [self.node_bandwidth(i, failed) for i in range(self.num_nodes)]

    def rail_bandwidths(self) -> list[list[float]]:
        """Per-node list of per-rail (NIC) bandwidths, rail-indexed.

        The discrete-event simulator uses this to map a timed
        ``Failure(node, rail, severity)`` onto the exact bandwidth slice it
        removes, including heterogeneous NICs within one node.
        """
        return [
            [nic.bandwidth for nic in sorted(node.nics, key=lambda n: n.rail)]
            for node in self.nodes
        ]

    def lost_fractions(self, failed: Iterable[tuple[int, int]] = ()) -> list[float]:
        return [self.nodes[i].lost_fraction(failed) for i in range(self.num_nodes)]

    def pair_bandwidth(
        self, u: int, v: int, failed: Iterable[tuple[int, int]] = ()
    ) -> float:
        """Effective bandwidth between ring neighbours u,v.

        In a rail-optimized fabric, traffic between u and v rides the rails
        both still have (the intersection); traffic on a rail one side lost
        must detour (intra-node forward), which R2CCL-Balance exploits but at
        reduced efficiency.  For planning we use the conservative intersection
        bandwidth, which is exactly the quantity Algorithm 1 repairs.
        """
        su, sv = self.rail_set(u, failed), self.rail_set(v, failed)
        shared = su & sv
        bw = {n.rail: n.bandwidth for n in self.nodes[u].nics}
        return sum(bw[r] for r in shared)


def make_cluster(num_nodes: int, devices_per_node: int = 8,
                 nic_bandwidth: float = ICI_LINK_BW) -> ClusterTopology:
    return ClusterTopology(num_nodes=num_nodes, devices_per_node=devices_per_node,
                           nic_bandwidth=nic_bandwidth)
