"""Compiled-HLO analysis: collective bytes, roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective traffic;
we parse the (SPMD-partitioned, per-device) HLO text and sum operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, recording replica-group sizes so wire-byte factors
(e.g. 2(n-1)/n for ring AllReduce) can be applied.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

# v5e hardware constants (per task spec)
PEAK_FLOPS = 197e12            # bf16 FLOP/s per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
HBM_PER_CHIP = 16e9            # v5e HBM capacity

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: dict[str, float]            # per-device operand bytes by op kind
    op_counts: dict[str, int]
    wire_bytes: float                     # per-device bytes on the wire (ring factors)
    group_sizes: dict[str, list[int]]

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.op_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    op_bytes: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    op_counts: dict[str, int] = {c: 0 for c in COLLECTIVES}
    group_sizes: dict[str, list[int]] = {c: [] for c in COLLECTIVES}
    wire = 0.0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s+(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([a-z0-9-]+)\(", line)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue                    # async pair: count the -start only
        base = None
        for c in COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        # operand shapes: everything inside the call parens
        paren = line.find("(")
        operands = line[paren + 1:line.rfind(")")] if paren > 0 else ""
        obytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(operands))
        if obytes == 0:
            # fall back to the output shape left of '='
            lhs = line.split("=", 1)[1]
            shapes = _SHAPE_RE.findall(lhs.split("(", 1)[0])
            obytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = len([t for t in gm.group(1).split(",") if t.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            gsize = int(gi.group(2)) if gi else 2
        gsize = max(gsize, 1)
        op_bytes[base] += obytes
        op_counts[base] += 1
        group_sizes[base].append(gsize)
        if base == "all-reduce":
            wire += obytes * 2 * (gsize - 1) / gsize
        elif base in ("all-gather", "reduce-scatter"):
            wire += obytes * (gsize - 1) / gsize if base == "reduce-scatter" \
                else obytes * (gsize - 1)   # AG operand is the shard
        elif base == "all-to-all":
            wire += obytes * (gsize - 1) / gsize
        else:                               # collective-permute
            wire += obytes
    return CollectiveStats(op_bytes, op_counts, wire, group_sizes)


def roofline_terms(
    *,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    wire_bytes_per_device: float,
    chips: int,
) -> dict[str, float]:
    """The three roofline terms, in seconds (global work / global capacity
    == per-device work / per-device capacity)."""
    compute = flops_per_device / PEAK_FLOPS
    memory = hbm_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bottleneck": dominant[0],
        "bound_s": dominant[1],
    }


def model_flops(cfg, tokens: float, mode: str) -> float:
    """6·N_active·D for training, 2·N_active·D for inference."""
    n = cfg.active_param_count()
    per_tok = 6.0 * n if mode == "train" else 2.0 * n
    return per_tok * tokens
