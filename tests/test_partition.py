"""Appendix A: optimal partition math (property-based)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import (
    brute_force_y,
    plan_partition,
    plan_partition_overlapped,
    ring_coeff,
    ring_time,
    stage_times,
    total_time,
    total_time_overlapped,
    x_threshold,
    y_star,
    y_star_overlapped,
)


@given(n=st.integers(2, 32), g=st.integers(2, 16))
def test_threshold_formula(n, g):
    ng = n * g
    assert abs(x_threshold(n, g) - ng / (3 * ng - 2)) < 1e-12
    # threshold always in (1/3, 0.35] for ng >= 4 — the paper's 1/3 rule
    assert 1 / 3 < x_threshold(n, g) <= 0.5


@settings(max_examples=30, deadline=None)
@given(x=st.floats(0.05, 0.95), n=st.integers(3, 16), g=st.integers(2, 8))
def test_y_star_is_global_min(x, n, g):
    ys = y_star(x, n, g)
    yb = brute_force_y(x, n, g, grid=4000)
    assert total_time(ys, x, n, g) <= total_time(yb, x, n, g) + 1e-9


@settings(max_examples=30, deadline=None)
@given(x=st.floats(0.01, 0.99), n=st.integers(3, 16), g=st.integers(2, 8))
def test_plan_never_worse_than_ring(x, n, g):
    plan = plan_partition(x, n, g, practice_threshold=False)
    assert plan.t_r2ccl <= plan.t_ring + 1e-9
    if x <= x_threshold(n, g):
        assert not plan.use_r2ccl          # Appendix A: ring optimal below thr


@settings(max_examples=30, deadline=None)
@given(x=st.floats(0.01, 0.95), n=st.integers(3, 16), g=st.integers(2, 8))
def test_overlapped_beats_serialized(x, n, g):
    """The stage-2-overlap variant dominates the serialized model and beats
    plain ring for every X>0 (the paper's measured behavior)."""
    po = plan_partition_overlapped(x, n, g)
    ps = plan_partition(x, n, g, practice_threshold=False)
    assert po.t_r2ccl <= ps.t_r2ccl + 1e-9
    if x > 0.02:
        assert po.use_r2ccl
        assert po.t_r2ccl < ring_time(x, n, g)


@given(x=st.floats(0.05, 0.95), n=st.integers(3, 12), g=st.integers(2, 8))
def test_stage_times_positive(x, n, g):
    t1, t2, t3 = stage_times(0.3, x, n, g)
    assert t1 >= 0 and t2 >= 0 and t3 >= 0


def test_matches_paper_regimes():
    # X=0.125 (1 of 8 NICs), 2x8 testbed: overlapped model ~0.93-0.96 of
    # healthy throughput (Fig. 15 measures 0.93)
    y = y_star_overlapped(0.125, 2, 8)
    frac = ring_coeff(16) / total_time_overlapped(y, 0.125, 2, 8)
    assert 0.9 < frac < 1.0
    # serialized Appendix-A model at the same point says use plain ring
    assert plan_partition(0.125, 2, 8).use_r2ccl is False


def test_invalid_x():
    with pytest.raises(ValueError):
        plan_partition(1.5, 4, 8)
    with pytest.raises(ValueError):
        y_star(1.0, 4, 8)
