"""Live migration for failure mitigation (paper Section 4.3).

Two techniques, mirrored from the paper:

* **Multi-NIC buffer registration** — every transfer buffer is registered
  with *all* NICs of the node at init time, so a backup NIC can take over a
  transfer without the multi-millisecond registration + connection setup on
  the recovery path.  Here: :class:`RegistrationTable` precomputes, per
  (device, buffer), the PCIe-distance-ordered failover chain.

* **DMA-buffer rollback** — on failure, the sender rewinds to the first
  chunk without a completion and the receiver resets to the last confirmed
  chunk; everything after the rollback point is retransmitted on the backup
  NIC.  Partially-written receive chunks are safely overwritten because
  consumers only read chunks with completions.  Here:
  :class:`ChunkTransfer` is an executable state machine over real numpy
  buffers, property-tested for losslessness under arbitrary failure points
  and repeated failovers.

The latency model (`migration_latency`) combines the detection budget from
``core.detection`` with registration/connection costs from the paper
(Silberstein et al. 2016: GPU memory registration = ms/buffer, RDMA
connection setup = tens of ms) to show why pre-registration keeps failover
in the low-millisecond range.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .detection import Diagnosis
from .topology import Nic, NodeTopology

# Costs avoided by pre-registration (seconds).
GPU_BUFFER_REGISTRATION = 2e-3        # per buffer, if done on demand
RDMA_CONNECTION_SETUP = 30e-3         # QP exchange + transition, if on demand
BACKUP_ACTIVATION = 50e-6             # flip to a pre-established "sleep" QP
ROLLBACK_CPU_COST = 10e-6             # rewind pointers, purge WQEs


@dataclasses.dataclass
class RegistrationTable:
    """Per-node multi-NIC registration + ordered failover chains."""

    node: NodeTopology
    pre_registered: bool = True

    def failover_chain(self, device: int,
                       failed: Sequence[tuple[int, int]] = ()) -> list[Nic]:
        return self.node.failover_chain(device, failed)

    def activation_cost(self, num_buffers: int = 1) -> float:
        """Time to make a backup NIC usable for ``num_buffers`` buffers."""
        if self.pre_registered:
            return BACKUP_ACTIVATION
        return (GPU_BUFFER_REGISTRATION * num_buffers) + RDMA_CONNECTION_SETUP

    def init_cost(self, num_buffers: int) -> float:
        """One-time cost paid at communicator init for pre-registration.

        Registration installs IOMMU/MR mapping entries only (no data copies),
        so the steady-state memory overhead is metadata-sized.
        """
        extra_nics = max(0, len(self.node.nics) - 1)
        return GPU_BUFFER_REGISTRATION * num_buffers * extra_nics


class TransferError(RuntimeError):
    pass


@dataclasses.dataclass
class _Chunk:
    index: int
    sent: bool = False          # posted to the NIC
    completed: bool = False     # work completion polled (acked end-to-end)


class ChunkTransfer:
    """One logical send of ``data`` split into ``num_chunks``, with failover.

    Models the NCCL-style invariants the paper relies on (Section 4.3):
    send buffers are not overwritten until their completion is polled, and
    receive chunks are not consumed before completion — so rollback +
    retransmit is always safe.
    """

    def __init__(self, data: np.ndarray, num_chunks: int,
                 chain: Sequence[Nic], *, inflight: int = 4):
        if num_chunks < 1:
            raise ValueError("num_chunks must be >= 1")
        self.src = np.asarray(data)
        self.chunks = np.array_split(self.src, num_chunks)
        self.state = [_Chunk(i) for i in range(num_chunks)]
        self.chain = list(chain)
        if not self.chain:
            raise TransferError("no healthy NIC available")
        self.active_nic = 0                      # index into the chain
        self.inflight = inflight
        # Receiver-side buffer; NaN = never written.  A partially-written
        # chunk is modeled by garbage followed by rollback-overwrite.
        self.rx = np.full_like(self.src, np.nan, dtype=np.float64)
        self.bytes_sent = 0                      # includes retransmissions
        self.failovers = 0

    # -- introspection --------------------------------------------------------
    @property
    def num_chunks(self) -> int:
        return len(self.chunks)

    def first_incomplete(self) -> int:
        for c in self.state:
            if not c.completed:
                return c.index
        return self.num_chunks

    def done(self) -> bool:
        return all(c.completed for c in self.state)

    def _chunk_slice(self, i: int) -> slice:
        start = sum(len(c) for c in self.chunks[:i])
        return slice(start, start + len(self.chunks[i]))

    # -- data plane ------------------------------------------------------------
    def step(self, *, fail_after_post: bool = False,
             partial_write_fraction: float = 0.0) -> int:
        """Advance the transfer by one pipeline step.

        Posts up to ``inflight`` chunks and completes the oldest one.  If
        ``fail_after_post`` is set, the NIC dies *after* DMA of the current
        chunk began: the receiver may hold a partial write
        (``partial_write_fraction`` of the chunk) with no completion.
        Returns the number of chunks completed this step (0 or 1).
        """
        base = self.first_incomplete()
        if base >= self.num_chunks:
            return 0
        # Post window [base, base+inflight).
        for i in range(base, min(base + self.inflight, self.num_chunks)):
            if not self.state[i].sent:
                self.state[i].sent = True
                self.bytes_sent += self.chunks[i].nbytes

        if fail_after_post:
            # Partial DMA of the in-flight chunk lands at the receiver with
            # no completion — consumers never read it (invariant), and the
            # retransmission will overwrite it.
            sl = self._chunk_slice(base)
            n = int(len(self.chunks[base]) * partial_write_fraction)
            if n > 0:
                self.rx[sl][:n] = -12345.0   # garbage
            raise TransferError(f"NIC {self.chain[self.active_nic].key} failed mid-chunk {base}")

        # Completion of the oldest posted chunk: full data lands at receiver.
        sl = self._chunk_slice(base)
        self.rx[sl] = self.chunks[base]
        self.state[base].completed = True
        return 1

    # -- failure path ------------------------------------------------------------
    def rollback_and_failover(self, diagnosis: Diagnosis | None = None) -> float:
        """DMA-buffer rollback + switch to the next NIC in the chain.

        Sender rewinds to the first chunk without a completion; receiver's
        partial writes stay in place (harmless, will be overwritten).  All
        chunks >= rollback point are marked unsent so they retransmit on the
        backup NIC.  Returns the modeled migration latency.
        """
        rb = self.first_incomplete()
        for c in self.state[rb:]:
            c.sent = False
        self.active_nic += 1
        if self.active_nic >= len(self.chain):
            raise TransferError("failover chain exhausted")
        self.failovers += 1
        latency = ROLLBACK_CPU_COST + BACKUP_ACTIVATION
        if diagnosis is not None:
            latency += diagnosis.localize_latency
        return latency

    def run_to_completion(self, failure_plan: dict[int, float] | None = None) -> None:
        """Drive the transfer, injecting failures per ``failure_plan``.

        ``failure_plan`` maps step-number -> partial_write_fraction; at each
        listed step the active NIC dies mid-chunk and we fail over.
        """
        failure_plan = dict(failure_plan or {})
        step_no = 0
        while not self.done():
            fail = step_no in failure_plan
            try:
                self.step(fail_after_post=fail,
                          partial_write_fraction=failure_plan.get(step_no, 0.0))
            except TransferError:
                self.rollback_and_failover()
            step_no += 1
            if step_no > 100 * self.num_chunks + 100:
                raise TransferError("transfer not making progress")

    # -- verification --------------------------------------------------------------
    def verify_lossless(self) -> bool:
        """Receiver buffer must equal the source exactly — no loss, no
        corruption from partial writes, no stale garbage."""
        return bool(np.array_equal(self.rx, self.src.astype(self.rx.dtype)))


def migration_latency(
    diagnosis: Diagnosis,
    remaining_bytes: int,
    backup_bandwidth: float,
    *,
    pre_registered: bool = True,
    num_buffers: int = 1,
) -> dict[str, float]:
    """End-to-end failover latency breakdown (paper: 'low-millisecond').

    Components: detect+localize (OOB + probes), rollback, backup activation
    (or on-demand registration when not pre-registered), and retransmission
    of the rolled-back bytes on the backup NIC.
    """
    activation = (
        BACKUP_ACTIVATION if pre_registered
        else GPU_BUFFER_REGISTRATION * num_buffers + RDMA_CONNECTION_SETUP
    )
    retransmit = remaining_bytes / backup_bandwidth if backup_bandwidth > 0 else float("inf")
    total = diagnosis.localize_latency + ROLLBACK_CPU_COST + activation + retransmit
    return {
        "detect_localize": diagnosis.localize_latency,
        "rollback": ROLLBACK_CPU_COST,
        "activation": activation,
        "retransmit": retransmit,
        "total": total,
    }
