"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle on CPU.

Wall-clock on CPU is NOT the TPU performance story (interpret mode runs the
kernel body in Python); the purpose here is (a) correctness at benchmark
shapes and (b) the oracle's jit path timing, which the roofline analysis
uses for structural comparisons."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import Reporter, timer


def run() -> None:
    r = Reporter("kernels_micro")
    key = jax.random.PRNGKey(0)
    B, T, KVH, G, D = 1, 512, 2, 2, 64
    q = jax.random.normal(key, (B, T, KVH, G, D), jnp.float32)
    k = jax.random.normal(key, (B, T, KVH, D), jnp.float32)
    v = jax.random.normal(key, (B, T, KVH, D), jnp.float32)

    o_ref = ref.reference_attention(q, k, v)
    o_pal = ops.flash_attention(q, k, v, q_block=128, kv_block=128)
    r.row("flash_attn_maxerr", float(jnp.abs(o_ref - o_pal).max()),
          f"shape B{B} T{T} KVH{KVH} G{G} D{D}")
    t = timer(lambda: ref.reference_attention(q, k, v).block_until_ready(),
              repeats=3)
    r.row("ref_attn_cpu_us", t * 1e6, "jnp oracle wall time")

    C, M = 16, 8192
    local = jax.random.normal(key, (C, M))
    recv = jax.random.normal(jax.random.PRNGKey(1), (C, M))
    seg = jnp.arange(C) % 2
    acc = jnp.arange(C) % 3 == 0
    o1 = ops.chunk_combine(local, recv, seg, acc)
    o2 = ref.reference_chunk_combine(local, recv, seg.astype(bool), acc)
    r.row("chunk_combine_maxerr", float(jnp.abs(o1 - o2).max()), "")

    Bs, Ts, W = 4, 256, 128
    a = jax.random.uniform(key, (Bs, Ts, W), minval=0.5, maxval=0.999)
    x = jax.random.normal(key, (Bs, Ts, W))
    o1 = ops.lru_scan(a, x)
    o2 = ref.reference_lru_scan(a, x, jnp.zeros((Bs, W)))
    r.row("lru_scan_maxerr", float(jnp.abs(o1 - o2).max()), "")
    r.save()


if __name__ == "__main__":
    run()
