"""SmolLM-360M [dense] — llama-architecture small model.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M family]
"""

from repro.configs.base import AttentionConfig, ModelConfig


CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-360M",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49_152,
    attention=AttentionConfig(
        kind="gqa", num_heads=15, num_kv_heads=5, head_dim=64,
        rope_theta=10_000.0,
    ),
    block_pattern=("attn",),
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=120,
        d_ff=320,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=6, num_kv_heads=2,
                                  head_dim=20),
        block_pattern=("attn",),
        activation="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        remat=False,
    )
