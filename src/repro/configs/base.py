"""Configuration system: model / parallelism / communication configs.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the full published configuration) and ``smoke_config()`` (a
reduced variant of the same family for CPU tests).  Input shapes are global
(``train_4k`` etc.) and sharding is expressed via logical-axis rules mapped
onto the production mesh by ``launch/mesh.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.planner import CommConfig


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"                  # "gqa" | "mla" | "none"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    rope_theta: float = 10_000.0
    use_rope: bool = True
    sliding_window: int | None = None  # window for "local" layers
    logit_softcap: float | None = None # gemma2-style soft capping
    causal: bool = True                # False for encoder-only backbones
    # --- MLA (DeepSeek-V3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0
    expert_d_ff: int = 0               # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_k_dense: int = 0             # leading layers with dense FFN
    #: mesh axis for expert parallelism.  None = let GSPMD decide (it
    #: replicates the expert einsum because the dispatch scatter is
    #: data-dependent); "model" = force sharded dispatch buffers
    #: (see EXPERIMENTS.md §Perf, dbrx hillclimb).
    expert_axis: str | None = None


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block (arXiv:2402.19427)."""

    lru_width: int = 0                 # recurrence width (d_model if 0)
    conv_width: int = 4                # temporal conv1d window
    c_constant: float = 8.0            # 'c' in a = exp(-c * softplus(Lambda))


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 'Finch' (arXiv:2404.05892)."""

    head_size: int = 64
    decay_lora: int = 64               # low-rank dim of data-dependent decay
    tokenshift_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModalityConfig:
    """Frontend stub spec for [audio] / [vlm] architectures.

    Per the task carve-out, the conv/ViT frontend is not implemented; the
    model consumes precomputed frame/patch embeddings of this shape.
    """

    kind: str = "text"                 # "text" | "audio_frames" | "vision_text"
    frontend_dim: int = 0              # embedding dim produced by the stub
    num_prefix_tokens: int = 0         # e.g. image patches for VLM
    frame_rate_divisor: int = 1        # audio: frames per token position


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    source: str                        # citation for the configuration
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig | None = None
    moe: MoEConfig | None = None
    rglru: RGLRUConfig | None = None
    rwkv: RWKVConfig | None = None
    modality: ModalityConfig = ModalityConfig()
    #: repeating block pattern; entries: "attn" | "local_attn" | "global_attn"
    #: | "rglru" | "rwkv".  Cycled over num_layers.
    block_pattern: tuple[str, ...] = ("attn",)
    activation: str = "swiglu"         # swiglu | geglu | gelu
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    encoder_only: bool = False
    embedding_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    #: sliding-window size substituted for global attention in long-context
    #: decode configs (the framework's sub-quadratic variant for dense archs)
    long_context_window: int = 8192
    #: DeepSeek-V3 multi-token prediction: an auxiliary head predicting
    #: token t+2 from [h_t ; emb(token_{t+1})] through one extra block
    mtp: bool = False
    mtp_loss_weight: float = 0.3
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)

    # -- derived -------------------------------------------------------------
    @property
    def pattern_layers(self) -> list[str]:
        p = self.block_pattern
        return [p[i % len(p)] for i in range(self.num_layers)]

    def param_count(self) -> float:
        """Approximate parameter count (embeddings + blocks)."""
        d, L, v = self.d_model, self.num_layers, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.pattern_layers:
            if kind in ("attn", "local_attn", "global_attn") and self.attention:
                a = self.attention
                if a.kind == "mla":
                    q = d * a.q_lora_rank + a.q_lora_rank * a.num_heads * (
                        a.qk_nope_head_dim + a.qk_rope_head_dim)
                    kv = d * (a.kv_lora_rank + a.qk_rope_head_dim) + \
                        a.kv_lora_rank * a.num_heads * (a.qk_nope_head_dim + a.v_head_dim)
                    o = a.num_heads * a.v_head_dim * d
                    total += q + kv + o
                elif a.kind == "gqa":
                    total += d * a.num_heads * a.head_dim        # Q
                    total += 2 * d * a.num_kv_heads * a.head_dim  # K,V
                    total += a.num_heads * a.head_dim * d        # O
            elif kind == "rglru" and self.rglru:
                w = self.rglru.lru_width or d
                total += 2 * d * w + w * d + 3 * w + self.rglru.conv_width * w
            elif kind == "rwkv" and self.rwkv:
                total += 5 * d * d + d * self.rwkv.decay_lora * 2
            # FFN / MoE for every block
            if self.moe and self.moe.num_experts > 0:
                e = self.moe
                ff = e.expert_d_ff or self.d_ff
                gates = 3 if self.activation in ("swiglu", "geglu") else 2
                total += e.num_experts * gates * d * ff + d * e.num_experts
                total += e.num_shared_experts * gates * d * ff
            else:
                gates = 3 if self.activation in ("swiglu", "geglu") else 2
                total += gates * d * self.d_ff
        return float(total)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.moe or self.moe.num_experts == 0:
            return self.param_count()
        e = self.moe
        ff = e.expert_d_ff or self.d_ff
        gates = 3 if self.activation in ("swiglu", "geglu") else 2
        per_layer_all = e.num_experts * gates * self.d_model * ff
        per_layer_active = (e.top_k + e.num_shared_experts) * gates * self.d_model * ff
        n_moe_layers = self.num_layers - e.first_k_dense
        return self.param_count() - n_moe_layers * (per_layer_all - per_layer_active)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # "train" | "prefill" | "decode"


#: The four assigned global input shapes.
INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Logical-axis -> mesh-axis rules (MaxText-style)."""

    mode: str = "tp"                   # "tp" | "fsdp_tp"
    rules: tuple[tuple[str, tuple[str, ...] | str | None], ...] = (
        ("batch", ("pod", "data")),
        ("seq", None),
        ("embed", None),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
        ("vocab", "model"),
        ("experts", "model"),
        ("expert_embed", None),
        ("expert_mlp", None),
        ("lru", "model"),
        ("cache_seq", None),
    )

    def lookup(self) -> dict[str, tuple[str, ...] | str | None]:
        return dict(self.rules)


FSDP_TP_RULES: tuple[tuple[str, tuple[str, ...] | str | None], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),
    ("embed", ("pod", "data")),       # ZeRO-3-style: shard params over data too
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"),
    # Expert weights shard ONLY on the expert dim: sharding a second axis
    # (embed or ff) over data makes GSPMD abandon the expert partitioning
    # of the dispatch einsum and replicate ALL expert compute (~E x FLOPs;
    # EXPERIMENTS.md §Perf dbrx iterations 1-3).  Memory-optimal 2D expert
    # sharding needs an explicit shard_map all-to-all EP path (future work,
    # noted in DESIGN.md).
    ("expert_embed", None),
    ("expert_mlp", None),
    ("lru", "model"),
    ("cache_seq", None),
)
