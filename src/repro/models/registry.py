"""Architecture registry: ``--arch <id>`` resolution for all entry points."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCHITECTURES: dict[str, str] = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "smollm-360m": "repro.configs.smollm_360m",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "glm4-9b": "repro.configs.glm4_9b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    # the paper's own simulated training model (Fig. 8)
    "paper-7b": "repro.configs.paper_7b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHITECTURES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHITECTURES)}")
    return importlib.import_module(ARCHITECTURES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in ARCHITECTURES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHITECTURES)}")
    return importlib.import_module(ARCHITECTURES[arch]).smoke_config()


def list_architectures() -> list[str]:
    return sorted(ARCHITECTURES)
