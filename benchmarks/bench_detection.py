"""Section 4 microbenchmarks: detection/localization latency and live
migration (rollback + failover) costs.

Paper claims: bilateral awareness cuts peer detection from minutes (NCCL
timeout) to milliseconds; pre-registration keeps migration in the
low-millisecond range vs tens of ms for on-demand registration + QP setup.
Also measures the real numpy-executor failover path (retransmitted bytes).
"""

from __future__ import annotations

import numpy as np

from repro.core.detection import (
    NCCL_DEFAULT_TIMEOUT,
    FailureDetector,
    FaultLocation,
)
from repro.core.executor_np import ExecStats, execute_chunk_schedule
from repro.core.failures import Failure, FailureState, FailureType
from repro.core.migration import ChunkTransfer, RegistrationTable, migration_latency
from repro.core.schedule import build_ring_all_reduce
from repro.core.topology import IB_NIC_BW, NodeTopology, make_cluster
from repro.runtime import ControlPlane

from .common import Reporter


def run() -> None:
    r = Reporter("detection_migration_sec4")
    det = FailureDetector(FailureState())
    f = Failure(FailureType.NIC_HARDWARE, 0, 0)
    diag = det.detect(f, (0, 0), (1, 0), aux=(2, 0))
    r.row("detect_latency_ms", diag.detect_latency * 1e3, "bilateral OOB")
    r.row("localize_latency_ms", diag.localize_latency * 1e3,
          "probe triangulation")
    r.row("speedup_vs_nccl_timeout",
          NCCL_DEFAULT_TIMEOUT / diag.detect_latency, "minutes -> ms")
    r.row("localization_correct",
          float(diag.location is FaultLocation.LOCAL_NIC), "truth table")

    node = NodeTopology(node_id=0)
    table = RegistrationTable(node, pre_registered=True)
    diag2 = det.detect(Failure(FailureType.LINK_DOWN, 0, 1), (0, 1), (1, 1),
                       aux=(2, 0))
    lat_pre = migration_latency(diag2, remaining_bytes=int(64e6),
                                backup_bandwidth=IB_NIC_BW, pre_registered=True)
    lat_cold = migration_latency(diag2, remaining_bytes=int(64e6),
                                 backup_bandwidth=IB_NIC_BW, pre_registered=False,
                                 num_buffers=8)
    r.row("migration_total_ms_preregistered", lat_pre["total"] * 1e3,
          "paper: low-millisecond")
    r.row("migration_total_ms_on_demand", lat_cold["total"] * 1e3,
          "paper: tens of ms")
    r.row("preregistration_speedup", lat_cold["total"] / lat_pre["total"], "")

    # real rollback/failover on the chunk state machine
    rng = np.random.default_rng(0)
    xfer = ChunkTransfer(rng.normal(size=1 << 14), num_chunks=64,
                         chain=table.failover_chain(0, failed=[(0, 0)]))
    xfer.run_to_completion(failure_plan={10: 0.5, 30: 0.25})
    r.row("rollback_lossless", float(xfer.verify_lossless()), "2 mid-chunk failures")
    r.row("retransmit_overhead_frac",
          xfer.bytes_sent / xfer.src.nbytes - 1.0, "chunk-granularity rollback")

    # schedule-level failover: ring AllReduce with a link dying mid-round
    n = 8
    data = [rng.normal(size=1024) for _ in range(n)]
    sched = build_ring_all_reduce(list(range(n)), n)
    stats = ExecStats()
    out = execute_chunk_schedule(sched, data, stats=stats,
                                 fail_at_round={5: (2, 3)})
    want = np.sum(np.stack(data), axis=0)
    ok = all(np.allclose(o, want) for o in out)
    r.row("inflight_failover_correct", float(ok), "round replay, no loss")
    r.row("inflight_retransmitted_bytes", stats.retransmitted_bytes, "")

    # detection-channel comparison: the same hard failure through the same
    # recovery pipeline, reported by a CQE (transport error, the oracle
    # path) vs inferred by the telemetry monitor (no CQE ever fires, so
    # detection is charged the monitor's sampling latency and diagnosis the
    # active probe round)
    cluster = make_cluster(2, 4)
    totals = {}
    for channel in ("cqe", "monitor"):
        cp = ControlPlane(cluster, payload_bytes=1e8)
        out = cp.handle_failure(
            Failure(FailureType.NIC_HARDWARE, 1, 0, at_time=1e-3), 1e-3,
            detected_by=channel)
        entry = out.entry
        totals[channel] = entry.total
        r.row(f"pipeline_{channel}_detect_ms",
              entry.stages.get("detect", 0.0) * 1e3,
              f"detected_by={channel}")
        r.row(f"pipeline_{channel}_total_ms", entry.total * 1e3,
              " + ".join(f"{k}={v * 1e3:.3g}" for k, v in
                         entry.stages.items() if v > 0))
    r.row("monitor_over_cqe_total", totals["monitor"] / totals["cqe"],
          "telemetry-inferred recovery is slower by construction (>1)")
    r.save()


if __name__ == "__main__":
    run()
