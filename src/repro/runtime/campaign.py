"""Multi-iteration training campaigns through the recovery runtime.

The paper's headline training result (<1% overhead, Figs. 7-10) is measured
over *many iterations*, with failures landing between and inside gradient
syncs and their recovery cost amortizing across the run.  A single
:func:`runtime.cosim.run_scenario` covers exactly one collective; this
module makes the *iteration loop* the unit of simulation:

* :func:`run_campaign` executes a :class:`runtime.scenarios.TrainingCampaign`
  — N gradient-sync collectives back-to-back through
  :mod:`core.event_sim` — with ONE persistent :class:`ControlPlane`
  spanning the whole campaign.  Flap counts, rebalance detour-efficiency
  capacity factors, and replanned programs carry from iteration to
  iteration instead of being rebuilt per collective: still-active
  degradations are handed to the next engine via ``initial_failures``
  (without re-running the pipeline), and at every iteration boundary the
  control plane settles (persistent degradation re-selects the algorithm
  for the *next* sync, charged once to the ledger).

* :func:`training_campaign_report` lifts a :class:`core.comm_sim.TrainJob`
  onto that runner: the DP gradient AllReduce is simulated per iteration
  with the same channel-capacity model as ``iteration_time(mode="event")``,
  the TP/PP terms stay analytic, and the reported overhead derives every
  per-failure recovery cost from the campaign's :class:`RecoveryLedger` —
  the alpha-beta ``R2CCL_MIGRATION_LATENCY`` closed form never enters this
  path (it remains the alpha-beta mode's approximation and a conformance
  target).

* Campaigns are **parallelism-aware**: ``run_campaign(streams=...)`` (or a
  :class:`~runtime.scenarios.TrainingCampaign` carrying ``streams``)
  co-schedules every iteration's gradient sync with TP/PP co-runner
  streams on the shared NICs, so rebalance/replan decisions are priced
  under cross-collective contention instead of an empty network.

The campaign timeline is the back-to-back *communication* timeline: compute
time between syncs is accounted analytically per iteration (as in
``iteration_time``), not simulated, so a failure's ``at_time`` is local to
its iteration's collective.  A failure scheduled after its iteration's
collective completes is dropped, exactly as in ``run_scenario``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.comm_sim import TrainJob, tp_pp_comm_times
from repro.core.event_sim import EventSimReport, EventSimulator, simulate_program
from repro.core.failures import Failure, FailureState
from repro.core.schedule import ring_program
from repro.core.topology import ClusterTopology, DEFAULT_ALPHA

from .control_plane import ControlPlane, LedgerEntry, RecoveryLedger, RecoveryState
from .cosim import (
    _EngineAdapter,
    build_engine_streams,
    plan_initial_program,
)
from .scenarios import StreamSpec, TrainingCampaign, at_iteration


@dataclasses.dataclass
class IterationReport:
    """One gradient sync of a campaign, as the engine executed it."""

    index: int
    t_start: float                     # campaign virtual time at sync start
    report: EventSimReport
    program: str                       # CollectiveProgram name that ran
    program_source: str                # "planned" | "replanned" (carried over)
    failures: tuple[Failure, ...]      # injected this iteration (local times)
    ledger_entries: tuple[LedgerEntry, ...]   # pipeline runs this iteration
    state_after: FailureState          # control-plane view at iteration end
    #: boundary re-selection latency charged after this sync (the replan
    #: broadcast blocks the next collective's start), 0 when none fired
    boundary_cost: float = 0.0

    @property
    def completion_time(self) -> float:
        return self.report.completion_time


@dataclasses.dataclass
class CampaignReport:
    """A whole training campaign, co-simulated end to end."""

    campaign: str
    iterations: list[IterationReport]
    ledger: RecoveryLedger             # the persistent control plane's view
    final_state: RecoveryState
    transitions: list[tuple[float, RecoveryState]]
    healthy_time: float                # one healthy collective
    total_time: float                  # sum of iteration completion times
    overhead: float                    # total / (N * healthy) - 1
    recovery_cost: float               # ledger total across the campaign
    control_plane: ControlPlane

    @property
    def stage_totals(self) -> dict[str, float]:
        return self.ledger.stage_totals()

    @property
    def replans(self) -> int:
        """Mid-collective swaps plus boundary re-selections."""
        return (sum(it.report.replans for it in self.iterations)
                + sum(1 for e in self.ledger.entries
                      if e.failure is None and e.strategy is not None))


def run_campaign(
    campaign: TrainingCampaign,
    cluster: ClusterTopology,
    payload_bytes: float,
    *,
    strategy: str = "ring",
    alpha: float = DEFAULT_ALPHA,
    control_plane: ControlPlane | None = None,
    capacities: Sequence[float] | None = None,
    g: int | None = None,
    rank_data: Sequence[np.ndarray] | None = None,
    healthy_time: float | None = None,
    streams: Sequence[StreamSpec] | None = None,
    verify_replans: bool = False,
) -> CampaignReport:
    """Drive a multi-iteration failure campaign through the co-simulated
    runtime with one persistent control plane.

    Per iteration the initial program is the control plane's carried-over
    replanned program when one is installed, else ``strategy`` planned
    against everything the control plane knows at that sync's start.  When
    ``rank_data`` is given, every iteration moves a fresh copy of the real
    payloads (a new gradient buffer per sync) so conservation is checkable
    across iteration boundaries — including a boundary where a program
    replanned in iteration k is reused in k+1, and *mid-collective* swaps
    inside an iteration (the chunk-map residual replan keeps them
    lossless).  A flap whose recovery is still awaiting its confirming
    probe tick at an iteration's end stays degraded into the next
    iteration: the carry re-announces the physical recovery at t=0 and the
    control plane's (campaign-global) tick decides when it clears.
    ``capacities`` (with ``g``) replaces the cluster's node egress with
    explicit per-rank channel capacities, matching
    ``iteration_time(mode="event")``'s channel model.

    ``streams`` (default: ``campaign.streams``) makes the campaign
    parallelism-aware: every iteration co-schedules the gradient sync with
    one fresh TP/PP stream per :class:`StreamSpec` on the shared NICs —
    contention, rollback, and rebalance re-pricing hit all of them, while
    control-plane replans stay scoped to the gradient-sync stream
    (``"dp"``).  Co-runner streams are rebuilt per iteration (activations
    are a new payload every step) and, with ``rank_data``, each moves its
    own copy so conservation is asserted per stream per iteration.
    """
    n = cluster.num_nodes
    g_eng = cluster.devices_per_node if g is None else g
    placement = ({"capacities": capacities, "g": g_eng}
                 if capacities is not None else {"cluster": cluster})
    cp = control_plane or ControlPlane(cluster, payload_bytes=payload_bytes)
    # the managed stream is always placed first by build_engine_streams, so
    # a control plane with the default stream=None targets it as the
    # engine's primary stream — a caller-provided control plane is never
    # mutated and stays reusable for single-stream runs
    specs = tuple(campaign.streams if streams is None else streams)

    if healthy_time is None:
        healthy_time = simulate_program(
            ring_program(list(range(n)), n), payload_bytes,
            alpha=alpha, **placement).completion_time

    offset = 0.0
    carry: list[tuple[Failure, dict[int, float]]] = []
    iterations: list[IterationReport] = []

    for k in range(campaign.iterations):
        fails = campaign.failures_for(k)
        if cp.current_program is not None:
            prog, source = cp.current_program, "replanned"
        else:
            prog = plan_initial_program(strategy, cluster, fails, g=g_eng,
                                        state=cp.failure_state)
            source = "planned"

        data = None
        if rank_data is not None:
            data = [np.asarray(d, dtype=np.float64).copy() for d in rank_data]
        adapter = _EngineAdapter(cp, offset=offset)
        if specs:
            # parallelism-aware iteration: the gradient sync plus fresh
            # TP/PP co-runner streams contending on the shared NICs
            sim = EventSimulator(
                streams=build_engine_streams(
                    prog, payload_bytes, specs, n, rank_data=data),
                alpha=alpha, failures=fails, controller=adapter,
                initial_failures=carry, verify_replans=verify_replans,
                **placement)
        else:
            sim = EventSimulator(
                prog, payload_bytes, alpha=alpha, failures=fails,
                rank_data=data, controller=adapter, initial_failures=carry,
                verify_replans=verify_replans, **placement)
        entries_before = len(cp.ledger.entries)
        report = sim.run()

        t_start = offset
        offset += report.completion_time
        # Boundary settle: persistent degradation re-selects the algorithm
        # for the NEXT gradient sync (charged once; no-op when already
        # REPLANNED or fully healthy).  The re-selection broadcast blocks
        # the next collective's start, so it advances the campaign clock —
        # keeping ledger times and transitions globally monotone.
        before_finalize = len(cp.ledger.entries)
        cp.finalize(offset)
        boundary_cost = 0.0
        if len(cp.ledger.entries) > before_finalize:
            boundary_cost = cp.ledger.entries[-1].total
            offset += boundary_cost

        # Hand still-active degradations to the next iteration's engine,
        # rebasing any pending recovery onto its run-local clock — whose
        # t=0 sits at ``offset`` *after* the boundary cost, so a flap
        # spanning the boundary still recovers at its physical global time.
        carry = []
        for f, scales in sim.active_degradations():
            rec = None
            if f.recovers_at is not None:
                rec = max(0.0, f.recovers_at - report.completion_time
                          - boundary_cost)
            carry.append(
                (dataclasses.replace(f, at_time=0.0, recovers_at=rec), scales))
        iterations.append(IterationReport(
            index=k, t_start=t_start, report=report,
            program=prog.name, program_source=source, failures=fails,
            ledger_entries=tuple(cp.ledger.entries[entries_before:]),
            state_after=cp.failure_state.copy(),
            boundary_cost=boundary_cost,
        ))

    return CampaignReport(
        campaign=campaign.name,
        iterations=iterations,
        ledger=cp.ledger,
        final_state=cp.state,
        transitions=list(cp.transitions),
        healthy_time=healthy_time,
        total_time=offset,
        overhead=offset / (campaign.iterations * healthy_time) - 1.0,
        recovery_cost=cp.ledger.total_latency(),
        control_plane=cp,
    )


# ---------------------------------------------------------------------------
# TrainJob front-end (paper Figs. 7-10: overhead of a whole training run)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainingCampaignResult:
    """End-to-end training overhead with ledger-derived recovery costs."""

    overhead: float                    # vs N healthy iterations
    recovery_cost: float               # campaign RecoveryLedger total
    healthy_iteration_total: float     # compute + exposed comm, healthy
    iteration_totals: list[float]      # per-iteration compute + exposed comm
    dp_comm_times: list[float]         # per-iteration simulated DP AllReduce
    campaign: CampaignReport


def training_campaign_report(
    job: TrainJob,
    cluster: ClusterTopology,
    failures: Sequence[Failure] = (),
    *,
    strategy: str = "auto",
    iterations: int = 8,
    fail_iteration: int | None = None,
    frac: float = 0.4,
    overlap_fraction: float = 0.0,
    alpha: float = DEFAULT_ALPHA,
    campaign: TrainingCampaign | None = None,
) -> TrainingCampaignResult:
    """Training overhead of ``job`` over an ``iterations``-long campaign.

    ``failures`` strike at gradient sync ``fail_iteration`` (default:
    mid-campaign), each ``frac`` of the way into that sync's collective
    unless it carries an explicit positive ``at_time`` (iteration-local).
    Pass ``campaign`` to place failures yourself (iteration-indexed, chunk
    granularity via :func:`runtime.scenarios.at_chunk`); ``failures`` is
    then ignored.  ``strategy="auto"`` starts on the healthy ring and lets
    the persistent control plane re-select the algorithm when the pipeline
    warrants it — recovery cost comes from the campaign ledger, never from
    the ``R2CCL_MIGRATION_LATENCY`` constant.
    """
    n = cluster.num_nodes
    g = cluster.devices_per_node
    healthy_bw = max(cluster.bandwidths(())) if n else 0.0
    chan_bw = healthy_bw / g * min(job.nic_stripe, g)
    caps = [chan_bw] * n
    payload = job.dp_allreduce_bytes()

    t_h = simulate_program(
        ring_program(list(range(n)), n), payload,
        capacities=caps, g=g, alpha=alpha).completion_time

    if campaign is None:
        k = iterations // 2 if fail_iteration is None else fail_iteration
        events = tuple(
            at_iteration(k, f if f.at_time > 0.0
                         else dataclasses.replace(f, at_time=frac * t_h))
            for f in failures)
        campaign = TrainingCampaign(
            f"training_dp{job.dp}", iterations, events,
            note=f"{len(events)} failure(s) at iteration {k}")

    init_strategy = "ring" if strategy == "auto" else strategy
    crep = run_campaign(
        campaign, cluster, payload, strategy=init_strategy, alpha=alpha,
        capacities=caps, g=g, healthy_time=t_h,
        control_plane=ControlPlane(cluster, payload_bytes=payload))

    compute = job.compute_time()
    tp_h, pp_h = tp_pp_comm_times(job, cluster, cluster.bandwidths(()))
    healthy_total = (compute + max(0.0, t_h - overlap_fraction * compute)
                     + tp_h + pp_h)

    dp_times: list[float] = []
    totals: list[float] = []
    for it in crep.iterations:
        dp = it.report.completion_time + it.boundary_cost
        bw = cluster.bandwidths(it.state_after.failed_nics)
        tp, pp = tp_pp_comm_times(job, cluster, bw)
        dp_times.append(dp)
        totals.append(compute + max(0.0, dp - overlap_fraction * compute)
                      + tp + pp)

    return TrainingCampaignResult(
        overhead=sum(totals) / (campaign.iterations * healthy_total) - 1.0,
        recovery_cost=crep.recovery_cost,
        healthy_iteration_total=healthy_total,
        iteration_totals=totals,
        dp_comm_times=dp_times,
        campaign=crep,
    )
