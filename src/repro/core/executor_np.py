"""Rank-parallel numpy executor for collective schedules — the oracle.

Executes a :class:`core.schedule.ChunkSchedule` / ``CollectiveProgram``
across ``n`` virtual ranks holding real numpy buffers.  Used for:

  * correctness property-tests of every schedule builder (result must equal
    the semantic collective, e.g. AllReduce == sum over ranks);
  * traffic accounting (per-edge / per-rank byte counters) that validates
    the analytic ``bytes_per_rank`` model;
  * alpha-beta step timing used by the microbenchmarks.

It also executes schedules *under failure*: a link can die at a given step,
triggering the detection + rollback + failover pipeline from
``core.detection`` / ``core.migration`` — this is the end-to-end hot-repair
model tested for losslessness.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .schedule import ChunkSchedule, CollectiveProgram
from .topology import DEFAULT_ALPHA, ClusterTopology


@dataclasses.dataclass
class ExecStats:
    rounds: int = 0
    edge_bytes: dict[tuple[int, int], float] = dataclasses.field(default_factory=dict)
    rank_tx: dict[int, float] = dataclasses.field(default_factory=dict)
    rank_rx: dict[int, float] = dataclasses.field(default_factory=dict)
    time: float = 0.0              # alpha-beta completion estimate
    retransmitted_bytes: float = 0.0
    failovers: int = 0

    def add_edge(self, src: int, dst: int, nbytes: float) -> None:
        self.edge_bytes[(src, dst)] = self.edge_bytes.get((src, dst), 0.0) + nbytes
        self.rank_tx[src] = self.rank_tx.get(src, 0.0) + nbytes
        self.rank_rx[dst] = self.rank_rx.get(dst, 0.0) + nbytes


def _pad_to(x: np.ndarray, multiple: int) -> tuple[np.ndarray, int]:
    orig = x.shape[-1]
    pad = (-orig) % multiple
    if pad:
        x = np.concatenate([x, np.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x, orig


def execute_chunk_schedule(
    sched: ChunkSchedule,
    rank_data: Sequence[np.ndarray],
    *,
    stats: ExecStats | None = None,
    bandwidth_fn: Callable[[int, int], float] | None = None,
    alpha: float = DEFAULT_ALPHA,
    fail_at_round: dict[int, tuple[int, int]] | None = None,
    on_failure: Callable[[int, tuple[int, int]], None] | None = None,
) -> list[np.ndarray]:
    """Run ``sched`` over per-rank flat float64 buffers; returns final buffers.

    ``bandwidth_fn(src, dst)`` — bytes/s of the (src,dst) path for timing;
    ``fail_at_round``          — {round_index: edge} links that die mid-round;
                                 the round is rolled back (chunk granularity —
                                 exactly the DMA-rollback semantics) and
                                 re-executed after ``on_failure`` repairs the
                                 bandwidth function.
    """
    n = sched.n
    assert len(rank_data) == n
    stats = stats if stats is not None else ExecStats()
    fail_at_round = dict(fail_at_round or {})

    bufs = []
    orig_len = None
    for r in range(n):
        b, o = _pad_to(np.asarray(rank_data[r], dtype=np.float64), sched.num_chunks)
        bufs.append(b.reshape(sched.num_chunks, -1).copy())
        orig_len = o
    chunk_bytes = bufs[0].shape[1] * 8.0

    round_no = 0
    step_idx = 0
    while step_idx < len(sched.steps):
        st = sched.steps[step_idx]
        if round_no in fail_at_round:
            # A link on this round's perm dies mid-transfer: every in-flight
            # chunk of this round is rolled back (receivers never consumed
            # them — the DMA-rollback invariant) and the round replays.
            edge = fail_at_round.pop(round_no)
            stats.failovers += 1
            size = (bufs[0].size * 8.0) if st.whole_buffer else chunk_bytes
            if edge in st.perm:
                stats.retransmitted_bytes += size
            if on_failure is not None:
                on_failure(round_no, edge)
            round_no += 1
            continue   # replay the same step on the repaired topology

        size = (bufs[0].size * 8.0) if st.whole_buffer else chunk_bytes
        # All transfers in a round are concurrent: round time = slowest edge.
        round_time = 0.0
        incoming: dict[int, np.ndarray] = {}
        for src, dst in st.perm:
            payload = bufs[src] if st.whole_buffer else bufs[src][st.send_chunk[src]]
            incoming[dst] = payload.copy()
            stats.add_edge(src, dst, size)
            if bandwidth_fn is not None:
                bw = bandwidth_fn(src, dst)
                round_time = max(round_time, alpha + (size / bw if bw > 0 else math.inf))
        for dst, payload in incoming.items():
            if st.whole_buffer:
                bufs[dst] = bufs[dst] + payload if st.accumulate else payload.copy()
            else:
                c = st.recv_chunk[dst]
                if st.accumulate:
                    bufs[dst][c] = bufs[dst][c] + payload
                else:
                    bufs[dst][c] = payload
        stats.time += round_time
        stats.rounds += 1
        round_no += 1
        step_idx += 1

    return [b.reshape(-1)[:orig_len] for b in bufs]


def execute_program(
    prog: CollectiveProgram,
    rank_data: Sequence[np.ndarray],
    *,
    stats: ExecStats | None = None,
    bandwidth_fn: Callable[[int, int], float] | None = None,
    alpha: float = DEFAULT_ALPHA,
) -> list[np.ndarray]:
    """Execute every segment of a program; segments partition the payload."""
    n = prog.n
    stats = stats if stats is not None else ExecStats()
    data = [np.asarray(d, dtype=np.float64) for d in rank_data]
    total = data[0].shape[-1]
    out = [np.empty_like(d) for d in data]
    start = 0
    for i, seg in enumerate(prog.segments):
        if i == len(prog.segments) - 1:
            end = total
        else:
            end = start + int(round(seg.frac * total))
        seg_data = [d[start:end] for d in data]
        res = execute_chunk_schedule(
            seg.schedule, seg_data, stats=stats,
            bandwidth_fn=bandwidth_fn, alpha=alpha,
        )
        for r in range(n):
            out[r][start:end] = res[r]
        start = end
    return out


# ---------------------------------------------------------------------------
# Semantic oracles
# ---------------------------------------------------------------------------

def all_reduce_oracle(rank_data: Sequence[np.ndarray]) -> np.ndarray:
    return np.sum(np.stack([np.asarray(d, dtype=np.float64) for d in rank_data]), axis=0)


def check_all_reduce(prog: CollectiveProgram, rank_data: Sequence[np.ndarray],
                     atol: float = 1e-9) -> bool:
    want = all_reduce_oracle(rank_data)
    got = execute_program(prog, rank_data)
    return all(np.allclose(g, want, atol=atol) for g in got)
