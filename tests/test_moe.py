"""MoE layer: capacity dispatch vs dense oracle, load-balance loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import moe as M


@settings(max_examples=12, deadline=None)
@given(e=st.sampled_from([4, 8]), k=st.integers(1, 3), t=st.integers(4, 32),
       shared=st.integers(0, 1), seed=st.integers(0, 50))
def test_dispatch_matches_dense_oracle(e, k, t, shared, seed):
    key = jax.random.PRNGKey(seed)
    d, ff = 16, 32
    p, _ = M.init_moe(key, d, ff, e, num_shared=shared, activation="swiglu")
    x = jax.random.normal(key, (2, t, d))
    y, aux = M.moe_ffn(p, x, num_experts=e, top_k=k, capacity_factor=16.0)
    want = M.moe_ffn_dense_reference(p, x, num_experts=e, top_k=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) >= 0


def test_capacity_drops_tokens():
    """With capacity 1 token per expert, overflow tokens contribute ~zero
    (dropped) but the layer stays finite."""
    key = jax.random.PRNGKey(0)
    p, _ = M.init_moe(key, 8, 16, 2, num_shared=0, activation="swiglu")
    x = jax.random.normal(key, (1, 32, 8))
    y_tight, _ = M.moe_ffn(p, x, num_experts=2, top_k=1, capacity_factor=0.1)
    y_loose, _ = M.moe_ffn(p, x, num_experts=2, top_k=1, capacity_factor=8.0)
    assert bool(jnp.isfinite(y_tight).all())
    # dropping must change the output (tokens actually overflowed)
    assert float(jnp.abs(y_tight - y_loose).max()) > 1e-6


def test_aux_loss_prefers_balance():
    """Uniform routing minimizes the load-balance loss (= aux_weight at
    perfect balance, higher when concentrated)."""
    e = 4
    probs_uniform = jnp.full((64, e), 1.0 / e)
    frac_u = jnp.full((e,), 1.0 / e)
    lb_uniform = e * jnp.sum(frac_u * probs_uniform.mean(0))
    frac_c = jnp.asarray([1.0, 0, 0, 0])
    probs_conc = jnp.tile(jnp.asarray([[0.97, 0.01, 0.01, 0.01]]), (64, 1))
    lb_conc = e * jnp.sum(frac_c * probs_conc.mean(0))
    assert float(lb_conc) > float(lb_uniform)


def test_grad_flows_through_dispatch():
    key = jax.random.PRNGKey(0)
    p, _ = M.init_moe(key, 8, 16, 4, num_shared=1, activation="swiglu")
    x = jax.random.normal(key, (1, 8, 8))

    def loss(p):
        y, aux = M.moe_ffn(p, x, num_experts=4, top_k=2)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router receives gradient (through weights and aux loss)
    assert float(jnp.abs(g["router"]).sum()) > 0
