"""R2CCL-Balance: NIC-level load redistribution (paper Section 5.1).

Keeps the collective algorithm fixed and intervenes only at the network
layer: the share of a node's inter-server traffic ``D_i`` that would have
used a failed NIC is redistributed across the remaining healthy NICs in
proportion to their available bandwidth, with a PCIe-/NUMA-/PXN-aware path
choice per detoured flow.

Applies to ReduceScatter, AllGather, Broadcast, Reduce, P2P and
latency-bound AllReduce (Table 1); throughput-bound AllReduce instead uses
``core.allreduce`` (R2CCL-AllReduce).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

from .topology import Nic, NodeTopology, NVLINK_BW, PCIE_GEN5_X16, UPI_BW


class DetourPath(enum.Enum):
    AFFINITY = "affinity"              # flow's own NIC (no detour)
    PCIE_DIRECT = "pcie_direct"        # same-NUMA backup NIC over PCIe
    PCIE_UPI = "pcie_upi"              # cross-NUMA over CPU interconnect
    PXN = "pxn"                        # NVLink relay via proxy device


@dataclasses.dataclass(frozen=True)
class FlowAssignment:
    """Where one (device -> remote) flow's bytes go after rebalancing."""

    device: int
    nic: tuple[int, int]
    path: DetourPath
    bytes: float


@dataclasses.dataclass
class BalancePlan:
    """Per-NIC load after redistribution on one node."""

    node_id: int
    flows: list[FlowAssignment]
    nic_load: dict[tuple[int, int], float]
    total_bytes: float

    @property
    def completion_time_ideal(self) -> float:
        """D_i / B_i^rem — the lower bound the paper argues Balance approaches."""
        return self.total_bytes / self._total_bw if self._total_bw else float("inf")

    @property
    def completion_time(self) -> float:
        """max over NICs of load/bandwidth (the actual bottleneck NIC)."""
        if not self.nic_load:
            return float("inf")
        return max(load / self._bw[k] for k, load in self.nic_load.items())

    def __post_init__(self) -> None:
        self._bw = {}
        for f in self.flows:
            pass
    # populated by the builder:
    _bw: dict[tuple[int, int], float] = dataclasses.field(default_factory=dict)
    _total_bw: float = 0.0


def choose_detour_path(
    node: NodeTopology, device: int, backup: Nic, *, pcie_headroom: float
) -> DetourPath:
    """Topology-aware path selection for one detoured flow (Section 5.1).

    Priorities (paper): a failed NIC frees its PCIe lane, so prefer direct
    PCIe when the backup NIC shares the NUMA node and the PCIe path has
    headroom; otherwise compare the CPU-interconnect (UPI) cost against the
    NVLink headroom available for PXN and take the cheaper hop.
    """
    dev_numa = 0 if device < max(1, node.num_devices // 2) else 1
    if backup.numa == dev_numa and pcie_headroom > 0:
        return DetourPath.PCIE_DIRECT
    # Cross-NUMA: UPI effective rate vs NVLink relay rate.  HostPing-style
    # measurements (paper Appendix B) put cross-socket at >= half line rate;
    # PXN costs one extra NVLink hop but NVLink bandwidth dwarfs PCIe.
    upi_rate = min(node.upi_bw, node.pcie_bw)
    pxn_rate = min(node.nvlink_bw, node.pcie_bw)
    return DetourPath.PCIE_UPI if upi_rate >= pxn_rate else DetourPath.PXN


def rebalance(
    node: NodeTopology,
    per_device_bytes: Sequence[float],
    failed: Sequence[tuple[int, int]] = (),
) -> BalancePlan:
    """Redistribute one node's egress across its healthy NICs.

    ``per_device_bytes[d]`` is the inter-server traffic device ``d`` must
    exchange for the current collective (the D_i decomposition).  Healthy
    devices keep their affinity NIC; devices whose affinity NIC failed have
    their bytes split across healthy NICs proportionally to available
    bandwidth (after accounting for the affinity load those NICs already
    carry).
    """
    healthy = node.healthy_nics(failed)
    if not healthy:
        raise ValueError(f"node {node.node_id}: no healthy NICs")
    bw = {n.key: n.bandwidth for n in healthy}
    total_bw = sum(bw.values())

    flows: list[FlowAssignment] = []
    nic_load: dict[tuple[int, int], float] = {k: 0.0 for k in bw}
    affinity = {d: (node.node_id, d % len(node.nics)) for d in range(len(per_device_bytes))}

    # Pass 1: affinity flows on healthy NICs.
    orphaned: list[tuple[int, float]] = []
    for d, nbytes in enumerate(per_device_bytes):
        key = affinity[d]
        if key in bw:
            flows.append(FlowAssignment(d, key, DetourPath.AFFINITY, nbytes))
            nic_load[key] += nbytes
        else:
            orphaned.append((d, nbytes))

    # Pass 2: water-fill orphaned traffic so every healthy NIC finishes at the
    # same time: target per-NIC load = share of (existing + orphaned) bytes
    # proportional to bandwidth.
    orphan_total = sum(b for _, b in orphaned)
    grand_total = sum(per_device_bytes)
    if orphan_total > 0:
        target = {k: grand_total * bw[k] / total_bw for k in bw}
        deficit = {k: max(0.0, target[k] - nic_load[k]) for k in bw}
        deficit_sum = sum(deficit.values()) or 1.0
        for d, nbytes in orphaned:
            chain = node.failover_chain(d, failed)
            for nic in chain:
                share = nbytes * deficit[nic.key] / deficit_sum
                if share <= 0:
                    continue
                path = choose_detour_path(
                    node, d, nic,
                    pcie_headroom=node.pcie_bw - nic_load[nic.key] / max(grand_total, 1) * node.pcie_bw,
                )
                flows.append(FlowAssignment(d, nic.key, path, share))
                nic_load[nic.key] += share

    plan = BalancePlan(node_id=node.node_id, flows=flows, nic_load=nic_load,
                       total_bytes=grand_total)
    plan._bw = bw
    plan._total_bw = total_bw
    return plan


def hot_repair_plan(
    node: NodeTopology,
    per_device_bytes: Sequence[float],
    failed: Sequence[tuple[int, int]] = (),
) -> BalancePlan:
    """Baseline for comparison: HotRepair only (no balancing).

    All orphaned traffic lands on the *single* closest backup NIC — the
    behavior the paper measures at ~46-50% throughput loss (Fig. 15/16).
    """
    healthy = node.healthy_nics(failed)
    if not healthy:
        raise ValueError(f"node {node.node_id}: no healthy NICs")
    bw = {n.key: n.bandwidth for n in healthy}
    flows: list[FlowAssignment] = []
    nic_load: dict[tuple[int, int], float] = {k: 0.0 for k in bw}
    for d, nbytes in enumerate(per_device_bytes):
        key = (node.node_id, d % len(node.nics))
        if key not in bw:
            key = node.failover_chain(d, failed)[0].key
            path = DetourPath.PCIE_DIRECT
        else:
            path = DetourPath.AFFINITY
        flows.append(FlowAssignment(d, key, path, nbytes))
        nic_load[key] += nbytes
    plan = BalancePlan(node_id=node.node_id, flows=flows, nic_load=nic_load,
                       total_bytes=sum(per_device_bytes))
    plan._bw = bw
    plan._total_bw = sum(bw.values())
    return plan
