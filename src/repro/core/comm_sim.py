"""Alpha-beta cluster simulator (SimAI-lite) for large-scale evaluation.

The paper complements its 2-node testbed with SimAI simulations of clusters
up to 1024 GPUs.  This module is our equivalent: an alpha-beta model of
training iterations and inference requests over a :class:`ClusterTopology`
with injected failures, reusing the *actual* planner / partition /
balance / recursive machinery (the numbers derive from the technique, not
from constants).  It backs the paper-figure benchmarks:

  Fig 7   training throughput under failure (Megatron DP / TP+PP)
  Fig 8   7B scaling, 4 -> 64 servers
  Fig 9   175B + RLHF extra-time vs AdapCC
  Fig 10  multi-failure Monte Carlo
  Fig 11-13  inference TTFT / TPOT under failure strategies
  Fig 14  DejaVu comparison
  Fig 15/16  collective bus-bandwidth microbenchmarks
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Sequence

from .balance import hot_repair_plan, rebalance
from .failures import Failure, FailureState
from .partition import (
    plan_partition,
    plan_partition_overlapped,
    ring_coeff,
)
from .planner import Collective, Plan, Planner, Strategy
from .recursive import build_recursive_all_reduce
from .recursive import predict_time as recursive_predict_time
from .recursive import spectrum_levels
from .topology import ClusterTopology, DEFAULT_ALPHA

#: simulator backends for the iteration/inference models:
#:   "alpha_beta" — closed-form rates (fast; steady-state only);
#:   "event"      — discrete-event execution of the real collective
#:                  schedules (core.event_sim): contention, stragglers and
#:                  mid-collective failures are simulated, not predicted.
SIM_MODES = ("alpha_beta", "event")

# --- hardware constants for the paper's testbed (H100 + CX7) ---------------
H100_BF16_FLOPS = 989e12
A100_BF16_FLOPS = 312e12
NIC_400G = 50e9                       # bytes/s
NIC_200G = 25e9
MFU = 0.45                            # typical Megatron MFU, for compute time

# --- failure-recovery cost constants (paper Section 2.2) --------------------
CHECKPOINT_RECOVERY_MEDIAN = 68 * 60.0     # s (He et al. 2023 / Jiang et al. 2024)
VLLM_RESTART_DELAY = 35.0                  # s (paper Section 8.1)
DEJAVU_OVERHEAD_RANGE = (0.14, 0.33)       # 14-33% penalty (paper Section 8.3)
R2CCL_MIGRATION_LATENCY = 1.5e-3           # s, low-millisecond hot repair

#: Efficiency of detoured (PCIe-forward / PXN) traffic relative to affinity
#: routing.  Calibrated from the paper's Fig. 15: Balance reaches 83% of
#: healthy throughput at X = 0.125, vs the 87.5% residual-bandwidth ideal
#: -> 0.83 / 0.875 ~= 0.95.
DETOUR_EFFICIENCY = 0.95


def strategy_rate(
    strategy: str,
    node_bw_healthy: float,
    x: float,
    *,
    n_nodes: int,
    g: int,
    bandwidth_spectrum: Sequence[float] | None = None,
    detour_eff: float = DETOUR_EFFICIENCY,
    overlapped: bool = True,
) -> float:
    """Effective collective rate (fraction of healthy node bandwidth) for an
    AllReduce under a lost-bandwidth fraction ``x`` at the bottleneck node.

    This is the calibrated reproduction of the paper's Fig. 15 regimes:
      * hot_repair — the backup NIC carries a doubled channel, so the
        collective completes at the doubled NIC's pace: rate = 1/2 once any
        NIC is doubled (measured ~46-50% loss);
      * balance    — residual bandwidth times detour efficiency
        (measured 83-92%);
      * r2ccl      — the AllReduce decomposition; ``overlapped=True`` uses
        the stage-2-overlap model that matches the measured 93%
        (the serialized Appendix-A model is the faithful baseline);
      * ring       — the degraded node throttles the whole ring: 1-x.
    """
    if x <= 0.0:
        return 1.0
    if strategy == "ring":
        return 1.0 - x
    if strategy == "hot_repair":
        # One failed NIC's channel lands on one backup NIC -> that NIC runs
        # two channels; completion doubles for the affected channels.
        return 0.5
    if strategy == "balance":
        return (1.0 - x) * detour_eff
    if strategy == "r2ccl":
        if n_nodes < 3:
            # 2-node testbed: the decomposition degenerates to a direct
            # exchange for the Y fraction; calibrated to the paper's
            # measured 93% of healthy throughput at X = 0.125 (Fig. 15).
            return max(0.0, 1.0 - 0.55 * x) if overlapped else (1.0 - x)
        plan = (plan_partition_overlapped(x, n_nodes, g) if overlapped
                else plan_partition(x, n_nodes, g))
        healthy_ring_t = ring_coeff(n_nodes * g)       # D=B=1 units
        return healthy_ring_t / plan.t_r2ccl if plan.t_r2ccl > 0 else 0.0
    if strategy == "recursive":
        assert bandwidth_spectrum is not None
        levels = spectrum_levels(list(bandwidth_spectrum))
        t = recursive_predict_time(levels, 1.0, g=g)
        healthy_t = ring_coeff(n_nodes * g) / max(bandwidth_spectrum)
        return healthy_t / t if t > 0 else 0.0
    raise ValueError(strategy)


@dataclasses.dataclass
class TrainJob:
    """A Megatron-style training job for the alpha-beta model."""

    params: float                 # total parameter count
    dp: int                       # data-parallel degree (groups)
    tp: int = 1
    pp: int = 1
    global_batch: int = 512
    seq_len: int = 4096
    layers: int = 32
    hidden: int = 4096
    flops_per_chip: float = A100_BF16_FLOPS
    grad_bytes_per_param: float = 2.0      # bf16 gradients
    #: NCCL channel striping: how many NICs one DP rank's ring channels ride
    #: (1 = strictly rail-aligned, g = full node striping).  Calibrated per
    #: deployment from measured healthy bus bandwidth.
    nic_stripe: int = 1

    @property
    def chips(self) -> int:
        return self.dp * self.tp * self.pp

    def compute_time(self) -> float:
        """6ND forward+backward, split across chips at MFU."""
        tokens = self.global_batch * self.seq_len
        flops = 6.0 * self.params * tokens
        return flops / (self.chips * self.flops_per_chip * MFU)

    def dp_allreduce_bytes(self) -> float:
        """Per-DP-rank gradient payload: the TP/PP shard of the model."""
        return self.params * self.grad_bytes_per_param / (self.tp * self.pp)

    def tp_allreduce_bytes(self) -> float:
        """Per-layer activation all-reduces (2 per layer fwd, 2 bwd)."""
        if self.tp == 1:
            return 0.0
        tokens = self.global_batch * self.seq_len / max(self.dp, 1)
        return 4.0 * self.layers * tokens * self.hidden * 2.0 / max(self.pp, 1)

    def pp_p2p_bytes(self) -> float:
        if self.pp == 1:
            return 0.0
        tokens = self.global_batch * self.seq_len / max(self.dp, 1)
        return 2.0 * (self.pp - 1) * tokens * self.hidden * 2.0


@dataclasses.dataclass
class IterationBreakdown:
    compute: float
    dp_comm: float
    tp_comm: float
    pp_comm: float
    exposed_comm: float
    total: float
    strategy: str


def _ring_ar_time(payload: float, node_bw: Sequence[float], n_nodes: int, g: int,
                  alpha: float = DEFAULT_ALPHA) -> float:
    bmin = min(node_bw)
    if bmin <= 0:
        return math.inf
    return 2 * (n_nodes * g - 1) * alpha + ring_coeff(n_nodes * g) * payload / bmin


def tp_pp_comm_times(
    job: TrainJob,
    cluster: ClusterTopology,
    bw: Sequence[float],
) -> tuple[float, float]:
    """Analytic TP/PP communication terms for one iteration under the node
    bandwidths ``bw`` (degraded or healthy).  TP groups are intra-node in
    both paper configs (TP=8 = one server); PP is a point-to-point handoff.
    Shared by ``iteration_time`` (both modes) and the campaign runner so the
    analytic terms cannot diverge between the per-iteration and multi-
    iteration paths."""
    n = cluster.num_nodes
    g = cluster.devices_per_node
    if job.tp <= g:
        nvlink = cluster.nodes[0].nvlink_bw
        tp_comm = job.tp_allreduce_bytes() / nvlink if job.tp > 1 else 0.0
    else:
        tp_comm = _ring_ar_time(job.tp_allreduce_bytes(), bw, n, g)
    pp_payload = job.pp_p2p_bytes()
    pp_comm = pp_payload / min(bw) if (job.pp > 1 and min(bw) > 0) else (
        math.inf if job.pp > 1 else 0.0)
    return tp_comm, pp_comm


# ---------------------------------------------------------------------------
# Discrete-event backend (mode="event")
# ---------------------------------------------------------------------------

def _strategy_program(
    strategy: str,
    cluster: ClusterTopology,
    state: FailureState,
    *,
    g: int,
):
    """The CollectiveProgram a strategy actually runs under ``state``.

    Ranks are nodes.  Single dispatch site for every event-mode entry point
    (iteration_time and event_failure_scenario), so strategy eligibility
    rules (r2ccl needs exactly one degraded node and n >= 3, recursive
    needs a spectrum) cannot diverge between them.  The R2CCL/recursive
    paths emit the *real* decomposed schedules, so stage overlap and
    stragglers come out of the simulation rather than a formula.
    """
    from .allreduce import build_r2ccl_all_reduce
    from .schedule import ring_program

    n = cluster.num_nodes
    degraded = state.degraded_nodes()
    order = list(range(n))

    if strategy in ("ring", "balance", "hot_repair") or not degraded:
        return ring_program(order, n)
    if strategy == "r2ccl":
        lost = cluster.lost_fractions(state.failed_nics)
        worst = max(range(n), key=lambda i: lost[i])
        if len(degraded) > 1 or n < 3:
            return ring_program(order, n)
        prog, _plan = build_r2ccl_all_reduce(order, worst, x=lost[worst], g=g)
        return prog
    if strategy == "recursive":
        # level structure depends only on bandwidth *ratios*, so raw node
        # bandwidths and channel-scaled capacities give the same program
        prog, _levels = build_recursive_all_reduce(
            cluster.bandwidths(state.failed_nics),
            rail_sets=cluster.rail_sets(state.failed_nics), g=g)
        return prog
    raise ValueError(strategy)


def _strategy_capacities(
    strategy: str,
    cluster: ClusterTopology,
    state: FailureState,
    *,
    chan_bw_healthy: float,
    detour_eff: float = DETOUR_EFFICIENCY,
) -> list[float]:
    """Per-node channel capacity under the strategy's NIC-level behavior."""
    n = cluster.num_nodes
    lost = cluster.lost_fractions(state.failed_nics)
    degraded = set(state.degraded_nodes())
    residual = [chan_bw_healthy * (1.0 - lost[i]) for i in range(n)]
    if strategy == "balance":
        return [r * detour_eff if i in degraded else r
                for i, r in enumerate(residual)]
    if strategy == "hot_repair":
        # the orphaned channel doubles one backup NIC: the node's collective
        # channel runs at half pace regardless of how much bandwidth is left
        return [chan_bw_healthy * 0.5 if i in degraded else r
                for i, r in enumerate(residual)]
    return residual


def event_dp_comm_time(
    job: TrainJob,
    cluster: ClusterTopology,
    state: FailureState,
    strategy: str,
    *,
    alpha: float = DEFAULT_ALPHA,
) -> float:
    """DP gradient AllReduce time by *executing* the collective schedule on
    the discrete-event engine (mode="event" backend of iteration_time)."""
    from .event_sim import simulate_program

    g = cluster.devices_per_node
    healthy_bw = max(cluster.bandwidths(())) if cluster.num_nodes else 0.0
    chan_bw = healthy_bw / g * min(job.nic_stripe, g)
    prog = _strategy_program(strategy, cluster, state, g=g)
    caps = _strategy_capacities(strategy, cluster, state,
                                chan_bw_healthy=chan_bw)
    report = simulate_program(prog, job.dp_allreduce_bytes(),
                              capacities=caps, g=g, alpha=alpha)
    return report.completion_time


def event_failure_scenario(
    cluster: ClusterTopology,
    payload_bytes: float,
    failures: Sequence[Failure] = (),
    *,
    strategy: str = "ring",            # ring|r2ccl|recursive
    alpha: float = DEFAULT_ALPHA,
    rank_data=None,
    healthy_time: float | None = None,  # precomputed healthy-ring baseline
) -> dict[str, float]:
    """One collective under timed failure injection, fully simulated.

    The schedule is planned against what the control plane knows at t=0
    (failures with ``at_time <= 0``); failures with a later ``at_time``
    strike *mid-collective* and exercise the rollback/retransmit path the
    alpha-beta model cannot express.  Returns completion time, overhead vs
    the healthy ring, retransmitted bytes, failover count, and the
    utilization spread across nodes.
    """
    from .event_sim import simulate_program
    from .schedule import ring_program

    n = cluster.num_nodes
    g = cluster.devices_per_node
    order = list(range(n))
    pre = FailureState()
    for f in failures:
        if f.at_time <= 0.0 and f.severity >= 1.0:
            pre.apply(f)

    prog = _strategy_program(strategy, cluster, pre, g=g)
    report = simulate_program(prog, payload_bytes, cluster=cluster,
                              alpha=alpha, failures=failures,
                              rank_data=rank_data)
    if healthy_time is None:
        healthy_time = simulate_program(
            ring_program(order, n), payload_bytes, cluster=cluster,
            alpha=alpha).completion_time
    util = list(report.link_utilization.values())
    return {
        "completion_time": report.completion_time,
        "healthy_time": healthy_time,
        "overhead": report.completion_time / healthy_time - 1.0,
        "retransmitted_bytes": report.retransmitted_bytes,
        "failovers": float(report.failovers),
        "util_min": min(util) if util else 0.0,
        "util_max": max(util) if util else 0.0,
        "transfers": float(report.transfers),
    }


def iteration_time(
    job: TrainJob,
    cluster: ClusterTopology,
    state: FailureState,
    *,
    strategy: str = "auto",            # auto|ring|hot_repair|balance|r2ccl|recursive
    overlap_fraction: float = 0.0,     # DP comm overlapped with backward
    overlapped_broadcast: bool = True, # r2ccl stage-2 overlap (beyond-paper)
    mode: str = "alpha_beta",          # SIM_MODES: alpha_beta | event
) -> IterationBreakdown:
    """One training iteration under the given failure state + strategy.

    The DP gradient AllReduce is the inter-node collective the paper
    optimizes; TP stays intra-node (NVLink/ICI) unless TP spans nodes.
    Ring channels are rail-aligned: each DP rank's ring rides its own NIC,
    so the per-rank channel bandwidth is node_bw / g and a failed NIC
    degrades the whole node's aggregate (the paper's setting).

    ``mode="event"`` replaces the closed-form DP-AllReduce rate with a
    discrete-event execution of the strategy's real schedule (ranks =
    nodes, so the ring coefficient is 2(n-1)/n instead of the alpha-beta
    2(ng-1)/ng — compare within one mode, not across).  TP/PP terms stay
    analytic in both modes (they are intra-node / point-to-point).
    """
    if mode not in SIM_MODES:
        raise ValueError(f"mode must be one of {SIM_MODES}, got {mode!r}")
    g = cluster.devices_per_node
    n = cluster.num_nodes
    bw = cluster.bandwidths(state.failed_nics)
    healthy_bw = max(bw) if bw else 0.0
    degraded = state.degraded_nodes()
    x_worst = max(cluster.lost_fractions(state.failed_nics)) if degraded else 0.0

    compute = job.compute_time()
    payload = job.dp_allreduce_bytes()
    # Rail-aligned channels: each DP rank's ring rides its affinity rail, so
    # the per-channel bandwidth is one NIC's worth (node_bw / g).  This is
    # the calibration that reproduces the paper's comm/compute ratios.
    ranks_per_node = max(1, g // max(job.tp, 1) // max(job.pp, 1))
    chan_bw_healthy = healthy_bw / g * min(job.nic_stripe, g)
    healthy_dp_comm = ring_coeff(n * ranks_per_node) * payload / chan_bw_healthy

    # --- choose/apply strategy on the DP AllReduce -------------------------
    if strategy == "auto":
        planner = Planner(cluster)
        plan = planner.choose_strategy(Collective.ALL_REDUCE, payload, state, g=g)
        strat = plan.strategy.value
        if strat in ("ring", "tree"):
            strat = "ring"
        elif strat == "r2ccl_all_reduce":
            strat = "r2ccl"
        elif strat not in ("hot_repair", "balance", "recursive"):
            strat = "balance"
    else:
        strat = strategy

    if mode == "event":
        dp_comm = event_dp_comm_time(job, cluster, state, strat)
    elif not degraded:
        dp_comm = healthy_dp_comm
    elif strat == "recursive":
        rate = strategy_rate("recursive", healthy_bw, x_worst, n_nodes=n, g=g,
                             bandwidth_spectrum=bw, overlapped=overlapped_broadcast)
        dp_comm = healthy_dp_comm / max(rate, 1e-9)
    else:
        rate = strategy_rate(strat, healthy_bw, x_worst, n_nodes=n, g=g,
                             overlapped=overlapped_broadcast)
        dp_comm = healthy_dp_comm / max(rate, 1e-9)

    # --- TP / PP comm -------------------------------------------------------
    tp_comm, pp_comm = tp_pp_comm_times(job, cluster, bw)

    exposed = max(0.0, dp_comm - overlap_fraction * compute) + tp_comm + pp_comm
    total = compute + exposed
    return IterationBreakdown(compute, dp_comm, tp_comm, pp_comm, exposed, total, strat)


def training_overhead(
    job: TrainJob,
    cluster: ClusterTopology,
    failures: Sequence[Failure],
    strategy: str = "auto",
    *,
    mode: str = "alpha_beta",
    iterations: int = 1,
    fail_iteration: int | None = None,
) -> float:
    """Relative iteration-time overhead vs the no-failure baseline.

    Healthy baseline and degraded iteration use the same simulator
    ``mode`` so the ratio is internally consistent.

    ``iterations > 1`` with ``mode="event"`` is the paper's actual
    measurement unit (Figs. 7-10 are multi-iteration training runs): the
    gradient syncs are executed back-to-back through the event engine with
    ONE persistent recovery control plane, ``failures`` strike at
    ``fail_iteration`` (default: mid-campaign), and every per-failure
    recovery cost is derived from the campaign's ``RecoveryLedger`` — the
    ``R2CCL_MIGRATION_LATENCY`` closed form never enters this path.  The
    single-iteration alpha-beta steady state is unchanged.
    """
    if iterations > 1:
        if mode != "event":
            raise ValueError(
                "multi-iteration campaigns require mode='event' (the "
                "alpha-beta closed form has no notion of a recovery "
                "transient amortizing across iterations)")
        from repro.runtime.campaign import training_campaign_report

        return training_campaign_report(
            job, cluster, failures, strategy=strategy,
            iterations=iterations, fail_iteration=fail_iteration).overhead
    healthy = iteration_time(job, cluster, FailureState(), strategy="ring",
                             mode=mode)
    st = FailureState()
    for f in failures:
        st.apply(f)
    failed = iteration_time(job, cluster, st, strategy=strategy, mode=mode)
    return failed.total / healthy.total - 1.0


def adapcc_overhead(job: TrainJob, cluster: ClusterTopology,
                    failures: Sequence[Failure]) -> float | None:
    """AdapCC excludes the GPU(s) bound to failed NICs from the collective.

    Valid only for pure DP (removing a rank breaks TP/PP partitioning —
    the paper measures 0 tokens/s for TP=8,PP=2).  The surviving chips
    re-shoulder the global batch (compute scales by chips/(chips-lost))
    and the affected node still runs its ring at residual bandwidth with
    no NIC-level rebalancing.
    """
    if job.tp * job.pp > 1:
        return None
    st = FailureState()
    for f in failures:
        st.apply(f)
    lost_gpus = len(st.failed_nics)       # one GPU rides each failed NIC
    if lost_gpus >= job.chips:
        return math.inf
    healthy = iteration_time(job, cluster, FailureState(), strategy="ring")
    degraded = iteration_time(job, cluster, st, strategy="ring")
    scale = job.chips / (job.chips - lost_gpus)
    return (degraded.compute * scale + degraded.exposed_comm) / healthy.total - 1.0


def monte_carlo_multi_failure(
    job: TrainJob,
    cluster: ClusterTopology,
    k_failures: int,
    *,
    trials: int = 50,
    seed: int = 0,
    strategy: str = "auto",
    mode: str = "alpha_beta",
) -> dict[str, float]:
    """Paper Fig. 10: average overhead across random k-failure patterns."""
    from .failures import random_failures

    overheads = []
    for t in range(trials):
        fs = random_failures(k_failures, cluster.num_nodes,
                             cluster.devices_per_node, seed=seed * 1000 + t)
        overheads.append(training_overhead(job, cluster, fs, strategy=strategy,
                                           mode=mode))
    overheads.sort()
    return {
        "mean": sum(overheads) / len(overheads),
        "p50": overheads[len(overheads) // 2],
        "p95": overheads[int(len(overheads) * 0.95) - 1],
        "max": overheads[-1],
    }


# ---------------------------------------------------------------------------
# Inference simulation (Figs 11-14)
# ---------------------------------------------------------------------------

H100_HBM_BW = 3.35e12


@dataclasses.dataclass
class ServeJob:
    params: float
    tp: int = 8
    pp: int = 2
    prompt_tokens: int = 2000
    gen_tokens: int = 256
    flops_per_chip: float = H100_BF16_FLOPS
    hbm_bw_per_chip: float = H100_HBM_BW
    decode_hbm_eff: float = 0.15           # achieved fraction of HBM bw at decode
    kv_bytes_per_token: float = 0.0        # set from model dims
    hidden: int = 8192

    @property
    def chips(self) -> int:
        return self.tp * self.pp

    def prefill_time(self, cluster: ClusterTopology, state: FailureState,
                     comm_rate: float = 1.0) -> float:
        flops = 2.0 * self.params * self.prompt_tokens
        t_comp = flops / (self.chips * self.flops_per_chip * MFU)
        # PP activation handoff crosses nodes; prefill also ships the KV cache
        # to the decode node in PD-disaggregated mode.
        bw = min(cluster.bandwidths(state.failed_nics))
        act = self.prompt_tokens * self.hidden * 2.0 * max(self.pp - 1, 1)
        return t_comp + act / max(bw * comm_rate, 1e-9)

    def decode_step_time(self, cluster: ClusterTopology, state: FailureState,
                         comm_rate: float = 1.0) -> float:
        """Decode is HBM-bound: every step streams the weights once; the
        inter-node part is the PP activation handoff (+TP collectives)."""
        t_mem = (2.0 * self.params) / (self.chips * self.hbm_bw_per_chip
                                       * self.decode_hbm_eff)
        bw = min(cluster.bandwidths(state.failed_nics))
        # per-token activations cross PP boundary; TP all-reduces stay
        # intra-node (NVLink) in the paper's configs.
        act = self.hidden * 2.0 * max(self.pp - 1, 1) * 16.0   # w/ microbatching
        return t_mem + act / max(bw * comm_rate, 1e-9)


def request_latency_under_failure(
    job: ServeJob,
    cluster: ClusterTopology,
    failures: Sequence[Failure],
    *,
    strategy: str,                 # no_failure|restart|reroute|dejavu|r2ccl
    fail_at_decode_step: int = 800,
    restart_delay: float = VLLM_RESTART_DELAY,
) -> dict[str, float]:
    """Single-request cumulative latency with a mid-decode failure
    (DejaVu evaluation methodology, paper Fig. 14).  ``restart_delay``
    defaults to the measured 35 s vLLM engine restart; the DejaVu-style
    worker restart (no engine relaunch) is ~5 s."""
    healthy = FailureState()
    st = FailureState()
    for f in failures:
        st.apply(f)

    t_prefill = job.prefill_time(cluster, healthy)
    d_healthy = job.decode_step_time(cluster, healthy)
    steps_before = min(fail_at_decode_step, job.gen_tokens)
    steps_after = job.gen_tokens - steps_before
    base = t_prefill + job.gen_tokens * d_healthy

    if strategy == "no_failure":
        total = base
    elif strategy == "restart":
        # Abort + relaunch + reprocess everything done so far.
        total = (t_prefill + steps_before * d_healthy) + restart_delay \
            + t_prefill + job.gen_tokens * d_healthy
    elif strategy == "reroute":
        # Healthy replica absorbs doubled load: its effective rate halves,
        # and the request re-runs prefill + all generated tokens.
        total = (t_prefill + steps_before * d_healthy) \
            + 2.0 * (t_prefill + job.gen_tokens * d_healthy)
    elif strategy == "dejavu":
        # KV replicated to host/neighbor: restart workers, stream KV back,
        # recompute only un-replicated tail.  Paper: 1.14x-1.33x total.
        import statistics
        penalty = statistics.mean(DEJAVU_OVERHEAD_RANGE)
        total = base * (1.0 + penalty)
    elif strategy == "r2ccl":
        # Transparent migration: pay the hot-repair latency once *per
        # escalated failure* (each dead NIC runs its own rollback +
        # backup-NIC activation), then proceed at the degraded rate.  A
        # slow NIC (fractional severity) triggers no hot repair.
        hot_repairs = sum(1 for f in failures
                          if f.supported and f.severity >= 1.0)
        d_degraded = job.decode_step_time(cluster, st)
        total = t_prefill + steps_before * d_healthy \
            + hot_repairs * R2CCL_MIGRATION_LATENCY + steps_after * d_degraded
    else:
        raise ValueError(strategy)
    return {"total": total, "baseline": base, "overhead": total / base - 1.0}


def ttft_vs_qps(
    job: ServeJob,
    cluster: ClusterTopology,
    failures: Sequence[Failure],
    qps_grid: Sequence[float],
    *,
    strategy: str,
    duration: float = 100.0,
    fail_time: float = 50.0,
    seed: int = 0,
) -> list[dict[str, float]]:
    """M/D/1-style queueing sim for TTFT percentiles vs offered load
    (paper Figs 11-13).  Deterministic service, fixed-rate arrivals."""
    st = FailureState()
    for f in failures:
        st.apply(f)
    out = []
    for qps in qps_grid:
        service_healthy = job.prefill_time(cluster, FailureState())
        service_failed = {
            "no_failure": service_healthy,
            "r2ccl": job.prefill_time(cluster, st),
            "reroute": 2.0 * service_healthy,
            "restart": service_healthy,
        }[strategy]
        ttfts = []
        server_free = 0.0
        i = 0
        t = 0.0
        restart_until = fail_time + VLLM_RESTART_DELAY if strategy == "restart" else None
        while t < duration:
            arrival = i / max(qps, 1e-9)
            if arrival >= duration:
                break
            start = max(arrival, server_free)
            if restart_until and start >= fail_time and start < restart_until:
                start = restart_until
            svc = service_healthy if start < fail_time else service_failed
            finish = start + svc
            ttfts.append(finish - arrival)
            server_free = finish
            t = arrival
            i += 1
        ttfts.sort()
        def pct(p: float) -> float:
            return ttfts[min(len(ttfts) - 1, int(p * len(ttfts)))] if ttfts else math.inf
        out.append({"qps": qps, "p50": pct(0.50), "p95": pct(0.95), "p99": pct(0.99)})
    return out
