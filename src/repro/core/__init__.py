"""R2CCL core: fault-tolerant collective communication in JAX.

The paper's contribution as a composable library:

  topology     — cluster / node / NIC (rail) model, PCIe-distance chains
  failures     — failure taxonomy (Table 2) + injection schedules
  detection    — bilateral awareness + probe triangulation (Section 4.1-4.2)
  migration    — multi-NIC registration + DMA-buffer rollback (Section 4.3)
  balance      — R2CCL-Balance NIC-level redistribution (Section 5.1)
  partition    — Appendix-A optimal split Y*, threshold ng/(3ng-2)
  allreduce    — R2CCL-AllReduce program builder (Section 5.2)
  reranking    — bridge-based logical re-ranking, Algorithm 1 (Section 6)
  recursive    — recursive decomposition over bandwidth spectra (Section 6)
  planner      — alpha-beta strategy selection (Table 1)
  schedule     — collective schedule IR + ring builders
  executor_np  — numpy rank-parallel oracle executor
  collectives  — JAX shard_map/ppermute execution (the data plane)
  event_sim    — discrete-event cluster simulator (per-link fair sharing,
                 timestamped failure injection, rollback accounting)
  comm_sim     — alpha-beta cluster simulator (SimAI-lite) for evaluation,
                 with mode="event" delegating to event_sim
"""

from . import (  # noqa: F401
    allreduce,
    balance,
    detection,
    event_sim,
    executor_np,
    failures,
    migration,
    partition,
    planner,
    recursive,
    reranking,
    schedule,
    topology,
)
from .event_sim import EventSimReport, simulate_program, simulate_schedule  # noqa: F401
from .failures import Failure, FailureState, FailureType  # noqa: F401
from .planner import CommConfig, Planner, Strategy  # noqa: F401

# collectives / comm_sim import jax lazily-heavy modules; keep them available
# as attributes without forcing jax import order issues for pure-math users.
from . import collectives, comm_sim  # noqa: F401  (jax-dependent)
from .collectives import all_reduce, all_reduce_mean, sync_gradients  # noqa: F401
