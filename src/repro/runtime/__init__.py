"""Online recovery runtime: the paper's pipeline as a closed loop.

  control_plane — HEALTHY→DETECTING→DIAGNOSING→MIGRATING→REBALANCED→
                  REPLANNED state machine over the detection / migration /
                  balance / planner models, with a per-stage latency ledger
  cosim         — co-simulation with core.event_sim (failover latency is
                  derived from the pipeline, not a constant)
  scenarios     — timed multi-failure campaign DSL (builders + text spec)
"""

from .control_plane import (  # noqa: F401
    ControlPlane,
    LedgerEntry,
    RecoveryLedger,
    RecoveryOutcome,
    RecoveryState,
    STAGES,
)
from .cosim import CoSimReport, run_scenario  # noqa: F401
from .scenarios import (  # noqa: F401
    Scenario,
    clean_nic_down,
    correlated_nic_down,
    failure_during_recovery,
    flap_storm,
    parse_campaign,
    slow_nic_degradation,
    standard_campaigns,
)
