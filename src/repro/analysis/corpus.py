"""Builder corpus: every schedule/program builder over a seeded parameter
sweep, for the CLI verifier, the CI lint gate, and the property tests.

``builder_corpus`` enumerates (label, schedule-or-program) pairs covering
all builders in ``core/schedule.py`` / ``core/allreduce.py`` /
``core/recursive.py`` across sizes, rotated and shuffled ring orders,
roots, degraded-bandwidth fractions, and bandwidth spectra.  Deterministic
for a given seed (shuffles use a local ``random.Random(seed)``).
"""

from __future__ import annotations

import random
from typing import Iterator

from repro.core.allreduce import build_partial_all_reduce, build_r2ccl_all_reduce
from repro.core.recursive import build_recursive_all_reduce
from repro.core.schedule import (
    ChunkSchedule,
    CollectiveProgram,
    build_ring_all_gather,
    build_ring_all_reduce,
    build_ring_broadcast,
    build_ring_reduce_scatter,
    build_tree_all_reduce,
    build_tree_broadcast,
    build_tree_reduce,
)

__all__ = ["builder_corpus", "corpus_orders"]

Entry = "tuple[str, ChunkSchedule | CollectiveProgram]"


def corpus_orders(n: int, rng: random.Random) -> list[list[int]]:
    """Identity, one rotation, one reversal, one shuffle of range(n)."""
    base = list(range(n))
    rot = base[1:] + base[:1]
    shuf = list(base)
    rng.shuffle(shuf)
    orders = [base, rot, base[::-1], shuf]
    uniq: list[list[int]] = []
    for o in orders:
        if o not in uniq:
            uniq.append(o)
    return uniq


def builder_corpus(seed: int = 0, max_n: int = 8) -> Iterator[Entry]:
    """Yield (label, schedule-or-program) for every builder sweep point."""
    rng = random.Random(seed)

    for n in range(2, max_n + 1):
        for oi, order in enumerate(corpus_orders(n, rng)):
            tag = f"n{n}.o{oi}"
            yield (f"ring_rs/{tag}", build_ring_reduce_scatter(order, n))
            yield (f"ring_ag/{tag}", build_ring_all_gather(order, n))
            yield (f"ring_ar/{tag}", build_ring_all_reduce(order, n))
            root = order[rng.randrange(n)]
            yield (f"ring_bcast/{tag}.r{root}",
                   build_ring_broadcast(order, n, root))
            yield (f"tree_reduce/{tag}.r{root}",
                   build_tree_reduce(order, n, root))
            yield (f"tree_bcast/{tag}.r{root}",
                   build_tree_broadcast(order, n, root))
            yield (f"tree_ar/{tag}.r{root}",
                   build_tree_all_reduce(order, n, root=root))

    # degraded-node family: partial AllReduce + the full R2CCL program
    for n in range(3, max_n + 1):
        order = list(range(n))
        rng.shuffle(order)
        degraded = order[rng.randrange(n)]
        healthy = [r for r in order if r != degraded]
        yield (f"partial_ar/n{n}.d{degraded}",
               build_partial_all_reduce(healthy, degraded, n))
        for x in (0.05, 0.4, 0.8):
            prog, _plan = build_r2ccl_all_reduce(order, degraded, x=x)
            yield (f"r2ccl/n{n}.d{degraded}.x{x}", prog)

    # recursive decomposition over bandwidth spectra (multi-segment,
    # exercises the multi-bridge subring builder when nodes drop out)
    spectra = [
        [1.0] * 4,                       # flat: single level
        [1.0, 1.0, 0.5, 1.0],            # one slow node
        [1.0, 0.6, 0.6, 0.3, 1.0],       # staircase
        [1.0, 1.0, 0.0, 1.0, 1.0, 0.7],  # dead node -> bridged subring
    ]
    for si, bw in enumerate(spectra):
        prog, _levels = build_recursive_all_reduce(bw)
        yield (f"recursive/s{si}", prog)
