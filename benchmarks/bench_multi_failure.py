"""Paper Fig. 10: Monte Carlo multi-failure resilience — k in 1..10 random
NIC failures across 64 servers (512 GPUs), 50 patterns each; overhead must
grow sub-linearly (paper: 1.5% at k=1 to 4.3% at k=10)."""

from __future__ import annotations

from repro.core.comm_sim import A100_BF16_FLOPS, NIC_200G, TrainJob, monte_carlo_multi_failure
from repro.core.topology import make_cluster

from .common import Reporter


def run(trials: int = 50) -> None:
    r = Reporter("multi_failure_fig10")
    cluster = make_cluster(64, 8, nic_bandwidth=NIC_200G)
    job = TrainJob(params=7e9, dp=128, tp=4, pp=1, global_batch=512,
                   flops_per_chip=A100_BF16_FLOPS)
    means = []
    for k in range(1, 11):
        mc = monte_carlo_multi_failure(job, cluster, k, trials=trials,
                                       strategy="auto")
        means.append(mc["mean"])
        r.row(f"k{k}_mean_overhead", mc["mean"],
              f"p95={mc['p95']:.3%} max={mc['max']:.3%}")
    r.row("k10_overhead", means[-1], "paper: 4.3%")
    # sub-linear growth: overhead(k=10) << 10 x overhead(k=1)
    r.row("sublinear_ratio", means[-1] / max(means[0] * 10, 1e-12),
          "<1 means sub-linear")
    r.save()


if __name__ == "__main__":
    run()
