"""Benchmark drift guard: the paper-figure drivers must run end-to-end at
tiny scale (<=8 simulated GPUs) in BOTH simulator modes.  Heavy benches
(kernels, training, inference) have their own tests; here we cover the
simulator-backed ones through the real ``benchmarks.run`` entry point so a
broken flag, signature, or Reporter path fails tier-1 immediately."""

import json
import os

import pytest

from benchmarks import common as bench_common
from benchmarks.run import main as bench_main


@pytest.fixture(autouse=True)
def _isolated_out_dir(tmp_path, monkeypatch):
    """Tiny-scale smoke results must not clobber real benchmark artifacts
    under experiments/bench/ — Reporter.save reads OUT_DIR at call time."""
    monkeypatch.setattr(bench_common, "OUT_DIR", str(tmp_path))
    yield


def _rows(name: str) -> dict[str, float]:
    with open(os.path.join(bench_common.OUT_DIR, f"{name}.json")) as f:
        doc = json.load(f)
    return {metric: float(value) for metric, value, _ in doc["rows"]}


@pytest.mark.parametrize("sim_mode", ["alpha_beta", "event"])
def test_multi_failure_bench_tiny(sim_mode):
    bench_main(["--only", "multi_failure", "--fast", "--tiny",
                "--sim-mode", sim_mode])
    rows = _rows("multi_failure_fig10")
    # sub-linearity is a scale property (asserted at 64 servers by the real
    # bench); at tiny scale just require a sane finite ratio
    assert 0 < rows["sublinear_ratio"] < 10.0
    # the event scenarios always run and must report the failure-path stats
    assert rows["event_healthy_ring_time"] > 0
    assert rows["event_nic_down_mid_time"] > rows["event_healthy_ring_time"]
    assert rows["event_nic_down_mid_retrans_bytes"] >= 0
    assert rows["event_slow_nic_spectrum_retrans_bytes"] == 0


@pytest.mark.parametrize("sim_mode", ["alpha_beta", "event"])
def test_scaling_bench_tiny(sim_mode):
    bench_main(["--only", "scaling", "--tiny", "--sim-mode", sim_mode])
    rows = _rows("scaling_fig8_fig9")
    assert 0 <= rows["r2ccl_max_overhead"] < 0.5
    # cross-validation row: the two backends differ only by the ring
    # coefficient (2(n-1)/n vs 2(ng-1)/ng) plus alpha terms
    assert 0.3 < rows["event_vs_alpha_beta_dp_comm"] < 1.2


def test_partition_bench_runs():
    bench_main(["--only", "partition"])
    assert os.path.exists(
        os.path.join(bench_common.OUT_DIR, "partition_appendix_a.json"))


def test_training_bench_tiny_campaign():
    """The multi-iteration campaign path (tiny shape: 3 iterations, one
    mid-campaign NIC failure) must run end-to-end on every push: overhead
    positive and sane, recovery cost ledger-derived (nonzero)."""
    bench_main(["--only", "training", "--tiny"])
    rows = _rows("training_fig7")
    assert rows["campaign_iterations"] == 3.0
    assert 0.0 < rows["campaign_overhead"] < 0.5
    assert rows["campaign_recovery_cost"] > 0.0
    assert rows["campaign_degraded_dp_comm"] > 0.0


def test_runtime_bench_tiny_campaign_sweep(tmp_path):
    """The bench_runtime campaign sweep rows (clean / flap storm / slow
    NIC over 3 iterations) must be emitted with ledger totals, and the
    mid-collective replan scenario (payload-conserving program swap) must
    report its retransmission/residual accounting.  Runs with ``--trace``
    so the export path (JSONL + Chrome) is exercised on every push."""
    trace_path = str(tmp_path / "run.trace.jsonl")
    bench_main(["--only", "runtime", "--tiny", "--trace", trace_path])
    rows = _rows("runtime_recovery")
    for name in ("campaign_clean_nic_down", "campaign_flap_storm",
                 "campaign_slow_nic"):
        assert f"{name}_overhead" in rows
        assert rows[f"{name}_ledger_total"] > 0.0
    # comm-only overhead: the repair window dominates at tiny payloads
    assert rows["campaign_clean_nic_down_overhead"] > 0.0
    # mid-collective replan row: the swap really happened with payloads
    # attached, the chunk map priced a sane residual, and nothing was lost
    assert rows["mid_replan_count"] >= 1.0
    assert rows["mid_replan_retrans_bytes"] >= 0.0
    assert 0.0 < rows["mid_replan_residual_fraction"] <= 1.0
    assert rows["mid_replan_payload_max_error"] < 1e-9
    # verified replans (static schedule verification on the hot swap path):
    # acceptance is < 10% wall overhead, and verification must not perturb
    # the simulated timeline at all
    assert rows["mid_replan_verify_overhead"] < 0.10
    assert rows["mid_replan_verified_equal"] == 1.0
    # contention rows: the multi-stream (TP+PP+DP) path runs in the tiny
    # tier too — fair sharing slows the contended DP sync (never speeds
    # it), every stream's payload is exact, a NIC-down costs at least as
    # much with co-running streams, and priority weighting buys the DP
    # sync real bandwidth back
    assert rows["multi_stream_healthy_dp_slowdown"] >= 1.0
    assert rows["multi_stream_payload_max_error"] < 1e-9
    assert rows["nic_down_contention_ratio"] >= 1.0 - 1e-9
    assert rows["nic_down_contended_dp_time"] > 0.0
    assert rows["stream_priority_dp_speedup"] > 1.0
    # telemetry-inferred detection rows: the oracle-free loop detected the
    # clean NIC-down, recovered through the ledger, and the exported trace
    # reconstructs every stage (cross-validation bit must be exactly 1)
    assert rows["clean_nic_down_monitor_ledger_total"] > 0.0
    assert rows["telemetry_trace_ledger_match"] == 1.0
    assert rows["monitor_vs_oracle_detect"] >= 1.0
    # --trace wrote both export formats and they parse + validate
    from repro.core.telemetry import validate_trace_schema
    with open(trace_path) as f:
        records = [json.loads(ln) for ln in f if ln.strip()]
    assert len(records) == rows["trace_records"]
    validate_trace_schema(records)
    with open(trace_path + ".chrome.json") as f:
        assert json.load(f)["traceEvents"]


def test_analysis_bench_tiny():
    """Static cost/coverage conformance bench: lockstep-uniform corpus
    entries priced bit-exactly, corpus error under the pinned tolerance,
    and the survivability fractions at their provable extremes."""
    from repro.analysis.cost import CORPUS_COST_TOLERANCE

    bench_main(["--only", "analysis", "--tiny"])
    rows = _rows("analysis_static")
    assert rows["static_cost_exact_fraction"] == 1.0
    assert rows["static_cost_max_error"] <= CORPUS_COST_TOLERANCE
    assert 0.5 < rows["static_cost_uniform_fraction"] <= 1.0
    assert rows["planner_drift_max"] >= rows["planner_drift_mean"] >= 0.0
    assert 0.0 <= rows["planner_static_agreement"] <= 1.0
    # 2 rails/rank: every single-rail failure survivable; 1 rail/rank:
    # every participant failure provably fatal
    assert rows["coverage_survivable_fraction"] == 1.0
    assert rows["coverage_single_rail_fraction"] == 0.0


def test_engine_perf_bench_tiny():
    """Event-engine throughput bench: the telemetry acceptance row (wall
    overhead with the monitor attached at its 64-sample budget) must stay
    under the 10% ceiling, and the throughput rows must be positive."""
    bench_main(["--only", "engine_perf", "--tiny"])
    rows = _rows("BENCH_event_engine")
    assert rows["healthy_events_per_sec"] > 0.0
    assert rows["stress_events"] > 0.0
    assert rows["stress_wall_time"] > 0.0
    assert rows["telemetry_overhead"] < 0.10
