"""PaliGemma-3B [vlm] — SigLIP patch embeddings + Gemma decoder.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216  [arXiv:2407.07726]
The SigLIP So400m vision tower is a STUB per the task carve-out:
``input_specs()`` supplies 256 precomputed patch embeddings (dim 1152);
the linear projector + Gemma-style decoder (prefix-LM over the image
prefix) are implemented.
"""

from repro.configs.base import AttentionConfig, ModalityConfig, ModelConfig


CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257_216,
    attention=AttentionConfig(
        kind="gqa", num_heads=8, num_kv_heads=1, head_dim=256,
        rope_theta=10_000.0,
    ),
    modality=ModalityConfig(kind="vision_text", frontend_dim=1152,
                            num_prefix_tokens=256),
    block_pattern=("attn",),
    activation="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embedding_scale=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke",
        family="vlm",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=1,
                                  head_dim=32),
        modality=ModalityConfig(kind="vision_text", frontend_dim=48,
                                num_prefix_tokens=8),
        block_pattern=("attn",),
        activation="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        embedding_scale=True,
        remat=False,
    )
