"""Pallas TPU kernels for the perf-critical compute layers.

  flash_attention — online-softmax attention, full mask menu (causal /
                    sliding-window / prefix-LM / logit softcap)
  chunk_combine   — fused R2CCL stage-2 merge (the paper's custom
                    broadcast-kernel analogue)
  lru_scan        — RG-LRU linear recurrence (RecurrentGemma)
  wkv_scan        — RWKV-6 WKV matrix-state recurrence

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling, an ``ops.py``
jit wrapper (padding/dispatch), and a pure-jnp oracle in ``ref.py``.
Validated with interpret=True on CPU; lowers to Mosaic on real TPU.
"""

from . import ops, ref  # noqa: F401
