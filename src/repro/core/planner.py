"""Failure-aware collective planner (paper Sections 5-6, Table 1).

Given the collective type, payload size, cluster topology, and the current
:class:`FailureState`, the planner selects among:

  * standard ring / tree (no failure, or latency-bound small messages);
  * R2CCL-Balance        (all collectives; NIC-level rebalancing);
  * R2CCL-AllReduce      (throughput-bound AllReduce, single bottleneck);
  * recursive R2CCL      (multi-failure bandwidth spectrum);

using NCCL's alpha-beta performance model extended with per-node residual
bandwidth (Section 6: "evaluate expected completion time at each recursion
depth").  The paper's runtime rule — crossover adapts to hardware via the
alpha/beta parameters rather than a fixed message-size threshold — is
implemented in :func:`choose_strategy`.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

from .balance import hot_repair_plan, rebalance
from .failures import FailureState
from .partition import plan_partition, plan_partition_overlapped, ring_coeff
from .recursive import predict_time, spectrum_levels
from .reranking import bridge_rerank
from .topology import DEFAULT_ALPHA, ClusterTopology


class Collective(enum.Enum):
    ALL_REDUCE = "all_reduce"
    REDUCE_SCATTER = "reduce_scatter"
    ALL_GATHER = "all_gather"
    BROADCAST = "broadcast"
    REDUCE = "reduce"
    ALL_TO_ALL = "all_to_all"
    SEND_RECV = "send_recv"


class Strategy(enum.Enum):
    RING = "ring"                    # vanilla schedule, affinity NICs
    TREE = "tree"                    # latency-optimal for tiny payloads
    HOT_REPAIR = "hot_repair"        # migrate to one backup NIC, no rebalance
    BALANCE = "balance"              # R2CCL-Balance
    R2CCL_ALL_REDUCE = "r2ccl_all_reduce"
    RECURSIVE = "recursive"


@dataclasses.dataclass(frozen=True)
class Plan:
    strategy: Strategy
    predicted_time: float
    ring_order: tuple[int, ...]
    degraded_node: int | None = None
    lost_fraction: float = 0.0
    partition_y: float = 0.0
    bandwidths: tuple[float, ...] = ()
    notes: str = ""


# ---------------------------------------------------------------------------
# alpha-beta cost model
# ---------------------------------------------------------------------------

def ring_time_hetero(
    payload: float, bandwidths: Sequence[float], g: int, alpha: float
) -> float:
    """Ring collective time when node bandwidths differ: the ring moves at
    the slowest node's rate."""
    n = len(bandwidths)
    bmin = min(bandwidths)
    if bmin <= 0:
        return float("inf")
    steps = 2 * (n * g - 1)
    return steps * alpha + ring_coeff(n * g) * payload / bmin


def tree_time(payload: float, bandwidths: Sequence[float], g: int, alpha: float) -> float:
    import math

    n = len(bandwidths)
    positive = [b for b in bandwidths if b > 0]
    if not positive:
        # every node dead: no tree can move data (mirrors ring_time_hetero)
        return float("inf")
    bmin = min(positive)
    depth = max(1, math.ceil(math.log2(max(n * g, 2))))
    return 2 * depth * alpha + 4.0 * payload / bmin   # reduce+broadcast, 2x data


def collective_payload_factor(coll: Collective) -> float:
    """Per-node traffic relative to the payload D (Section 5.1 lower bounds)."""
    return {
        Collective.ALL_REDUCE: 2.0,
        Collective.REDUCE_SCATTER: 1.0,
        Collective.ALL_GATHER: 1.0,
        Collective.BROADCAST: 1.0,
        Collective.REDUCE: 1.0,
        Collective.ALL_TO_ALL: 1.0,
        Collective.SEND_RECV: 1.0,
    }[coll]


@dataclasses.dataclass
class Planner:
    cluster: ClusterTopology
    alpha: float = DEFAULT_ALPHA
    #: payloads smaller than this always take the latency-optimal path
    latency_bound_bytes: float = 1 << 16
    #: evaluate R2CCL-AllReduce with the stage-2-overlap model (matches the
    #: paper's measured crossover; False = faithful serialized Appendix A)
    overlapped_broadcast: bool = True

    def node_bandwidths(self, state: FailureState) -> list[float]:
        return self.cluster.bandwidths(state.failed_nics)

    # -- entry point -----------------------------------------------------------
    def choose_strategy(
        self,
        coll: Collective,
        payload_bytes: float,
        state: FailureState,
        *,
        g: int | None = None,
        score: str = "alpha_beta",
    ) -> Plan:
        """Select a strategy and predict its completion time.

        ``score`` picks the cost model.  ``"alpha_beta"`` (default, the
        original behavior) ranks candidates with the closed-form
        approximations below.  ``"static"`` builds each eligible
        candidate's *actual* :class:`~repro.core.schedule.CollectiveProgram`
        and prices it with the static cost analyzer
        (:func:`repro.analysis.cost.analyze_program`) over the residual
        bandwidths — the same lockstep-round walk the event engine's healthy
        completion conforms to, so plan-vs-execution drift collapses to the
        analyzer's pinned tolerance.
        """
        if score not in ("alpha_beta", "static"):
            raise ValueError(
                f"score must be 'alpha_beta' or 'static', got {score!r}")
        g = g or self.cluster.devices_per_node
        if score == "static":
            return self._choose_static(coll, payload_bytes, state, g=g)
        n = self.cluster.num_nodes
        bw = self.node_bandwidths(state)
        healthy_bw = max(bw)
        degraded = state.degraded_nodes()
        ring = tuple(range(n))

        # Re-rank the ring if any edge's rail intersection collapsed.
        if degraded:
            rr = bridge_rerank(list(ring), self.cluster.rail_sets(state.failed_nics))
            ring = tuple(rr.ring)

        # --- no failure: vanilla ring/tree ---------------------------------
        if not degraded:
            t_ring = ring_time_hetero(payload_bytes, bw, g, self.alpha)
            t_tree = tree_time(payload_bytes, bw, g, self.alpha)
            if payload_bytes <= self.latency_bound_bytes and t_tree < t_ring:
                return Plan(Strategy.TREE, t_tree, ring, notes="latency-bound")
            return Plan(Strategy.RING, t_ring, ring)

        # --- failure present -------------------------------------------------
        # Balance: schedule unchanged, degraded nodes run at residual rate.
        t_balance = ring_time_hetero(payload_bytes, bw, g, self.alpha)
        # HotRepair: orphaned traffic lands on ONE backup NIC; that NIC
        # carries 2x its share, so the affected node behaves as if its
        # residual bandwidth were halved on the overloaded rail.
        worst = min(range(n), key=lambda i: bw[i])
        per_dev = [payload_bytes * collective_payload_factor(coll) / g] * g
        hr = hot_repair_plan(self.cluster.nodes[worst], per_dev, state.failed_nics)
        bal = rebalance(self.cluster.nodes[worst], per_dev, state.failed_nics)
        hr_slowdown = hr.completion_time / max(bal.completion_time, 1e-30)
        t_hot = t_balance * hr_slowdown

        if coll is not Collective.ALL_REDUCE or payload_bytes <= self.latency_bound_bytes:
            # Table 1: everything except throughput-bound AllReduce uses
            # Balance (it is never worse than HotRepair).
            return Plan(
                Strategy.BALANCE, t_balance, ring,
                degraded_node=worst,
                lost_fraction=self.cluster.nodes[worst].lost_fraction(state.failed_nics),
                bandwidths=tuple(bw),
                notes=f"hot_repair would be {hr_slowdown:.2f}x slower",
            )

        # Throughput-bound AllReduce: single vs multi bottleneck.  The
        # single-bottleneck decomposition only applies when exactly one node
        # is degraded (it can exclude one node from the partial ring).
        if len(degraded) == 1:
            x = 1.0 - bw[worst] / healthy_bw
            pp = (plan_partition_overlapped(x, n=n, g=g)
                  if self.overlapped_broadcast else plan_partition(x, n=n, g=g))
            t_r2 = pp.t_r2ccl * payload_bytes / healthy_bw
            if pp.use_r2ccl and t_r2 < t_balance:
                return Plan(
                    Strategy.R2CCL_ALL_REDUCE, t_r2, ring,
                    degraded_node=worst, lost_fraction=x, partition_y=pp.y,
                    bandwidths=tuple(bw),
                )
            return Plan(Strategy.BALANCE, t_balance, ring,
                        degraded_node=worst, lost_fraction=x, bandwidths=tuple(bw))

        # Bandwidth spectrum: recursive decomposition.
        levels = spectrum_levels(bw)
        t_rec = predict_time(levels, payload_bytes, g=g)
        if t_rec < t_balance and len(levels) > 1:
            return Plan(Strategy.RECURSIVE, t_rec, ring,
                        bandwidths=tuple(bw),
                        notes=f"{len(levels)} recursion levels")
        return Plan(Strategy.BALANCE, t_balance, ring, bandwidths=tuple(bw))

    def _choose_static(
        self,
        coll: Collective,
        payload_bytes: float,
        state: FailureState,
        *,
        g: int,
    ) -> Plan:
        """``score="static"``: price *built programs*, not closed forms.

        Every eligible candidate strategy's real AllReduce decomposition is
        built through the same single dispatch site the event engine runs
        (:func:`repro.core.comm_sim._strategy_program`) and priced with the
        static cost analyzer over the per-node residual bandwidths.  The
        candidates mirror the alpha-beta branch structure: ring/tree when
        healthy, balance always under failure, R2CCL-AllReduce with exactly
        one degraded node (n >= 3), recursive when the bandwidth spectrum
        has more than one level.  Non-AllReduce collectives are priced on
        the ring decomposition they would actually run (Table 1 sends them
        to Balance); the per-collective payload factors cancel in ranking.
        """
        # imported lazily: comm_sim and the analysis package both import
        # this module at load time
        from repro.analysis.cost import analyze_program
        from .comm_sim import _strategy_program
        from .schedule import tree_program

        n = self.cluster.num_nodes
        bw = self.node_bandwidths(state)
        degraded = state.degraded_nodes()
        ring = tuple(range(n))
        if degraded:
            rr = bridge_rerank(list(ring),
                               self.cluster.rail_sets(state.failed_nics))
            ring = tuple(rr.ring)

        candidates: list[tuple[Strategy, object]] = []
        if not degraded:
            candidates.append(
                (Strategy.RING, _strategy_program("ring", self.cluster,
                                                  state, g=g)))
            if payload_bytes <= self.latency_bound_bytes:
                candidates.append(
                    (Strategy.TREE, tree_program(list(range(n)), n)))
        else:
            candidates.append(
                (Strategy.BALANCE, _strategy_program("balance", self.cluster,
                                                     state, g=g)))
            if (coll is Collective.ALL_REDUCE
                    and payload_bytes > self.latency_bound_bytes):
                # r2ccl's partial ring needs the degraded node to retain
                # *some* bandwidth (the partition domain is X in [0, 1))
                if (len(degraded) == 1 and n >= 3
                        and min(bw) > 0.0):
                    candidates.append(
                        (Strategy.R2CCL_ALL_REDUCE,
                         _strategy_program("r2ccl", self.cluster, state,
                                           g=g)))
                if len(spectrum_levels(bw)) > 1:
                    candidates.append(
                        (Strategy.RECURSIVE,
                         _strategy_program("recursive", self.cluster, state,
                                           g=g)))

        scored: list[tuple[float, Strategy]] = []
        for strat, prog in candidates:
            rep = analyze_program(prog, payload_bytes, capacities=bw,
                                  alpha=self.alpha)
            scored.append((rep.predicted_time, strat))
        # stable: ties keep candidate order (ring/balance first)
        best_time, best = min(scored, key=lambda st: st[0])

        worst = min(range(n), key=lambda i: bw[i]) if degraded else None
        lost = (self.cluster.nodes[worst].lost_fraction(state.failed_nics)
                if worst is not None else 0.0)
        return Plan(
            best, best_time, ring,
            degraded_node=worst,
            lost_fraction=lost,
            bandwidths=tuple(bw),
            notes=f"static: priced {len(scored)} built program(s)",
        )


@dataclasses.dataclass
class CommConfig:
    """Framework-level communication configuration (first-class feature).

    Attached to every architecture config; consumed by ``training.train_step``
    and ``serving.engine``.
    """

    mode: str = "xla"                  # "xla" | "ring" | "r2ccl" | "recursive"
    degraded_rank: int | None = None   # data-parallel rank with lost bandwidth
    lost_fraction: float = 0.0         # X for that rank
    bandwidths: tuple[float, ...] = () # full spectrum for recursive mode
    devices_per_node: int = 8          # g in the Appendix-A coefficients
    #: wire dtype for the explicit gradient schedules; bf16 halves the ring
    #: bytes vs f32 gradients (EXPERIMENTS.md §Perf pair 3)
    comm_dtype: str = "bfloat16"

    def kwargs(self) -> dict:
        return dict(
            mode=self.mode,
            degraded=self.degraded_rank,
            lost_fraction=self.lost_fraction,
            bandwidths=self.bandwidths or None,
            g=self.devices_per_node,
        )
