"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness assertions, and prefill/decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# minutes of model compiles: excluded from the fast tier (scripts/test.sh)
pytestmark = pytest.mark.slow

from repro.data import make_batch
from repro.models import (
    apply_model,
    get_config,
    get_smoke_config,
    init_caches,
    init_model,
    list_architectures,
)
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step

ARCHS = [a for a in list_architectures() if a != "paper-7b"]
B, T = 2, 16


def _batch(cfg, key):
    if cfg.modality.kind == "vision_text":
        P = cfg.modality.num_prefix_tokens
        return {
            "patches": jax.random.normal(key, (B, P, cfg.modality.frontend_dim)),
            "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        }, P + T
    if cfg.modality.kind == "audio_frames":
        return {"frames": jax.random.normal(key, (B, T, cfg.modality.frontend_dim))}, T
    return {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}, T


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params, axes = init_model(jax.random.PRNGKey(0), cfg)
    batch, exp_t = _batch(cfg, jax.random.PRNGKey(1))
    logits, caches, aux = apply_model(params, cfg, batch, mode="train")
    assert logits.shape == (B, exp_t, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert caches is None
    # axes pytree mirrors params
    pl = jax.tree_util.tree_leaves(params)
    al = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x))
    assert len(pl) == len(al)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), sync="xla"))
    b = make_batch(cfg, seq_len=T, batch_size=B, step=0)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state.step) == 1
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_smoke_config(a).encoder_only])
def test_decode_matches_train(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    batch, full_t = _batch(cfg, jax.random.PRNGKey(1))
    if "tokens" in batch:
        batch["tokens"] = tokens
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
    fb = dict(batch)
    fb["tokens"] = jnp.concatenate([tokens, nxt], 1)
    lg_full, _, _ = apply_model(params, cfg, fb, mode="train")
    caches = init_caches(cfg, B, full_t + 4, dtype=jnp.float32)
    lg_pre, caches, _ = apply_model(params, cfg, batch, mode="prefill",
                                    caches=caches)
    np.testing.assert_allclose(np.asarray(lg_pre[:, -1], np.float32),
                               np.asarray(lg_full[:, full_t - 1], np.float32),
                               atol=5e-2, rtol=1e-2)
    lg_dec, _, _ = apply_model(params, cfg, {"tokens": nxt}, mode="decode",
                               caches=caches)
    np.testing.assert_allclose(np.asarray(lg_dec[:, -1], np.float32),
                               np.asarray(lg_full[:, -1], np.float32),
                               atol=5e-2, rtol=1e-2)


def test_full_configs_match_assignment():
    """The published full configs carry the exact assigned dimensions."""
    spec = {
        "recurrentgemma-9b": (38, 4096, 12288, 256_000),
        "paligemma-3b": (18, 2048, 16384, 257_216),
        "deepseek-67b": (95, 8192, 22016, 102_400),
        "dbrx-132b": (40, 6144, 10752, 100_352),
        "smollm-360m": (32, 960, 2560, 49_152),
        "hubert-xlarge": (48, 1280, 5120, 504),
        "rwkv6-1.6b": (24, 2048, 7168, 65_536),
        "glm4-9b": (40, 4096, 13696, 151_552),
        "gemma2-27b": (46, 4608, 36864, 256_000),
    }
    for arch, (L, d, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    v3 = get_config("deepseek-v3-671b")
    assert (v3.num_layers, v3.d_model, v3.vocab_size) == (61, 7168, 129_280)
    assert v3.moe.num_experts == 256 and v3.moe.top_k == 8
    assert v3.moe.num_shared_experts == 1
    assert v3.attention.kind == "mla"
    dbrx = get_config("dbrx-132b")
    assert dbrx.moe.num_experts == 16 and dbrx.moe.top_k == 4


def test_param_counts_in_range():
    """Sanity: param_count() lands near the advertised sizes."""
    for arch, lo, hi in [
        ("deepseek-67b", 55e9, 80e9),
        ("dbrx-132b", 110e9, 150e9),
        ("deepseek-v3-671b", 550e9, 750e9),
        ("gemma2-27b", 22e9, 32e9),
        ("smollm-360m", 0.25e9, 0.45e9),
        ("rwkv6-1.6b", 1.2e9, 2.2e9),
    ]:
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
    v3 = get_config("deepseek-v3-671b")
    assert v3.active_param_count() < 0.12 * v3.param_count()
