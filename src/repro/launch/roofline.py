"""Roofline report generator: reads experiments/dryrun/*.json and emits the
EXPERIMENTS.md tables (single-pod baselines for every arch x shape, the
multi-pod lowering matrix, and per-pair bottleneck analysis).

  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.hlo_analysis import HBM_PER_CHIP

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.1f}ms"
    return f"{v*1e6:.0f}us"


def fmt_b(v: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if v >= div:
            return f"{v/div:.1f}{unit}"
    return f"{v:.0f}B"


def baseline_table(results: list[dict]) -> str:
    rows = [r for r in results if r["mesh"] == "16x16" and r.get("sync") in ("xla", "n/a")]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    lines = [
        "| arch | shape | mode | compute | memory | collective | bottleneck "
        "| useful FLOPs | bytes/chip | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"SKIP ({r['skipped'][:38]}) | — | — | — |")
            continue
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        per_chip = mem.get("total_bytes", 0)
        fits = "yes" if per_chip and per_chip <= HBM_PER_CHIP else \
            (f"no ({fmt_b(per_chip)})" if per_chip else "n/a")
        useful = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | **{t['bottleneck']}** "
            f"| {useful:.2f} | {fmt_b(per_chip)} | {fits} |"
            if useful is not None else
            f"| {r['arch']} | {r['shape']} | {r['mode']} | — | — | — | — | — | — | — |")
    return "\n".join(lines)


def multipod_matrix(results: list[dict]) -> str:
    lines = ["| arch | " + " | ".join(SHAPE_ORDER) + " |",
             "|---|" + "---|" * len(SHAPE_ORDER)]
    by = {}
    for r in results:
        if r["mesh"] == "2x16x16":
            by[(r["arch"], r["shape"])] = r
    archs = sorted({r["arch"] for r in results})
    for a in archs:
        cells = []
        for s in SHAPE_ORDER:
            r = by.get((a, s))
            if r is None:
                cells.append("—")
            elif "error" in r:
                cells.append("FAIL")
            elif "skipped" in r:
                cells.append("skip")
            else:
                cells.append(f"ok ({r['compile_s']:.0f}s)")
        lines.append(f"| {a} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def bottleneck_notes(results: list[dict]) -> str:
    """One sentence per (arch, shape): what moves the dominant term down."""
    suggestions = {
        ("collective", "train"): "shard params over data (ZeRO) or bucket+overlap the gradient ring with backward compute",
        ("collective", "prefill"): "reduce TP all-gathers by sequence-sharding activations (ring attention) or 2D sharding the MLP",
        ("collective", "decode"): "keep KV cache fully resident per model shard; swap all-gather for one-hot gather",
        ("memory", "train"): "increase arithmetic intensity: larger per-chip batch, fuse norm/rope, drop remat on cheap layers",
        ("memory", "prefill"): "larger attention blocks (more reuse per HBM read), bf16 cache writes",
        ("memory", "decode"): "decode is inherently weight-streaming-bound; batch more sequences per chip or quantize weights",
        ("compute", "train"): "already compute-bound — good; push MXU utilization via 128-multiple tiles",
        ("compute", "prefill"): "already compute-bound — good",
        ("compute", "decode"): "unusual; check for redundant recompute",
    }
    lines = []
    for r in results:
        if r["mesh"] != "16x16" or "skipped" in r or "error" in r:
            continue
        t = r["roofline"]
        key = (t["bottleneck"], r["mode"])
        lines.append(f"- **{r['arch']} x {r['shape']}** -> {t['bottleneck']}-bound "
                     f"({fmt_s(t['bound_s'])}); {suggestions.get(key, '')}")
    return "\n".join(sorted(lines))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    args = ap.parse_args()
    results = load(args.dir)
    print("## Single-pod (16x16 = 256 chips) baseline roofline\n")
    print(baseline_table(results))
    print("\n## Multi-pod (2x16x16 = 512 chips) lowering matrix\n")
    print(multipod_matrix(results))
    print("\n## Per-pair bottleneck notes\n")
    print(bottleneck_notes(results))


if __name__ == "__main__":
    main()
