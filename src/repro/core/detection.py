"""Failure detection & localization (paper Section 4.1-4.2).

TPU/XLA exposes no QP-level error semantics to a JAX program, so the
*control plane* is modeled as a discrete-event simulation with the paper's
latency budget; the *data plane* consequence (schedule switch + chunk
rollback) is executed for real by ``core.migration`` / ``core.collectives``.

Three mechanisms, mirrored 1:1 from the paper:

  * bilateral awareness — when either endpoint sees an error it immediately
    notifies its peer over the out-of-band (OOB) bootstrap channel, so the
    peer never spins on a dead connection (Section 4.1);
  * probe triangulation — both endpoints plus one auxiliary node issue
    zero-byte probes; correlating {local error, peer timeout, aux outcome}
    pinpoints LOCAL_NIC vs REMOTE_NIC vs LINK (Section 4.2);
  * periodic re-probing — detects component recovery and re-enables paths.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Callable, Iterable

from .failures import Failure, FailureState, FailureType

# Latency budget (seconds).  The paper reports detection going from minutes
# (NCCL timeout) to milliseconds; these constants reproduce that regime and
# are surfaced in the detection benchmark.
CQE_ERROR_DELAY = 100e-6        # NIC -> CPU error propagation on the detecting side
OOB_NOTIFY_LATENCY = 50e-6      # one-way OOB (bootstrap TCP/MPI) message
PROBE_RTT = 10e-6               # zero-byte RDMA write completion
PROBE_TIMEOUT = 1e-3            # probe declared lost after this long
BROADCAST_LATENCY = 100e-6      # OOB broadcast of the diagnosis to all ranks
NCCL_DEFAULT_TIMEOUT = 120.0    # what the peer would wait without bilateral awareness
REPROBE_PERIOD = 1.0            # base recovery re-probing cadence
REPROBE_PERIOD_MIN = 0.25       # stable links re-probe this fast (cadence floor)
REPROBE_PERIOD_MAX = 8.0        # flappy links back off to at most this (ceiling)


def adaptive_reprobe_period(
    recent_flaps: int,
    *,
    base: float = REPROBE_PERIOD,
    floor: float = REPROBE_PERIOD_MIN,
    ceiling: float = REPROBE_PERIOD_MAX,
) -> float:
    """Re-probe cadence adapted to the observed flap history of a NIC.

    The paper adapts probe frequency to observed failure/recovery patterns:
    a link with no recent flaps is probed *faster* than the base cadence
    (recovery detection latency shrinks on stable links), while each recent
    flap doubles the period (a flapping link is not trusted the instant it
    answers one probe).  Clamped to [floor, ceiling] so a flap storm cannot
    silence re-probing and a quiet link cannot busy-poll.
    """
    if recent_flaps < 0:
        raise ValueError(f"recent_flaps must be >= 0, got {recent_flaps}")
    period = base * 2.0 ** (recent_flaps - 1)
    return min(max(period, floor), ceiling)


class FaultLocation(enum.Enum):
    LOCAL_NIC = "local_nic"     # NIC at the endpoint that raised the error
    REMOTE_NIC = "remote_nic"
    LINK = "link"               # cable / ToR path between them
    UNKNOWN = "unknown"


class ProbeOutcome(enum.Enum):
    OK = "ok"
    LOCAL_ERROR = "local_error"  # immediate CQE error at the prober
    TIMEOUT = "timeout"


@dataclasses.dataclass(frozen=True)
class Diagnosis:
    location: FaultLocation
    failed_nic: tuple[int, int] | None     # (node, rail) when attributable
    detect_latency: float                  # error -> both endpoints aware
    localize_latency: float                # error -> diagnosis broadcast done
    probes: dict[str, ProbeOutcome] = dataclasses.field(default_factory=dict)


def probe_outcome(
    prober_nic_failed: bool, target_nic_failed: bool, link_failed: bool
) -> ProbeOutcome:
    """Outcome of a zero-byte RDMA write probe from one NIC to another.

    A dead *local* NIC errors immediately (the HCA rejects the WQE); a dead
    remote NIC or broken link surfaces as a timeout (one-sided writes have no
    receiver involvement, so nothing NACKs).
    """
    if prober_nic_failed:
        return ProbeOutcome.LOCAL_ERROR
    if target_nic_failed or link_failed:
        return ProbeOutcome.TIMEOUT
    return ProbeOutcome.OK


def triangulate(
    local: ProbeOutcome, peer: ProbeOutcome, aux_to_local: ProbeOutcome,
    aux_to_peer: ProbeOutcome,
) -> FaultLocation:
    """Section 4.2 truth table.

    * local NIC dead  -> local probe LOCAL_ERROR, peer TIMEOUT,
                         aux->local TIMEOUT, aux->peer OK
    * remote NIC dead -> symmetric
    * link broken     -> both endpoints TIMEOUT, but aux reaches *both*
    """
    if local is ProbeOutcome.LOCAL_ERROR:
        return FaultLocation.LOCAL_NIC
    if peer is ProbeOutcome.LOCAL_ERROR:
        return FaultLocation.REMOTE_NIC
    if local is ProbeOutcome.TIMEOUT and peer is ProbeOutcome.TIMEOUT:
        # Both sides time out toward each other.  The auxiliary vantage point
        # distinguishes single-endpoint impairment from a broken shared link.
        if aux_to_local is ProbeOutcome.TIMEOUT and aux_to_peer is not ProbeOutcome.TIMEOUT:
            return FaultLocation.LOCAL_NIC
        if aux_to_peer is ProbeOutcome.TIMEOUT and aux_to_local is not ProbeOutcome.TIMEOUT:
            return FaultLocation.REMOTE_NIC
        if aux_to_local is ProbeOutcome.OK and aux_to_peer is ProbeOutcome.OK:
            return FaultLocation.LINK
    if local is ProbeOutcome.TIMEOUT and peer is ProbeOutcome.OK:
        # Peer's datapath NIC answers the aux but the A->B direction is dead:
        # attribute to the remote NIC/port (uni-directional fault).
        return FaultLocation.REMOTE_NIC
    if peer is ProbeOutcome.TIMEOUT and local is ProbeOutcome.OK:
        return FaultLocation.LOCAL_NIC
    return FaultLocation.UNKNOWN


@dataclasses.dataclass
class DetectionEvent:
    time: float
    kind: str
    detail: str = ""

    def __lt__(self, other: "DetectionEvent") -> bool:
        return self.time < other.time


class FailureDetector:
    """Discrete-event model of bilateral awareness + triangulation.

    ``detect(failure, src, dst)`` plays out the timeline of a failure on the
    (src -> dst) connection and returns a :class:`Diagnosis` plus the ordered
    event log (used by the detection benchmark).
    """

    def __init__(self, state: FailureState | None = None, *,
                 bilateral: bool = True):
        self.state = state or FailureState()
        self.bilateral = bilateral
        self.log: list[DetectionEvent] = []

    def _emit(self, t: float, kind: str, detail: str = "") -> None:
        self.log.append(DetectionEvent(t, kind, detail))

    def detect(
        self,
        failure: Failure,
        src: tuple[int, int],
        dst: tuple[int, int],
        aux: tuple[int, int] | None = None,
    ) -> Diagnosis:
        """Timeline of detecting+localizing ``failure`` on connection src->dst.

        src/dst/aux are (node, rail) NIC keys.  ``aux`` defaults to a NIC on a
        third node (three-point triangulation requires >= 3 nodes; with two
        nodes the location degrades to LINK-vs-NIC ambiguity, also modeled).
        """
        self.log = []
        t0 = failure.at_time
        self._emit(t0, "failure", f"{failure.ftype.value}@{failure.nic_key}")

        failed = set(self.state.failed_nics) | {failure.nic_key}
        link_failed = failure.ftype in (FailureType.LINK_DOWN, FailureType.LINK_FLAPPING)
        if link_failed:
            failed.discard(failure.nic_key)   # link fault: both NICs healthy

        def nic_dead(key: tuple[int, int]) -> bool:
            return key in failed

        # --- phase 1: local error + bilateral notification -----------------
        # The endpoint whose transfer errors sees a CQE error; its peer sees
        # nothing (asymmetric visibility).
        detector_side = src if (nic_dead(src) or link_failed) else dst
        other_side = dst if detector_side == src else src
        t_local = t0 + CQE_ERROR_DELAY
        self._emit(t_local, "cqe_error", f"at {detector_side}")
        if self.bilateral:
            t_peer = t_local + OOB_NOTIFY_LATENCY
            self._emit(t_peer, "oob_notify", f"{detector_side} -> {other_side}")
        else:
            t_peer = t0 + NCCL_DEFAULT_TIMEOUT   # peer spins until timeout
            self._emit(t_peer, "peer_timeout", f"at {other_side}")
        detect_latency = t_peer - t0

        # --- phase 2: probe triangulation -----------------------------------
        probes: dict[str, ProbeOutcome] = {}
        probes["local"] = probe_outcome(nic_dead(src), nic_dead(dst), link_failed)
        probes["peer"] = probe_outcome(nic_dead(dst), nic_dead(src), link_failed)
        if aux is not None:
            # The auxiliary rides a different link; only endpoint NIC health
            # matters for its probes.
            probes["aux_to_local"] = probe_outcome(nic_dead(aux), nic_dead(src), False)
            probes["aux_to_peer"] = probe_outcome(nic_dead(aux), nic_dead(dst), False)
            loc = triangulate(probes["local"], probes["peer"],
                              probes["aux_to_local"], probes["aux_to_peer"])
        else:
            probes["aux_to_local"] = probes["aux_to_peer"] = ProbeOutcome.OK
            loc = (FaultLocation.LOCAL_NIC if probes["local"] is ProbeOutcome.LOCAL_ERROR
                   else FaultLocation.REMOTE_NIC if probes["peer"] is ProbeOutcome.LOCAL_ERROR
                   else FaultLocation.UNKNOWN)
        worst_probe = (PROBE_TIMEOUT
                       if ProbeOutcome.TIMEOUT in probes.values() else PROBE_RTT)
        t_probe = t_peer + worst_probe
        self._emit(t_probe, "probes_done", loc.value)

        # --- phase 3: broadcast the diagnosis to all ranks ------------------
        t_bcast = t_probe + BROADCAST_LATENCY
        self._emit(t_bcast, "diagnosis_broadcast", loc.value)

        failed_nic: tuple[int, int] | None
        if loc is FaultLocation.LOCAL_NIC:
            failed_nic = src
        elif loc is FaultLocation.REMOTE_NIC:
            failed_nic = dst
        elif loc is FaultLocation.LINK:
            failed_nic = failure.nic_key   # treat the link's rail as down
        else:
            failed_nic = None
        return Diagnosis(
            location=loc,
            failed_nic=failed_nic,
            detect_latency=detect_latency,
            localize_latency=t_bcast - t0,
            probes=probes,
        )

    # -- recovery re-probing -------------------------------------------------
    def reprobe(self, nic: tuple[int, int], now: float,
                recovered: bool, flap_count: int = 0,
                period: float | None = None) -> tuple[bool, float]:
        """Periodic health re-probe of a previously failed component.

        Returns (healthy_again, next_probe_time).  ``flap_count`` is the
        caller's recent-flap observation for this NIC (the control plane's
        sliding window); the cadence adapts to it — stable links are probed
        faster than the base period, flappy links back off exponentially
        between the floor and ceiling (the paper's 'adapting probe frequency
        based on observed failure and recovery patterns').  ``period``
        overrides the adaptive default when the caller runs its own cadence
        (e.g. a control plane with a rescaled probe base).
        """
        self._emit(now, "reprobe", f"{nic} -> {'ok' if recovered else 'still_down'}")
        if recovered:
            self.state.recover(nic)
        if period is None:
            period = adaptive_reprobe_period(flap_count)
        return recovered, now + period
