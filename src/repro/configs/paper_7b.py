"""The paper's simulated 7B training model (Fig. 8) — llama-architecture.

Used by the training-resilience benchmarks: 32L d_model=4096 32H
d_ff=11008 vocab=32000, global batch 512, on 4-64 8xA100 servers.
"""

from repro.configs.base import AttentionConfig, ModelConfig


CONFIG = ModelConfig(
    name="paper-7b",
    family="dense",
    source="R2CCL paper Section 8.2 (SimAI 7B)",
    num_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab_size=32_000,
    attention=AttentionConfig(
        kind="gqa", num_heads=32, num_kv_heads=32, head_dim=128,
    ),
    block_pattern=("attn",),
    activation="swiglu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paper-7b-smoke",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=4,
                                  head_dim=32),
        block_pattern=("attn",),
        activation="swiglu",
        norm="rmsnorm",
        remat=False,
    )
