"""Telemetry plane: metrics registry, structured trace, exports, and the
ledger<->trace cross-validation contract."""

import json

import numpy as np
import pytest

from repro.core.event_sim import simulate_program
from repro.core.failures import Failure, FailureType
from repro.core.schedule import ring_program
from repro.core.telemetry import (
    TRACE_SCHEMA,
    MetricsRegistry,
    Series,
    Telemetry,
    TraceLog,
    ledger_entries_from_trace,
    ledger_total_from_trace,
    stage_totals_from_trace,
    validate_trace_schema,
)
from repro.core.topology import make_cluster
from repro.runtime import clean_nic_down, run_scenario


# -- Series / registry -------------------------------------------------------

def test_series_ring_buffer_retains_newest():
    s = Series(capacity=4)
    for i in range(7):
        s.append(float(i), float(i * 10))
    assert len(s) == 4
    assert s.dropped == 3
    assert list(s.times()) == [3.0, 4.0, 5.0, 6.0]
    assert list(s.values()) == [30.0, 40.0, 50.0, 60.0]
    assert s.last() == (6.0, 60.0)


def test_series_empty_and_validation():
    s = Series(capacity=2)
    assert len(s) == 0 and s.last() is None
    assert list(s.times()) == []
    with pytest.raises(ValueError, match="capacity"):
        Series(capacity=0)


def test_registry_keys_and_last():
    reg = MetricsRegistry(capacity=8)
    reg.record("rank.tx_rate", (0,), 0.0, 1.5)
    reg.record("rank.tx_rate", (1,), 0.0, 2.5)
    reg.record("stream.goodput", ("dp",), 0.0, 9.0)
    assert reg.last("rank.tx_rate", (0,)) == 1.5
    assert reg.last("rank.tx_rate", (1,)) == 2.5
    assert reg.last("rank.tx_rate", (2,)) is None
    assert reg.series("nope", ()) is None
    assert ("stream.goodput", ("dp",)) in reg.names()
    # handle() returns the same live series record() feeds
    h = reg.handle("rank.tx_rate", (0,))
    h.append(1.0, 3.5)
    assert reg.last("rank.tx_rate", (0,)) == 3.5
    with pytest.raises(ValueError, match="capacity"):
        MetricsRegistry(capacity=0)


# -- trace log ---------------------------------------------------------------

def test_trace_log_trims_oldest():
    tl = TraceLog(max_records=100)
    for i in range(101):
        tl.add("sample", float(i), seq=i)
    assert len(tl.records) <= 100
    assert tl.dropped >= 1
    # the newest record survives, the oldest went first
    assert tl.records[-1]["seq"] == 100
    assert tl.records[0]["seq"] == tl.dropped
    with pytest.raises(ValueError, match="max_records"):
        TraceLog(max_records=0)


def test_trace_jsonl_roundtrip(tmp_path):
    tl = TraceLog()
    tl.add("failure", 0.5, node=1, rail=0, kind="nic_hardware",
           severity=1.0, silent=True)
    tl.add("recovery", 0.9, node=1, rail=0)
    path = tmp_path / "trace.jsonl"
    tl.write_jsonl(str(path))
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 2
    back = [json.loads(ln) for ln in lines]
    assert back == tl.records
    validate_trace_schema(back)


def test_validate_trace_schema_rejects_drift():
    with pytest.raises(ValueError, match="unknown trace type"):
        validate_trace_schema([{"type": "mystery", "t": 0.0}])
    with pytest.raises(ValueError, match="fields"):
        validate_trace_schema([{"type": "recovery", "t": 0.0, "node": 1}])
    with pytest.raises(ValueError, match="fields"):
        validate_trace_schema([{"type": "recovery", "t": 0.0, "node": 1,
                                "rail": 0, "extra": 1}])


def test_trace_schema_pins_record_fields():
    """The exported JSONL field sets are a compatibility surface (nightly CI
    uploads the trace as an artifact): changing a record type must be a
    deliberate schema edit here, not an accident."""
    assert TRACE_SCHEMA["transfer_start"] == (
        "t", "tid", "seg", "stream", "src", "dst", "bytes")
    assert TRACE_SCHEMA["rollback"] == (
        "t", "tid", "stream", "src", "dst", "sent_bytes", "delay")
    assert TRACE_SCHEMA["failure"] == (
        "t", "node", "rail", "kind", "severity", "silent")
    assert TRACE_SCHEMA["stage"] == ("t", "entry", "stage", "dur", "node",
                                     "rail")
    assert TRACE_SCHEMA["probe"] == ("t", "node", "rail", "outcome",
                                     "bw_fraction")
    assert TRACE_SCHEMA["detection"] == ("t", "node", "rail", "kind",
                                         "severity")
    assert set(TRACE_SCHEMA) == {
        "transfer_start", "transfer_finish", "rollback", "failure",
        "recovery", "recovery_confirmed", "replan", "probe", "stage",
        "transition", "detection", "detection_cleared", "sample"}


# -- telemetry bundle --------------------------------------------------------

def test_telemetry_sample_period_validation():
    with pytest.raises(ValueError, match="sample_period"):
        Telemetry(sample_period=0.0)
    with pytest.raises(ValueError, match="sample_period"):
        Telemetry(sample_period=-1e-3)
    tm = Telemetry.for_duration(1.0, samples=50)
    assert tm.sample_period == pytest.approx(0.02)
    with pytest.raises(ValueError, match="duration"):
        Telemetry.for_duration(0.0)
    with pytest.raises(ValueError, match="sample"):
        Telemetry.for_duration(1.0, samples=0)


# -- engine integration ------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    cluster = make_cluster(4, 8)
    payload = 4e8
    order = list(range(4))
    t_h = simulate_program(ring_program(order, 4), payload,
                           cluster=cluster).completion_time
    tm = Telemetry.for_duration(t_h, samples=64)
    rep = run_scenario(clean_nic_down(t_h), cluster, payload,
                       healthy_time=t_h, telemetry=tm)
    return rep, tm, t_h


def test_engine_emits_schema_valid_trace(traced_run):
    rep, tm, _ = traced_run
    types = {r["type"] for r in tm.trace.records}
    assert {"transfer_start", "transfer_finish", "sample", "failure",
            "stage", "transition"} <= types
    validate_trace_schema(tm.trace.records)


def test_engine_samples_counters(traced_run):
    rep, tm, t_h = traced_run
    s = tm.registry.series("rank.tx_rate", (0,))
    assert s is not None and len(s) > 10
    # rates are sampled while the collective is moving bytes
    assert float(np.max(s.values())) > 0.0
    assert tm.registry.series("stream.goodput", ("main",)) is not None
    assert tm.registry.series("stream.remaining", ("main",)) is not None
    # sample times advance at the configured cadence
    times = s.times()
    assert np.all(np.diff(times) > 0)


def test_ledger_reconstructible_from_trace(traced_run):
    """Cross-validation contract: every LedgerEntry stage breakdown is
    recoverable from the exported trace alone, and the totals agree."""
    rep, tm, _ = traced_run
    records = json.loads("[%s]" % ",".join(
        json.dumps(r) for r in tm.trace.records))   # via serialized form
    recon = ledger_entries_from_trace(records)
    assert recon == [e.stages for e in rep.ledger.entries]
    assert stage_totals_from_trace(records) == pytest.approx(
        rep.ledger.stage_totals())
    assert ledger_total_from_trace(records) == pytest.approx(
        rep.ledger.total_latency())


def test_chrome_trace_export(tmp_path, traced_run):
    rep, tm, _ = traced_run
    doc = tm.trace.to_chrome_trace()
    events = doc["traceEvents"]
    assert events, "no chrome events"
    phases = {e["ph"] for e in events}
    assert "X" in phases and "M" in phases
    slices = [e for e in events if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in slices)
    names = {e["name"] for e in slices}
    # transfer slices and control-plane stage slices both present
    assert any(n.startswith("xfer") for n in names)
    assert {"detect", "diagnose"} <= names
    path = tmp_path / "trace.json"
    tm.trace.write_chrome_trace(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_telemetry_does_not_change_physics():
    """Attaching the observability plane must not perturb virtual time."""
    cluster = make_cluster(2, 4)
    payload = 4e8
    t_plain = simulate_program(ring_program([0, 1], 2), payload,
                               cluster=cluster).completion_time
    t_tm = simulate_program(
        ring_program([0, 1], 2), payload, cluster=cluster,
        telemetry=Telemetry.for_duration(t_plain, samples=32),
    ).completion_time
    assert t_tm == pytest.approx(t_plain, rel=1e-12)
