"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

KV activations are compressed into a low-rank latent ``c_kv`` (plus a
shared RoPE key ``k_pe``); the KV cache stores only
``kv_lora_rank + qk_rope_head_dim`` floats per token — the memory win that
makes 128-head attention serveable.  Queries are likewise produced through
a low-rank projection.

Train/prefill path: decompress K/V per head and run blockwise attention.
Decode path: the **absorbed** formulation — fold W_uk into the query and
W_uv into the output so attention runs directly against the cached latents
(never materializing per-head K/V for the full context).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import apply_rope, blockwise_attention, dense_init


def init_mla(key, d_model: int, num_heads: int, *, q_lora_rank: int,
             kv_lora_rank: int, qk_nope_head_dim: int, qk_rope_head_dim: int,
             v_head_dim: int, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    qk_head_dim = qk_nope_head_dim + qk_rope_head_dim
    params = {
        # query path: d -> q_lora -> heads*(nope+rope)
        "w_dq": dense_init(ks[0], (d_model, q_lora_rank), d_model, dtype),
        "w_uq": dense_init(ks[1], (q_lora_rank, num_heads, qk_head_dim), q_lora_rank, dtype),
        # kv path: d -> kv_lora (+ shared rope key)
        "w_dkv": dense_init(ks[2], (d_model, kv_lora_rank), d_model, dtype),
        "w_kpe": dense_init(ks[3], (d_model, qk_rope_head_dim), d_model, dtype),
        "w_uk": dense_init(ks[4], (kv_lora_rank, num_heads, qk_nope_head_dim), kv_lora_rank, dtype),
        "w_uv": dense_init(ks[5], (kv_lora_rank, num_heads, v_head_dim), kv_lora_rank, dtype),
        "w_o": dense_init(ks[6], (num_heads, v_head_dim, d_model),
                          num_heads * v_head_dim, dtype),
    }
    axes = {
        "w_dq": ("embed", None),
        "w_uq": (None, "heads", None),
        "w_dkv": ("embed", None),
        "w_kpe": ("embed", None),
        "w_uk": (None, "heads", None),
        "w_uv": (None, "heads", None),
        "w_o": ("heads", None, "embed"),
    }
    return params, axes


@dataclasses.dataclass
class MLACache:
    """Latent KV cache: (B, S, kv_lora_rank) + (B, S, rope_dim)."""

    c_kv: jnp.ndarray
    k_pe: jnp.ndarray
    index: jnp.ndarray


jax.tree_util.register_dataclass(
    MLACache, data_fields=["c_kv", "k_pe", "index"], meta_fields=[]
)


def init_mla_cache(batch: int, size: int, kv_lora_rank: int,
                   qk_rope_head_dim: int, dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, size, kv_lora_rank), dtype),
        k_pe=jnp.zeros((batch, size, qk_rope_head_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mla_attention(
    params,
    x: jnp.ndarray,                    # (B, T, d)
    *,
    num_heads: int,
    qk_nope_head_dim: int,
    qk_rope_head_dim: int,
    v_head_dim: int,
    rope_theta: float = 10_000.0,
    cache: MLACache | None = None,
    mode: str = "train",
) -> tuple[jnp.ndarray, MLACache | None]:
    B, T, d = x.shape
    H = num_heads
    qk_head_dim = qk_nope_head_dim + qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_head_dim)

    cq = x @ params["w_dq"]                                    # (B,T,q_lora)
    q = jnp.einsum("btr,rhk->bthk", cq, params["w_uq"])        # (B,T,H,nope+rope)
    q_nope, q_pe = q[..., :qk_nope_head_dim], q[..., qk_nope_head_dim:]

    if mode == "decode":
        assert cache is not None and T == 1
        pos = cache.index
        q_pe = apply_rope(q_pe, jnp.full((B, 1), pos), rope_theta)
        c_new = x @ params["w_dkv"]                            # (B,1,R)
        kpe_new = apply_rope((x @ params["w_kpe"])[:, :, None, :],
                             jnp.full((B, 1), pos), rope_theta)[:, :, 0]
        S = cache.c_kv.shape[1]
        slot = pos % S
        c_all = lax.dynamic_update_slice(
            cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, slot, 0))
        kpe_all = lax.dynamic_update_slice(
            cache.k_pe, kpe_new.astype(cache.k_pe.dtype), (0, slot, 0))
        # absorbed attention: score = q_nope @ W_uk^T @ c_kv + q_pe @ k_pe
        q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, params["w_uk"])  # (B,1,H,R)
        s_nope = jnp.einsum("bthr,bsr->bhts", q_abs, c_all.astype(q_abs.dtype))
        s_pe = jnp.einsum("bthk,bsk->bhts", q_pe, kpe_all.astype(q_pe.dtype))
        s = (s_nope + s_pe).astype(jnp.float32) * scale        # (B,H,1,S)
        k_pos = jnp.where(jnp.arange(S) < jnp.minimum(pos + 1, S),
                          jnp.arange(S), -1)                   # ring validity
        valid = (k_pos >= 0)
        s = jnp.where(valid[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # out = p @ c_kv @ W_uv
        ctx = jnp.einsum("bhts,bsr->bthr", p.astype(c_all.dtype), c_all)  # (B,1,H,R)
        out = jnp.einsum("bthr,rhv->bthv", ctx, params["w_uv"])  # (B,1,H,v)
        y = jnp.einsum("bthv,hvd->btd", out, params["w_o"])
        return y, MLACache(c_all, kpe_all, pos + 1)

    positions = jnp.arange(T)[None, :]
    q_pe = apply_rope(q_pe, positions, rope_theta)
    c_kv = x @ params["w_dkv"]                                 # (B,T,R)
    k_pe = apply_rope((x @ params["w_kpe"])[:, :, None, :], positions,
                      rope_theta)[:, :, 0]                     # (B,T,rope)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, params["w_uk"])
    v = jnp.einsum("btr,rhv->bthv", c_kv, params["w_uv"])      # (B,T,H,v)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, T, H, qk_rope_head_dim))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad v to qk_head_dim for the shared blockwise kernel, then slice
    if v_head_dim < qk_head_dim:
        v_in = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head_dim - v_head_dim)))
    else:
        v_in = v
    qg = q_full.reshape(B, T, H, 1, qk_head_dim)
    out = blockwise_attention(qg, k, v_in, causal=True, scale=scale)
    out = out.reshape(B, T, H, qk_head_dim)[..., :v_head_dim]
    y = jnp.einsum("bthv,hvd->btd", out, params["w_o"])

    new_cache = None
    if mode == "prefill":
        size = cache.c_kv.shape[1] if cache is not None else T
        dtype = cache.c_kv.dtype if cache is not None else jnp.bfloat16
        keep = min(size, T)
        ck = jnp.zeros((B, size, c_kv.shape[-1]), dtype).at[:, :keep].set(
            c_kv[:, -keep:].astype(dtype))
        kp = jnp.zeros((B, size, k_pe.shape[-1]), dtype).at[:, :keep].set(
            k_pe[:, -keep:].astype(dtype))
        new_cache = MLACache(ck, kp, jnp.asarray(T, jnp.int32))
    return y, new_cache
