from .synthetic import SyntheticConfig, SyntheticTokens, make_batch  # noqa: F401
