"""Discrete-event cluster simulator executing real collective schedules.

The alpha-beta model in :mod:`core.comm_sim` predicts a collective's time
from a closed-form formula; it cannot represent mid-collective failures,
contention between concurrent transfers, or straggler dynamics.  This
module is the SimAI-style counterpart: an absolute-time event engine that
*executes* the actual :class:`core.schedule.CollectiveProgram` emitted by
``recursive.py`` / ``planner.py`` / ``allreduce.py`` — the same IR the
numpy oracle and the JAX backend run — transfer by transfer.

Model
-----
* Each program rank is a node with full-duplex egress/ingress capacity
  (the sum of its healthy NICs, or an explicit per-rank capacity).
* All transfers concurrently in flight share bandwidth by **max-min
  fairness** subject to per-rank tx and rx capacities (progressive
  filling), recomputed at every event — the flow-level network model used
  by SimAI's analytical backend.
* A transfer of step ``i`` is released once both its endpoints finished
  their transfers of their previous participating step (per-rank lockstep;
  no global barrier).  Segments of a program run concurrently and compete
  for bandwidth, so the stage-overlap of the R2CCL decomposition *emerges*
  instead of being assumed.
* Each released transfer pays the per-hop latency ``alpha``, then streams
  its bytes at the fair-share rate.
* Failures are injected at absolute simulated timestamps from
  :class:`core.failures.Failure`: a hard NIC/link failure removes that
  NIC's bandwidth and **rolls back** every in-flight transfer riding it
  (chunk-granularity DMA rollback — bytes already streamed are counted as
  retransmitted and the transfer restarts after ``repair_latency``); a
  ``recovers_at`` timestamp restores the bandwidth (link flap); a
  fractional ``severity`` (slow NIC) only rescales bandwidth and triggers
  no rollback.
* When ``rank_data`` is given the engine also moves real numpy payloads
  (snapshot at transfer start, write/accumulate at completion), so
  conservation under failure is *checked*, not presumed.
* An optional ``controller`` (the online recovery control plane in
  :mod:`repro.runtime`) is consulted at every failure/recovery event in
  virtual time.  Its :class:`RecoveryDecision` *derives* the restart delay
  from the detect→diagnose→migrate→rebalance pipeline instead of the
  closed-form ``repair_latency`` constant, rescales residual capacity by
  the rebalance detour efficiency, and may swap in a freshly planned
  :class:`CollectiveProgram` mid-collective at chunk granularity
  (completed chunk work is retained; the new schedule covers the
  remaining bytes).

The engine reports per-collective completion time, per-link bytes,
per-rank egress utilization, and retransmitted bytes after failover.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Mapping, Sequence

import numpy as np

from .failures import Failure, OUT_OF_SCOPE
from .schedule import ChunkSchedule, CollectiveProgram
from .topology import ClusterTopology, DEFAULT_ALPHA

#: restart delay after a rollback (matches the paper's low-millisecond
#: hot-repair figure; see core.migration.migration_latency for the breakdown)
DEFAULT_REPAIR_LATENCY = 1.5e-3

_BLOCKED, _LATENT, _ACTIVE, _DONE, _CANCELLED = range(5)


class EventSimError(RuntimeError):
    pass


class StalledError(EventSimError):
    """No transfer can make progress and no future event can unblock one."""


@dataclasses.dataclass
class _Transfer:
    tid: int
    seg: int
    step: int
    src: int
    dst: int
    size: float                  # bytes
    accumulate: bool
    whole_buffer: bool
    send_chunk: int
    recv_chunk: int
    deps: int = 0                # unfinished prerequisite transfers
    state: int = _BLOCKED
    remaining: float = 0.0
    payload: np.ndarray | None = None
    dependents: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class RecoveryDecision:
    """What the online control plane tells the engine to do about one failure.

    Returned by ``controller.on_failure``; every field is optional-by-default
    so a controller can intervene as little or as much as it likes.
    """

    #: restart delay for transfers rolled back by this failure — derived from
    #: the detect→diagnose→migrate→rebalance pipeline, replacing the engine's
    #: closed-form ``repair_latency`` constant
    repair_latency: float
    #: per-rank multiplicative factor on residual capacity (rebalance detour
    #: efficiency); removed again when the failure recovers
    capacity_scale: Mapping[int, float] | None = None
    #: new collective program to swap in mid-collective (algorithm
    #: re-selection); completed chunk work is retained
    replan: "CollectiveProgram | None" = None
    #: virtual time from the failure until the new program is live (the full
    #: pipeline latency including the replan stage)
    replan_delay: float = 0.0


@dataclasses.dataclass(frozen=True)
class RepairEvent:
    """One hard failure's hot-repair as the engine observed it."""

    at_time: float
    delay: float                 # restart delay applied to rolled-back flows
    rollbacks: int               # in-flight transfers rewound by this failure
    derived: bool                # True = delay came from a controller pipeline


@dataclasses.dataclass
class EventSimReport:
    """What one simulated collective did."""

    completion_time: float
    #: absolute finish time of each segment's last transfer
    segment_finish: list[float]
    #: bytes moved per directed (src, dst) rank pair, retransmissions included
    link_bytes: dict[tuple[int, int], float]
    rank_tx_bytes: dict[int, float]
    rank_rx_bytes: dict[int, float]
    #: egress busy fraction per rank: bytes sent / (healthy capacity * makespan)
    link_utilization: dict[int, float]
    retransmitted_bytes: float
    failovers: int
    transfers: int
    events: int
    #: final per-rank buffers when ``rank_data`` was supplied, else None
    rank_data: list[np.ndarray] | None = None
    #: mid-collective program swaps performed by the control plane
    replans: int = 0
    #: transfers of a superseded program cancelled at a replan point
    cancelled_transfers: int = 0
    #: per-hard-failure hot-repair record, in virtual-time order
    repair_events: list[RepairEvent] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# capacity bookkeeping
# ---------------------------------------------------------------------------

class _Capacities:
    """Per-rank egress/ingress capacity under timed NIC-level degradation."""

    def __init__(self, base: Sequence[float], rail_bw: Sequence[Sequence[float]]):
        self.base = list(base)
        self.rail_bw = [list(r) for r in rail_bw]          # per rank, per rail
        # active degradations keyed by the *failure event itself* so a
        # flap's recovery can never resurrect a rail a different failure
        # killed: per rank, failure -> (rail, severity)
        self._lost: list[dict[Failure, tuple[int, float]]] = [{} for _ in base]
        # multiplicative residual-capacity factors installed by the control
        # plane (rebalance detour efficiency), keyed by failure for the same
        # recovery-safety reason: per rank, failure -> factor
        self._scale: list[dict[Failure, float]] = [{} for _ in base]

    @classmethod
    def from_cluster(cls, cluster: ClusterTopology) -> "_Capacities":
        return cls(cluster.bandwidths(), cluster.rail_bandwidths())

    @classmethod
    def uniform(cls, capacities: Sequence[float], g: int) -> "_Capacities":
        rails = [[c / g] * g for c in capacities]
        return cls(capacities, rails)

    def num_rails(self, rank: int) -> int:
        return len(self.rail_bw[rank])

    def fail(self, rank: int, failure: Failure) -> None:
        self._lost[rank][failure] = (failure.rail, failure.severity)

    def recover(self, rank: int, failure: Failure) -> None:
        self._lost[rank].pop(failure, None)
        for scales in self._scale:
            scales.pop(failure, None)

    def scale(self, rank: int, failure: Failure, factor: float) -> None:
        """Install a residual-capacity factor tied to ``failure``'s lifetime."""
        self._scale[rank][failure] = factor

    def active(self) -> dict[Failure, dict[int, float]]:
        """Degradations still installed: failure -> {rank: scale factor}.

        A failure with no control-plane capacity factor maps to an empty
        dict.  This is what a campaign runner carries into the next
        collective's engine so persistent failures keep degrading capacity
        across run boundaries.
        """
        out: dict[Failure, dict[int, float]] = {}
        for lost in self._lost:
            for f in lost:
                out.setdefault(f, {})
        for rank, scales in enumerate(self._scale):
            for f, factor in scales.items():
                out.setdefault(f, {})[rank] = factor
        return out

    def capacity(self, rank: int) -> float:
        # a rail's loss is the worst active degradation on it (a dead NIC is
        # dead; a concurrent slow-NIC event on the same rail adds nothing)
        worst: dict[int, float] = {}
        for rail, sev in self._lost[rank].values():
            worst[rail] = max(worst.get(rail, 0.0), sev)
        lost = sum(self.rail_bw[rank][rail] * sev for rail, sev in worst.items())
        cap = max(0.0, self.base[rank] - lost)
        for factor in self._scale[rank].values():
            cap *= factor
        return cap


def _fair_share(flows: Sequence[_Transfer], cap) -> dict[int, float]:
    """Max-min fair rates under per-rank tx and rx capacity (water-filling)."""
    rates: dict[int, float] = {}
    remaining = list(flows)
    avail: dict[tuple[str, int], float] = {}
    for f in remaining:
        avail.setdefault(("tx", f.src), cap(f.src))
        avail.setdefault(("rx", f.dst), cap(f.dst))
    while remaining:
        counts: dict[tuple[str, int], int] = {}
        for f in remaining:
            counts[("tx", f.src)] = counts.get(("tx", f.src), 0) + 1
            counts[("rx", f.dst)] = counts.get(("rx", f.dst), 0) + 1
        bottleneck = min(counts, key=lambda k: avail[k] / counts[k])
        share = max(0.0, avail[bottleneck] / counts[bottleneck])
        frozen = [f for f in remaining
                  if (bottleneck[0] == "tx" and f.src == bottleneck[1])
                  or (bottleneck[0] == "rx" and f.dst == bottleneck[1])]
        for f in frozen:
            rates[f.tid] = share
            avail[("tx", f.src)] -= share
            avail[("rx", f.dst)] -= share
        remaining = [f for f in remaining if f.tid not in rates]
    return rates


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class EventSimulator:
    """One collective program, executed on an absolute-time event queue."""

    def __init__(
        self,
        prog: CollectiveProgram,
        total_bytes: float,
        *,
        cluster: ClusterTopology | None = None,
        capacities: Sequence[float] | None = None,
        g: int = 8,
        alpha: float = DEFAULT_ALPHA,
        failures: Sequence[Failure] = (),
        rank_data: Sequence[np.ndarray] | None = None,
        repair_latency: float = DEFAULT_REPAIR_LATENCY,
        controller: object | None = None,
        initial_failures: Sequence[
            tuple[Failure, Mapping[int, float] | None]] = (),
    ):
        prog.validate()
        self.prog = prog
        self.active_prog = prog           # replaced on a mid-collective replan
        self.total_bytes = float(total_bytes)
        self.alpha = alpha
        self.repair_latency = repair_latency
        # duck-typed recovery control plane: on_failure(sim, now, failure) ->
        # RecoveryDecision | None, on_recover(sim, now, failure) -> None
        self.controller = controller
        if cluster is not None:
            if cluster.num_nodes != prog.n:
                raise EventSimError(
                    f"program has {prog.n} ranks but cluster has "
                    f"{cluster.num_nodes} nodes")
            self.caps = _Capacities.from_cluster(cluster)
        elif capacities is not None:
            if len(capacities) != prog.n:
                raise EventSimError("capacities must have one entry per rank")
            self.caps = _Capacities.uniform(capacities, g)
        else:
            raise EventSimError("need either cluster= or capacities=")
        self.healthy_caps = [self.caps.capacity(r) for r in range(prog.n)]

        self.transfers: list[_Transfer] = []
        self._instantiate(prog, self.total_bytes)
        self._remaining = len(self.transfers)
        self._max_iters = 50 * len(self.transfers) + 10_000
        self._init_data(rank_data)

        # event queue: (time, seq, kind, arg)
        self._events: list[tuple[float, int, str, object]] = []
        self._seq = 0
        # Degradations carried over from a previous collective (a training
        # campaign's earlier iteration): installed before t=0 with their
        # control-plane capacity factors, WITHOUT consulting the controller
        # again (the pipeline already ran when the failure first struck) and
        # without rollback (nothing is in flight yet).  A pending recovery
        # (``recovers_at``, already rebased to this run's clock) is scheduled
        # so a flap spanning the boundary still comes back up.
        for f, scales in initial_failures:
            self._check_target(f)
            self.caps.fail(f.node, f)
            if scales:
                for r, factor in scales.items():
                    self.caps.scale(r, f, factor)
            if f.recovers_at is not None:
                self._push(f.recovers_at, "recover", f)
        for f in failures:
            # NIC-level events only: hard failures R2CCL can see (supported /
            # escalated) or fractional degradations (slow NIC).  Out-of-scope
            # types (switch outage, process crash) are not transport events,
            # whatever their severity.
            if f.ftype in OUT_OF_SCOPE:
                continue
            if not (f.supported or f.severity < 1.0):
                continue
            self._check_target(f)
            self._push(f.at_time, "fail", f)
            if f.recovers_at is not None:
                self._push(f.recovers_at, "recover", f)

        self._active: set[int] = set()
        self.link_bytes: dict[tuple[int, int], float] = {}
        self.rank_tx: dict[int, float] = {r: 0.0 for r in range(prog.n)}
        self.rank_rx: dict[int, float] = {r: 0.0 for r in range(prog.n)}
        self.retransmitted_bytes = 0.0
        self.failovers = 0
        self.replans = 0
        self.cancelled_transfers = 0
        self.repair_events: list[RepairEvent] = []
        self.events_processed = 0
        self.segment_finish = [0.0] * len(prog.segments)

    # -- construction --------------------------------------------------------
    def _check_target(self, f: Failure) -> None:
        if not 0 <= f.node < self.prog.n:
            raise EventSimError(
                f"failure targets node {f.node} but the program has "
                f"ranks 0..{self.prog.n - 1}: {f}")
        if not 0 <= f.rail < self.caps.num_rails(f.node):
            raise EventSimError(
                f"failure targets rail {f.rail} but node {f.node} has "
                f"rails 0..{self.caps.num_rails(f.node) - 1}: {f}")

    def _push(self, t: float, kind: str, arg: object) -> None:
        heapq.heappush(self._events, (t, self._seq, kind, arg))
        self._seq += 1

    def _instantiate(self, prog: CollectiveProgram, total_bytes: float) -> list[_Transfer]:
        """Build + dependency-wire ``prog``'s transfers over ``total_bytes``.

        Appends to ``self.transfers`` (tids continue after existing ones) and
        returns the new transfers.  Dependency rule: transfer (seg, step i,
        {s,d}) waits on all transfers of s's and d's previous participating
        step in the same segment.  Used both at init and when the control
        plane swaps in a replanned program mid-collective.
        """
        base = len(self.transfers)
        for si, seg in enumerate(prog.segments):
            sched = seg.schedule
            seg_bytes = total_bytes * seg.frac
            chunk_bytes = seg_bytes / sched.num_chunks
            for step_i, st in enumerate(sched.steps):
                size = seg_bytes if st.whole_buffer else chunk_bytes
                for src, dst in st.perm:
                    self.transfers.append(_Transfer(
                        tid=len(self.transfers), seg=si, step=step_i,
                        src=src, dst=dst, size=size,
                        accumulate=st.accumulate,
                        whole_buffer=st.whole_buffer,
                        send_chunk=st.send_chunk[src],
                        recv_chunk=st.recv_chunk[dst],
                    ))
        new = self.transfers[base:]
        by_seg_step_rank: dict[tuple[int, int, int], list[_Transfer]] = {}
        for t in new:
            for r in (t.src, t.dst):
                by_seg_step_rank.setdefault((t.seg, t.step, r), []).append(t)
        for si, seg in enumerate(prog.segments):
            rank_steps = seg.schedule.rank_steps()
            for t in new:
                if t.seg != si:
                    continue
                prereqs: set[int] = set()
                for r in {t.src, t.dst}:
                    steps = rank_steps[r]
                    pos = steps.index(t.step)
                    if pos > 0:
                        prev = steps[pos - 1]
                        for p in by_seg_step_rank.get((si, prev, r), []):
                            prereqs.add(p.tid)
                prereqs.discard(t.tid)
                t.deps = len(prereqs)
                for p in prereqs:
                    self.transfers[p].dependents.append(t.tid)
        return new

    def _init_data(self, rank_data: Sequence[np.ndarray] | None) -> None:
        """Per-rank, per-segment chunked float64 buffers (as executor_np)."""
        self._data = None
        if rank_data is None:
            return
        n = self.prog.n
        assert len(rank_data) == n
        data = [np.asarray(d, dtype=np.float64) for d in rank_data]
        total = data[0].shape[-1]
        self._orig_total = total
        # segment boundaries mirror executor_np.execute_program
        bounds = []
        start = 0
        for i, seg in enumerate(self.prog.segments):
            end = total if i == len(self.prog.segments) - 1 else \
                start + int(round(seg.frac * total))
            bounds.append((start, end))
            start = end
        self._seg_bounds = bounds
        self._data = []           # [seg][rank] -> (chunked buffer, orig_len)
        for si, seg in enumerate(self.prog.segments):
            s, e = bounds[si]
            nc = seg.schedule.num_chunks
            bufs = []
            orig = e - s
            for r in range(n):
                b = data[r][s:e]
                pad = (-orig) % nc
                if pad:
                    b = np.concatenate([b, np.zeros(pad, np.float64)])
                bufs.append(b.reshape(nc, -1).copy())
            self._data.append((bufs, orig))

    # -- data plane ----------------------------------------------------------
    def _snapshot(self, t: _Transfer) -> None:
        if self._data is None:
            return
        bufs, _ = self._data[t.seg]
        src_buf = bufs[t.src]
        t.payload = src_buf.copy() if t.whole_buffer else src_buf[t.send_chunk].copy()

    def _deliver(self, t: _Transfer) -> None:
        if self._data is None or t.payload is None:
            return
        bufs, _ = self._data[t.seg]
        if t.whole_buffer:
            bufs[t.dst] = bufs[t.dst] + t.payload if t.accumulate \
                else t.payload.copy()
        else:
            c = t.recv_chunk
            if t.accumulate:
                bufs[t.dst][c] = bufs[t.dst][c] + t.payload
            else:
                bufs[t.dst][c] = t.payload
        t.payload = None

    def _final_data(self) -> list[np.ndarray] | None:
        if self._data is None:
            return None
        n = self.prog.n
        out = [np.empty(self._orig_total, np.float64) for _ in range(n)]
        for si in range(len(self.prog.segments)):
            s, e = self._seg_bounds[si]
            bufs, orig = self._data[si]
            for r in range(n):
                out[r][s:e] = bufs[r].reshape(-1)[:orig]
        return out

    # -- scheduling ----------------------------------------------------------
    def _release(self, now: float, t: _Transfer, extra_delay: float = 0.0) -> None:
        t.state = _LATENT
        self._push(now + self.alpha + extra_delay, "activate", t.tid)

    def _activate(self, now: float, t: _Transfer) -> None:
        t.state = _ACTIVE
        t.remaining = t.size
        self._active.add(t.tid)
        self._snapshot(t)

    def _complete(self, now: float, t: _Transfer) -> None:
        t.state = _DONE
        t.remaining = 0.0
        self._active.discard(t.tid)
        self._deliver(t)
        e = (t.src, t.dst)
        self.link_bytes[e] = self.link_bytes.get(e, 0.0) + t.size
        self.rank_tx[t.src] += t.size
        self.rank_rx[t.dst] += t.size
        self.segment_finish[t.seg] = max(self.segment_finish[t.seg], now)
        for d in t.dependents:
            dep = self.transfers[d]
            dep.deps -= 1
            if dep.deps == 0 and dep.state == _BLOCKED:
                self._release(now, dep)

    def _rollback(self, now: float, t: _Transfer,
                  delay: float | None = None) -> None:
        """DMA rollback: bytes already streamed are retransmitted; the
        transfer restarts (on a healthy rail) after the repair latency —
        the closed-form constant, or the control plane's derived delay."""
        sent = t.size - t.remaining
        self.retransmitted_bytes += sent
        self.rank_tx[t.src] += sent          # wasted egress really happened
        e = (t.src, t.dst)
        self.link_bytes[e] = self.link_bytes.get(e, 0.0) + sent
        self.failovers += 1
        t.payload = None
        t.state = _LATENT
        self._active.discard(t.tid)
        d = self.repair_latency if delay is None else delay
        self._push(now + d + self.alpha, "activate", t.tid)

    def _apply_failure(self, now: float, f: Failure, recovering: bool) -> None:
        rank = f.node
        if recovering:
            self.caps.recover(rank, f)
            if self.controller is not None:
                self.controller.on_recover(self, now, f)
            return
        self.caps.fail(rank, f)
        # Consult the co-simulated control plane *at the failure instant*:
        # the pipeline it runs (detect → diagnose → migrate → rebalance →
        # replan) determines the restart delay, the post-rebalance residual
        # efficiency, and whether a new program is swapped in.
        decision: RecoveryDecision | None = None
        if self.controller is not None:
            decision = self.controller.on_failure(self, now, f)
        if decision is not None and decision.capacity_scale:
            for r, factor in decision.capacity_scale.items():
                self.caps.scale(r, f, factor)
        if f.severity >= 1.0 and f.escalates:
            # A hard NIC death interrupts the node's striped channels: every
            # in-flight transfer touching the node rewinds to its last
            # completed chunk (DMA rollback) and restarts after the hot-repair
            # latency.
            delay = decision.repair_latency if decision is not None else None
            rollbacks = 0
            for tid in sorted(self._active):
                t = self.transfers[tid]
                if t.src == rank or t.dst == rank:
                    self._rollback(now, t, delay)
                    rollbacks += 1
            self.repair_events.append(RepairEvent(
                at_time=now,
                delay=self.repair_latency if delay is None else delay,
                rollbacks=rollbacks,
                derived=decision is not None,
            ))
        if decision is not None and decision.replan is not None:
            self._push(now + decision.replan_delay, "replan", decision.replan)

    def _do_replan(self, now: float, prog: CollectiveProgram) -> None:
        """Swap in a freshly planned program at chunk granularity.

        Completed chunk work is retained: the fraction of communication work
        already done under the old program stays done, every unfinished
        transfer is cancelled (streamed-but-unacked bytes count as
        retransmitted), and the new schedule is instantiated over the
        remaining payload bytes.
        """
        if self._data is not None:
            raise EventSimError(
                "mid-collective replan with rank_data is unsupported: partial "
                "progress of two different algorithms cannot be merged")
        prog.validate()
        if prog.n != self.active_prog.n:
            raise EventSimError(
                f"replanned program has {prog.n} ranks, expected "
                f"{self.active_prog.n}")
        live = [t for t in self.transfers if t.state != _CANCELLED]
        total_work = sum(t.size for t in live)
        done_work = sum(t.size for t in live if t.state == _DONE)
        frac_done = done_work / total_work if total_work > 0 else 1.0
        remaining_payload = self.total_bytes * max(0.0, 1.0 - frac_done)
        cancelled = 0
        for t in self.transfers:
            if t.state in (_BLOCKED, _LATENT, _ACTIVE):
                if t.state == _ACTIVE:
                    sent = t.size - t.remaining
                    self.retransmitted_bytes += sent
                    self.rank_tx[t.src] += sent
                    e = (t.src, t.dst)
                    self.link_bytes[e] = self.link_bytes.get(e, 0.0) + sent
                t.state = _CANCELLED
                t.payload = None
                self._active.discard(t.tid)
                cancelled += 1
        self.cancelled_transfers += cancelled
        self._remaining -= cancelled
        self.active_prog = prog
        self.segment_finish = [0.0] * len(prog.segments)
        new = self._instantiate(prog, remaining_payload)
        self._remaining += len(new)
        self._max_iters += 50 * len(new) + 1_000
        self.replans += 1
        for t in new:
            if t.deps == 0:
                self._release(now, t)

    # -- cross-run state -----------------------------------------------------
    def active_degradations(self) -> list[tuple[Failure, dict[int, float]]]:
        """Failures still degrading capacity when the run ended, with the
        control-plane capacity factors installed for each: what a campaign
        runner must carry into the next collective's ``initial_failures``.
        Deterministically ordered by (at_time, node, rail)."""
        return sorted(self.caps.active().items(),
                      key=lambda kv: (kv[0].at_time, kv[0].node, kv[0].rail))

    # -- main loop -----------------------------------------------------------
    def run(self) -> EventSimReport:
        now = 0.0
        # release all transfers with no prerequisites
        for t in self.transfers:
            if t.deps == 0:
                self._release(now, t)

        guard = 0
        while self._remaining > 0:
            guard += 1
            if guard > self._max_iters:
                raise EventSimError("event loop not converging")
            active = [self.transfers[i] for i in sorted(self._active)]
            rates = _fair_share(active, self.caps.capacity) if active else {}

            # earliest completion among active flows (size-relative epsilon:
            # float residue in `remaining` must not stall the clock)
            def eps(t: _Transfer) -> float:
                return max(1e-9, 1e-9 * t.size)

            t_complete = math.inf
            for t in active:
                r = rates.get(t.tid, 0.0)
                if r > 0 or t.size <= 0:
                    t_complete = min(
                        t_complete,
                        now + (0.0 if t.remaining <= eps(t)
                               else t.remaining / r))
            t_event = self._events[0][0] if self._events else math.inf
            t_next = min(t_complete, t_event)
            if math.isinf(t_next):
                stalled = [t.tid for t in active]
                blocked = [t.tid for t in self.transfers
                           if t.state in (_BLOCKED, _LATENT)]
                raise StalledError(
                    f"simulation stalled at t={now:.6g}s: active={stalled} "
                    f"have zero bandwidth and no future recovery event "
                    f"(blocked/latent: {len(blocked)})")

            # stream bytes until t_next
            dt = t_next - now
            if dt > 0:
                for t in active:
                    drained = rates.get(t.tid, 0.0) * dt
                    t.remaining = max(0.0, t.remaining - drained)
            now = t_next

            # completions strictly before/at events at the same timestamp:
            # finish flows first so dependents can react to the event epoch
            completed = [t for t in active
                         if t.remaining <= eps(t)
                         and (rates.get(t.tid, 0.0) > 0 or t.size <= 0)]
            for t in completed:
                self._complete(now, t)
                self._remaining -= 1
                self.events_processed += 1

            while self._events and self._events[0][0] <= now + 1e-15:
                _, _, kind, arg = heapq.heappop(self._events)
                self.events_processed += 1
                if kind == "activate":
                    t = self.transfers[arg]
                    if t.state == _LATENT:
                        self._activate(now, t)
                elif kind == "fail":
                    self._apply_failure(now, arg, recovering=False)
                elif kind == "recover":
                    self._apply_failure(now, arg, recovering=True)
                elif kind == "replan":
                    self._do_replan(now, arg)

        makespan = now
        util = {}
        for r in range(self.prog.n):
            denom = self.healthy_caps[r] * makespan
            util[r] = (self.rank_tx[r] / denom) if denom > 0 else 0.0
        return EventSimReport(
            completion_time=makespan,
            segment_finish=list(self.segment_finish),
            link_bytes=dict(self.link_bytes),
            rank_tx_bytes=dict(self.rank_tx),
            rank_rx_bytes=dict(self.rank_rx),
            link_utilization=util,
            retransmitted_bytes=self.retransmitted_bytes,
            failovers=self.failovers,
            transfers=len(self.transfers),
            events=self.events_processed,
            rank_data=self._final_data(),
            replans=self.replans,
            cancelled_transfers=self.cancelled_transfers,
            repair_events=list(self.repair_events),
        )


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def simulate_program(
    prog: CollectiveProgram,
    total_bytes: float,
    *,
    cluster: ClusterTopology | None = None,
    capacities: Sequence[float] | None = None,
    g: int = 8,
    alpha: float = DEFAULT_ALPHA,
    failures: Sequence[Failure] = (),
    rank_data: Sequence[np.ndarray] | None = None,
    repair_latency: float = DEFAULT_REPAIR_LATENCY,
    controller: object | None = None,
    initial_failures: Sequence[tuple[Failure, Mapping[int, float] | None]] = (),
) -> EventSimReport:
    """Execute ``prog`` on the discrete-event engine.

    Exactly one of ``cluster`` (rank i = node i, capacity = node egress)
    or ``capacities`` (explicit per-rank bytes/s, split over ``g`` equal
    rails for failure mapping) must be given.  ``failures`` are applied at
    their ``at_time`` timestamps; fractional ``severity`` rescales
    bandwidth only, full severity also rolls back in-flight transfers on
    the failed rail.  ``controller`` co-simulates an online recovery
    control plane (see :mod:`repro.runtime`): its per-failure pipeline
    replaces ``repair_latency`` and may replan mid-collective.
    ``initial_failures`` installs degradations carried over from a previous
    collective (with their control-plane capacity factors) before t=0,
    without re-running the pipeline — the campaign-runner handoff.
    """
    return EventSimulator(
        prog, total_bytes, cluster=cluster, capacities=capacities, g=g,
        alpha=alpha, failures=failures, rank_data=rank_data,
        repair_latency=repair_latency, controller=controller,
        initial_failures=initial_failures,
    ).run()


def simulate_schedule(
    sched: ChunkSchedule,
    total_bytes: float,
    **kw,
) -> EventSimReport:
    """Convenience wrapper for a single-segment schedule."""
    from .schedule import CollectiveProgram, Segment

    prog = CollectiveProgram(sched.name, sched.n, [Segment(1.0, sched)])
    return simulate_program(prog, total_bytes, **kw)


def predict_ring_all_reduce(n: int, payload: float, bandwidth: float,
                            alpha: float = DEFAULT_ALPHA) -> float:
    """The closed-form healthy baseline the event engine must reproduce:
    2(n-1) rounds of (alpha + (payload/n)/B)."""
    from .partition import ring_coeff

    return 2 * (n - 1) * alpha + ring_coeff(n) * payload / bandwidth
