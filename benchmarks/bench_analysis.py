"""Static-analysis conformance bench: cost analyzer vs event engine,
alpha-beta planner vs static scorer, and failure-coverage survivability.

Rows:

  * ``static_cost_max_error``       — max relative error of the static cost
    analyzer against the event engine's healthy completion over the builder
    corpus (must stay under ``CORPUS_COST_TOLERANCE``);
  * ``static_cost_exact_fraction``  — fraction of lockstep-uniform corpus
    entries priced *bit-exactly* (must be 1.0);
  * ``static_cost_uniform_fraction``— fraction of the corpus in the
    bit-exact (single-segment lockstep) class;
  * ``planner_drift_max/mean``      — relative gap between the alpha-beta
    closed forms and the static price of the *built* program for the chosen
    strategy, over a failure-state sweep;
  * ``planner_static_agreement``    — fraction of sweep points where both
    scorers pick the same strategy;
  * ``coverage_survivable_fraction``       — multi-rail capacity model
    (every rank keeps residual bandwidth; expect 1.0);
  * ``coverage_single_rail_fraction``      — one rail per rank (any rail
    failure strands its rank; expect 0.0).
"""

from __future__ import annotations

from benchmarks.common import Reporter
from repro.analysis.cost import (
    CONFORMANCE_CAPACITY,
    CONFORMANCE_PAYLOAD,
    analyze_program,
    as_program,
)
from repro.analysis.corpus import builder_corpus
from repro.analysis.coverage import analyze_coverage
from repro.core.event_sim import healthy_completion
from repro.core.failures import FailureState
from repro.core.planner import Collective, Planner
from repro.core.topology import make_cluster


def _conformance(rep: Reporter, max_n: int) -> None:
    max_rel = 0.0
    worst = "-"
    exact = uniform = total = 0
    for label, obj in builder_corpus(seed=0, max_n=max_n):
        prog = as_program(obj)
        caps = [CONFORMANCE_CAPACITY] * prog.n
        r = analyze_program(prog, CONFORMANCE_PAYLOAD, capacities=caps)
        engine = healthy_completion(prog, CONFORMANCE_PAYLOAD,
                                    capacities=caps, g=2)
        rel = abs(r.predicted_time - engine) / engine if engine > 0 else 0.0
        if rel > max_rel:
            max_rel, worst = rel, label
        total += 1
        if r.lockstep_uniform:
            uniform += 1
            exact += r.predicted_time == engine
    rep.row("static_cost_max_error", max_rel, f"worst={worst}")
    rep.row("static_cost_exact_fraction",
            exact / uniform if uniform else 1.0,
            f"{exact}/{uniform} lockstep-uniform entries bit-exact")
    rep.row("static_cost_uniform_fraction",
            uniform / total if total else 1.0,
            f"{uniform}/{total} corpus entries in the bit-exact class")


def _planner_drift(rep: Reporter, tiny: bool) -> None:
    n, g = (3, 4) if tiny else (4, 8)
    planner = Planner(make_cluster(n, g))
    payloads = [float(1 << 20), float(1 << 26)] if tiny else \
               [float(1 << 16), float(1 << 20), float(1 << 26), float(1 << 28)]
    # failure sweep: healthy, single-NIC, concentrated, multi-node spectrum
    sweeps: list[set[tuple[int, int]]] = [
        set(),
        {(0, 0)},
        {(0, 0), (0, 1)},
        {(0, 0), (1, 0), (1, 1)},
    ]
    drifts: list[float] = []
    agree = points = 0
    for failed in sweeps:
        state = FailureState(failed_nics=set(failed))
        for payload in payloads:
            ab = planner.choose_strategy(Collective.ALL_REDUCE, payload, state)
            st = planner.choose_strategy(Collective.ALL_REDUCE, payload,
                                         state, score="static")
            points += 1
            agree += ab.strategy is st.strategy
            if st.predicted_time > 0:
                drifts.append(abs(ab.predicted_time - st.predicted_time)
                              / st.predicted_time)
    rep.row("planner_drift_max", max(drifts),
            f"{points} sweep points ({n} nodes x {g} NICs)")
    rep.row("planner_drift_mean", sum(drifts) / len(drifts))
    rep.row("planner_static_agreement", agree / points,
            "fraction of sweep points with identical strategy choice")


def _coverage(rep: Reporter, max_n: int) -> None:
    multi = multi_total = single = single_total = 0
    for label, obj in builder_corpus(seed=0, max_n=max_n):
        prog = as_program(obj)
        caps = [CONFORMANCE_CAPACITY] * prog.n
        cov2 = analyze_coverage(prog, CONFORMANCE_PAYLOAD, capacities=caps,
                                g=2)
        multi += sum(1 for e in cov2.entries if e.survivable)
        multi_total += len(cov2.entries)
        cov1 = analyze_coverage(prog, CONFORMANCE_PAYLOAD, capacities=caps,
                                g=1)
        single += sum(1 for e in cov1.entries
                      if e.participates and e.survivable)
        single_total += sum(1 for e in cov1.entries if e.participates)
    rep.row("coverage_survivable_fraction",
            multi / multi_total if multi_total else 1.0,
            f"{multi_total} single-rail failures, 2 rails/rank")
    rep.row("coverage_single_rail_fraction",
            single / single_total if single_total else 0.0,
            f"{single_total} participant failures, 1 rail/rank "
            "(every one strands its rank)")


def run(tiny: bool = False, seed: int = 0) -> None:
    rep = Reporter("analysis_static")
    max_n = 4 if tiny else 8
    _conformance(rep, max_n)
    _planner_drift(rep, tiny)
    _coverage(rep, max_n)
    rep.save()


if __name__ == "__main__":
    run()
