"""Minimal offline hypothesis-compatible shim.

The container cannot ``pip install hypothesis``, which left 7 seed test
modules failing at *collection*.  This module implements the exact subset
of the hypothesis API those tests (and the event-sim property tests) use,
backed by a seeded :mod:`random` generator so runs are deterministic per
test.  ``conftest.py`` aliases it into ``sys.modules['hypothesis']`` only
when the real hypothesis is absent — when hypothesis is installable, the
real library is used unchanged.

Supported surface:
  * ``@given(**kwargs)`` with keyword strategies (the only form used here);
  * ``@settings(max_examples=..., deadline=...)`` in either decorator order;
  * ``assume(condition)`` — discards the current example and redraws;
  * ``strategies``: ``integers``, ``floats``, ``booleans``, ``lists``,
    ``sampled_from``, ``dictionaries``, ``just``, ``composite``, ``data``.

No shrinking: on failure the falsifying example is attached to the
exception message instead.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class UnsatisfiedAssumption(Exception):
    """Raised by :func:`assume` to discard the current example."""


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

class SearchStrategy:
    """A strategy draws a value from a ``random.Random``."""

    def __init__(self, draw_fn, label: str = "strategy"):
        self._draw = draw_fn
        self._label = label

    def do_draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self._draw(rng)),
                              f"{self._label}.map")

    def filter(self, pred) -> "SearchStrategy":
        def draw(rng: random.Random):
            for _ in range(100):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption()
        return SearchStrategy(draw, f"{self._label}.filter")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return self._label


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          f"floats({min_value}, {max_value})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements),
                          f"sampled_from({elements!r})")


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int | None = None) -> SearchStrategy:
    max_size = min_size + 10 if max_size is None else max_size

    def draw(rng: random.Random):
        k = rng.randint(min_size, max_size)
        return [elements.do_draw(rng) for _ in range(k)]

    return SearchStrategy(draw, "lists(...)")


def dictionaries(keys: SearchStrategy, values: SearchStrategy, *,
                 min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random):
        k = rng.randint(min_size, max_size)
        out = {}
        for _ in range(k * 3):          # keys may collide; over-draw a bit
            if len(out) >= k:
                break
            out[keys.do_draw(rng)] = values.do_draw(rng)
        return out

    return SearchStrategy(draw, "dictionaries(...)")


def composite(fn):
    """``@st.composite`` — fn's first arg is ``draw``."""

    @functools.wraps(fn)
    def make(*args, **kwargs) -> SearchStrategy:
        def draw(rng: random.Random):
            return fn(lambda strat: strat.do_draw(rng), *args, **kwargs)
        return SearchStrategy(draw, f"composite({fn.__name__})")

    return make


class DataObject:
    """Interactive draws inside the test body (``st.data()``)."""

    def __init__(self, rng: random.Random):
        self._rng = rng
        self.draws: list = []

    def draw(self, strategy: SearchStrategy, label: str | None = None):
        v = strategy.do_draw(self._rng)
        self.draws.append(v)
        return v

    def __repr__(self) -> str:
        return f"data(drawn={self.draws!r})"


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda rng: DataObject(rng), "data()")


def data() -> SearchStrategy:
    return _DataStrategy()


# ---------------------------------------------------------------------------
# given / settings
# ---------------------------------------------------------------------------

class settings:  # noqa: N801 - mirrors the hypothesis API name
    """Both a decorator (``@settings(...)``) and a value holder."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._propcheck_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError("propcheck shim supports keyword strategies only")

    def decorate(fn):
        inner_settings = getattr(fn, "_propcheck_settings", None)

        @functools.wraps(fn)
        def runner(*args, **fixture_kwargs):
            st_obj = (getattr(runner, "_propcheck_settings", None)
                      or inner_settings or settings())
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode()) & 0xFFFFFFFF
            rng = random.Random(seed)
            executed = 0
            rejected = 0
            while executed < st_obj.max_examples:
                example = None
                try:
                    # drawing stays inside the try: assume()/filter() called
                    # from composite strategies must also discard + redraw
                    example = {k: s.do_draw(rng)
                               for k, s in kw_strategies.items()}
                    fn(*args, **fixture_kwargs, **example)
                except UnsatisfiedAssumption:
                    rejected += 1
                    if rejected > 50 * st_obj.max_examples + 100:
                        raise RuntimeError(
                            f"{fn.__name__}: assume() rejected too many "
                            f"examples ({rejected})") from None
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified by example {example!r} "
                        f"(shim seed {seed}): {e!r}") from e
                executed += 1

        # pytest must not see the strategy kwargs as fixtures: drop the
        # __wrapped__ escape hatch and expose a signature without them.
        if hasattr(runner, "__wrapped__"):
            del runner.__wrapped__
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in kw_strategies]
        runner.__signature__ = sig.replace(parameters=keep)
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        return runner

    return decorate


# ---------------------------------------------------------------------------
# module aliasing (used by conftest.py)
# ---------------------------------------------------------------------------

def install() -> None:
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "lists", "dictionaries", "composite", "data",
                 "SearchStrategy"):
        setattr(strat, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = strat
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, filter_too_much=None, data_too_large=None)
    hyp.__version__ = "0.0-propcheck-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
