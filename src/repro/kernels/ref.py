"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_attention(
    q: jax.Array,                    # (B, Tq, KVH, G, D)
    k: jax.Array,                    # (B, Tk, KVH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int | None = None,
    logit_cap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Naive full-matrix softmax attention — the semantic ground truth."""
    B, Tq, KVH, G, D = q.shape
    Tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if logit_cap is not None:
        s = jnp.tanh(s / logit_cap) * logit_cap
    qp = jnp.arange(Tq)[:, None]
    kp = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        c = kp <= qp
        if prefix_len is not None:
            c = c | (kp < prefix_len)
        mask = mask & c
    if window is not None:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def reference_chunk_combine(local: jax.Array, recv: jax.Array,
                            seg_mask: jax.Array, accumulate: jax.Array) -> jax.Array:
    """Oracle for the R2CCL stage-2 combine: per-chunk select/accumulate.

    local/recv: (C, M); seg_mask, accumulate: (C,) bool.
    out[c] = local[c]                 if not seg_mask[c]
           = local[c] + recv[c]       if seg_mask[c] and accumulate[c]
           = recv[c]                  if seg_mask[c] and not accumulate[c]
    """
    lf = local.astype(jnp.float32)
    rf = recv.astype(jnp.float32)
    comb = jnp.where(accumulate[:, None], lf + rf, rf)
    return jnp.where(seg_mask[:, None], comb, lf).astype(local.dtype)


def reference_lru_scan(a: jax.Array, x: jax.Array, h0: jax.Array) -> jax.Array:
    """Sequential oracle for the RG-LRU scan: h_t = a_t h_{t-1} + x_t.

    a, x: (B, T, W); h0: (B, W).  Returns (B, T, W) in float32.
    """
    af = a.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (af.transpose(1, 0, 2), xf.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def reference_wkv(r, k, v, w, u):
    """Oracle for the WKV kernel: r/k/w (BH,T,K), v (BH,T,V), u (BH,K)
    -> (BH,T,V); S_0 = 0.  Sequential scan per (batch*head) row."""
    BH, T, K = r.shape
    rf, kf, vf, wf, uf = (x.astype(jnp.float32) for x in (r, k, v, w, u))

    def one(rb, kb, vb, wb, ub):
        def step(s, inp):
            rt, kt, vt, wt = inp
            kv = kt[:, None] * vt[None, :]
            ot = rt @ (s + ub[:, None] * kv)
            return wt[:, None] * s + kv, ot
        s0 = jnp.zeros((K, vb.shape[1]), jnp.float32)
        _, out = jax.lax.scan(step, s0, (rb, kb, vb, wb))
        return out

    return jax.vmap(one)(rf, kf, vf, wf, uf)
