"""Shared pytest fixtures.

NOTE: no XLA_FLAGS manipulation here — smoke tests and benches must see the
real single CPU device.  Multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
TESTS = os.path.dirname(os.path.abspath(__file__))

# Offline property-testing: alias tests/_propcheck.py into sys.modules as
# ``hypothesis`` ONLY when the real hypothesis cannot be imported (the
# container has no network for pip).  When hypothesis is installed, the
# real library is used and the shim never loads.
try:
    import hypothesis  # noqa: F401
except ImportError:
    if TESTS not in sys.path:
        sys.path.insert(0, TESTS)
    import _propcheck

    _propcheck.install()


def run_multidevice(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N virtual host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
