"""Section 4.3: multi-NIC registration + DMA-buffer rollback (lossless
under arbitrary failure points — property-based)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.detection import FailureDetector
from repro.core.failures import Failure, FailureState, FailureType
from repro.core.migration import (
    BACKUP_ACTIVATION,
    ChunkTransfer,
    GPU_BUFFER_REGISTRATION,
    RDMA_CONNECTION_SETUP,
    RegistrationTable,
    TransferError,
    migration_latency,
)
from repro.core.topology import NodeTopology


def _chain(failed=()):
    return RegistrationTable(NodeTopology(node_id=0)).failover_chain(0, failed)


@settings(max_examples=40, deadline=None)
@given(
    size=st.integers(10, 2000),
    num_chunks=st.integers(1, 32),
    fails=st.dictionaries(st.integers(0, 60), st.floats(0.0, 1.0), max_size=5),
)
def test_rollback_lossless(size, num_chunks, fails):
    rng = np.random.default_rng(size)
    xfer = ChunkTransfer(rng.normal(size=size), num_chunks, _chain())
    xfer.run_to_completion(failure_plan=fails)
    assert xfer.verify_lossless()
    assert xfer.failovers <= len(fails)


def test_partial_write_overwritten():
    rng = np.random.default_rng(0)
    xfer = ChunkTransfer(rng.normal(size=100), 10, _chain())
    with pytest.raises(TransferError):
        xfer.step(fail_after_post=True, partial_write_fraction=0.7)
    xfer.rollback_and_failover()
    xfer.run_to_completion()
    assert xfer.verify_lossless()              # garbage got overwritten


def test_chain_exhaustion():
    rng = np.random.default_rng(0)
    xfer = ChunkTransfer(rng.normal(size=50), 5, _chain()[:2])
    xfer.rollback_and_failover()
    with pytest.raises(TransferError):
        xfer.rollback_and_failover()


def test_failover_chain_ordering():
    node = NodeTopology(node_id=0)
    chain = node.failover_chain(device=0)
    dists = [node.pcie_distance(0, nic) for nic in chain]
    assert dists == sorted(dists)              # PCIe-distance ordered
    # affinity NIC first when healthy
    assert chain[0].rail in (0, 1)
    # failed affinity NIC is excluded
    chain2 = node.failover_chain(device=0, failed=[(0, 0)])
    assert all(nic.key != (0, 0) for nic in chain2)


def test_preregistration_latency_advantage():
    det = FailureDetector(FailureState())
    diag = det.detect(Failure(FailureType.NIC_HARDWARE, 0, 0), (0, 0), (1, 0),
                      aux=(2, 0))
    hot = migration_latency(diag, 10 << 20, 50e9, pre_registered=True)
    cold = migration_latency(diag, 10 << 20, 50e9, pre_registered=False,
                             num_buffers=4)
    assert hot["total"] < 5e-3                 # low-millisecond (paper)
    assert cold["total"] > hot["total"] * 5
    assert cold["activation"] == pytest.approx(
        GPU_BUFFER_REGISTRATION * 4 + RDMA_CONNECTION_SETUP)
    assert hot["activation"] == BACKUP_ACTIVATION


def test_registration_init_cost_scales_with_nics():
    node = NodeTopology(node_id=0)
    t = RegistrationTable(node)
    assert t.init_cost(10) == pytest.approx(
        GPU_BUFFER_REGISTRATION * 10 * (len(node.nics) - 1))
