"""Failure model for R2CCL (paper Table 2 + Section 2.2).

Defines the failure taxonomy, injection schedules, and the ``FailureState``
that the planner / schedule builders consume.  This is the single source of
truth for "what is currently broken" across the detection simulator, the JAX
collective layer, and the benchmarks.
"""

from __future__ import annotations

import dataclasses
import enum
import random
from typing import Iterable, Sequence


class FailureType(enum.Enum):
    NIC_HARDWARE = "nic_hardware"          # NIC/port dead (supported)
    LINK_DOWN = "link_down"                # cable / ToR port (supported)
    QP_ERROR = "qp_error"                  # transport-level error (supported)
    LINK_FLAPPING = "link_flapping"        # partial: only if it surfaces a timeout
    CRC_ERROR = "crc_error"                # partial
    NIC_DRIVER = "nic_driver"              # supported if process survives
    NIC_FIRMWARE = "nic_firmware"          # supported
    PCIE = "pcie"                          # partial: subset of NICs
    GPU_NIC_PATH = "gpu_nic_path"          # partial: GPUDirect degraded
    SLOW_NIC = "slow_nic"                  # partial: degraded, not dead (spectrum)
    NVLINK = "nvlink"                      # out of scope
    SWITCH_OUTAGE = "switch_outage"        # out of scope
    PROCESS_CRASH = "process_crash"        # out of scope


#: Failure types R2CCL can hot-repair (paper Table 2).
SUPPORTED = {
    FailureType.NIC_HARDWARE,
    FailureType.LINK_DOWN,
    FailureType.QP_ERROR,
    FailureType.NIC_DRIVER,
    FailureType.NIC_FIRMWARE,
}
#: Supported only when they escalate to an in-flight transport failure.
PARTIAL = {
    FailureType.LINK_FLAPPING,
    FailureType.CRC_ERROR,
    FailureType.PCIE,
    FailureType.GPU_NIC_PATH,
    FailureType.SLOW_NIC,
}
OUT_OF_SCOPE = {
    FailureType.NVLINK,
    FailureType.SWITCH_OUTAGE,
    FailureType.PROCESS_CRASH,
}


@dataclasses.dataclass(frozen=True)
class Failure:
    """One failure event."""

    ftype: FailureType
    node: int
    rail: int                       # -1 => whole-node scope (out-of-scope types)
    at_time: float = 0.0            # seconds into the run (for injection)
    escalates: bool = True          # for PARTIAL types: does it surface a timeout?
    recovers_at: float | None = None
    #: fraction of the NIC's bandwidth lost: 1.0 = fully dead (hard failures),
    #: <1.0 = the paper's Section-6 bandwidth *spectrum* (slow NIC).  Only the
    #: discrete-event simulator consumes fractional severities; the binary
    #: ``FailureState`` treats any escalated failure as the NIC being down.
    severity: float = 1.0
    #: a *silent* failure degrades the fabric without notifying the control
    #: plane: the event engine applies its physics (capacity loss, transport
    #: rollback at the closed-form repair latency) but never consults the
    #: attached controller — recovery orchestration only happens if a
    #: telemetry-driven detector infers the failure from measured signals.
    silent: bool = False

    def __post_init__(self) -> None:
        # A severity of 0 (nothing lost) or > 1 (more than the NIC's bandwidth)
        # has no physical meaning and used to be silently accepted, which the
        # slow-NIC spectrum then misinterpreted as a negative residual rate.
        if not 0.0 < self.severity <= 1.0:
            raise ValueError(
                f"Failure.severity must be in (0, 1], got {self.severity!r} "
                f"(1.0 = NIC fully dead, <1.0 = slow-NIC bandwidth spectrum)")

    @property
    def nic_key(self) -> tuple[int, int]:
        return (self.node, self.rail)

    @property
    def supported(self) -> bool:
        if self.ftype in SUPPORTED:
            return True
        if self.ftype in PARTIAL:
            return self.escalates
        return False


@dataclasses.dataclass
class FailureState:
    """The set of currently-failed NICs, as seen by the control plane."""

    failed_nics: set[tuple[int, int]] = dataclasses.field(default_factory=set)
    unsupported: list[Failure] = dataclasses.field(default_factory=list)

    def apply(self, failure: Failure) -> bool:
        """Apply a failure; returns True if R2CCL can handle it."""
        if not failure.supported:
            self.unsupported.append(failure)
            return False
        self.failed_nics.add(failure.nic_key)
        return True

    def recover(self, nic_key: tuple[int, int]) -> None:
        self.failed_nics.discard(nic_key)

    def failed_on_node(self, node: int) -> set[int]:
        return {r for (n, r) in self.failed_nics if n == node}

    def degraded_nodes(self) -> list[int]:
        return sorted({n for (n, _) in self.failed_nics})

    def copy(self) -> "FailureState":
        return FailureState(set(self.failed_nics), list(self.unsupported))


# ---------------------------------------------------------------------------
# Injection schedules (used by benchmarks & examples)
# ---------------------------------------------------------------------------

def single_nic_failure(node: int = 0, rail: int = 0, at_time: float = 0.0) -> list[Failure]:
    return [Failure(FailureType.NIC_HARDWARE, node, rail, at_time)]


def concentrated_failures(node: int, rails: Sequence[int], at_time: float = 0.0) -> list[Failure]:
    return [Failure(FailureType.NIC_HARDWARE, node, r, at_time) for r in rails]


def random_failures(
    k: int,
    num_nodes: int,
    rails_per_node: int = 8,
    seed: int = 0,
    at_time: float = 0.0,
) -> list[Failure]:
    """k distinct random NIC failures across the cluster (paper Fig. 10 setup)."""
    rng = random.Random(seed)
    all_nics = [(n, r) for n in range(num_nodes) for r in range(rails_per_node)]
    picks = rng.sample(all_nics, k)
    return [Failure(FailureType.NIC_HARDWARE, n, r, at_time) for (n, r) in picks]


def rail_mismatch_failures(node_a: int, node_b: int, rail_a: int, rail_b: int) -> list[Failure]:
    """The Section-6 motivating pattern: adjacent nodes lose *different* rails."""
    return [
        Failure(FailureType.NIC_HARDWARE, node_a, rail_a),
        Failure(FailureType.NIC_HARDWARE, node_b, rail_b),
    ]


# ---------------------------------------------------------------------------
# Timed injections for the discrete-event simulator (core.event_sim)
# ---------------------------------------------------------------------------

def nic_down_at(node: int, rail: int, at_time: float) -> Failure:
    """Hard NIC failure at an absolute simulated timestamp."""
    return Failure(FailureType.NIC_HARDWARE, node, rail, at_time=at_time)


def link_flap(node: int, rail: int, at_time: float, down_for: float) -> Failure:
    """Link goes down at ``at_time`` and recovers ``down_for`` seconds later
    (the flapping pattern of paper Table 2, surfaced as a timeout)."""
    return Failure(FailureType.LINK_FLAPPING, node, rail, at_time=at_time,
                   escalates=True, recovers_at=at_time + down_for)


def slow_nic(node: int, rail: int, at_time: float, lost_fraction: float) -> Failure:
    """NIC degrades to ``1 - lost_fraction`` of its bandwidth but stays up —
    one point of the Section-6 bandwidth spectrum.  Does not escalate to a
    transport failure, so no rollback is triggered."""
    assert 0.0 < lost_fraction < 1.0
    return Failure(FailureType.SLOW_NIC, node, rail, at_time=at_time,
                   escalates=False, severity=lost_fraction)


def flap_sequence(node: int, rail: int, *, start: float, period: float,
                  down_for: float, count: int) -> list[Failure]:
    """``count`` flaps of the same link, ``period`` seconds apart."""
    assert down_for < period
    return [link_flap(node, rail, start + i * period, down_for)
            for i in range(count)]


def silenced(failures: Iterable[Failure]) -> list[Failure]:
    """The same failure schedule with the oracle notification stripped:
    the engine still applies each failure's physics, but the control plane
    must *infer* it from telemetry (see :mod:`repro.runtime.inference`)."""
    return [dataclasses.replace(f, silent=True) for f in failures]
