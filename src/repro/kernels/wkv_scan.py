"""RWKV-6 WKV recurrence Pallas kernel (TPU target).

State S in R^{K x V} per (batch, head), data-dependent per-channel decay:

    out_t = r_t @ (S_{t-1} + u * k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t

Grid: (batch*heads, num_time_tiles) with the time dimension sequential and
the (K, V) state tile carried in VMEM scratch.  Within a tile the
recurrence is stepped with a fori_loop of rank-1 updates — outer products
and row-scales are VPU work; K=V=64 tiles match the lane layout.

The jnp oracle is ``repro.models.rwkv6.wkv_scan_ref`` (re-exported in
``ref.reference_wkv``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr,
                *, time_tile: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # (tt, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (tt, V)
    w = w_ref[0].astype(jnp.float32)          # (tt, K)
    u = u_ref[0].astype(jnp.float32)          # (K,)

    def step(t, carry):
        s, out = carry
        kt = k[t][:, None]                     # (K, 1)
        vt = v[t][None, :]                     # (1, V)
        kv = kt * vt                           # (K, V) rank-1
        ot = (r[t][None, :] @ (s + u[:, None] * kv))[0]      # (V,)
        s = w[t][:, None] * s + kv
        out = lax.dynamic_update_index_in_dim(out, ot, t, 0)
        return s, out

    s0 = s_scr[...]
    out0 = jnp.zeros((time_tile, v.shape[1]), jnp.float32)
    sT, out = lax.fori_loop(0, time_tile, step, (s0, out0))
    o_ref[0] = out.astype(o_ref.dtype)
    s_scr[...] = sT


def wkv_scan_pallas(
    r: jax.Array,                  # (BH, T, K)
    k: jax.Array,
    v: jax.Array,                  # (BH, T, V)
    w: jax.Array,                  # (BH, T, K) decay in (0,1)
    u: jax.Array,                  # (BH, K) bonus
    *,
    time_tile: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Returns (BH, T, V) float32; S_0 = 0 (prefill semantics)."""
    BH, T, K = r.shape
    V = v.shape[2]
    assert T % time_tile == 0
    grid = (BH, T // time_tile)
    return pl.pallas_call(
        functools.partial(_wkv_kernel, time_tile=time_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, time_tile, K), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, time_tile, K), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, time_tile, V), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, time_tile, K), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, K), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, time_tile, V), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, V), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
