"""HuBERT-XLarge [audio] — encoder-only masked-unit prediction.

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (cluster units)
[arXiv:2106.07447]  Encoder-only: no decode step (decode_32k / long_500k
are skipped for this arch — see DESIGN.md).  The mel-spectrogram + conv
feature extractor is a STUB: ``input_specs()`` supplies precomputed frame
embeddings (dim 512).
"""

from repro.configs.base import AttentionConfig, ModalityConfig, ModelConfig


CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    attention=AttentionConfig(
        kind="gqa", num_heads=16, num_kv_heads=16, head_dim=80,
        use_rope=False, causal=False,
    ),
    modality=ModalityConfig(kind="audio_frames", frontend_dim=512),
    block_pattern=("attn",),
    activation="gelu",
    norm="layernorm",
    encoder_only=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke",
        family="audio",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=256,
        vocab_size=104,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=4,
                                  head_dim=32, use_rope=False, causal=False),
        modality=ModalityConfig(kind="audio_frames", frontend_dim=48),
        block_pattern=("attn",),
        activation="gelu",
        norm="layernorm",
        encoder_only=True,
        remat=False,
    )
