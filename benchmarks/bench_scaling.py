"""Paper Fig. 8: simulated 7B training on 4-64 8xA100 servers (200Gb NICs),
single NIC failure: R2CCL-AllReduce stays <1.5% overhead while Balance
rises to ~5% at 64 servers; Fig. 9: 175B/1024-GPU pretrain and RLHF
fine-tune extra-time vs AdapCC (54x / 15x)."""

from __future__ import annotations

from repro.core.comm_sim import (
    A100_BF16_FLOPS,
    NIC_200G,
    TrainJob,
    adapcc_overhead,
    training_overhead,
)
from repro.core.failures import single_nic_failure
from repro.core.topology import make_cluster

from .common import Reporter


def run(mode: str = "alpha_beta", tiny: bool = False) -> None:
    r = Reporter("scaling_fig8_fig9")
    r.data["mode"] = mode
    fail = single_nic_failure(0, 0)
    curves: dict[str, list[float]] = {"servers": [], "balance": [], "r2ccl": [],
                                      "hot_repair": []}
    scales = (2,) if tiny else (4, 8, 16, 32, 64)
    devices = 4 if tiny else 8
    for servers in scales:
        cluster = make_cluster(servers, devices, nic_bandwidth=NIC_200G)
        # paper: two TP groups per server -> TP=4
        job = TrainJob(params=7e9, dp=servers * 2, tp=devices // 2, pp=1,
                       global_batch=512, flops_per_chip=A100_BF16_FLOPS)
        curves["servers"].append(servers)
        for strat in ("balance", "r2ccl", "hot_repair"):
            curves[strat].append(training_overhead(job, cluster, fail,
                                                   strategy=strat, mode=mode))
    r.data["curves"] = curves
    last = f"{scales[-1]}srv"
    r.row(f"r2ccl_overhead_{last}", curves["r2ccl"][-1], "paper: <1.5%")
    r.row(f"balance_overhead_{last}", curves["balance"][-1], "paper: ~5%")
    r.row("r2ccl_max_overhead", max(curves["r2ccl"]), "paper: <1.5%")

    # cross-validation: the two simulator backends must agree on the healthy
    # ring regime (the event engine *executes* what alpha-beta predicts)
    from repro.core.comm_sim import iteration_time
    from repro.core.failures import FailureState
    xcluster = make_cluster(2 if tiny else 8, devices, nic_bandwidth=NIC_200G)
    xjob = TrainJob(params=7e9, dp=(2 if tiny else 8) * 2, tp=devices // 2,
                    pp=1, global_batch=512, flops_per_chip=A100_BF16_FLOPS)
    ab = iteration_time(xjob, xcluster, FailureState(), strategy="ring",
                        mode="alpha_beta")
    ev = iteration_time(xjob, xcluster, FailureState(), strategy="ring",
                        mode="event")
    r.row("event_vs_alpha_beta_dp_comm", ev.dp_comm / max(ab.dp_comm, 1e-12),
          "ring-coefficient ratio 2(n-1)/n vs 2(ng-1)/ng expected")
    if tiny:
        r.save()
        return

    # --- Fig. 9: extra failure-induced time vs AdapCC ------------------------
    # 175B pretrain, 1024 GPUs (TP=8, PP=8, DP=16)
    cluster = make_cluster(128, 8, nic_bandwidth=NIC_200G)
    job175 = TrainJob(params=175e9, dp=16, tp=8, pp=8, global_batch=1024,
                      flops_per_chip=A100_BF16_FLOPS)
    r2 = training_overhead(job175, cluster, fail, strategy="r2ccl")
    # AdapCC cannot remove a rank under TP/PP: it must hold the collective
    # until the next boundary and exclude the whole DP replica, losing
    # 1/DP of compute plus its reconfiguration stall.
    adapcc_extra = 1.0 / job175.dp + 0.01
    r.row("175b_r2ccl_overhead", r2, "transport-layer reroute")
    r.row("175b_adapcc_overhead", adapcc_extra, "replica exclusion")
    r.row("175b_extra_time_ratio", adapcc_extra / max(r2, 1e-9), "paper: ~54x")

    # RLHF (DeepSpeed-Chat), 64 GPUs (TP=8, PP=1, DP=8) with FSDP.  FSDP
    # moves params + grads every step (all-gather fwd + all-gather bwd +
    # reduce-scatter), ~4x the plain-DP gradient payload; actor+critic
    # double it again -> grad_bytes_per_param=8.
    cluster_r = make_cluster(8, 8, nic_bandwidth=NIC_200G)
    job_rlhf = TrainJob(params=7e9, dp=8, tp=8, pp=1, global_batch=256,
                        flops_per_chip=A100_BF16_FLOPS,
                        grad_bytes_per_param=8.0)
    r2r = training_overhead(job_rlhf, cluster_r, fail, strategy="r2ccl")
    # TP=8 pins a replica to a server: losing a GPU stalls that replica for
    # the training phase (~30% of RLHF wall time) until reconfiguration.
    adapcc_r = (1.0 / job_rlhf.dp) * 0.3 + 0.01
    r.row("rlhf_extra_time_ratio", adapcc_r / max(r2r, 1e-9), "paper: ~15x")

    # headline: 12.18x lower overhead than AdapCC (testbed DP=16 figure)
    from repro.core.topology import IB_NIC_BW
    from repro.core.comm_sim import H100_BF16_FLOPS
    tb = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    job_tb = TrainJob(params=2.7e9, dp=16, tp=1, pp=1, global_batch=256,
                      seq_len=2048, flops_per_chip=H100_BF16_FLOPS,
                      nic_stripe=3)
    r2_tb = training_overhead(job_tb, tb, fail, strategy="r2ccl")
    ad_tb = adapcc_overhead(job_tb, tb, fail)
    r.row("testbed_adapcc_over_r2ccl", (ad_tb or 0) / max(r2_tb, 1e-9),
          "paper: 12.18x")
    r.save()


if __name__ == "__main__":
    run()
