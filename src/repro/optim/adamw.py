"""AdamW in pure JAX (decoupled weight decay, bias-corrected moments)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float | None = 1.0


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """One AdamW step.  Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, gnorm
