"""Shared benchmark helpers: CSV rows + JSON artifacts."""

from __future__ import annotations

import json
import os
import time
from typing import Any

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


class Reporter:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[tuple[str, float, str]] = []
        self.data: dict[str, Any] = {}

    def row(self, metric: str, value: float, derived: str = "") -> None:
        self.rows.append((metric, value, derived))
        print(f"{self.name},{metric},{value:.6g},{derived}")

    def save(self) -> None:
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(os.path.join(OUT_DIR, f"{self.name}.json"), "w") as f:
            json.dump({"rows": [list(r) for r in self.rows], **self.data}, f,
                      indent=1, default=str)


def timer(fn, *args, repeats: int = 3, **kw) -> float:
    fn(*args, **kw)                       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / repeats
