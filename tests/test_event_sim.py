"""Discrete-event simulator: alpha-beta conformance on healthy rings,
determinism, mid-collective failure semantics, and (property-based, via the
offline shim) payload conservation + bounded retransmission under randomly
injected NIC failures — the event-engine mirror of ``ChunkTransfer``
losslessness in test_migration.py."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allreduce import build_r2ccl_all_reduce
from repro.core.comm_sim import event_failure_scenario
from repro.core.event_sim import (
    EventSimError,
    StalledError,
    Stream,
    predict_ring_all_reduce,
    simulate_program,
    simulate_schedule,
    simulate_streams,
)
from repro.core.executor_np import all_reduce_oracle
from repro.core.failures import (
    FailureType,
    Failure,
    flap_sequence,
    link_flap,
    nic_down_at,
    slow_nic,
)
from repro.core.recursive import build_recursive_all_reduce
from repro.core.schedule import (
    build_ring_broadcast,
    ring_program,
    tree_program,
)
from repro.core.topology import DEFAULT_ALPHA, make_cluster


def _data(n, size, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=size) for _ in range(n)]


# ---------------------------------------------------------------------------
# conformance: healthy ring == alpha-beta closed form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
@pytest.mark.parametrize("payload,bw", [(100e6, 50e9), (1e9, 25e9), (4e6, 50e9)])
def test_healthy_ring_matches_alpha_beta(n, payload, bw):
    """On a homogeneous healthy ring the event engine must reproduce
    2(n-1) * (alpha + chunk/B) — same rounds, same rates, no contention."""
    prog = ring_program(list(range(n)), n)
    rep = simulate_program(prog, payload, capacities=[bw] * n, g=8)
    want = predict_ring_all_reduce(n, payload, bw)
    assert rep.completion_time == pytest.approx(want, rel=1e-6)


def test_healthy_ring_matches_cluster_capacities():
    cluster = make_cluster(8, 8, nic_bandwidth=25e9)
    prog = ring_program(list(range(8)), 8)
    rep = simulate_program(prog, 200e6, cluster=cluster)
    want = predict_ring_all_reduce(8, 200e6, 8 * 25e9)
    assert rep.completion_time == pytest.approx(want, rel=1e-6)


def test_straggler_ring_no_worse_than_bottleneck_formula():
    """One slow node throttles the ring; completion lands between the
    fast-node and slow-node closed forms (pipelining hides some of it)."""
    n, payload = 8, 400e6
    caps = [50e9] * n
    caps[3] = 20e9
    prog = ring_program(list(range(n)), n)
    rep = simulate_program(prog, payload, capacities=caps, g=8)
    t_fast = predict_ring_all_reduce(n, payload, 50e9)
    t_slow = predict_ring_all_reduce(n, payload, 20e9)
    assert t_fast < rep.completion_time <= t_slow * (1 + 1e-6)


def test_utilization_near_one_when_healthy():
    prog = ring_program(list(range(8)), 8)
    rep = simulate_program(prog, 800e6, capacities=[50e9] * 8, g=8)
    for r, u in rep.link_utilization.items():
        assert 0.9 < u <= 1.0 + 1e-9, (r, u)


def test_link_bytes_match_schedule_model():
    """Simulated per-edge traffic equals the IR's analytic edge_bytes."""
    n, payload = 6, 120e6
    prog = ring_program(list(range(n)), n)
    rep = simulate_program(prog, payload, capacities=[50e9] * n, g=8)
    want = prog.segments[0].schedule.edge_bytes(payload)
    assert set(rep.link_bytes) == set(want)
    for e, b in want.items():
        assert rep.link_bytes[e] == pytest.approx(b, rel=1e-9)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_deterministic_under_failures():
    n = 8
    prog = ring_program(list(range(n)), n)
    fails = [nic_down_at(2, 0, 3e-4), link_flap(5, 1, 8e-4, 4e-4)]
    reps = [
        simulate_program(prog, 500e6, capacities=[50e9] * n, g=8,
                         failures=fails)
        for _ in range(2)
    ]
    assert reps[0].completion_time == reps[1].completion_time
    assert reps[0].retransmitted_bytes == reps[1].retransmitted_bytes
    assert reps[0].failovers == reps[1].failovers
    assert reps[0].link_bytes == reps[1].link_bytes


# ---------------------------------------------------------------------------
# correctness of the data plane across program kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("builder", ["ring", "tree", "r2ccl", "recursive"])
def test_programs_produce_allreduce(builder):
    n, size = 6, 150
    if builder == "ring":
        prog = ring_program(list(range(n)), n)
    elif builder == "tree":
        prog = tree_program(list(range(n)), n)
    elif builder == "r2ccl":
        prog, _ = build_r2ccl_all_reduce(list(range(n)), 2, x=0.6, g=8)
    else:
        prog, _ = build_recursive_all_reduce(
            [100e9, 250e9, 400e9, 400e9, 400e9, 400e9])
    data = _data(n, size, seed=3)
    rep = simulate_program(prog, size * 8.0, capacities=[50e9] * n,
                           rank_data=data)
    want = all_reduce_oracle(data)
    for d in rep.rank_data:
        np.testing.assert_allclose(d, want, atol=1e-9)


def test_broadcast_schedule():
    n = 5
    data = _data(n, 64, seed=1)
    sched = build_ring_broadcast(list(range(n)), n, root=2)
    rep = simulate_schedule(sched, 64 * 8.0, capacities=[50e9] * n,
                            rank_data=data)
    for d in rep.rank_data:
        np.testing.assert_allclose(d, data[2], atol=1e-12)


# ---------------------------------------------------------------------------
# failure semantics
# ---------------------------------------------------------------------------

def _mid_time(n, payload, bw, frac=0.37):
    return frac * predict_ring_all_reduce(n, payload, bw)


def test_nic_down_mid_collective_rolls_back():
    n, payload, bw = 8, 800e6, 50e9
    prog = ring_program(list(range(n)), n)
    tf = _mid_time(n, payload, bw)
    data = _data(n, 256, seed=7)
    rep = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                           rank_data=data, failures=[nic_down_at(3, 0, tf)])
    healthy = predict_ring_all_reduce(n, payload, bw)
    assert rep.failovers >= 1
    assert rep.retransmitted_bytes > 0
    # the rollback window is one chunk per interrupted transfer
    chunk = payload / n
    assert rep.retransmitted_bytes <= rep.failovers * chunk * (1 + 1e-9)
    assert rep.completion_time > healthy
    want = all_reduce_oracle(data)
    for d in rep.rank_data:
        np.testing.assert_allclose(d, want, atol=1e-9)


def test_flap_recovery_faster_than_permanent_death():
    n, payload, bw = 8, 800e6, 50e9
    prog = ring_program(list(range(n)), n)
    tf = _mid_time(n, payload, bw)
    dead = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                            failures=[nic_down_at(3, 0, tf)])
    flap = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                            failures=[link_flap(3, 0, tf, 1e-3)])
    assert flap.completion_time <= dead.completion_time + 1e-12


def test_flap_recovery_cannot_resurrect_dead_nic():
    """A flap recovering on a rail a *different* failure killed must not
    restore the dead NIC's bandwidth (losses are keyed per failure event)."""
    n, payload, bw = 4, 400e6, 50e9
    prog = ring_program(list(range(n)), n)
    tf = _mid_time(n, payload, bw)
    dead_only = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                                 failures=[nic_down_at(1, 0, tf)])
    dead_and_flap = simulate_program(
        prog, payload, capacities=[bw] * n, g=8,
        failures=[nic_down_at(1, 0, tf),
                  link_flap(1, 0, tf * 1.2, tf * 0.2)])
    # the extra flap can only add delay, never speed the run up
    assert dead_and_flap.completion_time >= dead_only.completion_time - 1e-12


def test_failure_on_unknown_rank_rejected():
    prog = ring_program([0, 1, 2], 3)
    with pytest.raises(EventSimError):
        simulate_program(prog, 1e6, capacities=[50e9] * 3, g=8,
                         failures=[nic_down_at(7, 0, 1e-4)])
    with pytest.raises(EventSimError):        # rail out of range too
        simulate_program(prog, 1e6, capacities=[50e9] * 3, g=8,
                         failures=[nic_down_at(1, 9, 1e-4)])


def test_out_of_scope_types_never_become_events():
    """Out-of-scope failure types are not transport events, even with a
    fractional severity or the whole-node rail=-1 convention."""
    prog = ring_program([0, 1, 2, 3], 4)
    bad = Failure(FailureType.SWITCH_OUTAGE, 1, -1, at_time=1e-5, severity=0.5)
    rep = simulate_program(prog, 100e6, capacities=[50e9] * 4, g=8,
                          failures=[bad])
    want = predict_ring_all_reduce(4, 100e6, 50e9)
    assert rep.completion_time == pytest.approx(want, rel=1e-6)


def test_slow_nic_degrades_without_rollback():
    n, payload, bw = 8, 800e6, 50e9
    prog = ring_program(list(range(n)), n)
    rep = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                           failures=[slow_nic(3, 0, 0.0, 0.5)])
    healthy = predict_ring_all_reduce(n, payload, bw)
    assert rep.failovers == 0 and rep.retransmitted_bytes == 0
    assert rep.completion_time > healthy
    # losing half of one of 8 rails costs at most the 1/(1-x) ring slowdown
    assert rep.completion_time <= predict_ring_all_reduce(
        n, payload, bw * (1 - 0.5 / 8)) * (1 + 1e-6)


def test_all_rails_dead_stalls():
    n = 4
    prog = ring_program(list(range(n)), n)
    fails = [nic_down_at(1, r, 1e-5) for r in range(8)]
    with pytest.raises(StalledError):
        simulate_program(prog, 100e6, capacities=[50e9] * n, g=8,
                         failures=fails)


def test_flap_of_all_rails_recovers_and_completes():
    n = 4
    prog = ring_program(list(range(n)), n)
    fails = [link_flap(1, r, 1e-5, 5e-3) for r in range(8)]
    rep = simulate_program(prog, 100e6, capacities=[50e9] * n, g=8,
                           failures=fails)
    assert rep.completion_time > 5e-3   # had to wait out the outage


def test_bad_arguments():
    prog = ring_program([0, 1, 2], 3)
    with pytest.raises(EventSimError):
        simulate_program(prog, 1e6)                       # no capacities
    with pytest.raises(EventSimError):
        simulate_program(prog, 1e6, capacities=[1e9] * 2)  # wrong arity
    with pytest.raises(EventSimError):
        simulate_program(prog, 1e6, cluster=make_cluster(4, 8))


# ---------------------------------------------------------------------------
# property tests (offline shim): conservation under random mid-collective
# NIC failures — every rank still ends with the full reduced payload, and
# retransmitted bytes never exceed the rollback window.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 8),
    size=st.integers(8, 200),
    seed=st.integers(0, 99),
    fail_fracs=st.lists(st.floats(0.05, 0.95), min_size=0, max_size=3),
    fail_node=st.integers(0, 7),
)
def test_event_conservation_under_failures(n, size, seed, fail_fracs, fail_node):
    fail_node = fail_node % n
    payload = size * 8.0
    bw = 50e9
    prog = ring_program(list(range(n)), n)
    healthy = predict_ring_all_reduce(n, payload, bw)
    fails = [
        # distinct rails so no event is a duplicate of an already-dead NIC;
        # recovery keeps the sim from stalling when all rails get hit
        link_flap(fail_node, i, f * healthy, healthy)
        for i, f in enumerate(fail_fracs)
    ]
    data = _data(n, size, seed)
    rep = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                           rank_data=data, failures=fails,
                           repair_latency=1e-5)
    want = all_reduce_oracle(data)
    for d in rep.rank_data:                       # losslessness
        np.testing.assert_allclose(d, want, atol=1e-9)
    # rollback window: at most one in-flight chunk per failover retransmits
    max_transfer = payload / prog.segments[0].schedule.num_chunks
    assert rep.retransmitted_bytes <= rep.failovers * max_transfer * (1 + 1e-9)
    assert rep.failovers <= 2 * len(fails)        # tx + rx per failed node


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 7),
    deg=st.integers(0, 6),
    x=st.floats(0.1, 0.9),
    seed=st.integers(0, 50),
    fail_frac=st.floats(0.05, 0.9),
)
def test_r2ccl_program_conserves_under_failure(n, deg, x, seed, fail_frac):
    """The decomposed R2CCL program (concurrent segments) stays lossless
    when a NIC dies mid-collective."""
    deg = deg % n
    payload = 160 * 8.0
    prog, _ = build_r2ccl_all_reduce(list(range(n)), deg, x=x, g=8)
    healthy = simulate_program(prog, payload, capacities=[50e9] * n, g=8)
    fails = [link_flap((deg + 1) % n, 0,
                       fail_frac * healthy.completion_time,
                       healthy.completion_time)]
    data = _data(n, 160, seed)
    rep = simulate_program(prog, payload, capacities=[50e9] * n, g=8,
                           rank_data=data, failures=fails, repair_latency=1e-5)
    want = all_reduce_oracle(data)
    for d in rep.rank_data:
        np.testing.assert_allclose(d, want, atol=1e-9)


# ---------------------------------------------------------------------------
# concurrent streams sharing NICs (multi-stream engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fails", [
    [],
    [nic_down_at(3, 0, 5e-4)],
    [link_flap(2, 1, 3e-4, 2e-4)],
])
def test_single_stream_matches_single_program_engine(fails):
    """Refactor-equivalence guard: one stream through the multi-stream
    engine must reproduce the single-program engine EXACTLY — same
    timings, same per-link traffic, same failover accounting, same data —
    so nothing priced before the refactor moved."""
    n, payload, bw = 8, 500e6, 50e9
    prog = ring_program(list(range(n)), n)
    data = _data(n, 96, seed=5)
    a = simulate_program(prog, payload, capacities=[bw] * n, g=8,
                         rank_data=data, failures=fails)
    b = simulate_streams(
        [Stream("main", prog, payload, rank_data=data)],
        capacities=[bw] * n, g=8, failures=fails)
    assert a.completion_time == b.completion_time
    assert a.link_bytes == b.link_bytes
    assert a.retransmitted_bytes == b.retransmitted_bytes
    assert a.failovers == b.failovers
    assert a.segment_finish == b.segment_finish
    for x, y in zip(a.rank_data, b.rank_data):
        assert np.array_equal(x, y)
    # the single-program report carries exactly one stream, and its
    # breakdown IS the report's scalars
    assert list(a.streams) == list(b.streams) == ["main"]
    sr = b.streams["main"]
    assert sr.retransmitted_bytes == b.retransmitted_bytes
    assert sr.failovers == b.failovers
    assert sr.completion_time == b.completion_time


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 6),
    k=st.integers(2, 3),
    size=st.integers(8, 64),
    seed=st.integers(0, 99),
    prios=st.lists(st.floats(0.5, 4.0), min_size=3, max_size=3),
)
def test_multi_stream_conservation_and_contention(n, k, size, seed, prios):
    """Property: K concurrent AllReduce streams on a healthy ring each
    conserve their payload exactly, no stream finishes faster than it would
    alone (fair sharing only removes bandwidth), the joint makespan is at
    least any solo run, and the report's scalars are the per-stream sums."""
    bw = 50e9
    prog = ring_program(list(range(n)), n)
    datas = [_data(n, size, seed + i) for i in range(k)]
    streams = [
        Stream(f"s{i}", prog, (i + 1) * size * 8.0, priority=prios[i],
               rank_data=datas[i])
        for i in range(k)
    ]
    rep = simulate_streams(streams, capacities=[bw] * n, g=8)
    solo = [
        simulate_program(prog, s.payload_bytes, capacities=[bw] * n, g=8)
        .completion_time
        for s in streams
    ]
    assert rep.completion_time >= max(solo) * (1 - 1e-9)
    for i, s in enumerate(streams):
        sr = rep.streams[s.name]
        want = all_reduce_oracle(datas[i])
        for d in sr.rank_data:
            np.testing.assert_allclose(d, want, atol=1e-9)
        assert sr.completion_time >= solo[i] * (1 - 1e-9)
        assert sr.retransmitted_bytes == 0.0
    # aggregate scalars == per-stream sums, and all wire bytes accounted
    assert rep.retransmitted_bytes == pytest.approx(
        sum(sr.retransmitted_bytes for sr in rep.streams.values()))
    assert rep.failovers == sum(sr.failovers for sr in rep.streams.values())
    assert sum(rep.link_bytes.values()) == pytest.approx(
        sum(sr.moved_bytes for sr in rep.streams.values()))


def test_stream_priority_weights_bandwidth():
    """Two identical streams: raising one's priority must finish it sooner
    than in its equal-priority run and clearly ahead of the peer (weighted
    max-min share).  The peer cannot beat its own solo time, and — sharing
    being work-conserving — total wire traffic is unchanged."""
    n, payload, bw = 6, 400e6, 50e9
    prog = ring_program(list(range(n)), n)
    solo = predict_ring_all_reduce(n, payload, bw)

    def run(p_hi):
        return simulate_streams(
            [Stream("hi", prog, payload, priority=p_hi),
             Stream("lo", prog, payload)],
            capacities=[bw] * n, g=8)

    eq = run(1.0)
    wt = run(3.0)
    assert wt.streams["hi"].completion_time < eq.streams["hi"].completion_time
    assert wt.streams["hi"].completion_time < wt.streams["lo"].completion_time
    assert wt.streams["lo"].completion_time >= solo * (1 - 1e-9)
    # the weighted run still conserves total wire traffic
    assert sum(wt.link_bytes.values()) == pytest.approx(
        sum(eq.link_bytes.values()))


def test_stream_start_time_offsets_release():
    """A stream released later cannot finish before its start; the early
    stream's pre-overlap phase runs uncontended."""
    n, payload, bw = 4, 200e6, 50e9
    prog = ring_program(list(range(n)), n)
    t_solo = predict_ring_all_reduce(n, payload, bw)
    late = 0.6 * t_solo
    rep = simulate_streams(
        [Stream("early", prog, payload),
         Stream("late", prog, payload, start_time=late)],
        capacities=[bw] * n, g=8)
    assert rep.streams["late"].completion_time >= late + t_solo * (1 - 1e-9)
    assert rep.streams["early"].completion_time < \
        rep.streams["late"].completion_time


def test_multi_stream_failure_rolls_back_every_stream_on_the_rail():
    """A hard NIC death interrupts in-flight transfers of EVERY stream
    riding the node, not just one collective's."""
    n, payload, bw = 6, 600e6, 50e9
    prog = ring_program(list(range(n)), n)
    tf = 0.4 * predict_ring_all_reduce(n, payload, bw)
    rep = simulate_streams(
        [Stream("a", prog, payload, rank_data=_data(n, 64, 1)),
         Stream("b", prog, payload, rank_data=_data(n, 64, 2))],
        capacities=[bw] * n, g=8, failures=[nic_down_at(2, 0, tf)])
    assert rep.streams["a"].failovers >= 1
    assert rep.streams["b"].failovers >= 1
    assert rep.failovers == (rep.streams["a"].failovers
                             + rep.streams["b"].failovers)
    for name, seed in (("a", 1), ("b", 2)):
        want = all_reduce_oracle(_data(n, 64, seed))
        for d in rep.streams[name].rank_data:
            np.testing.assert_allclose(d, want, atol=1e-9)


def test_stream_validation_errors():
    prog3 = ring_program([0, 1, 2], 3)
    prog4 = ring_program([0, 1, 2, 3], 4)
    with pytest.raises(EventSimError):      # duplicate names
        simulate_streams([Stream("x", prog3, 1e6), Stream("x", prog3, 1e6)],
                         capacities=[1e9] * 3, g=8)
    with pytest.raises(EventSimError):      # mismatched rank counts
        simulate_streams([Stream("a", prog3, 1e6), Stream("b", prog4, 1e6)],
                         capacities=[1e9] * 3, g=8)
    with pytest.raises(EventSimError):      # non-positive priority
        simulate_streams([Stream("a", prog3, 1e6, priority=0.0)],
                         capacities=[1e9] * 3, g=8)
    with pytest.raises(EventSimError):      # negative start
        simulate_streams([Stream("a", prog3, 1e6, start_time=-1.0)],
                         capacities=[1e9] * 3, g=8)
    with pytest.raises(EventSimError):      # no streams at all
        simulate_streams([], capacities=[1e9] * 3, g=8)
    from repro.core.event_sim import EventSimulator
    with pytest.raises(EventSimError):      # both APIs at once
        EventSimulator(prog3, 1e6, streams=[Stream("a", prog3, 1e6)],
                       capacities=[1e9] * 3, g=8)


# ---------------------------------------------------------------------------
# scenario helper (comm_sim.event_failure_scenario)
# ---------------------------------------------------------------------------

def test_scenario_preplanned_r2ccl_beats_mid_ring():
    cluster = make_cluster(4, 8, nic_bandwidth=25e9)
    known = event_failure_scenario(cluster, 100e6,
                                   [nic_down_at(1, 0, 0.0)], strategy="r2ccl")
    surprise = event_failure_scenario(
        cluster, 100e6,
        [nic_down_at(1, 0, 0.37 * known["healthy_time"])], strategy="ring")
    assert known["retransmitted_bytes"] == 0      # planned around the failure
    assert surprise["failovers"] >= 1             # caught mid-flight
    assert known["completion_time"] < surprise["completion_time"]


def test_scenario_unsupported_failure_ignored_by_planner():
    cluster = make_cluster(4, 8, nic_bandwidth=25e9)
    bad = Failure(FailureType.SWITCH_OUTAGE, 0, -1)
    sc = event_failure_scenario(cluster, 50e6, [bad], strategy="r2ccl")
    # out-of-scope failures are not transport events: nothing degrades
    assert sc["overhead"] == pytest.approx(0.0, abs=1e-9)
    assert sc["failovers"] == 0


# ---------------------------------------------------------------------------
# event-loop float-time hazards + stall-guard contract (PR 10 satellites)
# ---------------------------------------------------------------------------

def test_time_tolerance_tracks_clock_ulp():
    """The same-timestamp bucket tolerance must stay above one float ulp of
    the clock (or co-timestamped events split across loop iterations once
    now > ~10 s) while staying far below alpha (or genuinely distinct
    rounds would merge)."""
    import math as _math

    from repro.core.event_sim import _time_tol

    for now in (0.0, 1.0, 30.0, 1e4, 16384.0, 1e6):
        assert _time_tol(now) >= _math.ulp(now)
        assert _time_tol(now) < DEFAULT_ALPHA / 100


def test_co_timestamped_events_bucket_at_large_clock():
    """Two arrivals at the same logical instant, computed through different
    float associations — ``(t + a) + b`` vs ``t + (a + b)`` — genuinely
    diverge by ulps at a large clock value; the pop tolerance must still
    bucket them (the old absolute 1e-15 epsilon could not)."""
    from repro.core.event_sim import _time_tol

    t0, a = 16384.0, 1.5e-3
    for k in range(1, 64):
        b = 5e-6 * k
        direct, chained = t0 + (a + b), (t0 + a) + b
        if direct != chained:
            break
    else:  # pragma: no cover - float paths always diverge somewhere
        pytest.fail("no diverging association found")
    gap = abs(direct - chained)
    assert gap > 1e-15                              # old epsilon splits them
    assert gap <= _time_tol(min(direct, chained))   # new tolerance buckets


def test_large_start_offset_timeline_translates():
    """A stream launched 2^14 s into the campaign must process the same
    events and move the same bytes as at t=0 — the event-time bucketing
    may not degrade with the clock magnitude."""
    n, payload, bw = 6, 300e6, 50e9
    prog = ring_program(list(range(n)), n)
    off = float(1 << 14)
    base = simulate_streams([Stream("m", prog, payload)],
                            capacities=[bw] * n, g=8)
    late = simulate_streams([Stream("m", prog, payload, start_time=off)],
                            capacities=[bw] * n, g=8)
    assert late.events == base.events + 1       # one extra timed start event
    assert late.link_bytes == base.link_bytes
    assert late.retransmitted_bytes == base.retransmitted_bytes
    assert late.completion_time == pytest.approx(base.completion_time + off,
                                                 rel=1e-9)


def test_all_rails_dead_stalls_with_telemetry_attached():
    """The no-observer stall guard (now an O(active) counter check, not a
    full event-queue rescan) must still raise StalledError when sampling
    ticks alone keep the queue alive on a dead fabric."""
    from repro.core.telemetry import Telemetry

    n = 4
    prog = ring_program(list(range(n)), n)
    fails = [nic_down_at(1, r, 1e-5) for r in range(8)]
    tm = Telemetry(sample_period=5e-5)
    with pytest.raises(StalledError):
        simulate_program(prog, 100e6, capacities=[50e9] * n, g=8,
                         failures=fails, telemetry=tm)
