"""RWKV6-1.6B [ssm] — Finch, data-dependent decay; attention-free.

24L d_model=2048 d_ff=7168 (channel-mix 3.5x) vocab=65536  [arXiv:2404.05892]
"""

from repro.configs.base import ModelConfig, RWKVConfig


CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65_536,
    attention=None,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, tokenshift_lora=32),
    block_pattern=("rwkv",),
    activation="swiglu",           # unused by rwkv blocks (channel-mix inside)
    norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=448,
        vocab_size=512,
        attention=None,
        rwkv=RWKVConfig(head_size=32, decay_lora=16, tokenshift_lora=8),
        block_pattern=("rwkv",),
        activation="swiglu",
        norm="layernorm",
        remat=False,
    )
