"""Paper Fig. 10: Monte Carlo multi-failure resilience — k in 1..10 random
NIC failures across 64 servers (512 GPUs), 50 patterns each; overhead must
grow sub-linearly (paper: 1.5% at k=1 to 4.3% at k=10).

Runs in either simulator mode (``mode="alpha_beta" | "event"``); the event
mode executes the real collective schedules on the discrete-event engine
(smaller cluster — the per-transfer simulation is ~1000x more work than the
closed form).  A second section always exercises the *mid-collective*
failure scenarios only the event engine can express: NIC death mid
AllReduce (rollback + retransmit), link flap with recovery, and a slow-NIC
bandwidth spectrum — reporting completion time and retransmitted bytes per
scenario.
"""

from __future__ import annotations

from repro.core.comm_sim import (
    A100_BF16_FLOPS,
    NIC_200G,
    TrainJob,
    event_failure_scenario,
    monte_carlo_multi_failure,
)
from repro.core.failures import (
    flap_sequence,
    link_flap,
    nic_down_at,
    slow_nic,
)
from repro.core.topology import make_cluster

from .common import Reporter


def _event_scenarios(r: Reporter, *, servers: int, devices: int,
                     payload: float, seed: int = 0) -> None:
    """Mid-collective failure patterns, fully simulated.  The named
    scenarios are deterministic; ``seed`` drives the random k-failure
    pattern row so the JSON is reproducible run-to-run."""
    from repro.core.failures import random_failures

    cluster = make_cluster(servers, devices, nic_bandwidth=NIC_200G)
    healthy = event_failure_scenario(cluster, payload, [])
    t_h = healthy["completion_time"]
    r.row("event_healthy_ring_time", t_h, f"{servers}x{devices}, no failure")

    mid = 0.37 * t_h                     # mid-flight, off any round boundary
    scenarios = {
        "nic_down_mid": ("ring", [nic_down_at(1, 0, mid)]),
        "nic_down_preplanned_r2ccl": ("r2ccl", [nic_down_at(1, 0, 0.0)]),
        "link_flap_recovers": ("ring", [link_flap(1, 0, mid, 0.25 * t_h)]),
        "repeated_flaps": ("ring", flap_sequence(
            1, 0, start=0.2 * t_h, period=0.3 * t_h,
            down_for=0.1 * t_h, count=3)),
        "slow_nic_spectrum": ("ring", [
            slow_nic(i, 0, 0.0, lost_fraction=0.1 + 0.15 * i)
            for i in range(min(3, servers))
        ]),
        "two_node_mid": ("ring", [nic_down_at(1, 0, mid),
                                  nic_down_at(servers - 1, 1, 0.61 * t_h)]),
        "random_k2_mid": ("ring", random_failures(
            2, servers, devices, seed=seed, at_time=mid)),
    }
    for name, (strategy, fails) in scenarios.items():
        sc = event_failure_scenario(cluster, payload, fails, strategy=strategy,
                                    healthy_time=t_h)
        r.row(f"event_{name}_time", sc["completion_time"],
              f"overhead={sc['overhead']:.3%} "
              f"retrans={sc['retransmitted_bytes']:.3g}B "
              f"failovers={sc['failovers']:.0f}")
        r.row(f"event_{name}_retrans_bytes", sc["retransmitted_bytes"],
              f"of {payload:.3g}B payload")


def run(trials: int = 50, mode: str = "alpha_beta", tiny: bool = False,
        seed: int = 0) -> None:
    r = Reporter("multi_failure_fig10")
    r.data["mode"] = mode
    r.data["seed"] = seed

    if tiny:
        servers, devices, ks = 2, 4, (1, 2)
        trials = min(trials, 3)
    elif mode == "event":
        # per-transfer simulation: shrink the Monte Carlo to stay fast
        servers, devices, ks = 16, 8, (1, 2, 4, 8)
        trials = min(trials, 10)
    else:
        servers, devices, ks = 64, 8, tuple(range(1, 11))
    cluster = make_cluster(servers, devices, nic_bandwidth=NIC_200G)
    job = TrainJob(params=7e9, dp=servers * 2, tp=devices // 2, pp=1,
                   global_batch=512, flops_per_chip=A100_BF16_FLOPS)
    means = []
    for k in ks:
        mc = monte_carlo_multi_failure(job, cluster, k, trials=trials,
                                       strategy="auto", mode=mode, seed=seed)
        means.append(mc["mean"])
        r.row(f"k{k}_mean_overhead", mc["mean"],
              f"p95={mc['p95']:.3%} max={mc['max']:.3%}")
    r.row(f"k{ks[-1]}_overhead", means[-1],
          "paper: 4.3% at k=10" if ks[-1] == 10 else "")
    # sub-linear growth: overhead(k_max) << (k_max/k_min) x overhead(k_min)
    scale = ks[-1] / ks[0]
    r.row("sublinear_ratio", means[-1] / max(means[0] * scale, 1e-12),
          "<1 means sub-linear")

    if mode == "event":
        # Fleet-scale row the per-epoch global fill could not afford: 1024
        # GPUs (128 servers x 8 NICs), full event-mode Monte Carlo through
        # the incremental vectorized water-fill.  Few trials — the point is
        # that the scale is now *reachable*, and each trial is exact.
        big_servers, big_devices = 128, 8
        big_trials = 2 if tiny else 3
        big = make_cluster(big_servers, big_devices, nic_bandwidth=NIC_200G)
        big_job = TrainJob(params=7e9, dp=big_servers * 2,
                           tp=big_devices // 2, pp=1, global_batch=1024,
                           flops_per_chip=A100_BF16_FLOPS)
        mc = monte_carlo_multi_failure(big_job, big, 2, trials=big_trials,
                                       strategy="auto", mode=mode, seed=seed)
        r.row("event_1024gpu_k2_mean_overhead", mc["mean"],
              f"{big_servers}x{big_devices} cluster, {big_trials} trials, "
              f"p95={mc['p95']:.3%}; vectorized-fill tier")

    _event_scenarios(r, servers=2 if tiny else 8, devices=4 if tiny else 8,
                     payload=2e6 if tiny else 100e6, seed=seed)
    r.save()


if __name__ == "__main__":
    run()
