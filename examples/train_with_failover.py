"""Training through a NIC failure: the paper's core scenario end-to-end.

A smoke-size model trains with explicit R2CCL gradient synchronization.
Mid-run we inject a NIC hardware failure: the detector localizes it via
probe triangulation in ~1 ms of control-plane time, the failover chain
activates a pre-registered backup path, and the gradient AllReduce switches
to the failure-aware R2CCL-AllReduce schedule (built at init — nothing is
planned on the failure path).  Training continues losslessly; we verify
the loss trajectory stays on course and compare against what a vanilla
NCCL-style stack would do (crash + checkpoint restore, median 68 min).

  PYTHONPATH=src python examples/train_with_failover.py
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core.comm_sim import CHECKPOINT_RECOVERY_MEDIAN
from repro.core.detection import FailureDetector
from repro.core.failures import Failure, FailureState, FailureType
from repro.core.migration import RegistrationTable, migration_latency
from repro.core.planner import CommConfig, Planner, Collective
from repro.core.topology import IB_NIC_BW, NodeTopology, make_cluster
from repro.data import make_batch
from repro.models import get_smoke_config, init_model
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step

STEPS, FAIL_AT = 60, 30


def main() -> None:
    cfg = get_smoke_config("glm4-9b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)

    # Pre-built steps: the analogue of pre-established backup connections.
    healthy_step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=2e-3), sync="xla"))
    degraded_step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=2e-3), sync="xla"))  # single device: same math

    cluster = make_cluster(8, 8, nic_bandwidth=IB_NIC_BW)
    fstate = FailureState()
    detector = FailureDetector(fstate)
    planner = Planner(cluster)
    table = RegistrationTable(NodeTopology(node_id=2))

    active = healthy_step
    losses = []
    downtime = 0.0
    for i in range(STEPS):
        if i == FAIL_AT:
            print(f"\n--- step {i}: NIC (2,3) hardware failure ---")
            failure = Failure(FailureType.NIC_HARDWARE, 2, 3)
            diag = detector.detect(failure, (2, 3), (3, 3), aux=(0, 0))
            fstate.apply(failure)
            print(f"detected+localized: {diag.location.value} in "
                  f"{diag.localize_latency*1e3:.2f} ms "
                  f"(vs 120 s NCCL timeout)")
            chain = table.failover_chain(3, failed=[(2, 3)])
            lat = migration_latency(diag, remaining_bytes=32 << 20,
                                    backup_bandwidth=chain[0].bandwidth)
            print(f"hot repair: backup NIC {chain[0].key} "
                  f"(PCIe distance {table.node.pcie_distance(3, chain[0])}), "
                  f"migration {lat['total']*1e3:.2f} ms")
            plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 28, fstate)
            print(f"re-planned collective: {plan.strategy.value} "
                  f"(Y*={plan.partition_y:.3f}, X={plan.lost_fraction:.3f})")
            downtime = lat["total"]
            active = degraded_step
            print(f"--- training continues (downtime {downtime*1e3:.1f} ms; "
                  f"checkpoint recovery would be "
                  f"{CHECKPOINT_RECOVERY_MEDIAN/60:.0f} min) ---\n")
        b = make_batch(cfg, 48, 8, step=i)
        state, m = active(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
        if i % 10 == 0 or i == STEPS - 1:
            print(f"step {i:3d}  loss {losses[-1]:.4f}")

    pre = np.mean(losses[FAIL_AT - 5:FAIL_AT])
    post = np.mean(losses[-5:])
    print(f"\nloss before failure: {pre:.4f}; at end: {post:.4f} "
          f"(still improving: {post < pre})")
    speedup = CHECKPOINT_RECOVERY_MEDIAN / max(downtime, 1e-9)
    print(f"R2CCL downtime vs checkpoint recovery: {speedup:,.0f}x smaller")


if __name__ == "__main__":
    main()
