"""The R2CCL collective layer, standalone: build schedules, inspect traffic,
execute on virtual ranks, and see the planner's decisions.

  PYTHONPATH=src python examples/collective_demo.py
"""

import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.allreduce import bottleneck_traffic, build_r2ccl_all_reduce
from repro.core.executor_np import ExecStats, execute_program
from repro.core.failures import FailureState, concentrated_failures, single_nic_failure
from repro.core.partition import plan_partition, plan_partition_overlapped
from repro.core.planner import Collective, Planner
from repro.core.recursive import build_recursive_all_reduce
from repro.core.schedule import ring_program
from repro.core.topology import make_cluster


def main() -> None:
    n, g = 8, 8
    cluster = make_cluster(n, g)
    rng = np.random.default_rng(0)
    data = [rng.normal(size=1024) for _ in range(n)]
    want = np.sum(np.stack(data), axis=0)

    print("== healthy: ring AllReduce ==")
    prog = ring_program(list(range(n)), n)
    stats = ExecStats()
    out = execute_program(prog, data, stats=stats,
                          bandwidth_fn=lambda s, d: 400e9)
    print(f"correct: {all(np.allclose(o, want) for o in out)}; "
          f"rounds={stats.rounds}, est time={stats.time*1e6:.1f} us")

    print("\n== node 3 loses 4 of 8 NICs (X=0.5) ==")
    plan_s = plan_partition(0.5, n, g)
    plan_o = plan_partition_overlapped(0.5, n, g)
    print(f"Appendix-A (serialized): Y*={plan_s.y:.4f}, "
          f"predicted speedup {plan_s.speedup:.2f}x over throttled ring")
    print(f"overlapped stage-2:      Y*={plan_o.y:.4f}, "
          f"predicted speedup {plan_o.t_ring/plan_o.t_r2ccl:.2f}x")
    prog2, pp = build_r2ccl_all_reduce(list(range(n)), 3, x=0.5, g=g)
    out2 = execute_program(prog2, data)
    print(f"R2CCL-AllReduce correct: {all(np.allclose(o, want) for o in out2)}")
    d = 1.0
    print(f"degraded-node traffic: ring {bottleneck_traffic(prog, d, 3):.3f}D "
          f"-> r2ccl {bottleneck_traffic(prog2, d, 3):.3f}D (paper Fig. 5)")

    print("\n== bandwidth spectrum: recursive decomposition ==")
    bw = [400, 400, 200, 400, 300, 400, 350, 400]
    prog3, levels = build_recursive_all_reduce([b * 1e9 for b in bw])
    out3 = execute_program(prog3, data)
    print(f"correct: {all(np.allclose(o, want) for o in out3)}")
    for lv in levels:
        print(f"  level: {len(lv.members)} members, excl {lv.excluded}, "
              f"{lv.frac:.1%} of payload")

    print("\n== planner decisions (Table 1) ==")
    planner = Planner(cluster)
    for desc, failures, payload in [
        ("healthy, 1GB", [], 1 << 30),
        ("healthy, 4KB", [], 1 << 12),
        ("1 NIC down, 1GB", single_nic_failure(3, 0), 1 << 30),
        ("1 NIC down, 4KB", single_nic_failure(3, 0), 1 << 12),
        ("4 NICs down on node 3, 1GB", concentrated_failures(3, [0, 1, 2, 3]), 1 << 30),
        ("failures on 3 nodes, 1GB",
         concentrated_failures(1, [0, 1]) + single_nic_failure(4, 0)
         + concentrated_failures(6, [0, 1, 2]), 1 << 30),
    ]:
        st = FailureState()
        for f in failures:
            st.apply(f)
        plan = planner.choose_strategy(Collective.ALL_REDUCE, payload, st)
        print(f"  {desc:32s} -> {plan.strategy.value:18s} "
              f"(t={plan.predicted_time*1e3:.2f} ms) {plan.notes}")


if __name__ == "__main__":
    main()
