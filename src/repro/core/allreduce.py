"""R2CCL-AllReduce schedule builder (paper Section 5.2, Figure 5).

Decomposes an AllReduce under single-node bandwidth degradation into:

  Stage 1 (concurrent):
    * a *global* ring AllReduce over all n nodes on a (1-Y) fraction of the
      payload (throttled by the degraded node's residual bandwidth), and
    * a *partial* ring AllReduce over the n-1 healthy nodes on the Y
      fraction.  The degraded node's contribution for that fraction enters
      via a single injection edge to the healthy ring.
  Stage 2:
    * delivery of the partial result back to the degraded node (the paper's
      pipelined broadcast; in the IR the healthy ring's AllGather already
      distributes the result among healthy nodes, so stage 2 reduces to the
      final delivery edge plus — for analysis — the broadcast time T3).

Y is chosen by ``core.partition`` (Appendix A).  The resulting
:class:`CollectiveProgram` is executable by both the numpy oracle and the
JAX ``shard_map`` backend, and is exactly sum-preserving: every rank ends
with the full sum over all ranks.
"""

from __future__ import annotations

from typing import Sequence

from .partition import PartitionPlan, plan_partition
from .schedule import (
    ChunkSchedule,
    CollectiveProgram,
    Segment,
    Step,
    build_ring_all_gather,
    build_ring_all_reduce,
    build_ring_reduce_scatter,
)


def build_partial_all_reduce(
    healthy_order: Sequence[int], degraded: int, n: int
) -> ChunkSchedule:
    """Partial AllReduce over ``healthy_order`` with injection/delivery edges
    so the *degraded* rank's data is included and it receives the result.

    Rounds:
      1. inject: degraded -> healthy_order[0], whole buffer, accumulate;
      2. ring ReduceScatter over the healthy ring;
      3. ring AllGather over the healthy ring;
      4. deliver: healthy_order[-1] -> degraded, whole buffer, overwrite.

    The degraded rank only touches the network twice (send Y*D, recv Y*D),
    which is what removes it from the bandwidth-critical path.
    """
    from repro.analysis.errors import Provenance, ScheduleError

    k = len(healthy_order)
    if k < 2:
        raise ScheduleError(
            f"partial AllReduce needs >= 2 healthy ranks, got {k}",
            Provenance(schedule=f"partial_ar[{k}]+bridge"))
    if degraded in healthy_order:
        raise ScheduleError(
            f"degraded rank {degraded} must not appear in healthy_order "
            f"{list(healthy_order)}",
            Provenance(schedule=f"partial_ar[{k}]+bridge", rank=degraded))
    h0, hlast = healthy_order[0], healthy_order[-1]

    def whole(src: int, dst: int, accumulate: bool) -> Step:
        send = [-1] * n
        recv = [-1] * n
        send[src] = 0
        recv[dst] = 0
        return Step(((src, dst),), tuple(send), tuple(recv),
                    accumulate=accumulate, whole_buffer=True)

    inject = whole(degraded, h0, accumulate=True)
    rs = build_ring_reduce_scatter(healthy_order, n)
    ag = build_ring_all_gather(healthy_order, n)
    deliver = whole(hlast, degraded, accumulate=False)

    steps = [inject] + rs.steps + ag.steps + [deliver]
    sched = ChunkSchedule(
        f"partial_ar[{k}]+bridge", n, k, steps,
        result_ranks=tuple(list(healthy_order) + [degraded]),
    )
    sched.validate()
    return sched


def build_r2ccl_all_reduce(
    ring_order: Sequence[int],
    degraded: int,
    *,
    x: float,
    g: int = 8,
    n_ranks: int | None = None,
    practice_threshold: bool = True,
) -> tuple[CollectiveProgram, PartitionPlan]:
    """Build the full R2CCL-AllReduce program for one degraded node.

    ``ring_order``  — logical node ring (post re-ranking), all n nodes;
    ``degraded``    — the node with lost bandwidth fraction ``x``;
    ``g``           — devices per node (enters the Appendix-A coefficients).

    Returns (program, partition_plan).  When the plan says plain ring is
    optimal (x below threshold), the program is a standard ring AllReduce.
    """
    n = n_ranks if n_ranks is not None else len(ring_order)
    order = list(ring_order)
    if degraded not in order:
        from repro.analysis.errors import Provenance, ScheduleError

        raise ScheduleError(
            f"degraded rank {degraded} not in ring_order {order}",
            Provenance(schedule="r2ccl_all_reduce", rank=degraded))
    plan = plan_partition(x, n=len(order), g=g, practice_threshold=practice_threshold)

    if not plan.use_r2ccl:
        prog = CollectiveProgram(
            "ring_all_reduce", n, [Segment(1.0, build_ring_all_reduce(order, n))]
        )
        prog.validate()
        return prog, plan

    healthy = [r for r in order if r != degraded]
    global_seg = Segment(1.0 - plan.y, build_ring_all_reduce(order, n))
    partial_seg = Segment(plan.y, build_partial_all_reduce(healthy, degraded, n))
    prog = CollectiveProgram("r2ccl_all_reduce", n, [global_seg, partial_seg])
    prog.validate()
    return prog, plan


def bottleneck_traffic(prog: CollectiveProgram, total_bytes: float,
                       rank: int) -> float:
    """tx+rx bytes at ``rank`` — the quantity Figure 5 reduces from 2D to
    ~1.75D at the degraded node."""
    b = prog.bytes_per_rank(total_bytes)[rank]
    return b["tx"] + b["rx"]
