"""Sharding spec construction: divisibility fallbacks, cache specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import FSDP_TP_RULES, ShardingConfig
from repro.launch.sharding import batch_pspecs, cache_pspecs, param_pspec, param_pspecs
from repro.models import get_smoke_config, init_caches


@pytest.fixture(scope="module")
def mesh():
    # single real device is fine: AbstractMesh-like construction not needed
    # for spec logic; use a 1-device mesh shaped (1,1) with the right names.
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


class FakeMesh:
    """Spec-level mesh stand-in with production extents."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_pspec_divisible():
    rules = ShardingConfig().lookup()
    spec = param_pspec(FakeMesh(), rules, ("embed", "heads", None), (4096, 32, 128))
    assert spec == P(None, "model", None)


def test_param_pspec_fallback_on_indivisible():
    rules = ShardingConfig().lookup()
    # 15 heads don't divide 16 -> replicate
    spec = param_pspec(FakeMesh(), rules, ("embed", "heads", None), (960, 15, 64))
    assert spec == P(None, None, None)


def test_no_double_axis_use():
    rules = dict(FSDP_TP_RULES)
    # vocab->model and embed->data: both shardable, distinct axes
    spec = param_pspec(FakeMesh(), rules, ("vocab", "embed"), (256000, 4096))
    assert spec == P("model", ("pod", "data")) or spec == P("model", "data")


def test_fsdp_rules_shard_embed_over_data():
    rules = dict(FSDP_TP_RULES)
    spec = param_pspec(FakeMesh(), rules, ("embed", "mlp"), (8192, 22016))
    flat = []
    for e in spec:
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert "data" in flat and "model" in flat


def test_cache_specs_kv_heads_vs_seq():
    caches = jax.eval_shape(
        lambda: init_caches(get_smoke_config("glm4-9b"), 32, 64))
    specs = cache_pspecs(FakeMesh(), caches, ("data",))
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in leaves)
    # batch=32 divisible by 16 -> sharded on dim after the stacked layer dim
    kspec = jax.tree_util.tree_flatten_with_path(
        specs)[0]
    found = False
    for path, spec in kspec:
        names = [getattr(p, "name", getattr(p, "key", None)) for p in path]
        if "k" in names:
            assert spec[0] is None          # stacked layer-group dim
            assert spec[1] == "data"        # batch
            found = True
    assert found


def test_batch_pspecs():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "odd": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    specs = batch_pspecs(FakeMesh(), batch, ("data",))
    assert specs["tokens"] == P("data", None)
    assert specs["odd"] == P(None, None)     # 7 not divisible by 16


def test_all_archs_get_valid_specs():
    """param_pspecs must succeed for every smoke config (structure parity
    between params and axes trees)."""
    from repro.models import init_model, list_architectures
    rules = ShardingConfig().lookup()
    for arch in list_architectures():
        cfg = get_smoke_config(arch)
        holder = {}

        def capture():
            p, a = init_model(jax.random.PRNGKey(0), cfg)
            holder["a"] = a
            return p

        pshape = jax.eval_shape(capture)
        specs = param_pspecs(FakeMesh(), rules, holder["a"], pshape)
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        n_params = len(jax.tree_util.tree_leaves(pshape))
        assert n_specs == n_params, arch
