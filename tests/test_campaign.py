"""Multi-iteration training campaigns through the recovery runtime.

Covers the PR-3 acceptance criteria:
  * determinism under a fixed seed (same campaign twice -> identical
    timelines and ledgers);
  * the persistent control plane's ledger equals the per-iteration engine
    delays, summed across the whole campaign;
  * payload conservation across an iteration boundary where a program
    replanned after iteration k is reused in k+1;
  * ``training_overhead(mode="event", iterations>=8)`` with one
    mid-campaign NIC failure derives recovery cost from the campaign
    ``RecoveryLedger`` and stays inside the paper's <1% envelope, while
    alpha-beta results are unchanged.
"""

import numpy as np
import pytest

from repro.core.comm_sim import H100_BF16_FLOPS, TrainJob, training_overhead
from repro.core.event_sim import simulate_program
from repro.core.failures import (
    link_flap,
    nic_down_at,
    single_nic_failure,
    slow_nic,
)
from repro.core.schedule import ring_program
from repro.core.topology import IB_NIC_BW, make_cluster
from repro.runtime import (
    ControlPlane,
    RecoveryState,
    TrainingCampaign,
    at_chunk,
    at_iteration,
    campaign_clean_nic_down,
    campaign_flap_storm,
    campaign_mid_replan,
    parse_training_campaign,
    run_campaign,
    standard_parallel_streams,
    training_campaign_report,
)

NIC_BW = 25e9
PAYLOAD = 20e6


@pytest.fixture(scope="module")
def cluster():
    return make_cluster(4, 4, nic_bandwidth=NIC_BW)


@pytest.fixture(scope="module")
def t_h(cluster):
    return simulate_program(ring_program(list(range(4)), 4), PAYLOAD,
                            cluster=cluster).completion_time


def _data(n, size=64, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=size) for _ in range(n)]


def _mixed_campaign(t_h, iterations=6):
    return TrainingCampaign(
        "mixed", iterations,
        (at_iteration(1, nic_down_at(1, 0, 0.4 * t_h)),
         at_iteration(2, link_flap(2, 1, 0.2 * t_h, 0.05 * t_h)),
         at_iteration(4, slow_nic(0, 1, 0.1 * t_h, lost_fraction=0.3))))


# ---------------------------------------------------------------------------
# campaign semantics
# ---------------------------------------------------------------------------

def test_campaign_determinism(cluster, t_h):
    """Same campaign, same seed-free inputs -> bit-identical timelines."""
    a = run_campaign(_mixed_campaign(t_h), cluster, PAYLOAD, healthy_time=t_h)
    b = run_campaign(_mixed_campaign(t_h), cluster, PAYLOAD, healthy_time=t_h)
    assert [it.completion_time for it in a.iterations] == \
        [it.completion_time for it in b.iterations]
    assert a.total_time == b.total_time
    assert a.recovery_cost == b.recovery_cost
    assert a.transitions == b.transitions
    assert [(e.t_start, e.total) for e in a.ledger.entries] == \
        [(e.t_start, e.total) for e in b.ledger.entries]


def test_ledger_equals_engine_delays_across_iterations(cluster, t_h):
    """Every derived repair delay the engines applied, campaign-wide, must
    equal the corresponding hard-failure pipeline's ledger latency — the
    recovery cost is derived once, in one persistent control plane."""
    rep = run_campaign(_mixed_campaign(t_h), cluster, PAYLOAD, healthy_time=t_h)
    derived = [ev for it in rep.iterations
               for ev in it.report.repair_events if ev.derived]
    hard = [e for e in rep.ledger.entries
            if e.failure is not None and e.failure.severity >= 1.0]
    assert len(derived) == len(hard) >= 2        # NIC down + flap
    for ev, e in zip(derived, hard):
        assert ev.delay == pytest.approx(e.hot_repair_latency)
    assert rep.recovery_cost == pytest.approx(
        sum(e.total for e in rep.ledger.entries))


def test_state_carries_over_iterations(cluster, t_h):
    """A hard failure in iteration k degrades every later iteration: the
    capacity loss, the control plane's failure state, and the boundary
    replan all persist instead of being rebuilt per collective."""
    rep = run_campaign(campaign_clean_nic_down(t_h, iterations=6),
                       cluster, PAYLOAD, healthy_time=t_h)
    its = rep.iterations
    # before the failure: healthy ring at the healthy time
    assert its[0].completion_time == pytest.approx(t_h)
    assert not its[0].state_after.failed_nics
    # after: the NIC stays failed and every later sync runs degraded
    for it in its[4:]:
        assert it.state_after.failed_nics == {(1, 0)}
        assert it.completion_time > t_h
        assert it.program_source == "replanned"   # boundary re-selection
    # the failing iteration itself paid the (ledger-derived) repair window
    assert its[3].completion_time > its[4].completion_time
    assert rep.final_state is RecoveryState.REPLANNED
    # one pipeline run + one boundary replan, nothing rebuilt per iteration
    assert len(rep.ledger.entries) == 2


def test_flap_storm_across_iterations_replans(cluster, t_h):
    """Flaps spread one-per-iteration only cross the replan threshold
    because the flap window spans gradient syncs; the adapted program then
    sticks while the NIC remains a known flapper.

    Repeat recoveries are only *confirmed* at the NIC's next scheduled
    re-probe tick, so the probe cadence is rescaled to the collective's
    timescale — at the default ~1 s base the ticks would land far beyond
    this sub-millisecond campaign and the NIC would stay administratively
    down (terminal REPLANNED instead of HEALTHY)."""
    cp = ControlPlane(cluster, payload_bytes=PAYLOAD, reprobe_base=0.5 * t_h)
    rep = run_campaign(campaign_flap_storm(t_h, iterations=6), cluster,
                       PAYLOAD, healthy_time=t_h, control_plane=cp)
    assert any("replan" in e.stages for e in rep.ledger.entries)
    assert any(it.program_source == "replanned" for it in rep.iterations)
    # every flap recovered and was re-probed -> campaign ends healthy
    assert rep.final_state is RecoveryState.HEALTHY
    assert not rep.iterations[-1].state_after.failed_nics


def test_unconfirmed_recovery_defers_to_probe_tick(cluster, t_h):
    """The default (unscaled) cadence on the same storm: the second and
    later flap recoveries cannot be confirmed inside the campaign, so the
    failure state persists to the end — the regression the rescaled test
    above guards from the other side."""
    rep = run_campaign(campaign_flap_storm(t_h, iterations=6), cluster,
                       PAYLOAD, healthy_time=t_h)
    assert rep.final_state is RecoveryState.REPLANNED
    assert rep.iterations[-1].state_after.failed_nics


def test_mid_collective_replan_carries_across_boundary(cluster):
    """Satellite of the chunk-map replan (PR 4): a flap storm inside one
    gradient sync swaps the program *mid-collective* with real payloads in
    flight; the residual resumes chunk-exactly, the re-selected program is
    reused from iteration k+1, and every iteration's AllReduce stays exact.
    Needs a payload whose collective outlives the ~1.7 ms replan broadcast
    latency, hence the larger-than-module payload here."""
    payload = 100e6
    t_big = simulate_program(ring_program(list(range(4)), 4), payload,
                             cluster=cluster).completion_time
    data = _data(4)
    want = np.sum(np.stack(data), axis=0)
    cp = ControlPlane(cluster, payload_bytes=payload,
                      reprobe_base=0.5 * t_big)
    rep = run_campaign(campaign_mid_replan(t_big, iterations=4), cluster,
                       payload, healthy_time=t_big, rank_data=data,
                       control_plane=cp)
    mid = rep.iterations[1]
    assert mid.report.replans >= 1                # swapped while in flight
    assert mid.report.replan_events
    for ev in mid.report.replan_events:
        assert 0.0 < ev.residual_fraction <= 1.0
        assert ev.residual_bytes == pytest.approx(
            ev.rereduce_bytes + ev.deliver_bytes)
    # the replanned program carries into the next iteration from a clean
    # start, and payloads are conserved on both sides of the boundary
    assert rep.iterations[2].program_source == "replanned"
    for it in rep.iterations:
        for r in it.report.rank_data:
            np.testing.assert_allclose(r, want, atol=1e-9)
    # the ledger recorded the mid-collective pipelines' residual view
    replans = [e for e in rep.ledger.entries if "replan" in e.stages]
    assert replans and all(0.0 <= e.residual_fraction <= 1.0
                           for e in replans)


def test_payload_conservation_across_replan_boundary(cluster, t_h):
    """Iteration k's persistent failure replans at the boundary; iteration
    k+1 reuses that program from a clean start — so real payloads stay
    conserved on BOTH sides of the boundary."""
    data = _data(4)
    want = np.sum(np.stack(data), axis=0)
    rep = run_campaign(
        campaign_clean_nic_down(t_h, iterations=4, fail_iteration=1),
        cluster, PAYLOAD, healthy_time=t_h, rank_data=data)
    assert rep.iterations[2].program_source == "replanned"
    for it in rep.iterations:
        assert it.report.rank_data is not None
        for r in it.report.rank_data:
            np.testing.assert_allclose(r, want, rtol=1e-12)


def test_campaign_global_ledger_times(cluster, t_h):
    """Ledger entries and state transitions are stamped in campaign-global
    virtual time, monotonically, even though each iteration's engine runs
    its own t=0 clock."""
    rep = run_campaign(_mixed_campaign(t_h), cluster, PAYLOAD, healthy_time=t_h)
    times = [t for t, _ in rep.transitions]
    assert times == sorted(times)
    starts = [e.t_start for e in rep.ledger.entries]
    assert starts == sorted(starts)
    # the NIC-down pipeline ran during iteration 1, in global time
    hard = next(e for e in rep.ledger.entries
                if e.failure is not None and e.failure.severity >= 1.0)
    assert rep.iterations[1].t_start < hard.t_start < rep.iterations[2].t_start


def test_iteration_indexed_placement_validation(t_h):
    with pytest.raises(ValueError):
        TrainingCampaign("bad", 2, (at_iteration(5, nic_down_at(0, 0, 0.0)),))
    with pytest.raises(ValueError):
        at_chunk(t_h, chunk=4, num_chunks=4)
    # chunk placement lands strictly inside the collective
    assert 0.0 < at_chunk(t_h, 0, 4) < at_chunk(t_h, 3, 4) < t_h


def test_parse_training_campaign_roundtrip(t_h):
    tc = parse_training_campaign(
        "mid", "nic_down node=1 rail=0 iter=3 at=0.4; "
               "flap node=2 rail=1 iter=5 at=0.2 down=0.05",
        iterations=8, t_scale=t_h)
    assert tc.iterations == 8
    assert [k for k, _ in tc.events] == [3, 5]
    assert tc.failures_for(3)[0].at_time == pytest.approx(0.4 * t_h)
    with pytest.raises(ValueError):
        parse_training_campaign("bad", "nic_down node=0 rail=0 iter=9 at=0",
                                iterations=4)
    # iter= is rejected by the single-collective parser — any value,
    # including the default-looking iter=0
    from repro.runtime import parse_campaign
    with pytest.raises(ValueError):
        parse_campaign("bad", "nic_down node=0 rail=0 iter=1 at=0")
    with pytest.raises(ValueError):
        parse_campaign("bad", "nic_down node=0 rail=0 iter=0 at=0")


def test_flap_recovery_keeps_physical_time_across_boundary_replan(cluster, t_h):
    """A flap whose recovery lands in a later iteration must come back up
    at its physical campaign-global time even when a boundary replan
    advances the campaign clock in between (the carry rebasing accounts
    for the boundary cost)."""
    down_local = 10.0 * t_h                      # spans the iteration boundary
    tc = TrainingCampaign(
        "span", 8,
        (at_iteration(1, nic_down_at(1, 0, 0.37 * t_h)),   # forces boundary replan
         at_iteration(1, link_flap(2, 1, 0.43 * t_h, down_local))))
    rep = run_campaign(tc, cluster, PAYLOAD, healthy_time=t_h)
    # the boundary after iteration 1 charged a replan (nonzero clock advance)
    assert rep.iterations[1].boundary_cost > 0.0
    # the flap did not recover inside iteration 1
    flap_global = rep.iterations[1].t_start + 0.43 * t_h + down_local
    assert rep.iterations[1].t_start + rep.iterations[1].completion_time \
        < flap_global
    # the control plane observed the recovery at the physical global time
    reprobes = [e for e in rep.control_plane.detector.log
                if e.kind == "reprobe"]
    assert reprobes
    assert reprobes[-1].time == pytest.approx(flap_global, rel=1e-9)


# ---------------------------------------------------------------------------
# acceptance: concurrent TP/PP/DP streams through the campaign runner
# ---------------------------------------------------------------------------

def test_parallel_campaign_three_streams_mid_iteration_nic_down(cluster, t_h):
    """The multi-stream acceptance path: a TP+PP+DP campaign with a
    mid-iteration NIC-down runs end to end through one persistent
    ControlPlane — every iteration co-schedules all three streams, all
    three streams' payloads stay exact on both sides of the failure, the
    ledger's rebalance entry carries the cross-stream re-pricing factor
    (installed on the node, so TP/PP paid it too), and the replanned
    program carries into later iterations scoped to the DP stream."""
    specs = standard_parallel_streams(PAYLOAD)
    data = _data(4)
    want = np.sum(np.stack(data), axis=0)
    rep = run_campaign(
        campaign_clean_nic_down(t_h, iterations=4, fail_iteration=1),
        cluster, PAYLOAD, healthy_time=t_h, rank_data=data, streams=specs)

    for it in rep.iterations:
        assert set(it.report.streams) == {"dp", "tp", "pp"}
        for r in it.report.streams["dp"].rank_data:
            np.testing.assert_allclose(r, want, atol=1e-9)
        for r in it.report.streams["tp"].rank_data:
            np.testing.assert_allclose(r, want, atol=1e-9)
        for r in it.report.streams["pp"].rank_data:   # chain: root's buffer
            np.testing.assert_allclose(r, data[0], atol=1e-12)
        # report scalars are exactly the per-stream sums (bench rows built
        # on them stay stable as streams are added)
        assert it.report.retransmitted_bytes == pytest.approx(
            sum(sr.retransmitted_bytes
                for sr in it.report.streams.values()))
        assert it.report.failovers == sum(
            sr.failovers for sr in it.report.streams.values())

    # the NIC-down rolled back in-flight transfers of the co-runners too
    mid = rep.iterations[1]
    assert mid.report.failovers >= 1
    # rebalance entry: the detour-efficiency re-pricing every stream pays
    hard = next(e for e in rep.ledger.entries if e.failure is not None)
    assert "rebalance" in hard.stages
    assert hard.balance_efficiency < 1.0
    # the boundary re-selection carries the DP program into iteration 2;
    # co-runners are rebuilt fresh, unreplanned
    assert rep.iterations[2].program_source == "replanned"
    assert all(it.report.streams["tp"].replans == 0
               and it.report.streams["pp"].replans == 0
               for it in rep.iterations)
    assert rep.final_state is RecoveryState.REPLANNED
    # degraded iterations run slower than the pre-failure contended one
    assert rep.iterations[2].completion_time > \
        rep.iterations[0].completion_time


def test_campaign_streams_dimension_on_the_dsl(cluster, t_h):
    """TrainingCampaign carries its streams= dimension: a campaign built
    with streams runs them without run_campaign needing the argument, and
    the parser threads the textual form through."""
    tc = parse_training_campaign(
        "contended", "nic_down node=1 rail=0 iter=1 at=0.4",
        iterations=3, t_scale=t_h,
        streams="tp kind=allreduce frac=0.5; pp kind=p2p frac=0.125",
        stream_payload_scale=PAYLOAD)
    assert [s.name for s in tc.streams] == ["tp", "pp"]
    assert tc.streams[0].payload_bytes == pytest.approx(0.5 * PAYLOAD)
    rep = run_campaign(tc, cluster, PAYLOAD, healthy_time=t_h)
    assert all(set(it.report.streams) == {"dp", "tp", "pp"}
               for it in rep.iterations)
    # contention is real: the first (healthy) iteration is slower than the
    # stream-free healthy collective
    assert rep.iterations[0].completion_time > t_h
    with pytest.raises(ValueError):       # duplicate stream names rejected
        TrainingCampaign("dup", 2, (), streams=(
            tc.streams[0], tc.streams[0]))


# ---------------------------------------------------------------------------
# acceptance: training_overhead(mode="event") over a campaign
# ---------------------------------------------------------------------------

def test_training_overhead_event_campaign_paper_envelope():
    """>=8-iteration campaign, one mid-campaign NIC failure: overhead is
    ledger-derived and inside the paper's <1% envelope; the alpha-beta
    closed form is untouched."""
    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    job = TrainJob(params=2.7e9, dp=16, tp=1, pp=1, global_batch=256,
                   seq_len=2048, layers=32, hidden=2560,
                   flops_per_chip=H100_BF16_FLOPS, nic_stripe=3)
    fails = single_nic_failure(0, 0)

    ov = training_overhead(job, cluster, fails, mode="event", iterations=8)
    assert 0.0 < ov < 0.01

    res = training_campaign_report(job, cluster, fails, iterations=8)
    assert res.overhead == pytest.approx(ov)
    # recovery cost comes from the persistent control plane's ledger
    assert res.recovery_cost == pytest.approx(
        res.campaign.ledger.total_latency())
    assert res.recovery_cost > 0
    derived = [ev for it in res.campaign.iterations
               for ev in it.report.repair_events]
    assert derived and all(ev.derived for ev in derived)
    # iterations after the failure run degraded but recovered syncs
    assert max(res.dp_comm_times) > min(res.dp_comm_times)

    # alpha-beta mode unchanged: same value as the direct steady-state ratio
    from repro.core.comm_sim import FailureState, iteration_time
    healthy = iteration_time(job, cluster, FailureState(), strategy="ring")
    st = FailureState()
    for f in fails:
        st.apply(f)
    failed = iteration_time(job, cluster, st, strategy="r2ccl")
    assert training_overhead(job, cluster, fails, strategy="r2ccl") == \
        pytest.approx(failed.total / healthy.total - 1.0)


def test_multi_iteration_requires_event_mode():
    cluster = make_cluster(2, 4)
    job = TrainJob(params=1e9, dp=8)
    with pytest.raises(ValueError):
        training_overhead(job, cluster, single_nic_failure(0, 0),
                          mode="alpha_beta", iterations=4)
