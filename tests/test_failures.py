"""Failure model: severity domain validation (regression).

Severities outside (0, 1] used to be silently accepted and then
misinterpreted by the slow-NIC bandwidth spectrum (a severity of 1.5 would
subtract more than the rail's bandwidth; 0 or negative meant "failure that
removes nothing").  Construction now rejects them.
"""

import pytest

from repro.core.failures import Failure, FailureType, nic_down_at, slow_nic


def test_severity_one_and_fractional_accepted():
    assert Failure(FailureType.NIC_HARDWARE, 0, 0).severity == 1.0
    f = Failure(FailureType.SLOW_NIC, 1, 2, escalates=False, severity=0.25)
    assert f.severity == 0.25
    assert nic_down_at(0, 0, 1.0).severity == 1.0
    assert slow_nic(0, 0, 0.0, lost_fraction=0.5).severity == 0.5


@pytest.mark.parametrize("bad", [0.0, -0.5, 1.0001, 2.0, float("inf")])
def test_severity_out_of_domain_rejected(bad):
    with pytest.raises(ValueError, match="severity"):
        Failure(FailureType.SLOW_NIC, 0, 0, escalates=False, severity=bad)


def test_nan_severity_rejected():
    with pytest.raises(ValueError, match="severity"):
        Failure(FailureType.NIC_HARDWARE, 0, 0, severity=float("nan"))
