"""Paper Fig. 15: AllReduce bus bandwidth vs message size under a single
NIC failure (2 nodes x 8 GPUs x 8x400Gb NICs), four configurations:
vanilla (no failure), HotRepair, Balance, R2CCL-AllReduce.

Times come from the alpha-beta model over the *actual* collective schedules
(rounds x alpha + traffic / rate), with strategy rates derived from the
balance/partition machinery — the same code the data plane uses.
"""

from __future__ import annotations

import numpy as np

from repro.core.comm_sim import DETOUR_EFFICIENCY, strategy_rate
from repro.core.partition import ring_coeff
from repro.core.topology import DEFAULT_ALPHA, IB_NIC_BW

from .common import Reporter

N_NODES, G = 2, 8
NODE_BW = 8 * IB_NIC_BW                  # 400 GB/s per node
X = 1.0 / 8.0                            # one NIC lost


def allreduce_time(size: float, rate_frac: float) -> float:
    """Ring AllReduce: 2(ng-1) latency rounds + traffic at the rate."""
    ng = N_NODES * G
    rounds = 2 * (ng - 1)
    traffic = ring_coeff(ng) * size
    return rounds * DEFAULT_ALPHA + traffic / (NODE_BW * rate_frac)


def busbw(size: float, t: float) -> float:
    """NCCL-tests busbw convention: algbw * 2(n-1)/n."""
    ng = N_NODES * G
    return (size / t) * 2 * (ng - 1) / ng


def run() -> None:
    r = Reporter("allreduce_busbw_fig15")
    sizes = [2 ** e for e in range(3, 35)]          # 8B .. 16GB
    curves: dict[str, list[float]] = {}
    for name, rate in [
        ("no_failure", 1.0),
        ("hot_repair", strategy_rate("hot_repair", NODE_BW, X, n_nodes=N_NODES, g=G)),
        ("balance", strategy_rate("balance", NODE_BW, X, n_nodes=N_NODES, g=G)),
        ("r2ccl_allreduce", strategy_rate("r2ccl", NODE_BW, X, n_nodes=N_NODES, g=G)),
    ]:
        curves[name] = [busbw(s, allreduce_time(s, rate)) for s in sizes]
    r.data["sizes"] = sizes
    r.data["curves"] = curves

    peak = max(curves["no_failure"])
    r.row("peak_busbw_no_failure_GBs", peak / 1e9, "paper: 369 GB/s")
    big = -1                                        # largest message
    for name in ("hot_repair", "balance", "r2ccl_allreduce"):
        frac = curves[name][big] / curves["no_failure"][big]
        r.row(f"{name}_large_msg_frac", frac,
              {"hot_repair": "paper: ~0.54 (46% loss)",
               "balance": "paper: 0.83",
               "r2ccl_allreduce": "paper: 0.93"}[name])
    # small-message regime (<32MB): Balance beats the decomposition
    small = sizes.index(2 ** 23)                    # 8MB
    r.row("balance_small_msg_frac",
          curves["balance"][small] / curves["no_failure"][small],
          "paper: 0.92 for <32MB")
    r.save()


if __name__ == "__main__":
    run()
