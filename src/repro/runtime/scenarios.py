"""Timed multi-failure campaign DSL for the recovery runtime.

A :class:`Scenario` is a named list of timed :class:`core.failures.Failure`
events to inject into one co-simulated collective.  Campaign builders take
the healthy collective time ``t_h`` so injection points land mid-collective
regardless of payload/cluster scale, and :func:`parse_campaign` accepts a
compact textual spec for ad-hoc campaigns from benchmark CLIs and tests::

    nic_down node=1 rail=0 at=0.4; flap node=2 rail=1 at=0.2 down=0.05

Event kinds: ``nic_down`` (hard NIC death), ``flap`` (down then recovers
after ``down``), ``flaps`` (a storm: ``count`` flaps ``period`` apart),
``slow`` (bandwidth spectrum point, ``lost`` fraction).  All times are
fractions of ``t_scale`` (pass the healthy time to express campaign timing
relative to the collective).

A :class:`TrainingCampaign` lifts the same events to *multi-iteration*
training runs (the paper's Figs. 7-10 measurement unit): each failure is
placed at (iteration ``k``, iteration-local time), optionally at chunk
granularity via :func:`at_chunk`, and the campaign runner in
:mod:`runtime.campaign` executes the gradient syncs back-to-back through
one persistent control plane.  The text spec grows an ``iter=`` field::

    nic_down node=1 rail=0 iter=3 at=0.4; flap node=2 rail=1 iter=5 at=0.2 down=0.05

Real training parallelism runs TP/PP/DP collectives *concurrently* over
the same NICs, so campaigns carry a **streams** dimension: a
:class:`StreamSpec` names one co-running collective stream (kind, payload,
priority, start offset) next to the control-plane-managed gradient sync.
:func:`standard_parallel_streams` builds the default TP-allreduce +
PP-handoff pair, :func:`parse_streams` accepts a compact textual form::

    tp kind=allreduce frac=0.5 prio=1; pp kind=p2p frac=0.125 start=0.1
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.failures import (
    Failure,
    flap_sequence,
    link_flap,
    nic_down_at,
    silenced as silenced_failures,
    slow_nic,
)
from repro.core.schedule import (
    CollectiveProgram,
    Segment,
    build_ring_broadcast,
    ring_program,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named failure-injection campaign."""

    name: str
    failures: tuple[Failure, ...]
    note: str = ""

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "failures",
            tuple(sorted(self.failures, key=lambda f: f.at_time)))

    def silenced(self) -> "Scenario":
        """The same campaign with every failure's oracle notification
        stripped: the engine still applies the physics, but only a
        telemetry-driven detector can tell the control plane (the
        oracle-free mode of :func:`runtime.cosim.run_scenario`)."""
        return Scenario(f"{self.name}_silent",
                        tuple(silenced_failures(self.failures)),
                        note=self.note)


#: name the runtime gives the control-plane-managed gradient-sync stream
#: in a multi-stream co-simulation; reserved — co-runner specs may not
#: claim it
MANAGED_STREAM = "dp"


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One co-running collective stream of a training iteration.

    ``kind`` selects the collective shape: ``"allreduce"`` (a ring
    AllReduce over all ranks — the TP activation sync or a second DP
    group) or ``"p2p"`` (a pipelined chain handoff from ``root`` — the PP
    activation send/recv, modeled as the chain broadcast whose result is
    the root's buffer at every rank).  ``payload_bytes`` is the stream's
    timing payload, ``priority`` its weight in the engine's weighted
    max-min fair bandwidth share, ``start_time`` its release offset into
    the iteration.  The control-plane-managed gradient sync is NOT a spec:
    the runtime builds it from its planned (or carried replanned) program
    and places it first — specs describe only the co-runners contending
    with it.
    """

    name: str
    kind: str
    payload_bytes: float
    priority: float = 1.0
    start_time: float = 0.0
    root: int = 0

    def __post_init__(self) -> None:
        if self.name == MANAGED_STREAM:
            raise ValueError(
                f"stream name {MANAGED_STREAM!r} is reserved for the "
                f"control-plane-managed gradient sync; co-runner specs "
                f"must use another name")
        if self.kind not in ("allreduce", "p2p"):
            raise ValueError(
                f"unknown stream kind {self.kind!r} "
                f"(expected 'allreduce' or 'p2p')")
        if self.payload_bytes <= 0:
            raise ValueError(
                f"stream {self.name!r} payload must be > 0, got "
                f"{self.payload_bytes!r}")
        if self.priority <= 0:
            raise ValueError(
                f"stream {self.name!r} priority must be > 0, got "
                f"{self.priority!r}")
        if self.start_time < 0:
            raise ValueError(
                f"stream {self.name!r} start_time must be >= 0, got "
                f"{self.start_time!r}")


def build_stream_program(spec: StreamSpec, n: int) -> CollectiveProgram:
    """The :class:`CollectiveProgram` a co-running stream executes on an
    ``n``-rank cluster (ranks are nodes, as everywhere in the event
    engine)."""
    order = list(range(n))
    if spec.kind == "allreduce":
        return ring_program(order, n)
    root = spec.root % n
    return CollectiveProgram(
        f"pp_chain[{n}]", n,
        [Segment(1.0, build_ring_broadcast(order, n, root=root))])


def standard_parallel_streams(
    payload_bytes: float,
    *,
    tp_frac: float = 0.5,
    pp_frac: float = 0.125,
    tp_priority: float = 1.0,
    pp_priority: float = 1.0,
) -> tuple[StreamSpec, ...]:
    """The default TP+PP co-runner pair next to a DP gradient sync of
    ``payload_bytes``: a TP activation AllReduce at ``tp_frac`` of the DP
    payload and a PP activation chain handoff at ``pp_frac`` — the 3-stream
    (TP+PP+DP) shape the paper's training figures run under."""
    return (
        StreamSpec("tp", "allreduce", tp_frac * payload_bytes,
                   priority=tp_priority),
        StreamSpec("pp", "p2p", pp_frac * payload_bytes,
                   priority=pp_priority),
    )


def parse_streams(
    spec: str, *, payload_scale: float = 1.0, t_scale: float = 1.0,
) -> tuple[StreamSpec, ...]:
    """Parse the textual streams dimension: ';'-separated
    ``name k=v k=v ...`` entries.  Fields: ``kind`` (allreduce|p2p, default
    allreduce), ``frac`` (payload as a fraction of ``payload_scale``,
    default 1.0), ``prio``, ``start`` (multiplied by ``t_scale``),
    ``root``::

        parse_streams("tp kind=allreduce frac=0.5; "
                      "pp kind=p2p frac=0.125 start=0.1",
                      payload_scale=dp_payload, t_scale=t_h)
    """
    out: list[StreamSpec] = []
    for name, kv, raw in _split_entries(spec, "stream"):
        out.append(StreamSpec(
            name,
            kv.pop("kind", "allreduce"),
            float(kv.pop("frac", 1.0)) * payload_scale,
            priority=float(kv.pop("prio", 1.0)),
            start_time=float(kv.pop("start", 0.0)) * t_scale,
            root=int(kv.pop("root", 0)),
        ))
        if kv:
            raise ValueError(
                f"unexpected fields {sorted(kv)} in stream {raw!r}")
    names = [s.name for s in out]
    if len(set(names)) != len(names):
        raise ValueError(f"stream names must be unique: {names}")
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class TrainingCampaign:
    """A multi-iteration failure campaign: N gradient syncs back-to-back.

    ``events`` are (iteration, failure) pairs; each failure's ``at_time`` is
    *iteration-local* (seconds into that iteration's collective, typically
    expressed as a fraction of the healthy collective time ``t_h``).  The
    campaign runner (:func:`runtime.campaign.run_campaign`) drives one
    persistent control plane across all iterations, so flap counts,
    capacity factors, and replanned programs carry over.  ``streams`` are
    the co-running parallelism collectives (TP/PP traffic) contending with
    every iteration's gradient sync on the shared NICs."""

    name: str
    iterations: int
    events: tuple[tuple[int, Failure], ...]
    note: str = ""
    streams: tuple[StreamSpec, ...] = ()

    def __post_init__(self) -> None:
        if self.iterations < 1:
            raise ValueError(f"need >= 1 iteration, got {self.iterations}")
        for k, f in self.events:
            if not 0 <= k < self.iterations:
                raise ValueError(
                    f"event at iteration {k} outside campaign of "
                    f"{self.iterations} iterations: {f}")
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda kf: (kf[0], kf[1].at_time))))
        object.__setattr__(self, "streams", tuple(self.streams))
        names = [s.name for s in self.streams]
        if len(set(names)) != len(names):
            raise ValueError(f"stream names must be unique: {names}")

    def failures_for(self, iteration: int) -> tuple[Failure, ...]:
        """The failures striking during ``iteration``, in injection order."""
        return tuple(f for k, f in self.events if k == iteration)


def at_iteration(iteration: int, failure: Failure) -> tuple[int, Failure]:
    """Place ``failure`` (iteration-local ``at_time``) at gradient sync
    ``iteration`` of a :class:`TrainingCampaign`."""
    return (iteration, failure)


def at_chunk(t_h: float, chunk: int, num_chunks: int) -> float:
    """Iteration-local injection time at which chunk ``chunk`` of
    ``num_chunks`` is in flight — chunk-granularity failure placement
    ("fail at iteration k, chunk c") assuming chunks stream uniformly over
    the healthy collective time ``t_h``."""
    if not 0 <= chunk < num_chunks:
        raise ValueError(f"chunk {chunk} outside 0..{num_chunks - 1}")
    return t_h * (chunk + 0.5) / num_chunks


# ---------------------------------------------------------------------------
# campaign builders
# ---------------------------------------------------------------------------

def clean_nic_down(t_h: float, *, node: int = 1, rail: int = 0,
                   frac: float = 0.4) -> Scenario:
    """The paper's headline case: one NIC dies mid-collective, hot repair
    lands it on the backup NIC within the low-millisecond budget."""
    return Scenario(
        "clean_nic_down",
        (nic_down_at(node, rail, frac * t_h),),
        note="single hard NIC death mid-collective (conformance target)")


def correlated_nic_down(t_h: float, *, node: int = 1, rails: tuple[int, ...] = (0, 1),
                        frac: float = 0.35, stagger: float = 0.01) -> Scenario:
    """Several NICs of one node die almost together (shared PCIe riser /
    firmware bug): each loss re-runs the pipeline against a shrinking
    backup chain."""
    fails = tuple(
        nic_down_at(node, r, (frac + i * stagger) * t_h)
        for i, r in enumerate(rails))
    return Scenario("correlated_nic_down", fails,
                    note=f"{len(rails)} rails of node {node} die {stagger:.0%} apart")


def flap_storm(t_h: float, *, node: int = 1, rail: int = 0, count: int = 4,
               start_frac: float = 0.15, period_frac: float = 0.18,
               down_frac: float = 0.06) -> Scenario:
    """Repeated link flaps of one NIC; past the flap threshold the control
    plane stops re-migrating and re-plans the algorithm instead."""
    fails = tuple(flap_sequence(
        node, rail, start=start_frac * t_h, period=period_frac * t_h,
        down_for=down_frac * t_h, count=count))
    return Scenario("flap_storm", fails,
                    note=f"{count} flaps, replan after the threshold")


def slow_nic_degradation(t_h: float, *, nodes: tuple[int, ...] = (0, 1),
                         base_lost: float = 0.2, step: float = 0.15,
                         frac: float = 0.1) -> Scenario:
    """A bandwidth spectrum: NICs on several nodes degrade (no transport
    error) — caught by monitoring, handled by rebalance alone."""
    fails = tuple(
        slow_nic(nd, 0, frac * t_h, lost_fraction=min(0.9, base_lost + i * step))
        for i, nd in enumerate(nodes))
    return Scenario("slow_nic", fails,
                    note="fractional degradation, monitor-detected")


def failure_during_recovery(t_h: float, *, first_node: int = 1,
                            second_node: int = 2, rail: int = 0,
                            frac: float = 0.3, gap: float = 0.7e-3) -> Scenario:
    """A second hard failure strikes while the first one's hot repair is
    still in flight (rolled-back transfers not yet restarted) — the pipeline
    must compose, not serialize."""
    t1 = frac * t_h
    return Scenario(
        "failure_during_recovery",
        (nic_down_at(first_node, rail, t1),
         nic_down_at(second_node, rail, t1 + gap)),
        note=f"second failure {gap * 1e3:.1f} ms into the first repair window")


def standard_campaigns(t_h: float, *, num_nodes: int, rails: int) -> list[Scenario]:
    """The benchmark/acceptance campaign set, scaled to the cluster shape."""
    second = 2 if num_nodes > 2 else 0     # distinct from the first node
    campaigns = [
        clean_nic_down(t_h, node=min(1, num_nodes - 1)),
        flap_storm(t_h, node=min(1, num_nodes - 1)),
        slow_nic_degradation(t_h, nodes=tuple(range(min(2, num_nodes)))),
        failure_during_recovery(t_h, first_node=min(1, num_nodes - 1),
                                second_node=second),
    ]
    if rails >= 2:
        campaigns.insert(1, correlated_nic_down(
            t_h, node=min(1, num_nodes - 1), rails=(0, 1)))
    return campaigns


# ---------------------------------------------------------------------------
# training-campaign builders (multi-iteration)
# ---------------------------------------------------------------------------

def campaign_clean_nic_down(
    t_h: float, *, iterations: int = 8, fail_iteration: int | None = None,
    node: int = 1, rail: int = 0, frac: float = 0.4,
) -> TrainingCampaign:
    """The acceptance scenario: one NIC dies mid-collective at a
    mid-campaign gradient sync and stays dead; every later iteration runs
    on the control plane's carried-over state."""
    k = iterations // 2 if fail_iteration is None else fail_iteration
    return TrainingCampaign(
        "campaign_clean_nic_down", iterations,
        (at_iteration(k, nic_down_at(node, rail, frac * t_h)),),
        note=f"NIC ({node},{rail}) down at iteration {k}, {frac:.0%} in")


def campaign_flap_storm(
    t_h: float, *, iterations: int = 6, node: int = 1, rail: int = 0,
    start_iteration: int = 1, count: int = 4, frac: float = 0.2,
    down_frac: float = 0.05,
) -> TrainingCampaign:
    """One flap per iteration for ``count`` consecutive iterations: the
    flap window spans gradient syncs, so the replan decision depends on the
    control plane persisting across them."""
    events = tuple(
        at_iteration(start_iteration + i,
                     link_flap(node, rail, frac * t_h, down_frac * t_h))
        for i in range(count))
    return TrainingCampaign(
        "campaign_flap_storm", iterations, events,
        note=f"{count} flaps of ({node},{rail}), one per iteration")


def campaign_slow_nic(
    t_h: float, *, iterations: int = 6, node: int = 0, rail: int = 0,
    fail_iteration: int = 2, lost: float = 0.3, frac: float = 0.1,
) -> TrainingCampaign:
    """Monitor-detected fractional degradation mid-campaign: no rollback,
    but the residual rate carries into every later iteration."""
    return TrainingCampaign(
        "campaign_slow_nic", iterations,
        (at_iteration(fail_iteration,
                      slow_nic(node, rail, frac * t_h, lost_fraction=lost)),),
        note=f"NIC ({node},{rail}) loses {lost:.0%} bw at iteration "
             f"{fail_iteration}")


def campaign_mid_replan(
    t_h: float, *, iterations: int = 4, fail_iteration: int = 1,
    node: int = 1, rail: int = 0, count: int = 4, start_frac: float = 0.15,
    period_frac: float = 0.18, down_frac: float = 0.05,
) -> TrainingCampaign:
    """``count`` flaps of one NIC inside a *single* gradient sync: the flap
    threshold is crossed mid-collective, so the control plane swaps the
    program while payload is in flight (the chunk-exact residual replan)
    and the re-selected program then carries across the iteration boundary
    into every later sync.  The replan broadcast is a fixed ~1.7 ms
    pipeline latency, so the collective must be long enough to still be in
    flight when it lands: use a payload whose healthy time ``t_h`` is at
    least a millisecond or so."""
    events = tuple(
        at_iteration(fail_iteration, f) for f in flap_sequence(
            node, rail, start=start_frac * t_h, period=period_frac * t_h,
            down_for=down_frac * t_h, count=count))
    return TrainingCampaign(
        "campaign_mid_replan", iterations, events,
        note=f"{count} flaps of ({node},{rail}) inside iteration "
             f"{fail_iteration} force a mid-collective replan")


def standard_training_campaigns(
    t_h: float, *, iterations: int, num_nodes: int,
) -> list[TrainingCampaign]:
    """The multi-iteration benchmark set (paper Figs. 7-10 sweep), scaled
    to the cluster shape."""
    node = min(1, num_nodes - 1)
    return [
        campaign_clean_nic_down(t_h, iterations=iterations, node=node),
        campaign_flap_storm(
            t_h, iterations=iterations, node=node,
            start_iteration=min(1, iterations - 1),
            count=min(4, iterations - 1) or 1),
        campaign_slow_nic(t_h, iterations=iterations,
                          fail_iteration=min(2, iterations - 1)),
    ]


# ---------------------------------------------------------------------------
# textual campaign spec
# ---------------------------------------------------------------------------

_EVENT_KINDS = ("nic_down", "flap", "flaps", "slow")


def _split_entries(spec: str, noun: str):
    """Shared text-DSL tokenizer: ';'-separated ``head k=v k=v ...``
    entries.  Yields ``(head, kv, raw)`` with *string* values — callers
    convert per field (events are all-float, streams mix kinds)."""
    for raw in spec.split(";"):
        raw = raw.strip()
        if not raw:
            continue
        parts = raw.split()
        head, kv = parts[0], {}
        for tok in parts[1:]:
            if "=" not in tok:
                raise ValueError(f"malformed field {tok!r} in {noun} {raw!r}")
            k, v = tok.split("=", 1)
            kv[k] = v
        yield head, kv, raw


def _parse_events(
    spec: str, t_scale: float, *, allow_iter: bool,
) -> list[tuple[int, Failure]]:
    """Shared grammar: ';'-separated ``kind k=v ...`` events.  Returns
    (iteration, failure) pairs; ``iter=`` is only legal when ``allow_iter``
    (the single-collective :func:`parse_campaign` has no iterations)."""
    events: list[tuple[int, Failure]] = []
    for kind, raw_kv, raw in _split_entries(spec, "event"):
        if kind not in _EVENT_KINDS:
            raise ValueError(
                f"unknown event kind {kind!r} (expected one of {_EVENT_KINDS})")
        kv = {k: float(v) for k, v in raw_kv.items()}
        node, rail = int(kv.pop("node")), int(kv.pop("rail"))
        if "iter" in kv and not allow_iter:
            raise ValueError(
                f"iter= is only valid in a training-campaign spec "
                f"(parse_training_campaign): {raw!r}")
        it = int(kv.pop("iter", 0))
        at = kv.pop("at", 0.0) * t_scale
        # silent=1 strips the oracle notification from this event: the
        # engine applies the physics, a telemetry detector must infer it
        silent = bool(int(kv.pop("silent", 0)))
        new: list[tuple[int, Failure]] = []
        if kind == "nic_down":
            new.append((it, nic_down_at(node, rail, at)))
        elif kind == "flap":
            new.append((it, link_flap(node, rail, at,
                                      kv.pop("down") * t_scale)))
        elif kind == "flaps":
            new.extend((it, f) for f in flap_sequence(
                node, rail, start=at, period=kv.pop("period") * t_scale,
                down_for=kv.pop("down") * t_scale, count=int(kv.pop("count"))))
        elif kind == "slow":
            new.append((it, slow_nic(node, rail, at,
                                     lost_fraction=kv.pop("lost"))))
        if kv:
            raise ValueError(f"unexpected fields {sorted(kv)} in event {raw!r}")
        if silent:
            new = [(it_, f) for (it_, _), f in
                   zip(new, silenced_failures(f for _, f in new))]
        events.extend(new)
    return events


def parse_campaign(name: str, spec: str, *, t_scale: float = 1.0) -> Scenario:
    """Parse ``spec`` into a Scenario.

    ``spec`` is ';'-separated events, each ``kind k=v k=v ...``; time-like
    fields (``at``, ``down``, ``period``) are multiplied by ``t_scale``::

        parse_campaign("mix", "nic_down node=1 rail=0 at=0.4; "
                              "flaps node=2 rail=1 at=0.1 down=0.05 "
                              "period=0.2 count=3; "
                              "slow node=0 rail=0 at=0 lost=0.3", t_scale=t_h)
    """
    events = _parse_events(spec, t_scale, allow_iter=False)
    return Scenario(name, tuple(f for _, f in events), note=spec)


def parse_training_campaign(
    name: str, spec: str, *, iterations: int, t_scale: float = 1.0,
    streams: "str | Sequence[StreamSpec]" = (),
    stream_payload_scale: float = 1.0,
) -> TrainingCampaign:
    """Parse the same grammar into a :class:`TrainingCampaign`; each event
    takes an optional ``iter=k`` (default 0) placing it at gradient sync
    ``k``, with ``at`` still iteration-local.  ``streams`` adds the
    concurrent-parallelism dimension — either ready-made
    :class:`StreamSpec`\\ s or a :func:`parse_streams` string (``frac``
    scaled by ``stream_payload_scale``, ``start`` by ``t_scale``)::

        parse_training_campaign(
            "mid", "nic_down node=1 rail=0 iter=4 at=0.4",
            iterations=8, t_scale=t_h,
            streams="tp kind=allreduce frac=0.5; pp kind=p2p frac=0.125",
            stream_payload_scale=dp_payload)
    """
    events = _parse_events(spec, t_scale, allow_iter=True)
    if isinstance(streams, str):
        streams = parse_streams(streams, payload_scale=stream_payload_scale,
                                t_scale=t_scale)
    return TrainingCampaign(name, iterations, tuple(events), note=spec,
                            streams=tuple(streams))
