#!/usr/bin/env python
"""Nightly event-engine perf regression gate.

Replays the tiny-tier scale sweep (``benchmarks.bench_engine_perf.
scale_sweep``) and compares each row's events/sec against the committed
baseline in ``experiments/bench/BENCH_event_engine.json``.  Fails (exit 1)
when any sweep row regresses by more than ``REGRESSION_TOLERANCE`` —
wall-clock noise on shared CI runners stays well inside 30%, a lost
vectorized/incremental code path does not.

Rows present in the fresh sweep but missing from the committed JSON are
reported as NEW and do not fail the gate (they appear when the sweep
grows; regenerate the baseline with
``PYTHONPATH=src python -m benchmarks.run --only engine_perf``).

Absolute events/sec moves with host speed; the 30% window absorbs the
usual runner-to-runner spread, and ``ENGINE_PERF_TOLERANCE`` overrides it
(e.g. ``ENGINE_PERF_TOLERANCE=0.5``) for unusually slow hardware.

Usage: PYTHONPATH=src python scripts/check_engine_perf.py [baseline.json]
"""

from __future__ import annotations

import json
import os
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))
sys.path.insert(0, str(REPO / "src"))

REGRESSION_TOLERANCE = float(os.environ.get("ENGINE_PERF_TOLERANCE", 0.30))
DEFAULT_BASELINE = REPO / "experiments" / "bench" / "BENCH_event_engine.json"


def main(argv: list[str]) -> int:
    baseline_path = pathlib.Path(argv[1]) if len(argv) > 1 else \
        DEFAULT_BASELINE
    committed = json.loads(baseline_path.read_text())
    baseline = {name: value for name, value, _ in committed["rows"]
                if name.startswith("sweep_")
                and name.endswith("_events_per_sec")}
    if not baseline:
        print(f"ERROR: no sweep_*_events_per_sec rows in {baseline_path}; "
              "regenerate the bench JSON first")
        return 1

    from benchmarks.bench_engine_perf import scale_sweep

    failures = []
    print(f"{'row':<28} {'baseline':>12} {'fresh':>12} {'ratio':>7}")
    for row in scale_sweep(tiny=True):
        name = f"sweep_{row['kind']}{row['ranks']}_events_per_sec"
        fresh = row["events_per_sec"]
        base = baseline.get(name)
        if base is None:
            print(f"{name:<28} {'NEW':>12} {fresh:>12.0f}      -")
            continue
        ratio = fresh / base
        verdict = ""
        if fresh < (1.0 - REGRESSION_TOLERANCE) * base:
            failures.append((name, base, fresh))
            verdict = "  REGRESSION"
        print(f"{name:<28} {base:>12.0f} {fresh:>12.0f} {ratio:>6.2f}x"
              f"{verdict}")

    if failures:
        print(f"\nFAIL: {len(failures)} sweep row(s) regressed more than "
              f"{REGRESSION_TOLERANCE:.0%} vs {baseline_path}")
        return 1
    print(f"\nOK: all sweep rows within {REGRESSION_TOLERANCE:.0%} of the "
          "committed baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
