"""Section 6 / Algorithm 1: bridge-based logical re-ranking."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reranking import bridge_rerank, edge_capacity, is_valid_ring, ring_bottleneck
from repro.core.topology import make_cluster


def test_paper_example_rail_mismatch():
    """Adjacent nodes losing different rails: u lost rail 1, v lost rail 2.
    Their edge capacity collapses; a bridge with full connectivity fixes it."""
    full = frozenset(range(8))
    s_u = full - {1}
    s_v = full - {2}
    rails = [s_u, s_v, full, full, full, full]
    ring = [0, 1, 2, 3, 4, 5]
    before = ring_bottleneck(ring, rails)
    res = bridge_rerank(ring, rails)
    assert is_valid_ring(res.ring, ring)
    assert res.bottleneck_after >= before
    b_global = min(len(s) for s in rails)
    assert res.bottleneck_after >= b_global


@settings(max_examples=40, deadline=None)
@given(n=st.integers(3, 12), seed=st.integers(0, 200))
def test_rerank_invariants(n, seed):
    import random
    rng = random.Random(seed)
    rails = []
    for _ in range(n):
        lost = rng.sample(range(8), rng.randint(0, 3))
        rails.append(frozenset(range(8)) - frozenset(lost))
    ring = list(range(n))
    before = ring_bottleneck(ring, rails)
    res = bridge_rerank(ring, rails)
    # membership preserved, never worse
    assert is_valid_ring(res.ring, ring)
    assert res.bottleneck_after >= before
    assert res.bottleneck_before == before


def test_targeted_repair_preserves_most_edges():
    """Algorithm 1 moves bridges, it does not rebuild the whole ring."""
    full = frozenset(range(8))
    rails = [full - {1}, full - {2}] + [full] * 6
    ring = list(range(8))
    res = bridge_rerank(ring, rails)
    assert len(res.moved) <= 2


def test_cluster_rail_sets_feed_rerank():
    # 6 nodes: Algorithm 1 needs a bridge NOT adjacent to the broken edge,
    # so rings of >= 5 are repairable (a 4-ring is not — every candidate
    # touches the edge under repair).
    cluster = make_cluster(6, 8)
    failed = [(0, 1), (1, 2)]
    rails = cluster.rail_sets(failed)
    assert rails[0] == frozenset(range(8)) - {1}
    assert rails[1] == frozenset(range(8)) - {2}
    res = bridge_rerank(list(range(6)), rails)
    assert res.bottleneck_after >= 7   # bridge restores min |S_n| = 7
    # pair bandwidth reflects the intersection rule
    assert cluster.pair_bandwidth(0, 1, failed) == 6 * cluster.nic_bandwidth
    # a 4-ring with the same failure pattern cannot be repaired
    res4 = bridge_rerank([0, 1, 2, 3], make_cluster(4, 8).rail_sets(failed))
    assert res4.moved == []
