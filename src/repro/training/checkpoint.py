"""Lightweight checkpointing: flat-path npz + json metadata.

Complementary to R2CCL (the paper positions hot repair as reducing how
often you must fall back to checkpoint recovery, not replacing it).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(path: str, state, step: int, extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(path, f"step_{step}.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat), **(extra or {})}
    with open(os.path.join(path, f"step_{step}.json"), "w") as f:
        json.dump(meta, f)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(f[len("step_"):-len(".json")])
             for f in os.listdir(path) if f.endswith(".json") and f.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(path: str, state_template, step: int | None = None):
    """Restore into the structure of ``state_template`` (shapes must match)."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(os.path.join(path, f"step_{step}.npz"))
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    new_leaves = []
    for pth, leaf in leaves_with_path:
        key = SEP.join(_key_str(k) for k in pth)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
