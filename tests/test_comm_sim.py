"""Alpha-beta simulator regressions: the paper-claim regimes must hold."""

import pytest

from repro.core.comm_sim import (
    ServeJob,
    TrainJob,
    adapcc_overhead,
    iteration_time,
    monte_carlo_multi_failure,
    request_latency_under_failure,
    strategy_rate,
    training_overhead,
)
from repro.core.comm_sim import NIC_200G
from repro.core.failures import FailureState, random_failures, single_nic_failure
from repro.core.topology import IB_NIC_BW, make_cluster


def test_strategy_rate_ordering():
    """hot_repair < balance < r2ccl <= 1 for a single NIC failure."""
    kw = dict(n_nodes=8, g=8)
    hot = strategy_rate("hot_repair", 400e9, 0.125, **kw)
    bal = strategy_rate("balance", 400e9, 0.125, **kw)
    r2 = strategy_rate("r2ccl", 400e9, 0.125, **kw)
    ring = strategy_rate("ring", 400e9, 0.125, **kw)
    assert hot < bal < r2 <= 1.0
    assert bal < ring < r2       # balance pays detour tax; r2ccl beats ring


def test_fig15_regimes():
    assert strategy_rate("hot_repair", 400e9, 0.125, n_nodes=2, g=8) == 0.5
    assert strategy_rate("balance", 400e9, 0.125, n_nodes=2, g=8) == \
        pytest.approx(0.83, abs=0.01)
    assert strategy_rate("r2ccl", 400e9, 0.125, n_nodes=2, g=8) == \
        pytest.approx(0.93, abs=0.01)


def test_training_overhead_headline():
    """<1% training overhead under a single NIC failure (paper abstract)."""
    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    from repro.core.comm_sim import H100_BF16_FLOPS
    job = TrainJob(params=2.7e9, dp=16, tp=1, pp=1, global_batch=256,
                   seq_len=2048, flops_per_chip=H100_BF16_FLOPS, nic_stripe=3)
    ov = training_overhead(job, cluster, single_nic_failure(0, 0), strategy="r2ccl")
    assert 0 < ov < 0.01


def test_adapcc_cannot_do_tp_pp():
    cluster = make_cluster(2, 8)
    job = TrainJob(params=13e9, dp=1, tp=8, pp=2)
    assert adapcc_overhead(job, cluster, single_nic_failure(0, 0)) is None


def test_multi_failure_sublinear():
    cluster = make_cluster(64, 8, nic_bandwidth=NIC_200G)
    job = TrainJob(params=7e9, dp=128, tp=4, pp=1, global_batch=512)
    mc1 = monte_carlo_multi_failure(job, cluster, 1, trials=5)
    mc10 = monte_carlo_multi_failure(job, cluster, 10, trials=5)
    assert mc10["mean"] < 10 * max(mc1["mean"], 1e-6)
    assert mc10["mean"] < 0.10        # paper: 4.3%


def test_inference_overhead_headline():
    """<3% inference overhead under failure (paper abstract)."""
    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    job = ServeJob(params=405e9, tp=8, pp=2)
    out = request_latency_under_failure(job, cluster, single_nic_failure(0, 0),
                                        strategy="r2ccl",
                                        fail_at_decode_step=100)
    assert 0 <= out["overhead"] < 0.03


def test_r2ccl_hot_repair_charged_per_failure():
    """Regression: the r2ccl request path used to charge the hot-repair
    latency exactly once no matter how many failures were injected — each
    dead NIC runs its own rollback + backup-NIC activation."""
    from repro.core.comm_sim import R2CCL_MIGRATION_LATENCY
    from repro.core.failures import FailureState, concentrated_failures

    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    job = ServeJob(params=405e9, tp=8, pp=2)
    fails = concentrated_failures(0, [0, 1])
    out = request_latency_under_failure(job, cluster, fails,
                                        strategy="r2ccl",
                                        fail_at_decode_step=100)
    st = FailureState()
    for f in fails:
        st.apply(f)
    t_prefill = job.prefill_time(cluster, FailureState())
    d_healthy = job.decode_step_time(cluster, FailureState())
    d_degraded = job.decode_step_time(cluster, st)
    expected = t_prefill + 100 * d_healthy \
        + 2 * R2CCL_MIGRATION_LATENCY + (job.gen_tokens - 100) * d_degraded
    assert out["total"] == pytest.approx(expected)
    # one failure still pays exactly one hot repair
    one = request_latency_under_failure(job, cluster, single_nic_failure(0, 0),
                                        strategy="r2ccl",
                                        fail_at_decode_step=100)
    st1 = FailureState()
    for f in single_nic_failure(0, 0):
        st1.apply(f)
    d1 = job.decode_step_time(cluster, st1)
    assert one["total"] == pytest.approx(
        t_prefill + 100 * d_healthy + R2CCL_MIGRATION_LATENCY
        + (job.gen_tokens - 100) * d1)


def test_iteration_breakdown_consistency():
    cluster = make_cluster(4, 8)
    job = TrainJob(params=7e9, dp=32, tp=1, pp=1)
    it = iteration_time(job, cluster, FailureState(), strategy="ring")
    assert it.total >= it.compute
    assert it.total == pytest.approx(it.compute + it.exposed_comm)
    st = FailureState()
    for f in single_nic_failure(0, 0):
        st.apply(f)
    it2 = iteration_time(job, cluster, st, strategy="hot_repair")
    assert it2.total > it.total
