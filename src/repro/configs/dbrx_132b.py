"""DBRX-132B [moe] — 16 experts, top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352
[hf:databricks/dbrx-base]
"""

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig


CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    d_ff=10752,
    vocab_size=100_352,
    attention=AttentionConfig(
        kind="gqa", num_heads=48, num_kv_heads=8, head_dim=128,
        rope_theta=500_000.0,
    ),
    moe=MoEConfig(num_experts=16, top_k=4, expert_d_ff=10752,
                  capacity_factor=1.25),
    block_pattern=("attn",),
    activation="swiglu",
    norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke",
        family="moe",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        d_ff=192,
        vocab_size=512,
        attention=AttentionConfig(kind="gqa", num_heads=8, num_kv_heads=2,
                                  head_dim=16, rope_theta=500_000.0),
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=192,
                      capacity_factor=2.0),
        block_pattern=("attn",),
        activation="swiglu",
        norm="layernorm",
        remat=False,
    )
