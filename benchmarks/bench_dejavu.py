"""Paper Fig. 14: single-request cumulative latency with a failure at
decode step 800 — OPT-66B and BLOOM-176B, TP8 PP2, 500-token prompt,
1500-token generation.  Compares non-fault-tolerant restart, DejaVu
(KV-cache replication), and R2CCL's transparent migration.

Paper: baseline 1.62x / 1.79x; DejaVu 1.14-1.33x; R2CCL 0.71-1.58%
overhead => 8.6x and 47x lower recovery overhead than DejaVu."""

from __future__ import annotations

from repro.core.comm_sim import ServeJob, request_latency_under_failure
from repro.core.failures import single_nic_failure
from repro.core.topology import IB_NIC_BW, make_cluster

from .common import Reporter


def run() -> None:
    r = Reporter("dejavu_fig14")
    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    fail = single_nic_failure(0, 0)

    for params, label, paper_base, paper_dv, paper_r2 in [
        (66e9, "opt66b", 1.62, (1.14, 1.33), 0.0071),
        (176e9, "bloom176b", 1.79, (1.14, 1.33), 0.0158),
    ]:
        job = ServeJob(params=params, tp=8, pp=2, prompt_tokens=500,
                       gen_tokens=1500)
        out = {}
        for strat in ("restart", "dejavu", "r2ccl"):
            out[strat] = request_latency_under_failure(
                job, cluster, fail, strategy=strat, fail_at_decode_step=800,
                restart_delay=5.0)     # DejaVu-style worker restart, not the
                                       # 35 s full-engine relaunch of Fig.11
        r.row(f"{label}_restart_ratio", 1.0 + out["restart"]["overhead"],
              f"paper: {paper_base}x")
        r.row(f"{label}_dejavu_ratio", 1.0 + out["dejavu"]["overhead"],
              f"paper: {paper_dv[0]}-{paper_dv[1]}x")
        r.row(f"{label}_r2ccl_overhead", out["r2ccl"]["overhead"],
              f"paper: {paper_r2:.2%} (testbed noise floor; our physical "
              "model has no noise term, so ours is smaller)")
        ratio = out["dejavu"]["overhead"] / max(out["r2ccl"]["overhead"], 1e-9)
        r.row(f"{label}_dejavu_over_r2ccl_ge_paper",
              float(ratio >= (8.6 if label == "opt66b" else 47.0)),
              f"ratio={ratio:.0f}; paper claims 8.6x/47x — validated as >=")
        r.row(f"{label}_baseline_over_r2ccl_ge_paper",
              float(out["restart"]["overhead"] /
                    max(out["r2ccl"]["overhead"], 1e-9) >=
                    (38.9 if label == "opt66b" else 113.0)),
              "paper: 38.9x / 113x — validated as >=")
    r.save()


if __name__ == "__main__":
    run()
