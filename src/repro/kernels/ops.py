"""Jit'd public wrappers for the Pallas kernels (padding, dtype, dispatch).

``impl`` selection:
  * "pallas"     — pl.pallas_call, TPU lowering (interpret=False);
  * "interpret"  — same kernel body executed in interpret mode (CPU CI);
  * "reference"  — the pure-jnp oracle from ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .chunk_combine import chunk_combine_pallas
from .flash_attention import flash_attention_pallas
from .lru_scan import lru_scan_pallas


def _pad_axis(x, axis, multiple):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "prefix_len", "logit_cap", "scale",
    "q_block", "kv_block", "impl"))
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int | None = None,
    logit_cap: float | None = None,
    scale: float | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    impl: str = "interpret",
):
    """(B,Tq,KVH,G,D) x (B,Tk,KVH,D)^2 -> (B,Tq,KVH,G,D)."""
    if impl == "reference":
        return ref.reference_attention(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            logit_cap=logit_cap, scale=scale)
    Tq, Tk = q.shape[1], k.shape[1]
    qb = min(q_block, Tq) if Tq >= 8 else Tq
    kb = min(kv_block, Tk) if Tk >= 8 else Tk
    qp, tq = _pad_axis(q, 1, qb)
    kp, _ = _pad_axis(k, 1, kb)
    vp, _ = _pad_axis(v, 1, kb)
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window, prefix_len=prefix_len,
        logit_cap=logit_cap, scale=scale, q_block=qb, kv_block=kb,
        interpret=(impl != "pallas"))
    return out[:, :tq]


@functools.partial(jax.jit, static_argnames=("tile", "impl"))
def chunk_combine(local, recv, seg_mask, accumulate, *, tile: int = 512,
                  impl: str = "interpret"):
    """Fused R2CCL stage-2 merge; (C,M) buffers, (C,) masks."""
    if impl == "reference":
        return ref.reference_chunk_combine(local, recv, seg_mask, accumulate)
    lp, m = _pad_axis(local, 1, tile)
    rp, _ = _pad_axis(recv, 1, tile)
    out = chunk_combine_pallas(lp, rp, seg_mask, accumulate, tile=min(tile, lp.shape[1]),
                               interpret=(impl != "pallas"))
    return out[:, :m]


@functools.partial(jax.jit, static_argnames=("time_tile", "width_tile",
                                             "batch_tile", "impl"))
def lru_scan(a, x, *, time_tile: int = 128, width_tile: int = 128,
             batch_tile: int = 8, impl: str = "interpret"):
    """RG-LRU hidden states with h0=0; (B,T,W) -> (B,T,W) float32."""
    if impl == "reference":
        h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
        return ref.reference_lru_scan(a, x, h0)
    B, T, W = a.shape
    tt = min(time_tile, T)
    wt = min(width_tile, W)
    bt = min(batch_tile, B)
    ap, t0 = _pad_axis(a, 1, tt)
    xp, _ = _pad_axis(x, 1, tt)
    ap, w0 = _pad_axis(ap, 2, wt)
    xp, _ = _pad_axis(xp, 2, wt)
    ap, b0 = _pad_axis(ap, 0, bt)
    xp, _ = _pad_axis(xp, 0, bt)
    # padded decay must be *1* with x=0 so the scan carry passes through
    if ap.shape != a.shape:
        mask_t = jnp.arange(ap.shape[1]) < t0
        ap = jnp.where(mask_t[None, :, None], ap, 1.0)
    out = lru_scan_pallas(ap, xp, time_tile=tt, width_tile=wt, batch_tile=bt,
                          interpret=(impl != "pallas"))
    return out[:b0, :t0, :w0]


@functools.partial(jax.jit, static_argnames=("time_tile", "impl"))
def wkv_scan(r, k, v, w, u, *, time_tile: int = 64, impl: str = "interpret"):
    """RWKV-6 WKV recurrence: r/k/w (BH,T,K), v (BH,T,V), u (BH,K)
    -> (BH,T,V) float32, S_0 = 0."""
    from .wkv_scan import wkv_scan_pallas
    if impl == "reference":
        return ref.reference_wkv(r, k, v, w, u)
    T = r.shape[1]
    tt = min(time_tile, T)
    rp, t0 = _pad_axis(r, 1, tt)
    kp, _ = _pad_axis(k, 1, tt)
    vp, _ = _pad_axis(v, 1, tt)
    wp, _ = _pad_axis(w, 1, tt)
    out = wkv_scan_pallas(rp, kp, vp, wp, u, time_tile=tt,
                          interpret=(impl != "pallas"))
    return out[:, :t0]
