"""Paper Fig. 7: Megatron training under a single NIC failure on the
2-node H100 testbed — GPT 2.7B DP=16 and GPT 13B TP=8,PP=2.

Reproduced with the alpha-beta simulator over the real planner/partition
machinery.  Paper numbers: R2CCL-AllReduce 0.71% overhead (DP=16),
Balance 1.32%, HotRepair 4.82%, AdapCC 8.65% and 0 tok/s under TP/PP;
two concurrent failures: 1.24% / 1.01%.

The paper measures these overheads over whole multi-iteration training
runs, so the bench also emits a *campaign* section: N gradient syncs
back-to-back through the event engine with one persistent recovery
control plane, every per-failure recovery cost derived from the campaign
``RecoveryLedger`` (the ``R2CCL_MIGRATION_LATENCY`` constant never enters
this path).  ``tiny`` shrinks it to the CI smoke shape: 3 iterations, one
failure.
"""

from __future__ import annotations

from repro.core.comm_sim import (
    H100_BF16_FLOPS,
    TrainJob,
    adapcc_overhead,
    iteration_time,
    training_overhead,
)
from repro.core.failures import FailureState, concentrated_failures, single_nic_failure
from repro.core.topology import IB_NIC_BW, make_cluster

from .common import Reporter


def run(tiny: bool = False) -> None:
    r = Reporter("training_fig7")
    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    fail1 = single_nic_failure(0, 0)
    fail2 = concentrated_failures(0, [0, 1])

    # --- GPT-2.7B, DP=16 ----------------------------------------------------
    # nic_stripe=3 calibrated from the testbed's healthy AllReduce busbw
    job = TrainJob(params=2.7e9, dp=16, tp=1, pp=1, global_batch=256,
                   seq_len=2048, layers=32, hidden=2560,
                   flops_per_chip=H100_BF16_FLOPS, nic_stripe=3)
    for strat, paper in [("r2ccl", 0.0071), ("balance", 0.0132),
                         ("hot_repair", 0.0482)]:
        ov = training_overhead(job, cluster, fail1, strategy=strat)
        r.row(f"dp16_2.7b_{strat}_overhead", ov, f"paper: {paper:.2%}")
    adc = adapcc_overhead(job, cluster, fail1)
    r.row("dp16_2.7b_adapcc_overhead", adc, "paper: 8.65%")
    ov2 = training_overhead(job, cluster, fail2, strategy="r2ccl")
    r.row("dp16_2.7b_two_failures_overhead", ov2, "paper: 1.24%")

    # --- GPT-13B, TP=8 PP=2 --------------------------------------------------
    job13 = TrainJob(params=13e9, dp=1, tp=8, pp=2, global_batch=64,
                     seq_len=2048, layers=40, hidden=5120,
                     flops_per_chip=H100_BF16_FLOPS, nic_stripe=3)
    for strat, paper in [("balance", 0.0038), ("hot_repair", 0.0131)]:
        ov = training_overhead(job13, cluster, fail1, strategy=strat)
        r.row(f"tp8pp2_13b_{strat}_overhead", ov, f"paper: {paper:.2%}")
    adc13 = adapcc_overhead(job13, cluster, fail1)
    r.row("tp8pp2_13b_adapcc_tokens", 0.0 if adc13 is None else 1.0,
          "paper: 0 tokens/s (rank removal breaks TP/PP)")
    ov2 = training_overhead(job13, cluster, fail2, strategy="balance")
    r.row("tp8pp2_13b_two_failures_overhead", ov2, "paper: 1.01%")

    # headline claim: <1% training overhead under failure
    best = training_overhead(job, cluster, fail1, strategy="r2ccl")
    r.row("headline_training_overhead_lt_1pct", float(best < 0.01),
          f"measured {best:.2%}")

    # --- multi-iteration campaign (event mode, ledger-derived recovery) -----
    from repro.runtime.campaign import training_campaign_report

    if tiny:    # CI smoke shape: <=8 simulated GPUs, 3 iterations, 1 failure
        iters = 3
        camp_cluster = make_cluster(2, 4, nic_bandwidth=IB_NIC_BW)
        camp_job = TrainJob(params=2.7e9, dp=8, tp=1, pp=1, global_batch=256,
                            seq_len=2048, layers=32, hidden=2560,
                            flops_per_chip=H100_BF16_FLOPS, nic_stripe=3)
    else:
        iters, camp_cluster, camp_job = 8, cluster, job
    res = training_campaign_report(camp_job, camp_cluster, fail1,
                                   iterations=iters)
    k = iters // 2
    r.row("campaign_iterations", float(iters),
          f"1 NIC down at iteration {k}, persistent control plane")
    r.row("campaign_overhead", res.overhead,
          f"vs {iters} healthy iterations; recovery cost from the ledger")
    r.row("campaign_recovery_cost", res.recovery_cost,
          f"{len(res.campaign.ledger.entries)} pipeline runs "
          f"(state={res.campaign.final_state.value})")
    r.row("campaign_degraded_dp_comm", max(res.dp_comm_times),
          f"healthy {min(res.dp_comm_times):.4g}s per sync")
    if not tiny:
        r.row("campaign_headline_lt_1pct", float(res.overhead < 0.01),
              f"measured {res.overhead:.2%} over {iters} iterations")
    r.save()


if __name__ == "__main__":
    run()
