"""Section 5.1: R2CCL-Balance NIC-level redistribution."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.balance import (
    DetourPath,
    choose_detour_path,
    hot_repair_plan,
    rebalance,
)
from repro.core.topology import NodeTopology


def _node():
    return NodeTopology(node_id=0)


def test_no_failure_identity():
    plan = rebalance(_node(), [100.0] * 8)
    assert all(f.path is DetourPath.AFFINITY for f in plan.flows)
    assert plan.completion_time == pytest.approx(plan.completion_time_ideal)


@settings(max_examples=30, deadline=None)
@given(loads=st.lists(st.floats(1.0, 1e9), min_size=8, max_size=8),
       failed_rail=st.integers(0, 7))
def test_rebalance_conserves_bytes(loads, failed_rail):
    node = _node()
    plan = rebalance(node, loads, failed=[(0, failed_rail)])
    assert sum(plan.nic_load.values()) == pytest.approx(sum(loads), rel=1e-6)
    assert (0, failed_rail) not in plan.nic_load


@settings(max_examples=30, deadline=None)
@given(failed_rail=st.integers(0, 7))
def test_balance_beats_hot_repair(failed_rail):
    node = _node()
    loads = [100e6] * 8
    bal = rebalance(node, loads, failed=[(0, failed_rail)])
    hot = hot_repair_plan(node, loads, failed=[(0, failed_rail)])
    assert bal.completion_time <= hot.completion_time + 1e-9
    # hot repair doubles one NIC: completion = 2/g vs ideal 1/(g-1)
    # -> 2(g-1)/g = 1.75x ideal for g=8
    assert hot.completion_time >= 1.7 * bal.completion_time_ideal


def test_balance_approaches_residual_ideal():
    """Paper: Balance's completion approaches D_i / B_i^rem."""
    node = _node()
    plan = rebalance(node, [100e6] * 8, failed=[(0, 3)])
    assert plan.completion_time <= plan.completion_time_ideal * 1.25


def test_multi_failure_balance():
    node = _node()
    plan = rebalance(node, [100e6] * 8, failed=[(0, 0), (0, 1), (0, 2)])
    assert len(plan.nic_load) == 5
    assert sum(plan.nic_load.values()) == pytest.approx(800e6, rel=1e-6)


def test_detour_path_policy():
    node = _node()
    # same-NUMA backup with PCIe headroom -> direct PCIe
    backup_same = node.nics[1]
    assert choose_detour_path(node, 0, backup_same, pcie_headroom=1e9) \
        is DetourPath.PCIE_DIRECT
    # cross-NUMA: NVLink PXN wins over UPI (paper topology: NVLink >> UPI)
    backup_far = node.nics[7]
    p = choose_detour_path(node, 0, backup_far, pcie_headroom=0)
    assert p in (DetourPath.PXN, DetourPath.PCIE_UPI)
    assert p is DetourPath.PXN            # NVLink headroom > UPI on this node


def test_no_healthy_nics_raises():
    node = _node()
    with pytest.raises(ValueError):
        rebalance(node, [1.0] * 8, failed=[(0, r) for r in range(8)])
