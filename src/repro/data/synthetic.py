"""Deterministic synthetic data pipeline.

Produces reproducible token/frame/patch batches for every modality with a
learnable signal (Zipfian n-gram language) so smoke training can show a
decreasing loss.  Batches are generated host-side with numpy, sharded by
the launcher.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticConfig:
    seq_len: int
    batch_size: int
    vocab_size: int
    seed: int = 0
    ngram: int = 2                 # learnable bigram structure


class SyntheticTokens:
    """Zipf-distributed bigram language: next ~ P(. | prev) with a fixed
    random transition table — learnable by any LM."""

    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        k = min(v, 32)
        # each token has k likely successors
        self.successors = rng.integers(0, v, size=(v, k))
        self.rng = np.random.default_rng(cfg.seed + 1)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, T, v = cfg.batch_size, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, T + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=B)
        k = self.successors.shape[1]
        choice = rng.integers(0, k, size=(B, T))
        mix = rng.random((B, T)) < 0.9            # 10% noise
        noise = rng.integers(0, v, size=(B, T))
        for t in range(T):
            nxt = self.successors[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(mix[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch(cfg: ModelConfig, seq_len: int, batch_size: int, step: int = 0,
               seed: int = 0) -> dict[str, np.ndarray]:
    """One global batch for any modality (numpy, deterministic)."""
    rng = np.random.default_rng((seed, step))
    if cfg.modality.kind == "audio_frames":
        frames = rng.standard_normal(
            (batch_size, seq_len, cfg.modality.frontend_dim)).astype(np.float32)
        labels = rng.integers(0, cfg.vocab_size, size=(batch_size, seq_len)).astype(np.int32)
        # HuBERT-style: predict cluster units at masked positions (~8%)
        mask = (rng.random((batch_size, seq_len)) < 0.08).astype(np.float32)
        return {"frames": frames, "labels": labels, "loss_mask": mask}
    if cfg.modality.kind == "vision_text":
        P = cfg.modality.num_prefix_tokens
        text_len = max(seq_len - P, 1)
        gen = SyntheticTokens(SyntheticConfig(text_len, batch_size, cfg.vocab_size, seed))
        b = gen.batch(step)
        patches = rng.standard_normal(
            (batch_size, P, cfg.modality.frontend_dim)).astype(np.float32)
        return {"patches": patches, "tokens": b["tokens"], "labels": b["labels"]}
    gen = SyntheticTokens(SyntheticConfig(seq_len, batch_size, cfg.vocab_size, seed))
    return gen.batch(step)
