"""Training step with pluggable gradient synchronization.

Two sync modes (the framework's first-class R2CCL integration):

  * ``sync="xla"``   — plain ``jax.grad`` under pjit; XLA inserts its own
    all-reduce over the data axes (the baseline).
  * ``sync="r2ccl"`` — gradients are computed under ``shard_map`` *manual*
    over the data axes (model axes stay auto/SPMD) and synchronized by an
    explicit R2CCL collective program (ring / r2ccl-allreduce / recursive,
    per the ``CommConfig``).  Failure-aware schedules switch here without
    touching the model code — the paper's drop-in-replacement property.

Multi-pod meshes sync hierarchically: the configured schedule runs over the
intra-pod ``data`` axis, then an explicit ring combines over ``pod``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.collectives import sync_gradients
from repro.core.planner import CommConfig
from repro.models import apply_model
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedules import cosine_with_warmup
from . import losses


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt_state", "step"], meta_fields=[]
)


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt_state=init_opt_state(params),
                      step=jnp.zeros((), jnp.int32))


def compute_loss(params, cfg: ModelConfig, batch) -> tuple[jnp.ndarray, dict]:
    logits, _, aux = apply_model(params, cfg, batch, mode="train")
    mtp_loss = jnp.zeros((), jnp.float32)
    if isinstance(aux, tuple):                 # MTP head active
        aux, mtp_logits = aux
        # position t's MTP target is token t+2 = labels[t+1]
        from repro.models.layers import cross_entropy
        mtp_loss = cross_entropy(mtp_logits[:, :-1], batch["labels"][:, 1:])
    loss = losses.task_loss(cfg, logits, batch)
    total = loss + aux + cfg.mtp_loss_weight * mtp_loss
    return total, {"loss": loss, "aux_loss": aux, "mtp_loss": mtp_loss}


def make_train_step(
    cfg: ModelConfig,
    opt: AdamWConfig,
    *,
    sync: str = "xla",                     # "xla" | "r2ccl"
    comm: CommConfig | None = None,
    mesh=None,
    data_axes: tuple[str, ...] = ("data",),
    total_steps: int = 10_000,
    warmup_steps: int = 100,
) -> Callable:
    """Builds ``train_step(state, batch) -> (state, metrics)``.

    ``comm.mode`` selects the gradient AllReduce schedule in r2ccl sync:
    "ring" (NCCL-equivalent explicit schedule), "r2ccl"
    (failure-aware decomposition for ``comm.degraded_rank``), "recursive"
    (multi-failure bandwidth spectrum), or "xla" (psum — for parity tests).
    """
    comm = comm or CommConfig()

    def loss_for_grad(params, batch):
        total, metrics = compute_loss(params, cfg, batch)
        return total, metrics

    def apply_updates(state: TrainState, grads, metrics):
        lr_scale = cosine_with_warmup(state.step, warmup_steps=warmup_steps,
                                      total_steps=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            opt, state.params, grads, state.opt_state, lr_scale=lr_scale)
        metrics = dict(metrics, grad_norm=gnorm,
                       lr=jnp.asarray(opt.lr) * lr_scale)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    if sync == "xla":
        def train_step(state: TrainState, batch):
            (_, metrics), grads = jax.value_and_grad(
                loss_for_grad, has_aux=True)(state.params, batch)
            return apply_updates(state, grads, metrics)
        return train_step

    if sync != "r2ccl":
        raise ValueError(f"unknown sync mode {sync!r}")

    assert mesh is not None, "r2ccl sync needs the mesh for shard_map"
    manual = set(data_axes)
    batch_spec = P(tuple(data_axes))

    def sharded_grads(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_for_grad, has_aux=True)(params, batch)
        # Wire dtype: ship gradients in bf16 (the XLA-native path fuses the
        # cast into its all-reduce; the explicit schedule must do the same
        # or pay 2x the ring bytes).
        wire_t = jnp.bfloat16 if comm.comm_dtype == "bfloat16" else jnp.float32
        orig_dtypes = jax.tree_util.tree_map(lambda g: g.dtype, grads)
        grads = jax.tree_util.tree_map(lambda g: g.astype(wire_t), grads)
        # Intra-pod sync with the configured (possibly failure-aware)
        # schedule; inter-pod combine with an explicit ring.
        grads = sync_gradients(grads, data_axes[-1], mean=True, **comm.kwargs())
        for ax in data_axes[:-1]:
            grads = sync_gradients(grads, ax, mode="ring" if comm.mode != "xla"
                                   else "xla", mean=True, g=comm.devices_per_node)
        grads = jax.tree_util.tree_map(
            lambda g, t: g.astype(t), grads, orig_dtypes)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, tuple(data_axes)), metrics)
        return grads, metrics

    def train_step(state: TrainState, batch):
        spec_batch = jax.tree_util.tree_map(lambda _: batch_spec, batch)
        grads, metrics = jax.shard_map(
            sharded_grads,
            mesh=mesh,
            in_specs=(P(), spec_batch),
            out_specs=(P(), P()),
            axis_names=manual,
            check_vma=False,
        )(state.params, batch)
        return apply_updates(state, grads, metrics)

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        total, metrics = compute_loss(params, cfg, batch)
        return dict(metrics, total_loss=total)
    return eval_step
