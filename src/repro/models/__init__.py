"""Model zoo: pattern-based block stacks covering 6 architecture types."""

from .registry import ARCHITECTURES, get_config, get_smoke_config, list_architectures  # noqa: F401
from .transformer import apply_model, init_caches, init_model  # noqa: F401
