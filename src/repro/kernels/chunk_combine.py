"""Fused chunk-combine Pallas kernel — the stage-2 merge of R2CCL-AllReduce.

The paper implements "a customized broadcast kernel to support the specific
requirements of the R2CCL-AllReduce phase" (Section 7): after the partial
AllReduce, received chunks must be merged into the local buffer — some
accumulated (reduction edges), some overwritten (broadcast edges), some
untouched (chunks the local rank already owns).  Doing this with separate
select/add ops costs three HBM round-trips over the gradient buffer; the
fused kernel does one read of each operand and one write.

Grid: one program per chunk tile; per-chunk control (segment membership,
accumulate-vs-overwrite) arrives as scalar-prefetch-style int32 operands in
SMEM-friendly (1,1) blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine_kernel(seg_ref, acc_ref, local_ref, recv_ref, o_ref):
    seg = seg_ref[0] != 0
    acc = acc_ref[0] != 0
    local = local_ref[...]
    recv = recv_ref[...]
    comb = jnp.where(acc, local + recv, recv)
    o_ref[...] = jnp.where(seg, comb, local)


def chunk_combine_pallas(
    local: jax.Array,               # (C, M)
    recv: jax.Array,                # (C, M)
    seg_mask: jax.Array,            # (C,) int32/bool
    accumulate: jax.Array,          # (C,) int32/bool
    *,
    tile: int = 512,
    interpret: bool = True,
) -> jax.Array:
    C, M = local.shape
    assert M % tile == 0, f"M={M} must be a multiple of tile={tile}"
    nm = M // tile
    seg = seg_mask.astype(jnp.int32)
    acc = accumulate.astype(jnp.int32)
    return pl.pallas_call(
        _combine_kernel,
        grid=(C, nm),
        in_specs=[
            pl.BlockSpec((1,), lambda c, m: (c,)),
            pl.BlockSpec((1,), lambda c, m: (c,)),
            pl.BlockSpec((1, tile), lambda c, m: (c, m)),
            pl.BlockSpec((1, tile), lambda c, m: (c, m)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda c, m: (c, m)),
        out_shape=jax.ShapeDtypeStruct(local.shape, local.dtype),
        interpret=interpret,
    )(seg, acc, local, recv)
