"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only <name>] [--fast]

Prints ``name,metric,value,derived`` CSV and writes per-benchmark JSON to
experiments/bench/.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time

BENCHES = [
    ("partition", "bench_partition", "Appendix A: Y*, threshold, Fig.5 traffic"),
    ("busbw", "bench_allreduce_busbw", "Fig.15 AllReduce bus bandwidth"),
    ("collectives", "bench_collectives", "Fig.16 AG/RS/SendRecv under failure"),
    ("training", "bench_training", "Fig.7 Megatron testbed overheads"),
    ("scaling", "bench_scaling", "Fig.8/9 7B scaling + 175B/RLHF vs AdapCC"),
    ("multi_failure", "bench_multi_failure", "Fig.10 Monte Carlo k failures"),
    ("runtime", "bench_runtime", "Sec.4-6 closed-loop recovery stage breakdown"),
    ("engine_perf", "bench_engine_perf",
     "event-engine throughput, telemetry overhead + 10k-rank fill sweep"),
    ("inference", "bench_inference", "Fig.11-13 TTFT/TPOT under failure"),
    ("dejavu", "bench_dejavu", "Fig.14 DejaVu comparison"),
    ("detection", "bench_detection", "Sec.4 detection + migration latency"),
    ("kernels", "bench_kernels", "Pallas kernels vs oracle"),
    ("analysis", "bench_analysis",
     "static cost/coverage conformance + planner drift"),
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="reduce Monte Carlo trials")
    ap.add_argument("--sim-mode", default="alpha_beta",
                    choices=("alpha_beta", "event"),
                    help="simulator backend for benches that support it "
                         "(event = discrete-event schedule execution)")
    ap.add_argument("--tiny", action="store_true",
                    help="<=8 simulated GPUs per bench (CI smoke scale)")
    ap.add_argument("--seed", type=int, default=0,
                    help="top-level RNG seed threaded into every bench that "
                         "randomizes (Monte Carlo patterns, event scenarios) "
                         "so the emitted JSON is reproducible run-to-run")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="dump the engine's structured trace from benches "
                         "that support it (the runtime bench): JSONL at "
                         "PATH plus Chrome trace_event JSON at "
                         "PATH.chrome.json for Perfetto/about:tracing")
    args = ap.parse_args(argv)

    print("benchmark,metric,value,derived")
    failures = []
    for name, module, desc in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["run"])
            accepted = inspect.signature(mod.run).parameters
            kw = {}
            if "mode" in accepted:
                kw["mode"] = args.sim_mode
            if "tiny" in accepted:
                kw["tiny"] = args.tiny
            if "seed" in accepted:
                kw["seed"] = args.seed
            if "trace" in accepted:
                kw["trace"] = args.trace
            if "trials" in accepted and args.fast:
                kw["trials"] = 10
            mod.run(**kw)
            print(f"# {name} ({desc}) done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", file=sys.stderr)
    if failures:
        for n, e in failures:
            print(f"FAILED,{n},0,{e}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
