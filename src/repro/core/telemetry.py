"""Flow-level telemetry plane: metrics registry + structured trace log.

Every failure the simulator handled so far was an *oracle* event handed
straight to the control plane.  R²CCL's detection story (paper §4.1-4.2)
and the observable-CCL line of work start from *measured* flow-level
signals — byte counters, instantaneous rates, probe outcomes — that must
be turned into a diagnosis.  This module is the measurement half of that
story:

* :class:`Series` — a fixed-capacity ring buffer of (t, value) points.
  Engine counters are sampled into these at a configurable virtual-time
  cadence, so a long campaign keeps a bounded recent window per signal
  (the NIC-counter / sFlow model: you get a sampling window, not the full
  history).
* :class:`MetricsRegistry` — named, labeled series: per-rank egress
  counter rate (``rank.tx_rate``), instantaneous water-fill share
  (``rank.fair_share``), in-flight transfer count (``rank.inflight``),
  cumulative retransmitted bytes (``rank.retrans_bytes``); per-stream
  moved-byte goodput (``stream.goodput``), cumulative moved bytes
  (``stream.moved_bytes``) and outstanding work-queue depth
  (``stream.remaining`` — the runtime issued those operations, so their
  incompleteness is an observable signal, not oracle knowledge).
* :class:`TraceLog` — typed structured records for every engine and
  control-plane event (transfer start/finish, rollback, failure
  injection, recovery, probe outcomes, recovery-pipeline stages, state
  transitions, replans, telemetry-inferred detections), exportable as
  JSONL (:meth:`TraceLog.to_jsonl`) and as Chrome ``trace_event`` JSON
  (:meth:`TraceLog.to_chrome_trace`) for about:tracing / Perfetto.
* :class:`Telemetry` — the bundle the event engine consumes: a sampling
  period (virtual seconds), a registry, a trace, and an optional
  ``observer`` called back at every sample tick (the telemetry-inferred
  failure detector in :mod:`repro.runtime.inference`).

The split matters: the **registry and probe records are the only signals
a telemetry-driven detector may consume** — the trace additionally logs
ground truth (failure injections, including ``silent`` ones) so tests and
benchmarks can score detection latency and false positives/negatives
against it, and so every :class:`~repro.runtime.control_plane.LedgerEntry`
is reconstructible from the exported trace
(:func:`stage_totals_from_trace` / :func:`ledger_entries_from_trace`).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

#: Pinned JSONL record schema: record ``type`` -> exact field set (every
#: record also carries ``type`` itself).  The trace-schema smoke test and
#: the nightly artifact consumers rely on these field names; extending a
#: record type means extending this table in the same change.
TRACE_SCHEMA: dict[str, tuple[str, ...]] = {
    "transfer_start": ("t", "tid", "seg", "stream", "src", "dst", "bytes"),
    "transfer_finish": ("t", "tid", "seg", "stream", "src", "dst", "bytes"),
    "rollback": ("t", "tid", "stream", "src", "dst", "sent_bytes", "delay"),
    "failure": ("t", "node", "rail", "kind", "severity", "silent"),
    "recovery": ("t", "node", "rail"),
    "recovery_confirmed": ("t", "node", "rail"),
    "replan": ("t", "stream", "residual_bytes", "rereduce_bytes",
               "deliver_bytes", "done_bytes", "cancelled"),
    "probe": ("t", "node", "rail", "outcome", "bw_fraction"),
    "stage": ("t", "entry", "stage", "dur", "node", "rail"),
    "transition": ("t", "state"),
    "detection": ("t", "node", "rail", "kind", "severity"),
    "detection_cleared": ("t", "node", "rail"),
    "sample": ("t", "seq"),
}


class Series:
    """Fixed-capacity ring buffer of (time, value) samples.

    Appends are O(1); :meth:`times` / :meth:`values` return the retained
    window in chronological order.  ``dropped`` counts points that fell
    out of the window — a consumer can tell a short history from a
    truncated one.
    """

    __slots__ = ("_t", "_v", "_head", "_len", "dropped")

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"Series capacity must be >= 1, got {capacity!r}")
        self._t = np.empty(capacity, dtype=np.float64)
        self._v = np.empty(capacity, dtype=np.float64)
        self._head = 0                     # next write position
        self._len = 0
        self.dropped = 0

    @property
    def capacity(self) -> int:
        return len(self._t)

    def __len__(self) -> int:
        return self._len

    def append(self, t: float, value: float) -> None:
        cap = len(self._t)
        self._t[self._head] = t
        self._v[self._head] = value
        self._head = (self._head + 1) % cap
        if self._len < cap:
            self._len += 1
        else:
            self.dropped += 1

    def _order(self) -> np.ndarray:
        cap = len(self._t)
        if self._len < cap:
            return np.arange(self._len)
        return np.arange(self._head, self._head + cap) % cap

    def times(self) -> np.ndarray:
        return self._t[self._order()].copy()

    def values(self) -> np.ndarray:
        return self._v[self._order()].copy()

    def last(self) -> tuple[float, float] | None:
        if self._len == 0:
            return None
        i = (self._head - 1) % len(self._t)
        return float(self._t[i]), float(self._v[i])


class MetricsRegistry:
    """Named, labeled ring-buffered time series.

    Keys are ``(name, labels)`` with ``labels`` a tuple of label values —
    ``("rank.tx_rate", (3,))`` is rank 3's egress counter rate,
    ``("stream.goodput", ("dp",))`` the DP stream's goodput.  Series are
    created on first record with the registry's capacity.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(
                f"MetricsRegistry capacity must be >= 1, got {capacity!r}")
        self.capacity = capacity
        self._series: dict[tuple[str, tuple], Series] = {}

    def handle(self, name: str, labels: tuple) -> Series:
        """The (created-if-missing) series for a key — a hot sampler caches
        these and appends directly, skipping the per-record dict lookup."""
        key = (name, labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = Series(self.capacity)
        return s

    def record(self, name: str, labels: tuple, t: float, value: float) -> None:
        self.handle(name, labels).append(t, value)

    def series(self, name: str, labels: tuple) -> Series | None:
        return self._series.get((name, labels))

    def last(self, name: str, labels: tuple) -> float | None:
        s = self._series.get((name, labels))
        if s is None:
            return None
        point = s.last()
        return None if point is None else point[1]

    def names(self) -> list[tuple[str, tuple]]:
        return sorted(self._series, key=repr)


class TraceLog:
    """Structured trace of typed records, bounded to ``max_records``.

    Records are plain dicts carrying ``type`` plus exactly the fields
    :data:`TRACE_SCHEMA` pins for that type.  The log is append-ordered
    (engine virtual time is monotone within a run); when the cap is hit
    the *oldest* records are dropped and counted, never the newest —
    a post-mortem wants the end of the timeline.
    """

    def __init__(self, max_records: int = 1_000_000):
        if max_records < 1:
            raise ValueError(
                f"TraceLog max_records must be >= 1, got {max_records!r}")
        self.max_records = max_records
        self.records: list[dict[str, Any]] = []
        self.dropped = 0

    def add(self, rtype: str, t: float, **fields: Any) -> None:
        rec = {"type": rtype, "t": t}
        rec.update(fields)
        self.records.append(rec)
        if len(self.records) > self.max_records:
            # amortized trim: drop the oldest 10% in one slice
            cut = max(1, self.max_records // 10)
            del self.records[:cut]
            self.dropped += cut

    def of_type(self, rtype: str) -> list[dict[str, Any]]:
        return [r for r in self.records if r["type"] == rtype]

    # -- export --------------------------------------------------------------
    def to_jsonl(self) -> str:
        """One JSON object per line, in record order."""
        return "\n".join(json.dumps(r, sort_keys=True, default=str)
                         for r in self.records)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
            if self.records:
                f.write("\n")

    def to_chrome_trace(self, *, time_unit: float = 1e6) -> dict[str, Any]:
        """Chrome ``trace_event`` JSON (open in about:tracing / Perfetto).

        Transfers become complete ("X") slices on a per-stream process
        (pid = stream track, tid = source rank), recovery-pipeline stages
        become slices on a dedicated control-plane track, failures /
        recoveries / replans / detections become instant ("i") events,
        and per-rank tx-rate samples become counter ("C") events.
        ``time_unit`` converts virtual seconds to trace ticks (default
        microseconds, the format's native unit).
        """
        events: list[dict[str, Any]] = []
        streams: dict[Any, int] = {}

        def pid_for(stream: Any) -> int:
            if stream not in streams:
                streams[stream] = len(streams) + 1
                events.append({
                    "name": "process_name", "ph": "M", "pid": streams[stream],
                    "tid": 0, "args": {"name": f"stream:{stream}"}})
            return streams[stream]

        CP_PID = 0
        events.append({"name": "process_name", "ph": "M", "pid": CP_PID,
                       "tid": 0, "args": {"name": "control-plane"}})
        open_starts: dict[int, dict[str, Any]] = {}
        for r in self.records:
            ts = r["t"] * time_unit
            rt = r["type"]
            if rt == "transfer_start":
                open_starts[r["tid"]] = r
            elif rt in ("transfer_finish", "rollback"):
                start = open_starts.pop(r["tid"], None)
                if start is None:
                    continue
                t0 = start["t"] * time_unit
                events.append({
                    "name": (f"xfer {r['src']}->{r['dst']}" if
                             rt == "transfer_finish" else
                             f"rollback {r['src']}->{r['dst']}"),
                    "ph": "X", "ts": t0, "dur": max(0.0, ts - t0),
                    "pid": pid_for(start["stream"]), "tid": r["src"],
                    "args": {k: v for k, v in r.items()
                             if k not in ("type", "t")},
                })
            elif rt == "stage":
                events.append({
                    "name": r["stage"], "ph": "X", "ts": ts,
                    "dur": r["dur"] * time_unit, "pid": CP_PID, "tid": 0,
                    "args": {"entry": r["entry"], "node": r["node"],
                             "rail": r["rail"]},
                })
            elif rt in ("failure", "recovery", "recovery_confirmed",
                        "replan", "detection", "detection_cleared", "probe",
                        "transition"):
                events.append({
                    "name": rt, "ph": "i", "ts": ts, "s": "g",
                    "pid": CP_PID, "tid": 0,
                    "args": {k: v for k, v in r.items()
                             if k not in ("type", "t")},
                })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, default=str)


@dataclasses.dataclass
class Telemetry:
    """The observability bundle one engine run samples into.

    ``sample_period`` is the virtual-time cadence at which the engine
    snapshots its counters into the registry (and calls ``observer``) —
    the NIC-counter polling interval of a real monitoring plane.  It must
    be strictly positive; zero or negative periods would schedule an
    event storm that never advances virtual time.
    """

    sample_period: float
    registry: MetricsRegistry = dataclasses.field(
        default_factory=MetricsRegistry)
    trace: TraceLog = dataclasses.field(default_factory=TraceLog)
    #: duck-typed sample hook: ``on_sample(sim, now)`` called after each
    #: sample lands in the registry (the telemetry-inferred detector)
    observer: Any | None = None

    def __post_init__(self) -> None:
        if not self.sample_period > 0.0:
            raise ValueError(
                f"Telemetry sample_period must be > 0 (virtual seconds "
                f"between counter samples), got {self.sample_period!r}")

    @classmethod
    def for_duration(cls, duration: float, *, samples: int = 64,
                     **kw: Any) -> "Telemetry":
        """A telemetry plane whose cadence yields ~``samples`` samples over
        ``duration`` virtual seconds (e.g. the healthy collective time)."""
        if not duration > 0.0:
            raise ValueError(
                f"Telemetry.for_duration needs duration > 0, got {duration!r}")
        if samples < 1:
            raise ValueError(f"need >= 1 sample, got {samples!r}")
        return cls(sample_period=duration / samples, **kw)


# ---------------------------------------------------------------------------
# ledger <-> trace cross-validation
# ---------------------------------------------------------------------------

def ledger_entries_from_trace(
    records: Iterable[Mapping[str, Any]],
) -> list[dict[str, float]]:
    """Reconstruct per-pipeline-run stage breakdowns from ``stage`` records.

    Returns one ``{stage: latency}`` dict per recovery-pipeline run, in
    entry order — the trace-side mirror of
    ``[e.stages for e in ledger.entries]``.  The cross-validation contract:
    a control plane given a trace emits one ``stage`` record per ledger
    stage, so the reconstruction must match the ledger exactly.
    """
    by_entry: dict[int, dict[str, float]] = {}
    for r in records:
        if r.get("type") != "stage":
            continue
        by_entry.setdefault(int(r["entry"]), {})[r["stage"]] = float(r["dur"])
    return [by_entry[i] for i in sorted(by_entry)]


def stage_totals_from_trace(
    records: Iterable[Mapping[str, Any]],
) -> dict[str, float]:
    """Per-stage latency totals summed over every pipeline run in the trace
    (the trace-side mirror of ``RecoveryLedger.stage_totals()``)."""
    out: dict[str, float] = {}
    for stages in ledger_entries_from_trace(records):
        for k, v in stages.items():
            out[k] = out.get(k, 0.0) + v
    return out


def ledger_total_from_trace(
    records: Iterable[Mapping[str, Any]],
) -> float:
    """Total recovery latency reconstructed from the trace (mirror of
    ``RecoveryLedger.total_latency()``)."""
    return sum(stage_totals_from_trace(records).values())


def validate_trace_schema(
    records: Iterable[Mapping[str, Any]],
    *,
    schema: Mapping[str, Sequence[str]] = TRACE_SCHEMA,
) -> None:
    """Raise ``ValueError`` on the first record whose type is unknown or
    whose field set differs from the pinned schema."""
    for i, r in enumerate(records):
        rtype = r.get("type")
        if rtype not in schema:
            raise ValueError(f"record {i}: unknown trace type {rtype!r}")
        want = set(schema[rtype]) | {"type"}
        have = set(r)
        if have != want:
            raise ValueError(
                f"record {i} ({rtype}): fields {sorted(have)} != pinned "
                f"schema {sorted(want)}")
