"""Static analysis over the collective-schedule IR and the simulator.

Two passes:

* :mod:`repro.analysis.verify` — schedule verifier: legality, abstract
  interpretation over contribution multisets (AllReduce / Reduce /
  ReduceScatter / AllGather / Broadcast proofs), and deadlock-freedom of
  the per-rank lockstep dependency graph.
* :mod:`repro.analysis.lint` — AST determinism lint over
  ``core/event_sim.py`` and ``runtime/`` (rules DET001–DET005).

Run both from the command line: ``python -m repro.analysis``.
"""

from .errors import (
    DataflowError,
    DeadlockError,
    DoubleReduceError,
    ProgramError,
    Provenance,
    ResultError,
    ResultRanksError,
    ScheduleError,
    StaleReadError,
    StepLegalityError,
)
from .verify import (
    Semantics,
    VerifyReport,
    check_deadlock_free,
    check_program,
    check_schedule,
    check_step,
    infer_semantics,
    verify_program,
    verify_schedule,
)
from .lint import DEFAULT_LINT_TARGETS, LintFinding, lint_paths, lint_source

__all__ = [
    "DataflowError",
    "DeadlockError",
    "DoubleReduceError",
    "ProgramError",
    "Provenance",
    "ResultError",
    "ResultRanksError",
    "ScheduleError",
    "StaleReadError",
    "StepLegalityError",
    "Semantics",
    "VerifyReport",
    "check_deadlock_free",
    "check_program",
    "check_schedule",
    "check_step",
    "infer_semantics",
    "verify_program",
    "verify_schedule",
    "DEFAULT_LINT_TARGETS",
    "LintFinding",
    "lint_paths",
    "lint_source",
]
