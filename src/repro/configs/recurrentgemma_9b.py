"""RecurrentGemma-9B [hybrid] — RG-LRU + local attention, 1:2 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000  [arXiv:2402.19427]
Griffin block pattern: two recurrent (RG-LRU) blocks followed by one local
(sliding-window 2048) attention block.
"""

from repro.configs.base import (
    AttentionConfig,
    ModalityConfig,
    ModelConfig,
    RGLRUConfig,
)


CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    d_ff=12288,
    vocab_size=256_000,
    attention=AttentionConfig(
        kind="gqa", num_heads=16, num_kv_heads=1, head_dim=256,
        rope_theta=10_000.0, sliding_window=2048,
    ),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    block_pattern=("rglru", "rglru", "local_attn"),
    activation="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embedding_scale=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        source=CONFIG.source,
        num_layers=3,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        attention=AttentionConfig(
            kind="gqa", num_heads=4, num_kv_heads=1, head_dim=32,
            sliding_window=16,
        ),
        rglru=RGLRUConfig(lru_width=128, conv_width=4),
        block_pattern=("rglru", "rglru", "local_attn"),
        activation="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        embedding_scale=True,
        remat=False,
    )
