"""Online recovery control plane (paper Sections 4-6 composed end-to-end).

R²CCL's headline claim is not any single mechanism but the *pipeline*:
bilateral-awareness detection, probe triangulation, pre-registered
connection migration, bandwidth-aware redistribution, and algorithm
re-selection composing into lossless low-millisecond failover.  This module
is that pipeline as an executable state machine:

    HEALTHY → DETECTING → DIAGNOSING → MIGRATING → REBALANCED → REPLANNED
        ^                                              |            |
        +------ re-probe success (all NICs healthy) ---+------------+

Each :meth:`ControlPlane.handle_failure` call plays one failure through the
stages, drawing every stage's latency from the corresponding offline model
(:mod:`core.detection`, :mod:`core.migration`, :mod:`core.balance`,
:mod:`core.planner`) and recording it in a per-stage :class:`RecoveryLedger`.
The returned :class:`core.event_sim.RecoveryDecision` feeds the co-simulated
discrete-event engine, so failover latency is *derived* from the pipeline
instead of the alpha-beta mode's ``R2CCL_MIGRATION_LATENCY`` constant — the
constant stays as the closed-form approximation and conformance target (a
clean single-NIC-down pipeline must land within 2x of it, in the paper's
low-millisecond hot-repair range).
"""

from __future__ import annotations

import dataclasses
import enum

from repro.core.balance import BalancePlan, rebalance
from repro.core.comm_sim import DETOUR_EFFICIENCY, _strategy_program
from repro.core.detection import (
    BROADCAST_LATENCY,
    PROBE_TIMEOUT,
    REPROBE_PERIOD,
    REPROBE_PERIOD_MAX,
    REPROBE_PERIOD_MIN,
    FailureDetector,
    adaptive_reprobe_period,
)
from repro.core.event_sim import ChunkProgress, RecoveryDecision
from repro.core.telemetry import TraceLog
from repro.core.failures import OUT_OF_SCOPE, Failure, FailureState, FailureType
from repro.core.migration import ROLLBACK_CPU_COST, RegistrationTable
from repro.core.planner import Collective, Planner, Strategy, collective_payload_factor
from repro.core.schedule import CollectiveProgram
from repro.core.topology import ClusterTopology

#: CPU time to compute a BalancePlan and install the detour routes (the plan
#: is a closed-form water-fill over <= g NICs; the cost is dominated by
#: updating the channel->NIC indirection tables on every device).
REBALANCE_COMPUTE_COST = 60e-6
#: CPU time for the planner's alpha-beta strategy sweep + schedule build.
REPLAN_COMPUTE_COST = 200e-6
#: A slow NIC raises no transport error; it is caught by the bandwidth
#: monitor's sampling window instead of a CQE (paper Section 4.2's periodic
#: probing, run against throughput counters).
SLOW_NIC_DETECT_LATENCY = 500e-6
#: Repeated flaps of the same NIC within one collective trigger algorithm
#: re-selection (the paper's "adapting to observed failure patterns").
DEFAULT_FLAP_REPLAN_THRESHOLD = 3
#: Sliding window (seconds of virtual time) over which flaps count toward the
#: replan threshold and the adaptive re-probe cadence.  Without it one
#: historical flap storm would push every later failure on that NIC over the
#: threshold forever; with it the threshold reflects *recent* flapping only.
DEFAULT_FLAP_WINDOW = 30.0


class RecoveryState(enum.Enum):
    HEALTHY = "healthy"
    DETECTING = "detecting"
    DIAGNOSING = "diagnosing"
    MIGRATING = "migrating"
    REBALANCED = "rebalanced"
    REPLANNED = "replanned"


#: ledger stage keys, in pipeline order
STAGES = ("detect", "diagnose", "migrate", "rebalance", "replan")


@dataclasses.dataclass
class LedgerEntry:
    """Per-stage latency breakdown of one recovery pipeline run."""

    failure: Failure | None            # None for the end-of-campaign replan
    t_start: float                     # virtual time the pipeline began
    stages: dict[str, float]           # stage -> latency (pipeline order)
    state_after: RecoveryState
    backup_nic: tuple[int, int] | None = None
    strategy: str | None = None        # planner choice when replanned
    balance_efficiency: float = 1.0    # residual-capacity factor installed
    #: fraction of the collective's payload still genuinely missing when a
    #: replan was planned (from the engine's chunk map); 1.0 = whole payload
    residual_fraction: float = 1.0
    #: how the pipeline learned of the failure: ``"cqe"`` (oracle transport
    #: event / OOB notify) or ``"monitor"`` (inferred from flow telemetry by
    #: :mod:`repro.runtime.inference` — no CQE ever fired)
    detected_by: str = "cqe"

    @property
    def total(self) -> float:
        return sum(self.stages.values())

    @property
    def hot_repair_latency(self) -> float:
        """Pipeline latency excluding the replan stage — the delay after
        which rolled-back transfers restart on the backup NIC."""
        return sum(v for k, v in self.stages.items() if k != "replan")


@dataclasses.dataclass
class RecoveryLedger:
    entries: list[LedgerEntry] = dataclasses.field(default_factory=list)

    def record(self, entry: LedgerEntry) -> None:
        self.entries.append(entry)

    def stage_totals(self) -> dict[str, float]:
        out = {s: 0.0 for s in STAGES}
        for e in self.entries:
            for k, v in e.stages.items():
                out[k] = out.get(k, 0.0) + v
        return out

    def total_latency(self) -> float:
        return sum(e.total for e in self.entries)


@dataclasses.dataclass
class RecoveryOutcome:
    """One handled failure: the ledger entry + the engine-facing decision."""

    entry: LedgerEntry
    decision: RecoveryDecision


class ControlPlane:
    """Closed-loop detect→diagnose→migrate→rebalance→replan runtime.

    Stateless about the data plane: it consumes failure/recovery events (from
    the co-simulated event engine, the serving engine, or a test harness),
    mutates its :class:`FailureState`, and emits :class:`RecoveryDecision`\\ s.
    """

    def __init__(
        self,
        cluster: ClusterTopology,
        *,
        payload_bytes: float = float(1 << 26),
        collective: Collective = Collective.ALL_REDUCE,
        flap_replan_threshold: int = DEFAULT_FLAP_REPLAN_THRESHOLD,
        flap_window: float = DEFAULT_FLAP_WINDOW,
        replan: bool = True,
        reprobe_base: float = REPROBE_PERIOD,
        state: FailureState | None = None,
        stream: str | None = None,
        trace: TraceLog | None = None,
        score: str = "alpha_beta",
    ):
        self.cluster = cluster
        self.payload_bytes = float(payload_bytes)
        self.collective = collective
        #: structured trace the pipeline mirrors itself into (``stage`` +
        #: ``transition`` records) — every ledger entry is reconstructible
        #: from it (:func:`repro.core.telemetry.ledger_entries_from_trace`)
        self.trace = trace
        #: name of the engine stream this control plane manages — the
        #: collective whose chunk map prices replans and whose program a
        #: replan decision swaps (co-running streams keep flowing).  None =
        #: the engine's primary stream (the single-stream case).
        self.stream = stream
        self.flap_replan_threshold = flap_replan_threshold
        if flap_window <= 0.0:
            raise ValueError(
                f"flap_window must be > 0 (seconds of virtual time over "
                f"which flaps count toward the replan threshold), got "
                f"{flap_window!r}")
        self.flap_window = float(flap_window)
        self.replan_enabled = replan
        #: base re-probe cadence; floor/ceiling scale with it so the adaptive
        #: back-off shape is preserved when a caller rescales the cadence to
        #: its collective's timescale
        if reprobe_base <= 0.0:
            raise ValueError(
                f"reprobe_base must be > 0 (seconds between probes), got "
                f"{reprobe_base!r}")
        self.reprobe_base = float(reprobe_base)
        #: planner cost model for every (re)plan: ``"alpha_beta"`` (default,
        #: closed forms) or ``"static"`` (price built programs through the
        #: static cost analyzer — opt-in, changes no default-path behavior)
        if score not in ("alpha_beta", "static"):
            raise ValueError(
                f"score must be 'alpha_beta' or 'static', got {score!r}")
        self.score = score
        self._reprobe_floor = REPROBE_PERIOD_MIN * self.reprobe_base / REPROBE_PERIOD
        self._reprobe_ceiling = REPROBE_PERIOD_MAX * self.reprobe_base / REPROBE_PERIOD
        self.failure_state = state if state is not None else FailureState()
        self.detector = FailureDetector(self.failure_state)
        self.planner = Planner(cluster)
        self.ledger = RecoveryLedger()
        self.state = RecoveryState.HEALTHY
        self.transitions: list[tuple[float, RecoveryState]] = [
            (0.0, RecoveryState.HEALTHY)]
        #: all-time flap totals per NIC (observability); decisions use the
        #: sliding-window timestamps below, never this monotonic counter
        self.flap_counts: dict[tuple[int, int], int] = {}
        #: virtual-time stamps of each NIC's flaps, pruned to ``flap_window``
        self.flap_history: dict[tuple[int, int], list[float]] = {}
        #: next scheduled re-probe per recovered NIC (adaptive cadence)
        self.next_reprobe: dict[tuple[int, int], float] = {}
        self.current_program: CollectiveProgram | None = None

    # -- flap bookkeeping ----------------------------------------------------
    def _record_flap(self, key: tuple[int, int], now: float) -> None:
        self.flap_counts[key] = self.flap_counts.get(key, 0) + 1
        hist = self.flap_history.setdefault(key, [])
        hist.append(now)
        # prune at record time only, so the history cannot grow without
        # bound; reads never mutate (a query with a later ``now`` must not
        # discard history a subsequent replan decision still needs)
        cutoff = now - self.flap_window
        while hist and hist[0] < cutoff:
            hist.pop(0)

    def recent_flaps(self, key: tuple[int, int], now: float) -> int:
        """Flaps of ``key`` within the sliding window ending at ``now``.
        Read-only: does not prune the history.  Bounded above by ``now`` so
        a *retrospective* query (reconstructing a past probe tick's cadence
        in :meth:`observe_physical_recovery`) never counts flaps from that
        tick's future."""
        cutoff = now - self.flap_window
        return sum(1 for t in self.flap_history.get(key, ())
                   if cutoff <= t <= now)

    def reprobe_period(self, key: tuple[int, int], now: float) -> float:
        """Adaptive re-probe cadence for ``key``: recent flaps back the
        period off exponentially; stable links probe faster than the base
        constant (floor/ceiling in :mod:`core.detection`, rescaled with
        ``reprobe_base``)."""
        return adaptive_reprobe_period(
            self.recent_flaps(key, now), base=self.reprobe_base,
            floor=self._reprobe_floor, ceiling=self._reprobe_ceiling)

    # -- state machine plumbing ---------------------------------------------
    def _transition(self, t: float, state: RecoveryState) -> None:
        self.state = state
        self.transitions.append((t, state))
        if self.trace is not None:
            self.trace.add("transition", t, state=state.value)

    def _trace_entry(self, entry: LedgerEntry) -> None:
        """Mirror one just-recorded ledger entry into the trace: one
        ``stage`` record per pipeline stage, stamped at the stage's virtual
        start time, carrying the entry's index — the ledger must be exactly
        reconstructible from these records (cross-validation contract)."""
        if self.trace is None:
            return
        idx = len(self.ledger.entries) - 1
        node = entry.failure.node if entry.failure is not None else -1
        rail = entry.failure.rail if entry.failure is not None else -1
        t = entry.t_start
        for stage in STAGES:
            if stage not in entry.stages:
                continue
            self.trace.add("stage", t, entry=idx, stage=stage,
                           dur=entry.stages[stage], node=node, rail=rail)
            t += entry.stages[stage]

    def _probe_points(
        self, failure: Failure
    ) -> tuple[tuple[int, int], tuple[int, int], tuple[int, int] | None]:
        """(src, peer, aux) NICs for triangulation: the failed connection's
        endpoints are ring neighbours on the same rail; the auxiliary vantage
        point needs a third node (with 2 nodes the location degrades to the
        LINK-vs-NIC ambiguity, which detection also models)."""
        n = self.cluster.num_nodes
        rail = max(failure.rail, 0)
        peer_node = (failure.node + 1) % n
        peer_rail = min(rail, len(self.cluster.nodes[peer_node].nics) - 1)
        aux = None
        if n >= 3:
            aux_node = (failure.node + 2) % n
            aux = (aux_node, min(rail, len(self.cluster.nodes[aux_node].nics) - 1))
        return (failure.node, rail), (peer_node, peer_rail), aux

    def _rebalance_plan(self, node_id: int) -> BalancePlan | None:
        node = self.cluster.nodes[node_id]
        g = self.cluster.devices_per_node
        factor = collective_payload_factor(self.collective)
        per_dev = [self.payload_bytes * factor / g] * g
        try:
            return rebalance(node, per_dev, self.failure_state.failed_nics)
        except ValueError:                 # no healthy NICs left on the node
            return None

    def _plan_program(
        self, payload_bytes: float | None = None,
    ) -> tuple[CollectiveProgram, str]:
        """Planner re-selection.  ``payload_bytes`` overrides the configured
        full payload — a mid-collective replan prices the *residual*
        collective (the engine's chunk map says how much is genuinely
        missing), not the whole payload."""
        payload = self.payload_bytes if payload_bytes is None else payload_bytes
        try:
            plan = self.planner.choose_strategy(
                self.collective, payload, self.failure_state,
                g=self.cluster.devices_per_node, score=self.score)
            strat = {
                Strategy.RING: "ring", Strategy.TREE: "ring",
                Strategy.HOT_REPAIR: "hot_repair", Strategy.BALANCE: "balance",
                Strategy.R2CCL_ALL_REDUCE: "r2ccl",
                Strategy.RECURSIVE: "recursive",
            }[plan.strategy]
            name = plan.strategy.value
        except ValueError:
            # A fully dead node leaves the planner nothing to price (zero
            # residual bandwidth everywhere it looks); fall back to the ring
            # schedule — completing the collective then needs node-level
            # recovery, which is out of R2CCL's NIC-failure scope.
            strat = name = "ring"
        prog = _strategy_program(strat, self.cluster, self.failure_state,
                                 g=self.cluster.devices_per_node)
        return prog, name

    # -- failure path --------------------------------------------------------
    def handle_failure(
        self,
        failure: Failure,
        now: float,
        progress: ChunkProgress | None = None,
        *,
        detected_by: str = "cqe",
    ) -> RecoveryOutcome | None:
        """Run the recovery pipeline for one failure event at virtual ``now``.

        ``progress`` is the co-simulated engine's chunk-map summary at the
        failure instant: when a replan is warranted, the planner prices the
        residual payload (what is genuinely missing) instead of the whole
        collective, and the ledger records the residual fraction.

        ``detected_by`` names the detection channel: ``"cqe"`` (default) is
        the oracle path — a transport error fired and bilateral awareness
        timed the detect/diagnose stages; ``"monitor"`` means a telemetry
        detector *inferred* the failure from flow counters (a silent
        failure), so detection is charged the bandwidth monitor's sampling
        latency and diagnosis the active probe burst + broadcast — there was
        no CQE to make it faster.

        Returns None (and records the failure as unsupported) when R2CCL
        cannot act on it — out-of-scope types, or non-escalating hard
        failures; fractional-severity degradations are always handled.
        """
        if detected_by not in ("cqe", "monitor"):
            raise ValueError(
                f"detected_by must be 'cqe' or 'monitor', got {detected_by!r}")
        if failure.ftype in OUT_OF_SCOPE:
            self.failure_state.unsupported.append(failure)
            return None
        escalated = failure.severity >= 1.0 and failure.supported
        if not escalated and failure.severity >= 1.0:
            self.failure_state.unsupported.append(failure)
            return None

        if failure.ftype is FailureType.LINK_FLAPPING or failure.recovers_at is not None:
            self._record_flap(failure.nic_key, now)

        stages: dict[str, float] = {}
        t = now
        backup: tuple[int, int] | None = None
        node_lost = False

        if escalated:
            if detected_by == "monitor":
                # DETECTING: no CQE fired — the bandwidth monitor's sampling
                # window caught the throughput collapse instead.
                self._transition(t, RecoveryState.DETECTING)
                stages["detect"] = SLOW_NIC_DETECT_LATENCY
                t += stages["detect"]
                # DIAGNOSING: an active probe burst localizes the rail (the
                # probe must *time out* — no error completion to shortcut
                # it), then the diagnosis broadcast.
                self._transition(t, RecoveryState.DIAGNOSING)
                stages["diagnose"] = PROBE_TIMEOUT + BROADCAST_LATENCY
                t += stages["diagnose"]
            else:
                # DETECTING: bilateral awareness — CQE error + OOB peer
                # notify.
                self._transition(t, RecoveryState.DETECTING)
                src, peer, aux = self._probe_points(failure)
                diag = self.detector.detect(failure, src, peer, aux)
                stages["detect"] = diag.detect_latency
                t += diag.detect_latency
                # DIAGNOSING: probe triangulation + diagnosis broadcast.
                self._transition(t, RecoveryState.DIAGNOSING)
                stages["diagnose"] = diag.localize_latency - diag.detect_latency
                t += stages["diagnose"]
            self.failure_state.apply(failure)
            # MIGRATING: rollback + pre-registered backup-NIC activation.
            self._transition(t, RecoveryState.MIGRATING)
            node = self.cluster.nodes[failure.node]
            table = RegistrationTable(node)
            device = max(failure.rail, 0)      # affinity: device d <-> rail d
            chain = table.failover_chain(device, self.failure_state.failed_nics)
            if chain:
                backup = chain[0].key
                stages["migrate"] = ROLLBACK_CPU_COST + table.activation_cost()
            else:
                node_lost = True               # every NIC dead: nothing to
                stages["migrate"] = ROLLBACK_CPU_COST   # migrate onto
            t += stages["migrate"]
        else:
            # Slow NIC: no transport error — the bandwidth monitor catches it.
            self._transition(t, RecoveryState.DETECTING)
            stages["detect"] = SLOW_NIC_DETECT_LATENCY
            t += stages["detect"]
            if detected_by == "monitor":
                # Telemetry-inferred: the monitor only flagged *a* slowdown;
                # the probe burst localizes which rail, then broadcasts.
                self._transition(t, RecoveryState.DIAGNOSING)
                stages["diagnose"] = PROBE_TIMEOUT + BROADCAST_LATENCY
                t += stages["diagnose"]

        # REBALANCED: redistribute the detoured flows across healthy NICs.
        # Only an escalated failure orphans flows onto backup NICs (paying
        # the PCIe/PXN detour efficiency); a slow NIC keeps its flows — the
        # water-fill just shifts load shares, which the engine's
        # severity-scaled capacity already reflects.
        eff = 1.0
        if escalated:
            plan = self._rebalance_plan(failure.node)
            if plan is not None and plan.completion_time > 0 and \
                    plan.completion_time != float("inf"):
                # How close the water-fill gets to the residual-bandwidth
                # ideal, times the calibrated PCIe/PXN detour efficiency.
                eff = DETOUR_EFFICIENCY * min(
                    1.0, plan.completion_time_ideal / plan.completion_time)
        stages["rebalance"] = REBALANCE_COMPUTE_COST
        t += stages["rebalance"]
        self._transition(t, RecoveryState.REBALANCED)

        # REPLANNED: algorithm re-selection when the diagnosis warrants it.
        # The chunk map makes it a *residual* replan: the planner prices the
        # payload still genuinely missing, and the engine will resume the
        # swapped-in program from the exact chunk state.
        prog: CollectiveProgram | None = None
        strategy: str | None = None
        replan_payload: float | None = None
        residual_fraction = 1.0
        need_replan = self.replan_enabled and (
            node_lost
            or self.recent_flaps(failure.nic_key, now) >= self.flap_replan_threshold
        )
        if need_replan:
            if progress is not None and progress.total_bytes > 0:
                residual_fraction = progress.residual_fraction
                if progress.residual_bytes > 0:
                    replan_payload = progress.residual_bytes
            prog, strategy = self._plan_program(replan_payload)
            # The mid-collective swap is priced on the residual; the program
            # carried into *subsequent* collectives moves the full payload
            # again, so it is re-priced at full size — a second planner
            # sweep, charged to the replan stage (its strategy may differ
            # from ``entry.strategy``, which records the swap's choice).
            sweeps = 1
            if replan_payload is not None:
                self.current_program = self._plan_program()[0]
                sweeps = 2
            else:
                self.current_program = prog
            stages["replan"] = sweeps * REPLAN_COMPUTE_COST + BROADCAST_LATENCY
            t += stages["replan"]
            self._transition(t, RecoveryState.REPLANNED)

        entry = LedgerEntry(
            failure=failure, t_start=now, stages=stages,
            state_after=self.state, backup_nic=backup, strategy=strategy,
            balance_efficiency=eff, residual_fraction=residual_fraction,
            detected_by=detected_by,
        )
        self.ledger.record(entry)
        self._trace_entry(entry)
        # The capacity scale is installed on the *node*: every stream whose
        # transfers cross the rebalanced NICs is re-priced by the detour
        # efficiency, not just the stream that observed the failure — the
        # engine's shared-capacity model applies it fabric-wide.  The replan
        # is stream-scoped: only the managed stream's program is swapped.
        scale = {failure.node: eff} if eff < 1.0 else None
        decision = RecoveryDecision(
            repair_latency=entry.hot_repair_latency,
            capacity_scale=scale,
            replan=prog,
            replan_delay=entry.total,
            replan_payload=replan_payload,
            replan_stream=self.stream,
        )
        return RecoveryOutcome(entry=entry, decision=decision)

    # -- recovery path -------------------------------------------------------
    def observe_physical_recovery(self, failure: Failure, now: float) -> float:
        """A component came back up physically at ``now``; return the virtual
        time at which the control plane *confirms* it — the next scheduled
        re-probe tick for this NIC (:attr:`next_reprobe`), so the adaptive
        cadence shapes recovery latency in the simulated timeline.  Failure
        state and capacity are cleared at the returned time, not at ``now``
        (call :meth:`handle_recovery` then).  A NIC with no probe schedule
        yet (first recovery) is confirmed immediately: the probe that
        noticed it is the confirming one.  Pure — safe to call repeatedly
        (a recovery re-announced across iteration boundaries)."""
        key = failure.nic_key
        tick = self.next_reprobe.get(key)
        if tick is None:
            return now
        # Probes kept firing every (adaptive) period while the NIC was down;
        # the confirming tick is the first one at/after the physical event.
        while tick < now:
            tick += self.reprobe_period(key, tick)
        return tick

    def handle_recovery(self, failure: Failure, now: float) -> bool:
        """Re-probe success for a previously failed component (flap up,
        repaired NIC).  Returns True when the whole cluster is healthy again
        — the recovery transition back to HEALTHY.  The next re-probe of
        this NIC is scheduled at the adaptive cadence: fast on stable links,
        backed off exponentially for recent flappers."""
        key = failure.nic_key
        _, next_probe = self.detector.reprobe(
            key, now, recovered=True,
            period=self.reprobe_period(key, now))
        self.next_reprobe[key] = next_probe
        if not self.failure_state.failed_nics:
            # Fully healthy again: a replanned program was a reaction to
            # degradation that no longer exists, so the next collective goes
            # back to the baseline algorithm — UNLESS this NIC is still a
            # known flapper (recent flaps at/over the threshold): then the
            # adaptation stays until the flap window drains (the paper's
            # "adapting to observed failure patterns").
            if self.recent_flaps(key, now) < self.flap_replan_threshold:
                self.current_program = None
            self._transition(now, RecoveryState.HEALTHY)
            return True
        return False

    # -- campaign end --------------------------------------------------------
    def finalize(self, now: float) -> CollectiveProgram | None:
        """Settle the state machine at the end of a failure campaign.

        Persistent degradation (failed NICs that never re-probed healthy)
        eventually triggers algorithm re-selection for the *next* collective
        — so every campaign terminates in HEALTHY or REPLANNED.
        """
        if self.failure_state.failed_nics and \
                self.state is not RecoveryState.REPLANNED and self.replan_enabled:
            prog, strategy = self._plan_program()
            stages = {"replan": REPLAN_COMPUTE_COST + BROADCAST_LATENCY}
            self._transition(now + stages["replan"], RecoveryState.REPLANNED)
            entry = LedgerEntry(
                failure=None, t_start=now, stages=stages,
                state_after=self.state, strategy=strategy)
            self.ledger.record(entry)
            self._trace_entry(entry)
            self.current_program = prog
            return prog
        if not self.failure_state.failed_nics and \
                self.state is not RecoveryState.HEALTHY and \
                self.state is not RecoveryState.REPLANNED:
            self._transition(now, RecoveryState.HEALTHY)
        return None
