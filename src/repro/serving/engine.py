"""Batched serving engine with failure-aware continuation.

A minimal vLLM-shaped engine: prefill builds per-layer caches, decode
iterates one token per step for the whole batch.  Failure handling follows
the paper's evaluation strategies:

  * ``restart``  — on failure, drop state, re-prefill and regenerate
                   (models the 35 s engine restart + reprocessing);
  * ``reroute``  — hand the batch to a healthy replica that also carries
                   its own load (service rate halves);
  * ``dejavu``   — KV replication: pay the replication overhead always and
                   a reconstruction penalty at failover;
  * ``r2ccl``    — transparent connection migration: the hiccup is the
                   recovery control plane's per-stage ledger total
                   (detect → diagnose → migrate → rebalance, from
                   ``repro.runtime``), then continue at the residual rate.

Compute runs for real (JAX); *network* failure costs are modeled in
virtual time via the co-simulated control-plane pipeline (r2ccl) and
``core.comm_sim`` constants (the baselines) because the container has no
NICs to kill — the same split as the paper's simulator experiments.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.comm_sim import (
    DEJAVU_OVERHEAD_RANGE,
    R2CCL_MIGRATION_LATENCY,
    VLLM_RESTART_DELAY,
    strategy_rate,
)
from repro.core.failures import Failure, FailureState
from repro.core.telemetry import TraceLog, stage_totals_from_trace
from repro.core.topology import make_cluster
from repro.models import apply_model, init_caches
from repro.runtime.control_plane import ControlPlane, LedgerEntry


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (T,) token ids
    max_new_tokens: int = 32


@dataclasses.dataclass
class RequestResult:
    tokens: list[int]
    ttft: float                        # virtual seconds
    tpot: float                        # mean time per output token
    total_latency: float
    failovers: int = 0


def make_prefill_fn(cfg: ModelConfig) -> Callable:
    @jax.jit
    def prefill(params, batch, caches):
        logits, caches, _ = apply_model(params, cfg, batch, mode="prefill",
                                        caches=caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return next_tok, caches
    return prefill


def make_decode_fn(cfg: ModelConfig) -> Callable:
    @jax.jit
    def decode(params, tokens, caches):
        logits, caches, _ = apply_model(
            params, cfg, {"tokens": tokens[:, None]}, mode="decode",
            caches=caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return next_tok, caches
    return decode


class ServingEngine:
    """One model replica serving batched greedy decoding."""

    def __init__(self, cfg: ModelConfig, params, *, context_len: int = 512,
                 strategy: str = "r2ccl", nics_per_node: int = 8,
                 tp: int = 8, pp: int = 2, cache_dtype=jnp.float32,
                 trace: TraceLog | None = None,
                 clock: Callable[[], float] | None = None):
        self.cfg = cfg
        self.params = params
        self.context_len = context_len
        self.strategy = strategy
        self.nics = nics_per_node
        # Host-clock seam: real compute (JAX prefill/decode) is *measured*,
        # never simulated, and the measurement enters through this injected
        # timer — the only wall-clock read the serving path makes.  Tests
        # inject a fake clock to make the whole engine a pure function of
        # its inputs (the determinism contract the lint gate enforces).
        self.clock = clock if clock is not None else time.perf_counter
        self.prefill = make_prefill_fn(cfg)
        self.decode = make_decode_fn(cfg)
        self.cache_dtype = cache_dtype
        self.failure_state = FailureState()
        self.failovers = 0
        # steady-state replication tax for DejaVu-style KV streaming
        self.dejavu_tax = float(np.mean(DEJAVU_OVERHEAD_RANGE))
        # The r2ccl hiccup is the recovery pipeline's ledger total, derived
        # per failure on this replica's node span (TP stays intra-node, so
        # the replica spans pp nodes; shared FailureState so the control
        # plane sees what the engine sees).  Serving has no collective
        # program to swap, so replanning is off.
        self.control_plane = ControlPlane(
            make_cluster(max(2, pp), nics_per_node), replan=False,
            state=self.failure_state)
        # Structured trace shared with the control plane: every recovery
        # pipeline run mirrors its per-stage spans here, so a serving
        # hiccup is attributable to the stage that caused it.
        self.trace = trace if trace is not None else TraceLog()
        self.control_plane.trace = self.trace
        self.last_recovery: LedgerEntry | None = None

    # -- failure plumbing ---------------------------------------------------
    def inject_failure(self, failure: Failure, at: float = 0.0) -> bool:
        """Apply a failure; returns whether serving can continue in-place."""
        ok = self.failure_state.apply(failure)
        self.trace.add("failure", at, node=failure.node, rail=failure.rail,
                       kind=failure.ftype.value, severity=failure.severity,
                       silent=failure.silent)
        return ok and self.strategy in ("r2ccl", "dejavu")

    def hiccup_attribution(self, *, normalize: bool = False) -> dict[str, float]:
        """Attribute serving hiccup time to recovery-pipeline stages.

        Reconstructed purely from the trace's ``stage`` spans (the control
        plane mirrors every ledger stage there), so the answer to "what was
        the token stall spent on" — detect vs diagnose vs migrate vs
        rebalance — comes from the export, not from engine-internal state.
        Returns per-stage virtual seconds (or fractions of the hiccup total
        with ``normalize=True``); empty for strategies that never run the
        pipeline (restart / reroute / dejavu)."""
        totals = stage_totals_from_trace(self.trace.records)
        if not normalize:
            return totals
        total = sum(totals.values())
        if total <= 0.0:
            return {}
        return {k: v / total for k, v in totals.items()}

    def _degraded_rate(self) -> float:
        """Residual comm-rate multiplier under the current failures."""
        lost = len(self.failure_state.failed_nics) / self.nics
        lost = min(lost, 0.99)
        if self.strategy == "r2ccl":
            return strategy_rate("balance", 1.0, lost, n_nodes=2, g=self.nics)
        return 1.0 - lost

    # -- serving ------------------------------------------------------------
    def run_batch(self, requests: list[Request], *,
                  fail_at_step: int | None = None,
                  failure: Failure | None = None) -> list[RequestResult]:
        """Serve a batch, optionally injecting ``failure`` at decode step
        ``fail_at_step``.  Returns per-request latency accounting in
        *virtual* time (real compute + modeled network events)."""
        cfg = self.cfg
        B = len(requests)
        T = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(requests):
            toks[i, T - len(r.prompt):] = r.prompt    # left-pad
        max_new = max(r.max_new_tokens for r in requests)

        caches = init_caches(cfg, B, self.context_len, dtype=self.cache_dtype)
        batch = {"tokens": jnp.asarray(toks)}

        vtime = 0.0
        t0 = self.clock()
        next_tok, caches = self.prefill(self.params, batch, caches)
        next_tok.block_until_ready()
        prefill_time = self.clock() - t0
        vtime += prefill_time
        ttft = vtime
        failovers = 0

        generated = [[int(next_tok[i])] for i in range(B)]
        decode_times: list[float] = []
        rate = 1.0
        step = 0
        while step < max_new - 1:
            if fail_at_step is not None and step == fail_at_step and failure is not None:
                can_continue = self.inject_failure(failure, at=vtime)
                if self.strategy == "restart":
                    vtime += VLLM_RESTART_DELAY
                    # reprocess everything generated so far
                    vtime += prefill_time + sum(decode_times)
                    failovers += 1
                elif self.strategy == "reroute":
                    rate = 0.5                        # doubled load on the peer
                    vtime += prefill_time             # re-prefill on the peer
                    failovers += 1
                elif self.strategy == "dejavu":
                    vtime += sum(decode_times) * 0.25  # reconstruct un-replicated tail
                    failovers += 1
                elif can_continue:                     # r2ccl hot repair
                    # Run the detect→diagnose→migrate→rebalance pipeline:
                    # the hiccup is its ledger total, not a constant.
                    outcome = None
                    if 0 <= failure.node < len(self.control_plane.cluster.nodes):
                        outcome = self.control_plane.handle_failure(
                            failure, vtime)
                    if outcome is not None:
                        self.last_recovery = outcome.entry
                        vtime += outcome.entry.total
                    else:          # outside this replica / out-of-pipeline
                        vtime += R2CCL_MIGRATION_LATENCY
                    rate = self._degraded_rate()
                    failovers += 1
            t0 = self.clock()
            next_tok, caches = self.decode(self.params, next_tok, caches)
            next_tok.block_until_ready()
            dt = self.clock() - t0
            base = dt * (1.0 + (self.dejavu_tax if self.strategy == "dejavu" else 0.0))
            decode_times.append(base / rate)
            vtime += base / rate
            for i in range(B):
                if len(generated[i]) < requests[i].max_new_tokens:
                    generated[i].append(int(next_tok[i]))
            step += 1

        self.failovers += failovers
        results = []
        for i, r in enumerate(requests):
            n = max(len(generated[i]) - 1, 1)
            results.append(RequestResult(
                tokens=generated[i],
                ttft=ttft,
                tpot=(vtime - ttft) / n,
                total_latency=vtime,
                failovers=failovers,
            ))
        return results


@dataclasses.dataclass
class TraceResult:
    qps: float
    ttft_p50: float
    ttft_p95: float
    tpot_p50: float
    completed: int
    failovers: int


def serve_trace(
    engine: "ServingEngine",
    *,
    qps: float,
    duration: float,
    prompt_len: int = 32,
    max_new_tokens: int = 8,
    batch_window: float = 0.05,
    fail_time: float | None = None,
    failure: Failure | None = None,
    seed: int = 0,
) -> TraceResult:
    """Arrival-driven serving on the real engine (virtual-time queueing).

    Fixed-rate arrivals are micro-batched in ``batch_window`` slices and fed
    through the engine; per-request TTFT = queue wait + measured prefill,
    TPOT from measured decode steps.  A failure can be injected at
    ``fail_time`` (virtual seconds) with the engine's configured strategy —
    this is the in-engine analogue of the paper's Fig. 11 methodology
    (their Figs use the alpha-beta simulator path in benchmarks/).
    """
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    while t < duration:
        arrivals.append(t)
        t += 1.0 / max(qps, 1e-9)

    ttfts: list[float] = []
    tpots: list[float] = []
    server_free = 0.0
    injected = False
    i = 0
    while i < len(arrivals):
        # group arrivals within the batch window
        j = i
        while j + 1 < len(arrivals) and arrivals[j + 1] - arrivals[i] < batch_window:
            j += 1
        group = arrivals[i:j + 1]
        start = max(group[-1], server_free)
        fail_step = None
        fail_obj = None
        if (fail_time is not None and not injected and start >= fail_time
                and failure is not None):
            fail_step, fail_obj = 1, failure
            injected = True
        reqs = [Request(prompt=rng.integers(0, engine.cfg.vocab_size, prompt_len),
                        max_new_tokens=max_new_tokens) for _ in group]
        results = engine.run_batch(reqs, fail_at_step=fail_step, failure=fail_obj)
        for arr, r in zip(group, results):
            ttfts.append((start - arr) + r.ttft)
            tpots.append(r.tpot)
        server_free = start + results[0].total_latency
        i = j + 1

    ttfts.sort()
    tpots.sort()
    pct = lambda xs, p: xs[min(len(xs) - 1, int(p * len(xs)))] if xs else float("inf")
    return TraceResult(
        qps=qps,
        ttft_p50=pct(ttfts, 0.50), ttft_p95=pct(ttfts, 0.95),
        tpot_p50=pct(tpots, 0.50),
        completed=len(ttfts),
        failovers=engine.failovers,
    )
