"""Telemetry-inferred failure detection: the oracle-free closed loop.

Every failure path so far handed the control plane a ground-truth
:class:`~repro.core.failures.Failure` object at the injection instant.
Real monitoring planes never get that: they see *counters* — per-rank
egress rates dipping, in-flight transfers stalling, probe RTTs timing out
— and must turn them into a diagnosis.  This module closes that loop over
the engine's telemetry plane (:mod:`repro.core.telemetry`):

* :class:`TelemetryDetector` rides the engine's sampling tick
  (``Telemetry.observer``).  It consumes **only** measured signals — the
  metrics registry's ``rank.tx_rate`` / ``rank.inflight`` series and
  active probe outcomes (:meth:`EventSimulator.probe_rank`) — never the
  engine's failure schedule.
* Passive trigger: a per-rank running-max baseline; a rank whose measured
  rate drops below ``drop_threshold`` of baseline while transfers are in
  flight, for ``consecutive`` samples, flags an anomaly.  A second,
  stream-level trigger catches full stalls the rank gate misses: goodput
  collapsing below threshold while the stream's outstanding work queue
  (``stream.remaining``) is non-empty — a hard failure can drain every
  in-flight transfer, but it cannot empty the queue.  The passive
  signal alone cannot *localize*: under max-min fairness a single
  degraded rank drags every rank's bottleneck rate down together, so a
  flagged sample window triggers an **active probe burst** over all
  ranks' rails, and the rails measuring lost bandwidth become inferred
  failures.
* Each inferred failure runs the existing recovery pipeline —
  :meth:`ControlPlane.handle_failure` with ``detected_by="monitor"`` (no
  CQE ever fired, so detection is charged the monitor's sampling latency
  and diagnosis the probe timeout) — and the resulting
  :class:`RecoveryDecision` is installed through
  :meth:`EventSimulator.apply_inferred_decision`: the same
  capacity-rebalance and mid-collective-replan path the oracle mode uses.
* Flagged rails are re-probed every tick; when the measured bandwidth
  returns, the inferred degradation is revoked and the control plane's
  recovery path runs — flaps are detectable end-to-end, with the
  detection *and* clearing latency visible in the trace.

:func:`score_detections` grades a run from its trace alone: injected
``failure`` records (ground truth, logged by the engine even for silent
failures) against ``detection`` records (the detector's claims), yielding
matched detection latencies plus false-positive / false-negative counts —
the measurable detection quality the paper's Section 4 argues for.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

from repro.core.failures import Failure, FailureType
from repro.core.telemetry import Telemetry

from .control_plane import ControlPlane, RecoveryOutcome

#: measured lost-bandwidth fraction below which a probed rail is considered
#: healthy (floating-point guard; a real monitor has measurement noise)
_LOSS_EPS = 1e-9


@dataclasses.dataclass
class DetectorConfig:
    """Thresholds of the goodput-drop heuristic.

    ``drop_threshold`` is the fraction of the per-rank baseline rate below
    which a sample is anomalous; ``consecutive`` anomalous samples (with
    transfers in flight) trigger the probe burst; ``warmup_samples`` ticks
    are observed before any judgment so the baseline reflects steady state;
    ``recover_threshold`` is the measured-bandwidth fraction at which a
    flagged rail is declared healthy again.
    """

    drop_threshold: float = 0.55
    consecutive: int = 2
    warmup_samples: int = 3
    recover_threshold: float = 0.9

    def __post_init__(self) -> None:
        if not 0.0 < self.drop_threshold < 1.0:
            raise ValueError(
                f"drop_threshold must be in (0, 1), got "
                f"{self.drop_threshold!r}")
        if self.consecutive < 1:
            raise ValueError(
                f"consecutive must be >= 1, got {self.consecutive!r}")
        if self.warmup_samples < 1:
            raise ValueError(
                f"warmup_samples must be >= 1, got {self.warmup_samples!r}")
        if not 0.0 < self.recover_threshold <= 1.0:
            raise ValueError(
                f"recover_threshold must be in (0, 1], got "
                f"{self.recover_threshold!r}")


@dataclasses.dataclass
class DetectionEvent:
    """One failure the detector inferred and played through the pipeline."""

    failure: Failure                   # the *inferred* failure object
    detected_at: float                 # sample tick that localized it
    outcome: RecoveryOutcome | None    # pipeline result (None = unsupported)

    @property
    def cleared(self) -> bool:
        return self.cleared_at is not None

    cleared_at: float | None = None


class TelemetryDetector:
    """Goodput-drop + probe-burst detector driving the recovery pipeline.

    Attach as ``Telemetry(observer=...)``; the engine calls
    :meth:`on_sample` at every monitoring tick.  All decisions are made
    from the metrics registry and active probes — the injected failure
    schedule is never consulted.
    """

    def __init__(self, control_plane: ControlPlane,
                 config: DetectorConfig | None = None):
        self.cp = control_plane
        self.config = config or DetectorConfig()
        self.detections: list[DetectionEvent] = []
        self._baseline: dict[int, float] = {}
        self._anomalous: dict[int, int] = {}
        self._stream_baseline: dict[tuple, float] = {}
        self._stream_anomalous: dict[tuple, int] = {}
        self._samples = 0
        #: rails currently attributed: (node, rail) -> inferred Failure
        self._flagged: dict[tuple[int, int], Failure] = {}

    # -- engine callback -----------------------------------------------------
    def on_sample(self, sim: Any, now: float) -> None:
        self._samples += 1
        self._watch_flagged(sim, now)
        cfg = self.config
        reg = sim.telemetry.registry
        trigger = False
        for r in range(sim.n):
            rate = reg.last("rank.tx_rate", (r,))
            inflight = reg.last("rank.inflight", (r,))
            if rate is None:
                continue
            base = self._baseline.get(r, 0.0)
            anomalous = (
                self._samples > cfg.warmup_samples
                and base > 0.0
                and (inflight or 0) > 0
                and rate < cfg.drop_threshold * base
            )
            if anomalous:
                self._anomalous[r] = self._anomalous.get(r, 0) + 1
                if self._anomalous[r] >= cfg.consecutive:
                    trigger = True
            else:
                self._anomalous[r] = 0
                self._baseline[r] = max(base, rate)
        # stream-level stall trigger: the rank gate requires transfers in
        # flight, which goes dark when a hard silent failure stalls the
        # whole ring (the dependency chain drains in-flight to zero while
        # rolled-back transfers wait out their repair).  The outstanding
        # work-queue depth is still observable and non-empty, and zero
        # goodput against a non-empty queue IS the anomaly.
        for name, labels in reg.names():
            if name != "stream.goodput":
                continue
            gp = reg.last(name, labels)
            remaining = reg.last("stream.remaining", labels) or 0
            if gp is None:
                continue
            base = self._stream_baseline.get(labels, 0.0)
            anomalous = (
                self._samples > cfg.warmup_samples
                and base > 0.0
                and remaining > 0
                and gp < cfg.drop_threshold * base
            )
            if anomalous:
                count = self._stream_anomalous.get(labels, 0) + 1
                self._stream_anomalous[labels] = count
                if count >= cfg.consecutive:
                    trigger = True
            else:
                self._stream_anomalous[labels] = 0
                self._stream_baseline[labels] = max(base, gp)
        if trigger:
            self._localize(sim, now)
            # restart the counting window either way: one degradation must
            # not re-trigger a probe burst on every subsequent sample
            self._anomalous.clear()
            self._stream_anomalous.clear()

    # -- localization --------------------------------------------------------
    def _localize(self, sim: Any, now: float) -> None:
        """Active probe burst over every rank's rails.  The passive trigger
        says *something* is slow; under the water-fill every rank slows
        together, so only probing tells us where."""
        for node in range(sim.n):
            for rail, loss in sim.probe_rank(now, node):
                key = (node, rail)
                if loss <= _LOSS_EPS or key in self._flagged:
                    continue
                self._infer(sim, now, node, rail, loss)

    def _infer(self, sim: Any, now: float, node: int, rail: int,
               loss: float) -> None:
        # The inferred object is the monitor's *claim*, stamped at the
        # inference instant — deliberately a different value (at_time=now)
        # from any injected Failure, so the capacity factors it keys in the
        # engine can never collide with the injection's own bookkeeping.
        if loss >= 1.0:
            inferred = Failure(FailureType.NIC_HARDWARE, node, rail,
                               at_time=now)
        else:
            inferred = Failure(FailureType.SLOW_NIC, node, rail, at_time=now,
                               escalates=False, severity=min(1.0, loss))
        outcome = self.cp.handle_failure(
            inferred, now, progress=sim.chunk_progress(self.cp.stream),
            detected_by="monitor")
        if outcome is not None:
            sim.apply_inferred_decision(now, inferred, outcome.decision)
        if sim.telemetry is not None:
            sim.telemetry.trace.add(
                "detection", now, node=node, rail=rail,
                kind=inferred.ftype.value, severity=inferred.severity)
        self._flagged[(node, rail)] = inferred
        self.detections.append(DetectionEvent(
            failure=inferred, detected_at=now, outcome=outcome))

    # -- recovery watch ------------------------------------------------------
    def _watch_flagged(self, sim: Any, now: float) -> None:
        """Re-probe every attributed rail; measured bandwidth back above the
        recovery threshold clears the inference through the control plane's
        normal recovery path."""
        by_node: dict[int, dict[int, float]] = {}
        for (node, rail), inferred in list(self._flagged.items()):
            if node not in by_node:
                by_node[node] = dict(sim.probe_rank(now, node))
            loss = by_node[node].get(rail, 0.0)
            healthy_frac = 1.0 - loss
            if healthy_frac < self.config.recover_threshold:
                continue
            sim.revoke_inferred(inferred)
            self.cp.handle_recovery(inferred, now)
            if sim.telemetry is not None:
                sim.telemetry.trace.add("detection_cleared", now,
                                        node=node, rail=rail)
            del self._flagged[(node, rail)]
            for ev in reversed(self.detections):
                if ev.failure is inferred:
                    ev.cleared_at = now
                    break

    @property
    def flagged(self) -> dict[tuple[int, int], Failure]:
        return dict(self._flagged)


# ---------------------------------------------------------------------------
# detection-quality scoring (trace-based)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DetectionScore:
    """Ground-truth comparison of one run's trace.

    ``latencies[i]`` is detection minus injection time of the i-th matched
    pair.  A detection with no prior unmatched injection on the same
    (node, rail) is a false positive; an injection never detected (before
    its recovery, when it has one) is a false negative.
    """

    matched: list[tuple[dict, dict]]
    latencies: list[float]
    false_positives: int
    false_negatives: int

    @property
    def true_positives(self) -> int:
        return len(self.matched)

    @property
    def mean_latency(self) -> float:
        return (sum(self.latencies) / len(self.latencies)
                if self.latencies else 0.0)

    @property
    def max_latency(self) -> float:
        return max(self.latencies, default=0.0)


def score_detections(
    records: Iterable[Mapping[str, Any]],
) -> DetectionScore:
    """Grade ``detection`` records against injected ``failure`` records.

    Matching is per (node, rail) in time order: each detection claims the
    earliest not-yet-matched injection at/before its timestamp.  An
    injection that recovered (``recovery`` record for the same rail) before
    any detection claimed it counts as a false negative — the monitor
    missed the whole failure window.  Works on a live ``TraceLog.records``
    list or re-parsed JSONL.
    """
    by_key_failures: dict[tuple[int, int], list[dict]] = {}
    by_key_detections: dict[tuple[int, int], list[dict]] = {}
    by_key_recoveries: dict[tuple[int, int], list[float]] = {}
    for r in records:
        rt = r.get("type")
        if rt not in ("failure", "detection", "recovery"):
            continue
        key = (int(r["node"]), int(r["rail"]))
        if rt == "failure":
            by_key_failures.setdefault(key, []).append(dict(r))
        elif rt == "detection":
            by_key_detections.setdefault(key, []).append(dict(r))
        else:
            by_key_recoveries.setdefault(key, []).append(float(r["t"]))

    matched: list[tuple[dict, dict]] = []
    latencies: list[float] = []
    false_positives = 0
    false_negatives = 0
    for key in sorted(set(by_key_failures) | set(by_key_detections)):
        fails = sorted(by_key_failures.get(key, []), key=lambda r: r["t"])
        dets = sorted(by_key_detections.get(key, []), key=lambda r: r["t"])
        unclaimed = list(fails)
        for det in dets:
            candidates = [f for f in unclaimed if f["t"] <= det["t"]]
            if not candidates:
                false_positives += 1
                continue
            f = candidates[0]
            unclaimed.remove(f)
            matched.append((f, det))
            latencies.append(det["t"] - f["t"])
        false_negatives += len(unclaimed)
    return DetectionScore(matched=matched, latencies=latencies,
                          false_positives=false_positives,
                          false_negatives=false_negatives)


def make_telemetry_detector(
    control_plane: ControlPlane,
    healthy_time: float,
    *,
    samples: int = 64,
    config: DetectorConfig | None = None,
) -> Telemetry:
    """A ready-wired telemetry plane for one collective: sampling cadence
    scaled to the healthy collective time, the detector attached as the
    observer, and the control plane mirroring its ledger into the shared
    trace (cross-validation contract)."""
    telemetry = Telemetry.for_duration(healthy_time, samples=samples)
    telemetry.observer = TelemetryDetector(control_plane, config)
    if control_plane.trace is None:
        control_plane.trace = telemetry.trace
    return telemetry
