"""Paper Figs. 11-13: vLLM-style inference under NIC failures.

TTFT vs QPS for Llama-3.1-70B/405B (TP=8 PP=2) under no-failure /
R2CCL-Balance / restart / reroute; TPOT overheads; multi-failure steady
state.  Paper claims: R2CCL TTFT overhead 0-0.6% (70B) and 0.3-3% (405B),
TPOT overhead <3%, 1.2-8.7x more throughput than restart under a 5s SLO,
multi-failure overhead 0-5%."""

from __future__ import annotations

import numpy as np

from repro.core.comm_sim import ServeJob, request_latency_under_failure, ttft_vs_qps
from repro.core.failures import concentrated_failures, single_nic_failure
from repro.core.topology import IB_NIC_BW, make_cluster

from .common import Reporter


def _sustained_qps(points, slo: float) -> float:
    """Highest offered load whose p50 TTFT meets the SLO (median service
    objective; the p95 is outage-dominated during the failure window)."""
    best = 0.0
    for p in points:
        if p["p50"] <= slo:
            best = max(best, p["qps"])
    return best


def run() -> None:
    r = Reporter("inference_fig11_13")
    cluster = make_cluster(2, 8, nic_bandwidth=IB_NIC_BW)
    fail = single_nic_failure(0, 0)

    for params, label in [(70e9, "70b"), (405e9, "405b")]:
        job = ServeJob(params=params, tp=8, pp=2, prompt_tokens=2000,
                       gen_tokens=256)
        from repro.core.failures import FailureState as _FS
        svc = job.prefill_time(cluster, _FS())
        # sweep to ~2.4x the healthy service rate so reroute (rate/2) and
        # restart saturate inside the grid
        qps_grid = list(np.linspace(0.05, 2.4, 32) / svc)
        base = ttft_vs_qps(job, cluster, [], qps_grid, strategy="no_failure")
        r2 = ttft_vs_qps(job, cluster, fail, qps_grid, strategy="r2ccl")
        rer = ttft_vs_qps(job, cluster, fail, qps_grid, strategy="reroute")
        res = ttft_vs_qps(job, cluster, fail, qps_grid, strategy="restart")
        # pre-saturation overhead (low-QPS p50)
        ov = r2[0]["p50"] / base[0]["p50"] - 1.0
        r.row(f"{label}_ttft_overhead_presat", ov,
              "paper: 0-0.6% (70b), 0.3-3% (405b)")
        slo = max(5.0, 3.0 * svc)
        q_r2, q_res = (_sustained_qps(p, slo) for p in (r2, res))
        r.row(f"{label}_qps_vs_restart", q_r2 / max(q_res, 1e-9),
              "paper: 1.2-8.7x")
        # reroute in steady state: the healthy replica carries doubled load,
        # so its saturation point is 0.5/svc vs r2ccl's ~(1-eps)/svc.
        q_rer = 0.5 / svc
        r.row(f"{label}_qps_vs_reroute", min(q_r2, 1.0 / svc) / q_rer,
              "paper: 1.6-1.9x")

    # --- TPOT under failure (405B TP+PP, Fig. 12/13) -------------------------
    job = ServeJob(params=405e9, tp=8, pp=2, prompt_tokens=2000, gen_tokens=256)
    from repro.core.failures import FailureState
    healthy = FailureState()
    st = FailureState()
    for f in fail:
        st.apply(f)
    d0 = job.decode_step_time(cluster, healthy)
    d1 = job.decode_step_time(cluster, st)
    r.row("405b_tpot_overhead_1fail", d1 / d0 - 1.0, "paper: <3%")

    # multiple failures on one node (Fig. 13): up to 5 NICs lost
    for k in (2, 3, 5):
        stk = FailureState()
        for f in concentrated_failures(0, list(range(k))):
            stk.apply(f)
        dk = job.decode_step_time(cluster, stk)
        r.row(f"405b_tpot_overhead_{k}fail", dk / d0 - 1.0, "paper: 0-5%")

    # headline: <3% inference overhead
    r.row("headline_inference_overhead_lt_3pct",
          float(d1 / d0 - 1.0 < 0.03), f"measured {d1/d0-1.0:.2%}")
    r.save()


if __name__ == "__main__":
    run()
