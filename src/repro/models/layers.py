"""Shared model layers: norms, RoPE, blockwise GQA attention, gated MLPs.

Pure-JAX (no flax): parameters are nested dicts of ``jnp`` arrays; every
``init_*`` returns ``(params, axes)`` where ``axes`` mirrors the params
pytree with tuples of *logical* axis names used by the sharding rules in
``launch/mesh.py``.

The attention here is the **blockwise (online-softmax) reference**: it is
the mathematical oracle for the Pallas flash kernel in
``kernels/flash_attention.py`` and the implementation used on CPU and for
dry-run lowering (Pallas custom-calls don't lower on the CPU backend).
Memory stays O(block^2) regardless of sequence length, which is what lets
the 32k/500k shapes compile with sane footprints.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict
Axes = dict

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(dtype)


def init_layernorm(d: int):
    return (
        {"scale": jnp.zeros((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps) * (1.0 + params["scale"]) + params["bias"]
    return y.astype(dtype)


def make_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return init_rmsnorm(d), rmsnorm
    if kind == "layernorm":
        return init_layernorm(d), layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., T, D/2)
    angles = angles[..., None, :]                                # (..., T, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# soft capping (gemma2)
# ---------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Blockwise attention (online softmax) — the flash-attention oracle
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, *, causal: bool, window: int | None,
                prefix_len, k_valid_len):
    """(qb, kb) boolean mask from absolute positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        c = kp <= qp
        if prefix_len is not None:
            c = c | (kp < prefix_len)     # prefix-LM: bidirectional prefix
        m = m & c
    if window is not None:
        m = m & (qp - kp < window)
    if k_valid_len is not None:
        m = m & (kp < k_valid_len)
    return m


def blockwise_attention(
    q: jnp.ndarray,              # (B, Tq, KVH, G, D)  — grouped query heads
    k: jnp.ndarray,              # (B, Tk, KVH, D)
    v: jnp.ndarray,              # (B, Tk, KVH, D)
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len=None,             # int | scalar array | None
    logit_cap: float | None = None,
    q_offset=0,                  # absolute position of q[0] (decode)
    k_valid_len=None,            # valid prefix of k/v (cache fill level)
    q_block: int = 512,
    kv_block: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    """Memory-bounded attention with online softmax over KV blocks.

    Returns (B, Tq, KVH, G, D).  All masking variants used by the model zoo
    (causal, sliding-window, prefix-LM, cache-validity) are expressed in
    ``_block_mask`` so the Pallas kernel and this oracle share semantics.
    """
    B, Tq, KVH, G, D = q.shape
    Tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q_block = min(q_block, Tq)
    kv_block = min(kv_block, Tk)
    # pad to block multiples
    pq = (-Tq) % q_block
    pk = (-Tk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_valid_len = Tk if k_valid_len is None else k_valid_len
    nq, nk = q.shape[1] // q_block, k.shape[1] // kv_block

    qf = (q * scale).astype(jnp.float32).reshape(B, nq, q_block, KVH, G, D)
    kf = k.astype(jnp.float32).reshape(B, nk, kv_block, KVH, D)
    vf = v.astype(jnp.float32).reshape(B, nk, kv_block, KVH, D)
    q_offset = jnp.asarray(q_offset)

    def q_step(_, qi):
        qb = qf[:, qi]                                  # (B, qb, KVH, G, D)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            kb = kf[:, ki]                              # (B, kb, KVH, D)
            vb = vf[:, ki]
            k_pos = ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb)  # (B,KVH,G,qb,kb)
            s = softcap(s, logit_cap)
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix_len, k_valid_len=k_valid_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)                  # (B,KVH,G,qb)
            m_new = jnp.maximum(m_prev, m_cur)
            # guard fully-masked rows
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KVH, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, q_block, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,KVH,G,qb,D)
        return None, out.transpose(0, 3, 1, 2, 4)        # (B,qb,KVH,G,D)

    _, blocks = lax.scan(q_step, None, jnp.arange(nq))   # (nq,B,qb,KVH,G,D)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_block, KVH, G, D)
    return out[:, :Tq].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,              # (B, 1, KVH, G, D)
    k: jnp.ndarray,              # (B, S, KVH, D)   — cache
    v: jnp.ndarray,
    *,
    q_position,                  # absolute position of the query token
    window: int | None = None,
    logit_cap: float | None = None,
    k_positions=None,            # (S,) absolute positions (ring-buffer cache)
    k_valid_len=None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Single-token attention over a (possibly ring-buffered) KV cache."""
    B, _, KVH, G, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = (q[:, 0] * scale).astype(jnp.float32)           # (B,KVH,G,D)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, kf)            # (B,KVH,G,S)
    s = softcap(s, logit_cap)
    k_pos = k_positions if k_positions is not None else jnp.arange(S)
    mask = (k_pos >= 0) & (k_pos <= q_position)   # -1 marks empty cache slots
    if window is not None:
        mask = mask & (q_position - k_pos < window)
    if k_valid_len is not None:
        mask = mask & (jnp.arange(S) < k_valid_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out[:, None].astype(q.dtype)                  # (B,1,KVH,G,D)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + cache handling)
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, num_heads: int, num_kv_heads: int, head_dim: int,
             dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": dense_init(k1, (d_model, num_heads, head_dim), d_model, dtype),
        "wk": dense_init(k2, (d_model, num_kv_heads, head_dim), d_model, dtype),
        "wv": dense_init(k3, (d_model, num_kv_heads, head_dim), d_model, dtype),
        "wo": dense_init(k4, (num_heads, head_dim, d_model),
                         num_heads * head_dim, dtype),
    }
    axes = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    return params, axes


@dataclasses.dataclass
class KVCache:
    """Per-layer KV cache; ``size`` may be a sliding window (ring buffer)."""

    k: jnp.ndarray               # (B, S, KVH, D)
    v: jnp.ndarray
    positions: jnp.ndarray       # (B, S) absolute position of each slot (-1 empty)
    index: jnp.ndarray           # scalar int32: next absolute position


def init_kv_cache(batch: int, size: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, size, num_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, size, num_kv_heads, head_dim), dtype),
        positions=jnp.full((batch, size), -1, jnp.int32),
        index=jnp.zeros((), jnp.int32),
    )


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "positions", "index"], meta_fields=[]
)


def gqa_attention(
    params: Params,
    x: jnp.ndarray,              # (B, T, d)
    *,
    num_kv_heads: int,
    num_heads: int,
    head_dim: int,
    rope_theta: float = 10_000.0,
    use_rope: bool = True,
    causal: bool = True,
    window: int | None = None,
    prefix_len=None,
    logit_cap: float | None = None,
    cache: KVCache | None = None,
    mode: str = "train",         # train | prefill | decode
    q_scale: float | None = None,
) -> tuple[jnp.ndarray, KVCache | None]:
    """GQA attention with optional sliding window / prefix-LM / KV cache."""
    B, T, d = x.shape
    G = num_heads // num_kv_heads
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])     # (B,T,H,D)
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])     # (B,T,KVH,D)
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])

    if mode == "decode":
        assert cache is not None and T == 1
        pos = cache.index
        if use_rope:
            q = apply_rope(q, jnp.full((B, 1), pos), rope_theta)
            k = apply_rope(k, jnp.full((B, 1), pos), rope_theta)
        S = cache.k.shape[1]
        slot = pos % S                                   # ring buffer
        ck = lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, slot, 0, 0))
        cpos = lax.dynamic_update_slice(
            cache.positions, jnp.full((B, 1), pos, jnp.int32), (0, slot))
        qg = q.reshape(B, 1, num_kv_heads, G, head_dim)
        out = decode_attention(
            qg, ck, cv, q_position=pos, window=window, logit_cap=logit_cap,
            k_positions=cpos[0], scale=q_scale,
        )
        out = out.reshape(B, 1, num_heads, head_dim)
        y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
        return y, KVCache(ck, cv, cpos, pos + 1)

    positions = jnp.arange(T)[None, :]
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    qg = q.reshape(B, T, num_kv_heads, G, head_dim)
    out = blockwise_attention(
        qg, k, v, causal=causal, window=window, prefix_len=prefix_len,
        logit_cap=logit_cap, scale=q_scale,
    )
    out = out.reshape(B, T, num_heads, head_dim)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])

    new_cache = None
    if mode == "prefill":
        # Build the cache from the tail of the sequence (window caches keep
        # only the last ``size`` positions).
        size = cache.k.shape[1] if cache is not None else T
        size = min(size, max(T, 1))
        cache_dtype = cache.k.dtype if cache is not None else jnp.bfloat16
        # Ring-buffer layout invariant: token p lives at slot p % size, so
        # the tail must be rolled to align with decode's slot indexing.
        shift = T % size
        tail_k = jnp.roll(k[:, -size:], shift, axis=1).astype(cache_dtype)
        tail_v = jnp.roll(v[:, -size:], shift, axis=1).astype(cache_dtype)
        tail_pos = jnp.roll(
            jnp.broadcast_to(positions[:, -size:], (B, size)), shift, axis=1
        ).astype(jnp.int32)
        if cache is not None and cache.k.shape[1] > size:
            S = cache.k.shape[1]
            ck = jnp.zeros_like(cache.k).at[:, :size].set(tail_k)
            cv = jnp.zeros_like(cache.v).at[:, :size].set(tail_v)
            cpos = jnp.full_like(cache.positions, -1).at[:, :size].set(tail_pos)
            new_cache = KVCache(ck, cv, cpos, jnp.asarray(T, jnp.int32))
        else:
            new_cache = KVCache(tail_k, tail_v, tail_pos, jnp.asarray(T, jnp.int32))
    return y, new_cache


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        params = {
            "wg": dense_init(k1, (d_model, d_ff), d_model, dtype),
            "wu": dense_init(k2, (d_model, d_ff), d_model, dtype),
            "wd": dense_init(k3, (d_ff, d_model), d_ff, dtype),
        }
        axes = {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"), "wd": ("mlp", "embed")}
    else:
        params = {
            "wu": dense_init(k1, (d_model, d_ff), d_model, dtype),
            "wd": dense_init(k3, (d_ff, d_model), d_ff, dtype),
        }
        axes = {"wu": ("embed", "mlp"), "wd": ("mlp", "embed")}
    return params, axes


def mlp(params: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ params["wg"], approximate=True) * (x @ params["wu"])
    elif activation == "gelu":
        h = jax.nn.gelu(x @ params["wu"], approximate=True)
    else:
        raise ValueError(activation)
    return h @ params["wd"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, tie: bool, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    params = {"embedding": embed_init(k1, (vocab, d_model), dtype)}
    axes = {"embedding": ("vocab", "embed")}
    if not tie:
        params["unembed"] = dense_init(k2, (d_model, vocab), d_model, dtype)
        axes["unembed"] = ("embed", "vocab")
    return params, axes


def embed(params, tokens, scale_by_dim: bool = False):
    x = jnp.take(params["embedding"], tokens, axis=0)
    if scale_by_dim:
        x = x * math.sqrt(params["embedding"].shape[-1])
    return x


def unembed(params, x, logit_cap: float | None = None):
    if "unembed" in params:
        logits = x @ params["unembed"]
    else:
        logits = x @ params["embedding"].T
    return softcap(logits.astype(jnp.float32), logit_cap)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None, z_loss: float = 0.0):
    """Token-level CE with optional z-loss; logits (…, V), labels (…)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
