"""Online recovery runtime: the paper's pipeline as a closed loop.

  control_plane — HEALTHY→DETECTING→DIAGNOSING→MIGRATING→REBALANCED→
                  REPLANNED state machine over the detection / migration /
                  balance / planner models, with a per-stage latency ledger
  cosim         — co-simulation with core.event_sim (failover latency is
                  derived from the pipeline, not a constant)
  scenarios     — timed multi-failure campaign DSL (builders + text spec),
                  single-collective and iteration-indexed (TrainingCampaign)
  campaign      — multi-iteration training campaign runner: N gradient syncs
                  back-to-back through ONE persistent control plane, with
                  ledger-derived recovery costs
  inference     — telemetry-inferred failure detection: goodput-drop +
                  probe-burst detector feeding the same pipeline with
                  detected_by="monitor" (oracle-free scenarios), plus
                  trace-based FP/FN/latency scoring
"""

from .campaign import (  # noqa: F401
    CampaignReport,
    IterationReport,
    TrainingCampaignResult,
    run_campaign,
    training_campaign_report,
)
from .control_plane import (  # noqa: F401
    ControlPlane,
    LedgerEntry,
    RecoveryLedger,
    RecoveryOutcome,
    RecoveryState,
    STAGES,
)
from .cosim import (  # noqa: F401
    MANAGED_STREAM,
    CoSimReport,
    build_engine_streams,
    run_scenario,
)
from .inference import (  # noqa: F401
    DetectionEvent,
    DetectionScore,
    DetectorConfig,
    TelemetryDetector,
    make_telemetry_detector,
    score_detections,
)
from .scenarios import (  # noqa: F401
    Scenario,
    StreamSpec,
    TrainingCampaign,
    at_chunk,
    at_iteration,
    build_stream_program,
    campaign_clean_nic_down,
    campaign_flap_storm,
    campaign_mid_replan,
    campaign_slow_nic,
    clean_nic_down,
    correlated_nic_down,
    failure_during_recovery,
    flap_storm,
    parse_campaign,
    parse_streams,
    parse_training_campaign,
    slow_nic_degradation,
    standard_campaigns,
    standard_parallel_streams,
    standard_training_campaigns,
)
