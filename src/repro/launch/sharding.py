"""Sharding spec construction: logical axes -> PartitionSpecs.

Params carry logical-axis tuples from the model init (``axes`` pytree);
``param_pspecs`` maps them onto the mesh with divisibility fallbacks
(a logical axis whose mesh extent doesn't divide the dimension is
replicated — the MaxText rule).  Caches get structural specs by dataclass
field name: batch -> data axes, kv-heads -> model (else the sequence dim
takes "model" so 32k/500k caches fit per-device HBM).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import KVCache
from repro.models.mla import MLACache
from repro.models.rglru import RGLRUState
from repro.models.rwkv6 import RWKVState


def _mesh_extent(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    ext = 1
    for a in axes:
        ext *= mesh.shape[a]
    return ext


def _spec_entry(mesh, rules, logical, dim_size):
    mesh_axes = rules.get(logical)
    if mesh_axes is None:
        return None
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    mesh_axes = tuple(a for a in mesh_axes if a in mesh.axis_names)
    if not mesh_axes:
        return None
    if dim_size % _mesh_extent(mesh, mesh_axes) != 0:
        return None                       # divisibility fallback: replicate
    return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]


def param_pspec(mesh, rules, logical_axes: tuple, shape) -> P:
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    entries = []
    for ax, dim in zip(logical_axes, shape):
        e = _spec_entry(mesh, rules, ax, dim) if ax is not None else None
        # one mesh axis may shard only one dim of a given array
        flat = (e,) if isinstance(e, str) else (e or ())
        if e is not None and any(a in used for a in flat):
            e = None
        if e is not None:
            used.update(flat)
        entries.append(e)
    return P(*entries)


def param_pspecs(mesh, rules, axes_tree, params_tree):
    """PartitionSpec pytree for params given their logical-axes pytree."""
    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)

    flat_axes = jax.tree_util.tree_flatten(axes_tree, is_leaf=is_axes_leaf)
    flat_params, treedef = jax.tree_util.tree_flatten(params_tree)
    axes_leaves = flat_axes[0]
    assert len(axes_leaves) == len(flat_params), (
        f"axes/params mismatch: {len(axes_leaves)} vs {len(flat_params)}")
    specs = [param_pspec(mesh, rules, ax, p.shape)
             for ax, p in zip(axes_leaves, flat_params)]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def _cache_leaf_spec(mesh, field: str, shape, batch_axes, stacked: bool):
    """Spec for one cache dataclass field.  ``stacked``: leading layer-group
    dim from scanned blocks."""
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    model_ok = lambda d: d % mesh.shape["model"] == 0
    batch_ok = lambda d: d % _mesh_extent(mesh, batch_axes) == 0
    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def bspec(d):
        return ba if batch_ok(d) else None

    if field in ("k", "v"):                    # (B, S, KVH, D)
        b, s, kvh, d = core
        if model_ok(kvh):
            return P(*lead, bspec(b), None, "model", None)
        if model_ok(s):
            return P(*lead, bspec(b), "model", None, None)
        return P(*lead, bspec(b), None, None, None)
    if field == "positions":                   # (B, S)
        b, s = core
        # must match the k/v seq sharding only if seq is sharded; positions
        # are tiny — replicate for simplicity and correctness.
        return P(*lead, bspec(b), None)
    if field in ("c_kv", "k_pe"):              # (B, S, R)
        b, s, r = core
        if model_ok(s):
            return P(*lead, bspec(b), "model", None)
        return P(*lead, bspec(b), None, None)
    if field == "h":                           # (B, W)
        b, w = core
        return P(*lead, bspec(b), "model" if model_ok(w) else None)
    if field == "conv_tail":                   # (B, cw-1, W)
        b, c, w = core
        return P(*lead, bspec(b), None, "model" if model_ok(w) else None)
    if field == "s":                           # (B, H, K, V)
        b, h, kk, vv = core
        return P(*lead, bspec(b), "model" if model_ok(h) else None, None, None)
    if field in ("shift_tm", "shift_cm"):      # (B, d)
        b, d = core
        return P(*lead, bspec(b), "model" if model_ok(d) else None)
    if field == "index":
        return P(*lead) if lead else P()
    # fallback: batch on dim0 when divisible
    if core and isinstance(core[0], int) and batch_ok(core[0]):
        return P(*lead, ba, *([None] * (len(core) - 1)))
    return P(*lead, *([None] * len(core)))


def cache_pspecs(mesh, cache_tree, batch_axes: tuple[str, ...]):
    """Spec pytree for an ``init_caches`` structure."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_tree), None
    flat, treedef = jax.tree_util.tree_flatten(cache_tree)
    paths = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    specs = []
    for (path, leaf) in paths:
        field = None
        for part in reversed(path):
            name = getattr(part, "name", None) or getattr(part, "key", None)
            if isinstance(name, str) and name in (
                    "k", "v", "positions", "index", "c_kv", "k_pe", "h",
                    "conv_tail", "s", "shift_tm", "shift_cm"):
                field = name
                break
        # scanned block caches have a leading layer-group dim; detect via
        # path containing the "blocks" key
        stacked = any(getattr(p, "key", None) == "blocks" for p in path)
        specs.append(_cache_leaf_spec(mesh, field or "", leaf.shape,
                                      batch_axes, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(mesh, batch_tree, batch_axes: tuple[str, ...]):
    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def spec(leaf):
        if leaf.shape and leaf.shape[0] % _mesh_extent(mesh, batch_axes) == 0:
            return P(ba, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map(spec, batch_tree)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
