"""Serving engine: batched decode, failure strategies, latency accounting."""

import jax
import numpy as np
import pytest

from repro.core.failures import Failure, FailureType
from repro.models import get_smoke_config, init_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("glm4-9b")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, n=2, plen=12, new=6):
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, plen),
                    max_new_tokens=new) for _ in range(n)]


def test_greedy_decode_deterministic(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    r1 = eng.run_batch(_reqs(cfg))
    r2 = eng.run_batch(_reqs(cfg))
    assert r1[0].tokens == r2[0].tokens
    assert len(r1[0].tokens) == 6


def test_r2ccl_continues_through_failure(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    fail = Failure(FailureType.NIC_HARDWARE, 0, 0)
    healthy = eng.run_batch(_reqs(cfg))
    eng2 = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    failed = eng2.run_batch(_reqs(cfg), fail_at_step=2, failure=fail)
    # same tokens (lossless), tiny latency overhead
    assert healthy[0].tokens == failed[0].tokens
    assert failed[0].failovers == 1
    assert failed[0].total_latency < healthy[0].total_latency * 1.5


def test_restart_pays_full_penalty(setup):
    cfg, params = setup
    fail = Failure(FailureType.NIC_HARDWARE, 0, 0)
    e_restart = ServingEngine(cfg, params, context_len=64, strategy="restart")
    e_r2 = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    r_restart = e_restart.run_batch(_reqs(cfg), fail_at_step=2, failure=fail)
    r_r2 = e_r2.run_batch(_reqs(cfg), fail_at_step=2, failure=fail)
    assert r_restart[0].total_latency > r_r2[0].total_latency + 30.0  # 35 s restart
    assert r_restart[0].tokens == r_r2[0].tokens                      # same result


def test_unsupported_failure_rejected(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    bad = Failure(FailureType.SWITCH_OUTAGE, 0, -1)
    assert eng.inject_failure(bad) is False
    assert len(eng.failure_state.unsupported) == 1


def test_r2ccl_hiccup_is_control_plane_ledger(setup):
    """The r2ccl failover hiccup is the recovery pipeline's ledger total,
    and a failure on a node outside the replica's span falls back to the
    constant instead of crashing (regression: used to IndexError)."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    assert len(eng.control_plane.cluster.nodes) == 2    # pp=2 replica span
    fail = Failure(FailureType.NIC_HARDWARE, 1, 0)
    res = eng.run_batch(_reqs(cfg), fail_at_step=2, failure=fail)
    assert res[0].failovers == 1
    assert eng.last_recovery is not None
    assert eng.last_recovery.total == sum(eng.last_recovery.stages.values())
    # out-of-replica node: constant-hiccup fallback, no crash
    eng2 = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    far = Failure(FailureType.NIC_HARDWARE, 5, 0)
    res2 = eng2.run_batch(_reqs(cfg), fail_at_step=2, failure=far)
    assert res2[0].failovers == 1
    assert eng2.last_recovery is None


def test_ttft_before_tpot(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    res = eng.run_batch(_reqs(cfg))
    assert res[0].ttft > 0 and res[0].tpot > 0
    assert res[0].total_latency >= res[0].ttft


def test_serve_trace(setup):
    from repro.serving import serve_trace
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    res = serve_trace(eng, qps=2.0, duration=3.0, prompt_len=12,
                      max_new_tokens=4)
    assert res.completed >= 4
    assert res.ttft_p95 >= res.ttft_p50 > 0
    assert res.tpot_p50 > 0


def test_serve_trace_failure_strategies_ordering(setup):
    """Under the same mid-trace failure, r2ccl's p95 TTFT must beat restart."""
    from repro.serving import serve_trace
    cfg, params = setup
    outs = {}
    for strat in ("r2ccl", "restart"):
        eng = ServingEngine(cfg, params, context_len=64, strategy=strat)
        outs[strat] = serve_trace(
            eng, qps=2.0, duration=3.0, prompt_len=12, max_new_tokens=4,
            fail_time=1.0,
            failure=Failure(FailureType.NIC_HARDWARE, 0, 0))
    assert outs["r2ccl"].ttft_p95 < outs["restart"].ttft_p95
    assert outs["r2ccl"].failovers == 1


def test_hiccup_attribution_from_trace(setup):
    """Hiccup attribution comes from trace stage spans alone and matches
    the ledger's stage totals; fractions sum to 1; diagnose (probe timeout
    + broadcast) dominates the clean NIC-down budget."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, context_len=64, strategy="r2ccl")
    assert eng.hiccup_attribution() == {}          # nothing happened yet
    fail = Failure(FailureType.NIC_HARDWARE, 1, 0)
    eng.run_batch(_reqs(cfg), fail_at_step=2, failure=fail)
    attr = eng.hiccup_attribution()
    assert attr == pytest.approx(
        {k: v for k, v in eng.last_recovery.stages.items() if v > 0})
    frac = eng.hiccup_attribution(normalize=True)
    assert sum(frac.values()) == pytest.approx(1.0)
    assert max(frac, key=frac.get) == "diagnose"
    # the failure injection itself is on the trace too
    kinds = {r["type"] for r in eng.trace.records}
    assert {"failure", "stage", "transition"} <= kinds
