"""Payload-conserving mid-collective replan (PR 4 acceptance).

The event engine tracks a per-rank, per-chunk completion map; a control
plane swapping in a new ``CollectiveProgram`` mid-collective resumes from
the exact chunk state: settled chunks are retained, chunks final at some
rank are broadcast to the ranks missing them, and chunks final nowhere roll
back to pristine contributions and re-reduce under the new program.  These
tests pin:

  * exact AllReduce results with ``rank_data`` *through* a replan (the old
    ``EventSimError`` refusal is gone), across algorithm pairs, random
    failure times, and chunk counts (propcheck);
  * the chunk-exact byte accounting that replaces the scalar ``frac_done``
    approximation (which re-included partially-streamed bytes in the
    remaining payload while also charging them as retransmitted);
  * ``segment_finish`` preservation across a swap;
  * residual threading into the planner (``ChunkProgress`` →
    ``RecoveryDecision.replan_payload`` / ``LedgerEntry.residual_fraction``);
  * the re-probe cadence shaping recovery latency (clearance deferred to
    the next scheduled probe tick).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allreduce import build_r2ccl_all_reduce
from repro.core.event_sim import (
    _DONE,
    ChunkProgress,
    EventSimulator,
    RecoveryDecision,
    Stream,
    predict_ring_all_reduce,
    simulate_program,
)
from repro.core.executor_np import all_reduce_oracle
from repro.core.failures import link_flap, slow_nic
from repro.core.schedule import CollectiveProgram, ring_program, tree_program
from repro.core.topology import make_cluster
from repro.runtime import (
    ControlPlane,
    flap_storm,
    parse_campaign,
    run_scenario,
)

BW = 50e9


def _data(n, size, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=size) for _ in range(n)]


def _program(kind, n):
    if kind == "ring":
        return ring_program(list(range(n)), n)
    if kind == "tree":
        return tree_program(list(range(n)), n)
    prog, _ = build_r2ccl_all_reduce(list(range(n)), 1, x=0.6, g=8)
    return prog


class _ForceReplan:
    """Stub control plane: swap in ``newprog`` on the first failure event.

    ``delay=0`` by default: at property-test payload sizes a microsecond of
    pipeline latency can outlive the whole collective."""

    def __init__(self, newprog, delay=0.0):
        self.newprog = newprog
        self.delay = delay
        self.fired = False
        self.progress = None

    def on_failure(self, sim, now, failure):
        if self.fired:
            return None
        self.fired = True
        self.progress = sim.chunk_progress()
        return RecoveryDecision(repair_latency=1e-5, replan=self.newprog,
                                replan_delay=self.delay)

    def on_recover(self, sim, now, failure):
        return None


def _run_with_replan(src_kind, dst_kind, n, size, frac, seed):
    """One collective of ``src_kind`` with a forced swap to ``dst_kind`` at
    ``frac`` of the healthy time; returns (sim, report, oracle)."""
    prog = _program(src_kind, n)
    payload = size * 8.0
    healthy = simulate_program(prog, payload,
                               capacities=[BW] * n).completion_time
    data = _data(n, size, seed)
    # a slow NIC triggers the controller without any rollback of its own,
    # so the swap is the only recovery mechanism in play
    sim = EventSimulator(
        prog, payload, capacities=[BW] * n,
        rank_data=[d.copy() for d in data],
        failures=[slow_nic(0, 0, frac * healthy, lost_fraction=0.3)],
        controller=_ForceReplan(_program(dst_kind, n)))
    rep = sim.run()
    return sim, rep, all_reduce_oracle(data)


# ---------------------------------------------------------------------------
# acceptance: exact allreduce through a mid-collective swap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("src,dst", [
    ("ring", "tree"), ("tree", "ring"),
    ("r2ccl", "ring"), ("ring", "r2ccl"),
])
@pytest.mark.parametrize("frac", [0.15, 0.45, 0.75])
def test_mid_replan_exact_allreduce(src, dst, frac):
    sim, rep, want = _run_with_replan(src, dst, n=6, size=150, frac=frac,
                                      seed=11)
    assert rep.replans == 1
    ev = rep.replan_events[0]
    assert 0.0 <= ev.residual_fraction <= 1.0 + 1e-12
    for d in rep.rank_data:
        np.testing.assert_allclose(d, want, atol=1e-9)


def test_two_swaps_stay_lossless():
    """A second replan lands on the first replan's residual program: the
    chunk map must compose across swaps."""
    n, size = 5, 120
    prog = ring_program(list(range(n)), n)
    payload = size * 8.0
    healthy = simulate_program(prog, payload,
                               capacities=[BW] * n).completion_time

    class Twice:
        def __init__(self):
            self.count = 0

        def on_failure(self, sim, now, failure):
            self.count += 1
            target = tree_program(list(range(n)), n) if self.count == 1 \
                else ring_program(list(range(n)), n)
            return RecoveryDecision(repair_latency=1e-5, replan=target,
                                    replan_delay=1e-6)

        def on_recover(self, sim, now, failure):
            return None

    data = _data(n, size, seed=4)
    rep = simulate_program(
        prog, payload, capacities=[BW] * n,
        rank_data=[d.copy() for d in data],
        failures=[slow_nic(0, 0, 0.3 * healthy, lost_fraction=0.3),
                  slow_nic(1, 0, 0.6 * healthy, lost_fraction=0.3)],
        controller=Twice())
    assert rep.replans == 2
    want = all_reduce_oracle(data)
    for d in rep.rank_data:
        np.testing.assert_allclose(d, want, atol=1e-9)


# ---------------------------------------------------------------------------
# property (offline shim): random failure time x chunk count x algorithm pair
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 8),                 # chunk count of the ring = n
    size=st.integers(8, 200),
    seed=st.integers(0, 99),
    frac=st.floats(0.05, 0.95),
    pair=st.sampled_from([("ring", "tree"), ("tree", "ring"),
                          ("ring", "ring"), ("r2ccl", "ring"),
                          ("ring", "r2ccl")]),
)
def test_replan_conservation_property(n, size, seed, frac, pair):
    src, dst = pair
    sim, rep, want = _run_with_replan(src, dst, n, size, frac, seed)
    assert rep.replans == 1
    for d in rep.rank_data:                       # losslessness through swap
        np.testing.assert_allclose(d, want, atol=1e-9)
    # moved-byte conservation: everything on the wire is either a completed
    # transfer or explicitly accounted retransmission waste
    useful = sum(t.size for t in sim.transfers if t.state == _DONE)
    assert sum(rep.link_bytes.values()) == \
        pytest.approx(useful + rep.retransmitted_bytes, rel=1e-9)
    ev = rep.replan_events[0]
    assert ev.residual_bytes == pytest.approx(
        ev.rereduce_bytes + ev.deliver_bytes)
    assert ev.residual_bytes <= size * 8.0 * (1 + 1e-9)


# ---------------------------------------------------------------------------
# replan of one stream under cross-stream contention
# ---------------------------------------------------------------------------

def _stream_identity(sim, rep):
    """Per-stream moved == useful + retransmitted, and the per-stream wire
    totals sum to the global link-byte total."""
    for name, sr in rep.streams.items():
        idx = sim._stream_index[name]
        useful = sum(t.size for t in sim.transfers
                     if t.state == _DONE and t.stream == idx)
        assert sr.moved_bytes == pytest.approx(
            useful + sr.retransmitted_bytes, rel=1e-9), name
    assert sum(rep.link_bytes.values()) == pytest.approx(
        sum(sr.moved_bytes for sr in rep.streams.values()), rel=1e-9)


def _contended_replan(src, dst, n, size, frac, seed, *, controller):
    """The managed stream (``src`` program) plus a TP-style AllReduce and a
    PP-style chain co-runner, with ``controller`` deciding at the failure."""
    from repro.runtime import StreamSpec, build_stream_program

    prog = _program(src, n)
    payload = size * 8.0
    healthy = simulate_program(prog, payload,
                               capacities=[BW] * n).completion_time
    dp_data = _data(n, size, seed)
    tp_data = _data(n, size, seed + 1)
    pp_data = _data(n, size, seed + 2)
    streams = [
        Stream("dp", prog, payload, rank_data=[d.copy() for d in dp_data]),
        Stream("tp", _program("ring", n), 0.5 * payload,
               rank_data=[d.copy() for d in tp_data]),
        Stream("pp", build_stream_program(StreamSpec("pp", "p2p", 1.0), n),
               0.25 * payload, rank_data=[d.copy() for d in pp_data]),
    ]
    sim = EventSimulator(
        streams=streams, capacities=[BW] * n,
        failures=[slow_nic(0, 0, frac * healthy, lost_fraction=0.3)],
        controller=controller)
    rep = sim.run()
    return sim, rep, dp_data, tp_data, pp_data


def test_mid_replan_under_contention_conserves_all_streams():
    """Satellite: replanning ONE stream mid-collective while TP/PP streams
    share the NICs conserves the replanned stream's payload exactly AND
    leaves the co-running streams' results bit-identical to a run without
    the swap — the swap is invisible to traffic it does not own."""
    n, size, frac, seed = 6, 150, 0.45, 11
    sim, rep, dp, tp, pp = _contended_replan(
        "ring", "tree", n, size, frac, seed,
        controller=_ForceReplan(tree_program(list(range(n)), n)))
    assert rep.replans == 1
    assert rep.streams["dp"].replans == 1
    assert rep.streams["tp"].replans == 0
    assert rep.streams["pp"].replans == 0
    assert rep.replan_events[0].stream == "dp"
    for d in rep.streams["dp"].rank_data:
        np.testing.assert_allclose(d, all_reduce_oracle(dp), atol=1e-9)
    for d in rep.streams["tp"].rank_data:
        np.testing.assert_allclose(d, all_reduce_oracle(tp), atol=1e-9)
    for d in rep.streams["pp"].rank_data:     # chain handoff: root's buffer
        np.testing.assert_allclose(d, pp[0], atol=1e-12)
    _stream_identity(sim, rep)

    # co-runner bit-exactness: same streams, same failure, no swap
    class Noop:
        def on_failure(self, sim, now, failure):
            return None

        def on_recover(self, sim, now, failure):
            return None

    _, base, _, _, _ = _contended_replan(
        "ring", "tree", n, size, frac, seed, controller=Noop())
    assert base.replans == 0
    for name in ("tp", "pp"):
        for x, y in zip(rep.streams[name].rank_data,
                        base.streams[name].rank_data):
            assert np.array_equal(x, y), name


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 7),
    size=st.integers(8, 120),
    seed=st.integers(0, 99),
    frac=st.floats(0.05, 0.95),
    pair=st.sampled_from([("ring", "tree"), ("tree", "ring"),
                          ("ring", "ring"), ("ring", "r2ccl")]),
)
def test_replan_conservation_under_contention_property(n, size, seed, frac,
                                                       pair):
    """Property: across algorithm pairs and failure times, a mid-collective
    swap of the managed stream under TP/PP contention conserves every
    stream's payload, the per-stream ``moved == useful + retransmitted``
    identity holds, and the swap stays scoped to the managed stream."""
    src, dst = pair
    sim, rep, dp, tp, pp = _contended_replan(
        src, dst, n, size, frac, seed,
        controller=_ForceReplan(_program(dst, n)))
    assert rep.replans == 1
    assert sum(sr.replans for sr in rep.streams.values()) == 1
    assert rep.streams["dp"].replans == 1
    for d in rep.streams["dp"].rank_data:
        np.testing.assert_allclose(d, all_reduce_oracle(dp), atol=1e-9)
    for d in rep.streams["tp"].rank_data:
        np.testing.assert_allclose(d, all_reduce_oracle(tp), atol=1e-9)
    for d in rep.streams["pp"].rank_data:
        np.testing.assert_allclose(d, pp[0], atol=1e-12)
    _stream_identity(sim, rep)
    ev = rep.replan_events[0]
    assert ev.stream == "dp"
    assert ev.residual_bytes == pytest.approx(
        ev.rereduce_bytes + ev.deliver_bytes)
    assert ev.residual_bytes <= size * 8.0 * (1 + 1e-9)


def test_replan_targets_named_stream():
    """RecoveryDecision.replan_stream routes the swap: naming a non-primary
    stream swaps THAT stream's program, and an unknown name is an error."""
    from repro.core.event_sim import EventSimError

    n, size = 5, 100
    payload = size * 8.0
    prog = ring_program(list(range(n)), n)
    healthy = simulate_program(prog, payload,
                               capacities=[BW] * n).completion_time

    class Target:
        def __init__(self, name):
            self.name = name
            self.fired = False

        def on_failure(self, sim, now, failure):
            if self.fired:
                return None
            self.fired = True
            return RecoveryDecision(
                repair_latency=1e-5, replan=tree_program(list(range(n)), n),
                replan_stream=self.name)

        def on_recover(self, sim, now, failure):
            return None

    def run(name):
        data = {"a": _data(n, size, 1), "b": _data(n, size, 2)}
        rep = EventSimulator(
            streams=[Stream("a", prog, payload, rank_data=data["a"]),
                     Stream("b", prog, payload, rank_data=data["b"])],
            capacities=[BW] * n,
            failures=[slow_nic(0, 0, 0.4 * healthy, lost_fraction=0.3)],
            controller=Target(name)).run()
        return rep, data

    rep, data = run("b")
    assert rep.streams["b"].replans == 1 and rep.streams["a"].replans == 0
    assert rep.replan_events[0].stream == "b"
    for name in ("a", "b"):
        want = all_reduce_oracle(data[name])
        for d in rep.streams[name].rank_data:
            np.testing.assert_allclose(d, want, atol=1e-9)
    with pytest.raises(EventSimError):
        run("nope")


# ---------------------------------------------------------------------------
# regression: the scalar frac_done double-charge
# ---------------------------------------------------------------------------

def test_replan_accounting_is_chunk_exact_not_scalar():
    """The frac_done double-charge regression.  A two-segment program where
    the small segment settles before the swap: the chunk-exact residual must
    exclude the settled segment entirely, while the old scalar accounting —
    ``total * (1 - done_work/total_work)`` with ``done_work`` counting only
    ``_DONE`` transfers — collapses per-segment progress into one number,
    re-including payload that already settled (and, with partially-streamed
    transfers cancelled, charging their bytes simultaneously as
    retransmitted and as remaining).  The moved = useful + retransmitted
    identity must hold exactly, and the data must stay exact."""
    from repro.core.schedule import Segment, build_ring_all_reduce

    n, size = 6, 600
    payload = size * 8.0
    sched = build_ring_all_reduce(list(range(n)), n)
    prog = CollectiveProgram(
        "two_seg_ring", n, [Segment(0.25, sched), Segment(0.75, sched)])
    # find when the small segment settles vs when the run ends
    probe = simulate_program(prog, payload, capacities=[BW] * n)
    t_small, t_all = probe.segment_finish[0], probe.completion_time
    assert t_small < t_all
    t_fail = 0.5 * (t_small + t_all)       # segment 0 settled, 1 in flight

    data = _data(n, size, seed=2)
    sim = EventSimulator(
        prog, payload, capacities=[BW] * n,
        rank_data=[d.copy() for d in data],
        failures=[slow_nic(0, 0, t_fail, lost_fraction=0.2)],
        controller=_ForceReplan(ring_program(list(range(n)), n)))
    rep = sim.run()
    assert rep.replans == 1
    ev = rep.replan_events[0]
    # chunk-exact: the settled 25% segment is not part of the residual
    assert 0.0 < ev.residual_bytes <= 0.75 * payload * (1 + 1e-9)
    # the scalar approximation would have sized the residual differently
    # (it cannot exclude the settled segment: transfer-work fractions and
    # chunk coverage disagree mid-flight)
    total_work = sum(t.size for t in sim.transfers
                     if t.seg < len(prog.segments))
    scalar_rem = payload * (1.0 - ev.done_bytes / total_work)
    assert ev.residual_bytes != pytest.approx(scalar_rem, rel=1e-3)
    # moved = useful + retransmitted, exactly
    useful = sum(t.size for t in sim.transfers if t.state == _DONE)
    assert sum(rep.link_bytes.values()) == \
        pytest.approx(useful + rep.retransmitted_bytes, rel=1e-9)
    # and the result is still exact
    want = all_reduce_oracle(data)
    for d in rep.rank_data:
        np.testing.assert_allclose(d, want, atol=1e-9)


def test_segment_finish_preserved_across_replan():
    """Regression: _do_replan used to reset ``segment_finish`` to zeros of
    the new program's length, erasing the finish timestamps of segments
    completed before the swap."""
    n, size = 6, 300
    payload = size * 8.0
    prog = ring_program(list(range(n)), n)
    healthy = predict_ring_all_reduce(n, payload, BW)
    sim = EventSimulator(
        prog, payload, capacities=[BW] * n,
        failures=[slow_nic(0, 0, 0.5 * healthy, lost_fraction=0.2)],
        controller=_ForceReplan(tree_program(list(range(n)), n)))
    rep = sim.run()
    assert rep.replans == 1
    # the superseded program's segment keeps its (partial) finish timestamp,
    # and the residual program's segments are appended after it
    assert len(rep.segment_finish) > len(prog.segments)
    assert rep.segment_finish[0] > 0.0
    assert rep.segment_finish[0] < rep.completion_time


# ---------------------------------------------------------------------------
# residual threading into the control plane / planner
# ---------------------------------------------------------------------------

def test_chunk_progress_reaches_planner_decision():
    """The engine's chunk map must reach the pipeline: a replan is priced on
    the residual payload, recorded in the ledger, and echoed in the
    decision."""
    cluster = make_cluster(4, 4, nic_bandwidth=25e9)
    cp = ControlPlane(cluster, payload_bytes=1e8)
    progress = ChunkProgress(total_bytes=1e8, rereduce_bytes=1.5e7,
                             deliver_bytes=0.5e7)
    outs = [cp.handle_failure(link_flap(1, 0, t, 0.01), now=t,
                              progress=progress)
            for t in (0.0, 1.0, 2.0)]
    replanned = outs[-1]
    assert "replan" in replanned.entry.stages
    assert replanned.decision.replan is not None
    assert replanned.decision.replan_payload == pytest.approx(2e7)
    assert replanned.entry.residual_fraction == pytest.approx(0.2)
    # entries that did not replan keep the default full fraction
    assert outs[0].entry.residual_fraction == 1.0
    # the program carried into subsequent (full-payload) collectives is
    # still installed
    assert cp.current_program is not None


def test_cosim_flap_storm_with_payloads_is_lossless():
    """The acceptance path: the closed-loop co-simulation replans
    mid-collective with real payloads attached — the old EventSimError
    refusal is gone and the collective result is exact."""
    cluster = make_cluster(4, 4, nic_bandwidth=25e9)
    payload = 100e6
    t_h = simulate_program(ring_program(list(range(4)), 4), payload,
                           cluster=cluster).completion_time
    data = _data(4, 64, seed=9)
    want = np.sum(np.stack(data), axis=0)
    rep = run_scenario(flap_storm(t_h, count=4), cluster, payload,
                       healthy_time=t_h, rank_data=data)
    assert rep.report.replans >= 1
    assert rep.report.replan_events
    for r in rep.report.rank_data:
        np.testing.assert_allclose(r, want, atol=1e-9)
    # the ledger recorded the replans' residual view
    replans = [e for e in rep.ledger.entries if "replan" in e.stages]
    assert replans
    assert all(0.0 <= e.residual_fraction <= 1.0 for e in replans)


# ---------------------------------------------------------------------------
# re-probe cadence shapes recovery latency (deferred clearance)
# ---------------------------------------------------------------------------

def test_slower_reprobe_cadence_lengthens_degradation():
    """A repeat recovery is only confirmed at the NIC's next scheduled probe
    tick: with a slower cadence the rail stays administratively down longer,
    so the observed degradation window — and the collective — stretches."""
    cluster = make_cluster(4, 4, nic_bandwidth=25e9)
    payload = 100e6
    t_h = simulate_program(ring_program(list(range(4)), 4), payload,
                           cluster=cluster).completion_time
    # two flaps of the same NIC: the first recovery schedules the probe, the
    # second recovery must wait for the tick (no replan: 2 < threshold)
    sc = parse_campaign(
        "double_flap",
        "flap node=1 rail=0 at=0.15 down=0.05; "
        "flap node=1 rail=0 at=0.45 down=0.05",
        t_scale=t_h)
    times = {}
    for name, base in [("fast", 0.1 * t_h), ("slow", 3.0 * t_h)]:
        cp = ControlPlane(cluster, payload_bytes=payload, reprobe_base=base)
        rep = run_scenario(sc, cluster, payload, healthy_time=t_h,
                           control_plane=cp)
        times[name] = rep.report.completion_time
    assert times["slow"] > times["fast"] * (1 + 1e-6)


def test_confirm_tick_does_not_clear_refailed_rail():
    """A confirmation pending from flap 1 must not report recovery if the
    same rail went down again (flap 2) before the tick: the probe observes
    the rail's current state, and only flap 2's own confirmation clears."""
    n = 4
    prog = ring_program(list(range(n)), n)
    payload = 4000 * 8.0
    healthy = predict_ring_all_reduce(n, payload, BW)
    t1, t1_up = 0.10 * healthy, 0.15 * healthy
    t2, t2_up = 0.20 * healthy, 0.30 * healthy
    tick1 = 0.25 * healthy                 # flap 1 confirm: inside flap 2
    confirmed = []

    class Stub:
        def on_failure(self, sim, now, f):
            return None

        def on_recover(self, sim, now, f):
            # flap 1's physical recovery defers to tick1; flap 2 confirms
            # immediately on its own recovery
            return tick1 if f.at_time == t1 else now

        def on_recovery_confirmed(self, sim, now, f):
            confirmed.append((now, f.at_time))

    rep = simulate_program(
        prog, payload, capacities=[BW] * n,
        failures=[link_flap(1, 0, t1, t1_up - t1),
                  link_flap(1, 0, t2, t2_up - t2)],
        controller=Stub())
    assert rep.completion_time > 0
    # only flap 2's confirmation reported a recovery; flap 1's tick landed
    # while the rail was down again and was swallowed
    assert [f_at for _, f_at in confirmed] == [t2]


def test_reprobe_base_must_be_positive():
    with pytest.raises(ValueError):
        ControlPlane(make_cluster(2, 2), reprobe_base=0.0)
    with pytest.raises(ValueError):
        ControlPlane(make_cluster(2, 2), reprobe_base=-1.0)


def test_first_recovery_confirms_immediately():
    """A NIC with no probe schedule yet is confirmed by the probe that
    noticed it: single-flap campaigns keep their instantaneous-recovery
    timeline (and their HEALTHY terminal state)."""
    cluster = make_cluster(4, 4, nic_bandwidth=25e9)
    cp = ControlPlane(cluster, payload_bytes=1e8)
    f = link_flap(1, 0, 0.0, 0.01)
    assert cp.observe_physical_recovery(f, 0.01) == 0.01
    cp.handle_failure(f, now=0.0)
    cp.handle_recovery(f, now=0.01)
    # now a schedule exists: the next physical recovery waits for the tick
    tick = cp.observe_physical_recovery(f, 0.02)
    assert tick == cp.next_reprobe[(1, 0)]
    assert tick > 0.02
    # and a recovery *after* that tick rolls forward to the next one
    late = cp.observe_physical_recovery(f, tick + 0.5)
    assert late >= tick + 0.5
