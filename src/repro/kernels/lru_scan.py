"""RG-LRU linear-scan Pallas kernel (TPU target).

Computes h_t = a_t * h_{t-1} + x_t over the time axis with the hidden
state carried in VMEM scratch across sequential time-tiles; batch and
width are parallel grid dimensions tiled to the VPU lane layout
(width tiles of 128 lanes, batch tiles of 8 sublanes).

Within a time tile the recurrence is evaluated by a log-depth blocked
Blelloch-style composition: the tile's (a, x) pairs are combined with
``(a2, b2) o (a1, b1) = (a1*a2, b1*a2 + b2)`` — the same associative
operator the jnp oracle uses — keeping the MXU-free VPU pipeline busy
instead of issuing T sequential multiply-adds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, x_ref, o_ref, h_scr, *, time_tile: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (tt, bb, W) -> time-major tile
    x = x_ref[0].astype(jnp.float32)

    # log-depth inclusive scan over the time tile via associative combine
    av, bv = a, x
    shift = 1
    while shift < time_tile:
        a_prev = jnp.concatenate(
            [jnp.ones_like(av[:shift]), av[:-shift]], axis=0)
        b_prev = jnp.concatenate(
            [jnp.zeros_like(bv[:shift]), bv[:-shift]], axis=0)
        valid = lax.broadcasted_iota(jnp.int32, av.shape, 0) >= shift
        av_new = jnp.where(valid, av * a_prev, av)
        bv_new = jnp.where(valid, bv + av * b_prev, bv)
        av, bv = av_new, bv_new
        shift *= 2

    h0 = h_scr[...]
    h = bv + av * h0[None]                     # fold in carry
    o_ref[0] = h.astype(o_ref.dtype)
    h_scr[...] = h[-1]


def lru_scan_pallas(
    a: jax.Array,                  # (B, T, W) decay in (0,1)
    x: jax.Array,                  # (B, T, W) gated input
    *,
    time_tile: int = 128,
    width_tile: int = 128,
    batch_tile: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """h0 = 0 (prefill semantics); returns (B, T, W) float32 hidden states."""
    B, T, W = a.shape
    assert T % time_tile == 0 and W % width_tile == 0 and B % batch_tile == 0
    # time-major layout inside blocks: (B,T,W) -> (nb, nt) grid
    at = a.transpose(1, 0, 2)                  # (T, B, W)
    xt = x.transpose(1, 0, 2)
    grid = (B // batch_tile, W // width_tile, T // time_tile)
    out = pl.pallas_call(
        functools.partial(_lru_kernel, time_tile=time_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, time_tile, batch_tile, width_tile),
                         lambda b, w, t: (0, t, b, w)),
            pl.BlockSpec((1, time_tile, batch_tile, width_tile),
                         lambda b, w, t: (0, t, b, w)),
        ],
        out_specs=pl.BlockSpec((1, time_tile, batch_tile, width_tile),
                               lambda b, w, t: (0, t, b, w)),
        out_shape=jax.ShapeDtypeStruct((1, T, B, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((batch_tile, width_tile), jnp.float32)],
        interpret=interpret,
    )(at[None], xt[None])
    return out[0].transpose(1, 0, 2)           # (B, T, W)
