"""End-to-end behaviour: the paper's workflow on real (smoke-sized) models.

Scenario: a training job and a serving job both hit a NIC failure; R2CCL
detects it in milliseconds, hot-repairs the connection losslessly, and
re-plans the collective schedule — the job finishes with the same result
it would have produced without the failure (modulo scheduling latency).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# end-to-end model training runs: excluded from the fast tier (scripts/test.sh)
pytestmark = pytest.mark.slow

from repro.core.detection import FailureDetector, FaultLocation
from repro.core.executor_np import ExecStats, execute_program
from repro.core.failures import Failure, FailureState, FailureType, single_nic_failure
from repro.core.planner import Collective, Planner, Strategy
from repro.core.topology import make_cluster
from repro.data import make_batch
from repro.models import get_smoke_config, init_model
from repro.optim import AdamWConfig
from repro.serving import Request, ServingEngine
from repro.training import init_train_state, make_train_step


def test_full_failure_handling_pipeline():
    """Detect -> localize -> migrate -> re-plan -> verified-lossless collective."""
    cluster = make_cluster(8, 8)
    state = FailureState()
    failure = Failure(FailureType.NIC_HARDWARE, node=2, rail=3)

    # 1. detection + localization (Section 4.1-4.2)
    det = FailureDetector(state)
    diag = det.detect(failure, (2, 3), (3, 3), aux=(0, 0))
    assert diag.location is FaultLocation.LOCAL_NIC
    assert diag.failed_nic == (2, 3)
    assert diag.localize_latency < 5e-3
    state.apply(failure)

    # 2. re-planning (Section 5)
    planner = Planner(cluster)
    plan = planner.choose_strategy(Collective.ALL_REDUCE, 1 << 28, state)
    assert plan.strategy is Strategy.R2CCL_ALL_REDUCE
    assert plan.degraded_node == 2

    # 3. the re-planned schedule is executed and is exactly sum-preserving
    from repro.core.allreduce import build_r2ccl_all_reduce
    prog, pp = build_r2ccl_all_reduce(list(plan.ring_order), 2,
                                      x=plan.lost_fraction, g=8)
    rng = np.random.default_rng(0)
    data = [rng.normal(size=257) for _ in range(8)]
    out = execute_program(prog, data)
    want = np.sum(np.stack(data), axis=0)
    for o in out:
        np.testing.assert_allclose(o, want, atol=1e-9)


def test_training_deterministic():
    cfg = get_smoke_config("smollm-360m")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    def train(sync):
        st = init_train_state(params)
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), sync=sync))
        m = None
        for i in range(3):
            b = make_batch(cfg, seq_len=24, batch_size=4, step=i)
            st, m = step(st, {k: jnp.asarray(v) for k, v in b.items()})
        return st, float(m["loss"])

    _, loss_a = train("xla")
    _, loss_b = train("xla")
    assert loss_a == loss_b


def test_serving_tokens_identical_under_failure():
    cfg = get_smoke_config("smollm-360m")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8)
    e1 = ServingEngine(cfg, params, context_len=32, strategy="r2ccl")
    healthy = e1.run_batch([Request(prompt=prompt, max_new_tokens=5)])
    e2 = ServingEngine(cfg, params, context_len=32, strategy="r2ccl")
    failed = e2.run_batch([Request(prompt=prompt, max_new_tokens=5)],
                          fail_at_step=1,
                          failure=Failure(FailureType.NIC_HARDWARE, 0, 0))
    assert healthy[0].tokens == failed[0].tokens   # lossless continuation
    assert failed[0].failovers == 1
