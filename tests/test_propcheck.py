"""The offline hypothesis shim itself: redraw-on-assume (including inside
composite strategies), determinism, and falsifying-example reporting.
Tests the shim directly so they hold whether or not real hypothesis is
installed."""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _propcheck as pc


def test_assume_in_test_body_redraws():
    seen = []

    @pc.settings(max_examples=10)
    @pc.given(x=pc.integers(0, 100))
    def prop(x):
        pc.assume(x % 2 == 0)
        seen.append(x)

    prop()
    assert len(seen) == 10
    assert all(x % 2 == 0 for x in seen)


def test_assume_inside_composite_redraws():
    """assume() called while *drawing* (composite body) must discard and
    redraw, not escape as an error."""

    @pc.composite
    def evens(draw):
        v = draw(pc.integers(0, 100))
        pc.assume(v % 2 == 0)
        return v

    seen = []

    @pc.settings(max_examples=8)
    @pc.given(x=evens())
    def prop(x):
        seen.append(x)

    prop()
    assert len(seen) == 8
    assert all(x % 2 == 0 for x in seen)


def test_filter_exhaustion_is_discard_not_error():
    hits = []

    @pc.settings(max_examples=3)
    @pc.given(x=pc.integers(0, 1).filter(lambda v: v >= 0))
    def prop(x):
        hits.append(x)

    prop()
    assert len(hits) == 3


def test_deterministic_across_runs():
    runs = []
    for _ in range(2):
        vals = []

        @pc.settings(max_examples=5)
        @pc.given(x=pc.integers(0, 10**6), y=pc.floats(0.0, 1.0))
        def prop(x, y):
            vals.append((x, y))

        prop.__qualname__ = "stable_name"
        prop()
        runs.append(vals)
    assert runs[0] == runs[1]


def test_falsifying_example_reported():
    @pc.settings(max_examples=20)
    @pc.given(x=pc.integers(0, 100))
    def prop(x):
        assert x < 101            # never fails
    prop()

    @pc.settings(max_examples=20)
    @pc.given(x=pc.integers(50, 100))
    def bad(x):
        assert x < 50             # always fails

    with pytest.raises(AssertionError, match="falsified by example"):
        bad()


def test_data_and_sampled_from():
    picks = []

    @pc.settings(max_examples=6)
    @pc.given(d=pc.data(), e=pc.sampled_from(["a", "b"]))
    def prop(d, e):
        v = d.draw(pc.lists(pc.booleans(), min_size=1, max_size=3))
        picks.append((e, tuple(v)))
        assert e in ("a", "b")
        assert 1 <= len(v) <= 3

    prop()
    assert len(picks) == 6


def test_dictionaries_respect_max_size():
    @pc.settings(max_examples=10)
    @pc.given(d=pc.dictionaries(pc.integers(0, 5), pc.floats(0, 1),
                                max_size=4))
    def prop(d):
        assert len(d) <= 4
        assert all(0 <= k <= 5 for k in d)

    prop()
