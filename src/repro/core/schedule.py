"""Collective schedule IR.

A schedule is an explicit, device-count-static description of a collective
as a sequence of :class:`Step`\\ s.  Each step performs one round of
point-to-point transfers (disjoint sources/destinations — the shape of a
single ``lax.ppermute``) over equal-size chunks of a flat buffer, optionally
accumulating at the receiver.

The same IR is executed by four backends:
  * ``core.executor_np``  — rank-parallel numpy oracle (correctness tests,
    traffic accounting, alpha-beta timing);
  * ``core.collectives``  — real JAX execution inside ``shard_map`` via
    ``lax.ppermute`` (training/serving data plane);
  * ``core.event_sim``    — discrete-event cluster simulation (per-link fair
    sharing, mid-collective failure injection, rollback/retransmit);
  * ``core.comm_sim``     — closed-form alpha-beta timing, with a
    ``mode="event"`` switch that delegates to ``core.event_sim``.

Builders for ring ReduceScatter / AllGather / AllReduce / Broadcast and the
R2CCL decompositions live in ``core.allreduce`` and ``core.recursive``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Step:
    """One communication round.

    ``perm``        — ((src, dst), ...) pairs; sources and destinations are
                      each unique within a step (ppermute semantics).
    ``send_chunk``  — length-n tuple; chunk index rank r sends (-1: not a src).
    ``recv_chunk``  — length-n tuple; chunk index written at rank r
                      (-1: not a dst).
    ``accumulate``  — receiver adds into the chunk instead of overwriting.
    ``whole_buffer``— ignore chunk indices and move the entire stacked
                      buffer (used for inject/deliver edges of the partial
                      AllReduce and for sub-ring hand-offs).
    """

    perm: tuple[tuple[int, int], ...]
    send_chunk: tuple[int, ...]
    recv_chunk: tuple[int, ...]
    accumulate: bool = False
    whole_buffer: bool = False

    def validate(self, n: int, num_chunks: int) -> None:
        """ppermute legality; raises :class:`repro.analysis.errors
        .StepLegalityError` (typed, survives ``python -O``) on violation."""
        from repro.analysis.verify import check_step

        check_step(self, n, num_chunks)


@dataclasses.dataclass
class ChunkSchedule:
    """A chunked collective over ``n`` ranks on one flat buffer segment."""

    name: str
    n: int
    num_chunks: int
    steps: list[Step]
    #: Ranks whose final buffer holds the collective result (for AllReduce
    #: semantics this is all ranks; for Reduce it is the root only).
    result_ranks: tuple[int, ...] = ()

    def validate(self) -> None:
        """Schedule-level legality (every step, ``result_ranks`` in range);
        raises typed :class:`repro.analysis.errors.ScheduleError`\\ s with
        step/rank/chunk provenance."""
        from repro.analysis.verify import check_schedule

        check_schedule(self)

    # -- analysis ------------------------------------------------------------
    def bytes_per_rank(self, seg_bytes: float) -> dict[int, dict[str, float]]:
        """Egress/ingress bytes per rank for a segment of ``seg_bytes``."""
        chunk = seg_bytes / self.num_chunks
        out: dict[int, dict[str, float]] = {
            r: {"tx": 0.0, "rx": 0.0} for r in range(self.n)
        }
        for st in self.steps:
            size = seg_bytes if st.whole_buffer else chunk
            for s, d in st.perm:
                out[s]["tx"] += size
                out[d]["rx"] += size
        return out

    def edge_bytes(self, seg_bytes: float) -> dict[tuple[int, int], float]:
        chunk = seg_bytes / self.num_chunks
        out: dict[tuple[int, int], float] = {}
        for st in self.steps:
            size = seg_bytes if st.whole_buffer else chunk
            for e in st.perm:
                out[e] = out.get(e, 0.0) + size
        return out

    def num_rounds(self) -> int:
        return len(self.steps)

    def step_participants(self) -> list[frozenset[int]]:
        """Ranks touched (as src or dst) by each step, in step order."""
        return [
            frozenset(r for e in st.perm for r in e) for st in self.steps
        ]

    def rank_steps(self) -> dict[int, list[int]]:
        """For every rank, the ordered step indices it participates in.

        This is the dependency structure the discrete-event simulator uses:
        a rank may engage in step ``i`` only once all its transfers in its
        previous participating step completed (per-rank lockstep, no global
        barrier — stragglers delay only the chains through them).
        """
        out: dict[int, list[int]] = {r: [] for r in range(self.n)}
        for i, parts in enumerate(self.step_participants()):
            for r in sorted(parts):
                out[r].append(i)
        return out


@dataclasses.dataclass
class Segment:
    """A contiguous fraction of the flat payload bound to one schedule."""

    frac: float                 # fraction of the total payload
    schedule: ChunkSchedule


@dataclasses.dataclass
class CollectiveProgram:
    """A full collective: the payload split into segments, each with its own
    schedule.  Segments are logically concurrent (stage overlap is captured
    by the alpha-beta timing model, not by the executor)."""

    name: str
    n: int
    segments: list[Segment]

    def validate(self) -> None:
        """Program-level legality (fractions sum to 1, rank counts agree,
        every segment schedule legal); raises typed
        :class:`repro.analysis.errors.ProgramError` on violation."""
        from repro.analysis.verify import check_program

        check_program(self)

    def bytes_per_rank(self, total_bytes: float) -> dict[int, dict[str, float]]:
        out = {r: {"tx": 0.0, "rx": 0.0} for r in range(self.n)}
        for seg in self.segments:
            seg_b = seg.schedule.bytes_per_rank(total_bytes * seg.frac)
            for r in range(self.n):
                out[r]["tx"] += seg_b[r]["tx"]
                out[r]["rx"] += seg_b[r]["rx"]
        return out


# ---------------------------------------------------------------------------
# Ring builders (the NCCL-equivalent baselines; Figure 4 of the paper)
# ---------------------------------------------------------------------------

def _ring_perm(order: Sequence[int]) -> tuple[tuple[int, int], ...]:
    k = len(order)
    return tuple((order[i], order[(i + 1) % k]) for i in range(k))


def build_ring_reduce_scatter(order: Sequence[int], n: int) -> ChunkSchedule:
    """k-1 rounds; afterwards order[i] holds the fully-reduced chunk
    (i+1) mod k (standard NCCL ring)."""
    k = len(order)
    pos = {r: i for i, r in enumerate(order)}
    steps: list[Step] = []
    for s in range(k - 1):
        send = [-1] * n
        recv = [-1] * n
        for r in order:
            i = pos[r]
            send[r] = (i - s) % k
            recv[r] = (i - s - 1) % k
        steps.append(Step(_ring_perm(order), tuple(send), tuple(recv), accumulate=True))
    return ChunkSchedule(f"ring_rs[{k}]", n, k, steps, result_ranks=tuple(order))


def build_ring_all_gather(order: Sequence[int], n: int,
                          owned_offset: int = 1) -> ChunkSchedule:
    """k-1 rounds; rank order[i] starts owning chunk (i+owned_offset) mod k
    (the post-ReduceScatter layout) and ends with all chunks."""
    k = len(order)
    pos = {r: i for i, r in enumerate(order)}
    steps: list[Step] = []
    for s in range(k - 1):
        send = [-1] * n
        recv = [-1] * n
        for r in order:
            i = pos[r]
            send[r] = (i + owned_offset - s) % k
            recv[r] = (i + owned_offset - s - 1) % k
        steps.append(Step(_ring_perm(order), tuple(send), tuple(recv), accumulate=False))
    return ChunkSchedule(f"ring_ag[{k}]", n, k, steps, result_ranks=tuple(order))


def build_ring_all_reduce(order: Sequence[int], n: int) -> ChunkSchedule:
    """ReduceScatter followed by AllGather over the same ring."""
    rs = build_ring_reduce_scatter(order, n)
    ag = build_ring_all_gather(order, n)
    return ChunkSchedule(
        f"ring_ar[{len(order)}]", n, len(order), rs.steps + ag.steps,
        result_ranks=tuple(order),
    )


def build_ring_broadcast(order: Sequence[int], n: int, root: int) -> ChunkSchedule:
    """Pipelined ring broadcast from ``root`` around ``order``.

    The payload is split into len(order) chunks streamed around the ring;
    round t forwards chunk c from position p to p+1 in pipeline fashion —
    (k-1) + (k-1) rounds total, bandwidth-optimal for large payloads.
    """
    k = len(order)
    assert root in order
    # Rotate so root is position 0.
    i0 = list(order).index(root)
    ring = [order[(i0 + i) % k] for i in range(k)]
    steps: list[Step] = []
    num_chunks = k
    # Pipeline: at round t, position p forwards chunk (t - p) if 0 <= t-p < C.
    total_rounds = (k - 1) + (num_chunks - 1)
    for t in range(total_rounds):
        perm: list[tuple[int, int]] = []
        send = [-1] * n
        recv = [-1] * n
        for p in range(k - 1):          # last position never forwards
            c = t - p
            if 0 <= c < num_chunks:
                src, dst = ring[p], ring[p + 1]
                perm.append((src, dst))
                send[src] = c
                recv[dst] = c
        if perm:
            steps.append(Step(tuple(perm), tuple(send), tuple(recv), accumulate=False))
    return ChunkSchedule(f"ring_bcast[{k}]", n, num_chunks, steps,
                         result_ranks=tuple(order))


def ring_program(order: Sequence[int], n: int) -> CollectiveProgram:
    return CollectiveProgram(
        "ring_all_reduce", n, [Segment(1.0, build_ring_all_reduce(order, n))]
    )


# ---------------------------------------------------------------------------
# Tree builders (latency-optimal path for small payloads; planner Table 1)
# ---------------------------------------------------------------------------

def build_tree_reduce(order: Sequence[int], n: int, root: int) -> ChunkSchedule:
    """Binomial-tree reduction to ``root``: ceil(log2 k) rounds, whole-buffer
    accumulate edges.  Latency-optimal (alpha-dominated) for tiny payloads."""
    k = len(order)
    assert root in order
    # relabel so root is rank 0 in tree space
    i0 = list(order).index(root)
    relab = [order[(i0 + i) % k] for i in range(k)]
    steps: list[Step] = []
    dist = 1
    while dist < k:
        perm = []
        send = [-1] * n
        recv = [-1] * n
        for i in range(0, k, 2 * dist):
            src_i = i + dist
            if src_i < k:
                src, dst = relab[src_i], relab[i]
                perm.append((src, dst))
                send[src] = 0
                recv[dst] = 0
        if perm:
            steps.append(Step(tuple(perm), tuple(send), tuple(recv),
                              accumulate=True, whole_buffer=True))
        dist *= 2
    sched = ChunkSchedule(f"tree_reduce[{k}]", n, 1, steps, result_ranks=(root,))
    sched.validate()
    return sched


def build_tree_broadcast(order: Sequence[int], n: int, root: int) -> ChunkSchedule:
    """Binomial-tree broadcast from ``root`` (the reduce mirrored)."""
    k = len(order)
    i0 = list(order).index(root)
    relab = [order[(i0 + i) % k] for i in range(k)]
    steps: list[Step] = []
    # highest power of two < k
    dist = 1
    while dist * 2 < k:
        dist *= 2
    while dist >= 1:
        perm = []
        send = [-1] * n
        recv = [-1] * n
        for i in range(0, k, 2 * dist):
            dst_i = i + dist
            if dst_i < k:
                src, dst = relab[i], relab[dst_i]
                perm.append((src, dst))
                send[src] = 0
                recv[dst] = 0
        if perm:
            steps.append(Step(tuple(perm), tuple(send), tuple(recv),
                              accumulate=False, whole_buffer=True))
        dist //= 2
    sched = ChunkSchedule(f"tree_bcast[{k}]", n, 1, steps,
                          result_ranks=tuple(order))
    sched.validate()
    return sched


def build_tree_all_reduce(order: Sequence[int], n: int,
                          root: int | None = None) -> ChunkSchedule:
    """Reduce-to-root + broadcast: 2*ceil(log2 k) alpha rounds vs the ring's
    2(k-1) — the latency-bound AllReduce of the planner's Table 1."""
    root = order[0] if root is None else root
    red = build_tree_reduce(order, n, root)
    bc = build_tree_broadcast(order, n, root)
    return ChunkSchedule(f"tree_ar[{len(order)}]", n, 1, red.steps + bc.steps,
                         result_ranks=tuple(order))


def tree_program(order: Sequence[int], n: int) -> CollectiveProgram:
    return CollectiveProgram(
        "tree_all_reduce", n, [Segment(1.0, build_tree_all_reduce(order, n))]
    )
