"""Event-engine throughput and telemetry overhead (BENCH_event_engine).

Wall-clock cost of the discrete-event engine itself, as a guard on the
observability plane: per-campaign wall time and events/second with the
telemetry plane detached vs attached (64 samples per healthy collective,
the default monitoring cadence).  The acceptance bar is telemetry-on
overhead < 10% on the tiny tier.  All timings are min-of-repeats — the
minimum is the noise-robust estimator for a deterministic workload, and
the overhead *ratio* of two minima is stable where a ratio of means
wobbles with scheduler jitter.

The **scale sweep** rows (``sweep_<kind><ranks>_*``) measure the
incremental vectorized water-fill at fleet scale — 256 → 1024 → 4096 →
10240 ranks — on a binomial-tree AllReduce (~2(n-1) transfers with
matching rounds up to n/2 flows wide: the fill-stressing shape that stays
affordable at 10k ranks, where a chunked ring would need ~2·10^8 transfer
objects) plus a chunked 256-rank ring (event-count stress: ~260k events).
Rows with a reference arm also report ``speedup_vs_reference`` against
``fill="reference"`` and assert the two timelines agree; the nightly CI
gate (``scripts/check_engine_perf.py``) replays the tiny sweep and fails
on >30% events/sec regression vs the committed JSON.
"""

from __future__ import annotations

import time

from repro.core.comm_sim import NIC_200G
from repro.core.event_sim import simulate_program
from repro.core.schedule import ring_program, tree_program
from repro.core.telemetry import Telemetry
from repro.core.topology import make_cluster
from repro.runtime import (
    StreamSpec,
    flap_storm,
    run_scenario,
    standard_campaigns,
)

from .common import Reporter

#: scale-sweep workloads: (kind, rank counts, full-tier reference-arm cap).
#: The reference fill is O(rounds · flows) *per epoch* — ~4.6 s for one
#: tree pass at 4096 ranks and far worse on chunked rings — so the slow
#: arm only runs where it finishes in seconds (tiny tier caps it at 1024,
#: the acceptance row's scale).
SWEEP_TREE_RANKS = (256, 1024, 4096, 10240)
SWEEP_TREE_RANKS_TINY = (256, 1024)
SWEEP_REFERENCE_MAX = 4096
SWEEP_REFERENCE_MAX_TINY = 1024


def scale_sweep(tiny: bool = False) -> list[dict]:
    """Run the fleet-scale sweep; returns one dict per row (shared with
    the nightly regression gate in ``scripts/check_engine_perf.py``)."""
    ref_max = SWEEP_REFERENCE_MAX_TINY if tiny else SWEEP_REFERENCE_MAX
    jobs = [("tree", n, tree_program)
            for n in (SWEEP_TREE_RANKS_TINY if tiny else SWEEP_TREE_RANKS)]
    jobs.append(("ring", 256, ring_program))
    rows = []
    for kind, n, build in jobs:
        prog = build(list(range(n)), n)
        caps = [NIC_200G] * n
        repeats = 2 if n <= 1024 else 1
        wall, rep = _min_time(
            lambda: simulate_program(prog, 1e9, capacities=caps, g=8),
            repeats)
        row = {"kind": kind, "ranks": n, "events": rep.events, "wall": wall,
               "events_per_sec": rep.events / wall}
        # chunked rings make the reference arm pathological (each of ~2n
        # epochs refills an n-flow matching at O(n^2) dict work), so only
        # tree rows carry the slow arm + speedup metric
        if kind == "tree" and n <= ref_max:
            wall_ref, rep_ref = _min_time(
                lambda: simulate_program(prog, 1e9, capacities=caps, g=8,
                                         fill="reference"), 1)
            assert rep_ref.completion_time == rep.completion_time
            assert rep_ref.events == rep.events
            row["reference_wall"] = wall_ref
            row["speedup"] = wall_ref / wall
        rows.append(row)
    return rows


def _min_time(fn, repeats: int):
    """(best wall seconds, last result) over ``repeats`` calls."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _min_time_paired(fn_a, fn_b, repeats: int):
    """Interleaved A/B timing: ((best_a, last_a), (best_b, last_b)).

    Alternating the two arms within one loop exposes both to the same
    background-load profile, so their min-ratio stays honest even when
    the machine gets busier mid-measurement (timing the arms in separate
    back-to-back blocks biases whichever ran during the noisier window).
    """
    best_a = best_b = float("inf")
    out_a = out_b = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out_a = fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_b = fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return (best_a, out_a), (best_b, out_b)


def run(tiny: bool = False, seed: int = 0) -> None:
    r = Reporter("BENCH_event_engine")
    servers, devices = (2, 4) if tiny else (4, 8)
    # Payload sized so the collective outlives the fixed recovery-pipeline
    # latencies (~ms): the monitoring cadence is t_h/64, so a collective
    # much shorter than a recovery would stretch the run over thousands of
    # sampling ticks and measure the sampler, not the engine.  Virtual-time
    # payload is free — event count and wall time don't scale with it.
    payload = 4e8 if tiny else 4e9
    repeats = 5
    r.data["seed"] = seed
    r.data["cluster"] = f"{servers}x{devices}"
    r.data["repeats"] = repeats

    cluster = make_cluster(servers, devices, nic_bandwidth=NIC_200G)
    order = list(range(servers))
    t_h = simulate_program(ring_program(order, servers), payload,
                           cluster=cluster).completion_time

    # -- raw engine throughput: healthy ring, no control plane ---------------
    wall, rep = _min_time(
        lambda: simulate_program(ring_program(order, servers), payload,
                                 cluster=cluster), repeats)
    r.row("healthy_events_per_sec", rep.events / wall,
          f"{rep.events} events in {wall * 1e3:.2f}ms wall")

    # -- per-campaign wall time through the full co-simulated loop -----------
    campaigns = standard_campaigns(t_h, num_nodes=servers, rails=devices)
    total_off = 0.0
    total_on = 0.0
    events_off = 0
    events_on = 0
    for sc in campaigns:
        (w_off, rep_off), (w_on, rep_on) = _min_time_paired(
            lambda sc=sc: run_scenario(sc, cluster, payload,
                                       healthy_time=t_h),
            lambda sc=sc: run_scenario(
                sc, cluster, payload, healthy_time=t_h,
                telemetry=Telemetry.for_duration(t_h, samples=64)), repeats)
        total_off += w_off
        total_on += w_on
        events_off += rep_off.report.events
        events_on += rep_on.report.events
        r.row(f"wall_time_{sc.name}", w_off,
              f"{rep_off.report.events} events; telemetry-on "
              f"{w_on * 1e3:.2f}ms ({rep_on.report.events} events)")

    r.row("campaign_events_per_sec", events_off / total_off,
          f"{events_off} events over {len(campaigns)} campaigns, "
          "telemetry off")
    r.row("campaign_events_per_sec_telemetry", events_on / total_on,
          f"{events_on} events (incl. sampling ticks), telemetry on")
    r.row("campaign_sweep_wall_ratio", total_on / total_off,
          f"{total_on * 1e3:.2f}ms on vs {total_off * 1e3:.2f}ms off; "
          "sampling ticks dominate these near-empty event queues")

    # -- telemetry overhead on a loaded engine (the acceptance metric) -------
    # The standard campaigns above are nearly empty event queues (tens of
    # events) moving no real bytes, so a per-collective 64-tick monitoring
    # cadence dwarfs them and the wall ratio measures the sampler alone.
    # The acceptance workload is the realistic regime on both axes: a flap
    # storm over many contending streams that *move real payloads* (every
    # transfer event does the actual numpy reduction work a collective
    # does), monitored at a 64-samples-per-campaign budget — the cadence a
    # fixed-rate monitor yields over one campaign, self-calibrated from the
    # telemetry-off run's completion time.
    n_streams = 12 if tiny else 16
    stress_streams = tuple(
        StreamSpec(f"s{i}", "allreduce" if i % 2 == 0 else "p2p",
                   payload * (0.3 + 0.1 * (i % 5)),
                   start_time=t_h * 0.05 * i, root=i % servers)
        for i in range(n_streams))
    storm = flap_storm(t_h, node=min(1, servers - 1),
                       count=8 if tiny else 12,
                       start_frac=0.1, period_frac=0.25, down_frac=0.04)
    import numpy as np
    rng = np.random.default_rng(seed)
    rank_data = [rng.normal(size=1 << 18) for _ in range(servers)]
    stress = lambda tm: run_scenario(
        storm, cluster, payload, healthy_time=t_h, streams=stress_streams,
        rank_data=rank_data, telemetry=tm() if tm else None)
    _, s_off = _min_time(lambda: stress(None), 1)     # calibration run
    campaign_t = s_off.report.completion_time
    (w_off, s_off), (w_on, s_on) = _min_time_paired(
        lambda: stress(None),
        lambda: stress(lambda: Telemetry.for_duration(campaign_t,
                                                      samples=64)),
        repeats)
    overhead = w_on / w_off - 1.0
    samples = s_on.telemetry.registry.series("rank.tx_rate", (0,))
    n_samples = (len(samples) + samples.dropped) if samples else 0
    r.row("stress_events", float(s_off.report.events),
          f"{n_streams} streams + {len(storm.failures)} flaps, real "
          f"payloads; {n_samples} sampling ticks when telemetry on")
    r.row("stress_wall_time", w_off,
          f"telemetry-on {w_on * 1e3:.2f}ms "
          f"({s_on.report.events} events)")
    r.row("telemetry_overhead", overhead,
          f"loaded-engine wall {w_on * 1e3:.2f}ms on vs "
          f"{w_off * 1e3:.2f}ms off; acceptance < 0.10")

    # -- fleet-scale sweep: incremental vectorized fill at 256..10240 ranks --
    for row in scale_sweep(tiny=tiny):
        tag = f"sweep_{row['kind']}{row['ranks']}"
        r.row(f"{tag}_events_per_sec", row["events_per_sec"],
              f"{row['events']} events in {row['wall'] * 1e3:.1f}ms wall")
        if "speedup" in row:
            r.row(f"{tag}_speedup_vs_reference", row["speedup"],
                  f"reference fill {row['reference_wall'] * 1e3:.1f}ms; "
                  "identical timeline; acceptance >= 5x at 1024")
    r.save()


if __name__ == "__main__":
    run()
