"""Static verifier for collective schedules: dataflow + deadlock analysis.

Two layers of checking over the :mod:`repro.core.schedule` IR:

**Legality** (``check_step`` / ``check_schedule`` / ``check_program``) —
the promoted form of the IR's old bare-``assert`` ``validate()`` methods:
ppermute step legality (unique sources/destinations, ranks and chunk
indices in range), segment fractions summing to 1, rank-count consistency.
``Step.validate`` / ``ChunkSchedule.validate`` / ``CollectiveProgram
.validate`` delegate here, so the checks survive ``python -O`` and carry
step/rank/chunk provenance (:class:`repro.analysis.errors.Provenance`).

**Semantics** (``verify_schedule`` / ``verify_program``) — abstract
interpretation of the schedule over per-(rank, chunk) *contribution
multisets*.  Each chunk's value is tracked symbolically as a multiset of
``(origin_rank, origin_chunk)`` atoms; every :class:`Step` is executed
symbolically (snapshot-reads-then-write, exactly the ppermute / event-engine
round semantics).  The verifier then statically proves, per collective
semantics (inferred from the schedule name or passed explicitly):

  * **AllReduce / Reduce** — every result rank ends holding *exactly* the
    full contribution set of every participant, once each, bound to the
    right chunk region; an accumulate that would double-count a
    contribution raises :class:`DoubleReduceError` at the offending step.
  * **Broadcast** — every result rank ends holding exactly the root's
    value for every chunk; non-root buffers start stale, so forwarding a
    chunk before receiving it raises :class:`StaleReadError`
    (read-before-write with step provenance).
  * **ReduceScatter** — every chunk is fully reduced at at least one
    result rank, with no double-count anywhere.
  * **AllGather** — all result ranks converge on one consistent origin
    value per chunk (region-preserving, no mixing).

**Deadlock-freedom** (``check_deadlock_free``) — the per-rank lockstep
dependency graph (the exact wiring rule of
``EventSimulator._instantiate``: a transfer of step *i* waits on both its
endpoints' transfers of their previous participating step) is built for the
whole program — all segments, including the multi-segment R2CCL
decompositions — and proved acyclic by exhaustion (Kahn).  A cycle is
reported as :class:`DeadlockError` with the offending transfer chain.

``EventSimulator(verify_replans=True)`` routes every dynamically generated
mid-collective resume program (the holder-broadcast / re-reduce residual of
``_do_replan``) through :func:`verify_program` before swapping it in.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Mapping, Sequence

from repro.core.schedule import ChunkSchedule, CollectiveProgram, Step

from .errors import (
    DataflowError,
    DeadlockError,
    DoubleReduceError,
    ProgramError,
    Provenance,
    ResultError,
    ResultRanksError,
    ScheduleError,
    StaleReadError,
    StepLegalityError,
)

__all__ = [
    "Semantics",
    "VerifyReport",
    "check_step",
    "check_schedule",
    "check_program",
    "check_deadlock_free",
    "infer_semantics",
    "verify_schedule",
    "verify_program",
]


# ---------------------------------------------------------------------------
# legality pass (what validate() delegates to)
# ---------------------------------------------------------------------------

def check_step(step: Step, n: int, num_chunks: int, *,
               step_index: int | None = None,
               schedule: str | None = None,
               segment: int | None = None) -> None:
    """ppermute legality of one step; raises :class:`StepLegalityError`."""

    def where(rank: int | None = None, chunk: int | None = None) -> Provenance:
        return Provenance(schedule=schedule, segment=segment,
                          step=step_index, rank=rank, chunk=chunk)

    srcs = [s for s, _ in step.perm]
    dsts = [d for _, d in step.perm]
    if len(set(srcs)) != len(srcs):
        dup = next(s for s in srcs if srcs.count(s) > 1)
        raise StepLegalityError(
            f"duplicate source rank {dup} in perm {step.perm}", where(dup))
    if len(set(dsts)) != len(dsts):
        dup = next(d for d in dsts if dsts.count(d) > 1)
        raise StepLegalityError(
            f"duplicate destination rank {dup} in perm {step.perm}",
            where(dup))
    if len(step.send_chunk) != n or len(step.recv_chunk) != n:
        raise StepLegalityError(
            f"send_chunk/recv_chunk must have length n={n}, got "
            f"{len(step.send_chunk)}/{len(step.recv_chunk)}", where())
    for s, d in step.perm:
        if not (0 <= s < n and 0 <= d < n):
            raise StepLegalityError(
                f"edge ({s}, {d}) outside rank space 0..{n - 1}",
                where(s if not 0 <= s < n else d))
        if not step.whole_buffer:
            if not 0 <= step.send_chunk[s] < num_chunks:
                raise StepLegalityError(
                    f"rank {s} sends chunk {step.send_chunk[s]} outside "
                    f"0..{num_chunks - 1}", where(s, step.send_chunk[s]))
            if not 0 <= step.recv_chunk[d] < num_chunks:
                raise StepLegalityError(
                    f"rank {d} receives into chunk {step.recv_chunk[d]} "
                    f"outside 0..{num_chunks - 1}",
                    where(d, step.recv_chunk[d]))


def check_schedule(sched: ChunkSchedule, *, segment: int | None = None) -> None:
    """Schedule-level legality: every step legal, ``result_ranks`` within
    the rank space, positive chunking."""
    if sched.n <= 0 or sched.num_chunks <= 0:
        raise StepLegalityError(
            f"need n > 0 and num_chunks > 0, got n={sched.n}, "
            f"num_chunks={sched.num_chunks}",
            Provenance(schedule=sched.name, segment=segment))
    for r in sched.result_ranks:
        if not 0 <= r < sched.n:
            raise ResultRanksError(
                f"result rank {r} outside rank space 0..{sched.n - 1}",
                Provenance(schedule=sched.name, segment=segment, rank=r))
    for i, st in enumerate(sched.steps):
        check_step(st, sched.n, sched.num_chunks, step_index=i,
                   schedule=sched.name, segment=segment)


def check_program(prog: CollectiveProgram) -> None:
    """Program-level legality: non-empty, fractions sum to 1, consistent
    rank counts, every segment schedule legal."""
    if not prog.segments:
        raise ProgramError(f"program {prog.name!r} has no segments",
                           Provenance(schedule=prog.name))
    total = sum(s.frac for s in prog.segments)
    if abs(total - 1.0) >= 1e-9:
        raise ProgramError(
            f"segment fractions must sum to 1, got "
            f"{[s.frac for s in prog.segments]} (sum={total!r})",
            Provenance(schedule=prog.name))
    for i, seg in enumerate(prog.segments):
        if seg.frac < 0:
            raise ProgramError(
                f"segment {i} has negative fraction {seg.frac!r}",
                Provenance(schedule=prog.name, segment=i))
        if seg.schedule.n != prog.n:
            raise ProgramError(
                f"segment {i} schedule {seg.schedule.name!r} has "
                f"{seg.schedule.n} ranks but program has {prog.n}",
                Provenance(schedule=seg.schedule.name, segment=i))
        check_schedule(seg.schedule, segment=i)


# ---------------------------------------------------------------------------
# deadlock-freedom of the per-rank lockstep dependency graph
# ---------------------------------------------------------------------------

def check_deadlock_free(
    prog: CollectiveProgram | ChunkSchedule,
    *,
    cross_segment_deps: Mapping[int, Sequence[int]] | None = None,
) -> int:
    """Prove the per-rank lockstep dependency graph acyclic; returns the
    transfer count.

    The graph is built with the event engine's exact wiring rule
    (``EventSimulator._instantiate``): one node per transfer ``(segment,
    step, src, dst)``; a transfer depends on every transfer of its
    endpoints' previous participating step within the same segment.
    Segments are logically concurrent and share no intra-program waits —
    ``cross_segment_deps`` (segment -> segments it must wait for) models
    externally imposed inter-segment barriers, e.g. a resume program whose
    delivery broadcast must precede a re-reduce over the same region.
    Proof is by exhaustion (Kahn's algorithm); any residue is a genuine
    wait cycle, reported with the offending transfer chain.
    """
    schedules: list[tuple[int, ChunkSchedule]]
    if isinstance(prog, ChunkSchedule):
        schedules = [(0, prog)]
        name = prog.name
    else:
        schedules = [(i, s.schedule) for i, s in enumerate(prog.segments)]
        name = prog.name

    nodes: list[tuple[int, int, int, int]] = []      # (seg, step, src, dst)
    deps: list[set[int]] = []
    seg_first: dict[int, int] = {}                   # seg -> first node id
    seg_last: dict[int, int] = {}
    for seg_i, sched in schedules:
        seg_first[seg_i] = len(nodes)
        # walk steps in order carrying each rank's most recent participating
        # step's transfer ids — exactly _instantiate's wiring rule, without
        # rebuilding rank_steps() index chains per node
        last: dict[int, list[int]] = {}
        for step_i, st in enumerate(sched.steps):
            cur: dict[int, list[int]] = {}
            for src, dst in st.perm:
                nid = len(nodes)
                nodes.append((seg_i, step_i, src, dst))
                d = set(last.get(src, ()))
                d.update(last.get(dst, ()))
                d.discard(nid)
                deps.append(d)
                cur.setdefault(src, []).append(nid)
                if dst != src:
                    cur.setdefault(dst, []).append(nid)
            for r, ids in cur.items():
                last[r] = ids
        seg_last[seg_i] = len(nodes)
    if cross_segment_deps:
        for seg_i, waits_on in cross_segment_deps.items():
            for dep_seg in waits_on:
                for nid in range(seg_first[seg_i], seg_last[seg_i]):
                    deps[nid].update(
                        range(seg_first[dep_seg], seg_last[dep_seg]))

    # Kahn's algorithm: if every transfer is eventually releasable the
    # graph is acyclic and the schedule cannot deadlock under per-rank
    # lockstep execution.
    dependents: list[list[int]] = [[] for _ in nodes]
    indeg = [0] * len(nodes)
    for nid, ds in enumerate(deps):
        indeg[nid] = len(ds)
        for p in ds:
            dependents[p].append(nid)
    ready = [nid for nid, d in enumerate(indeg) if d == 0]
    released = 0
    while ready:
        nid = ready.pop()
        released += 1
        for d in dependents[nid]:
            indeg[d] -= 1
            if indeg[d] == 0:
                ready.append(d)
    if released == len(nodes):
        return len(nodes)

    # Residue = at least one cycle: walk never-released nodes until one
    # repeats to extract a concrete wait chain for the diagnostic.
    stuck = {nid for nid in range(len(nodes)) if indeg[nid] > 0}
    nid = min(stuck)
    seen: dict[int, int] = {}
    chain: list[int] = []
    while nid not in seen:
        seen[nid] = len(chain)
        chain.append(nid)
        nid = min(p for p in deps[nid] if p in stuck)
    cycle = tuple(nodes[c] for c in chain[seen[nid]:])
    seg_i, step_i, src, dst = cycle[0]
    raise DeadlockError(
        f"lockstep dependency cycle among {len(stuck)} transfers of "
        f"{name!r}: " + " -> ".join(
            f"(seg {s}, step {t}, {a}->{b})" for s, t, a, b in cycle),
        Provenance(schedule=name, segment=seg_i, step=step_i, rank=src),
        cycle=cycle)


# ---------------------------------------------------------------------------
# semantics: abstract interpretation over contribution multisets
# ---------------------------------------------------------------------------

class Semantics(enum.Enum):
    """What a schedule claims to compute (drives the final-state proof)."""

    ALL_REDUCE = "all_reduce"
    REDUCE = "reduce"
    REDUCE_SCATTER = "reduce_scatter"
    ALL_GATHER = "all_gather"
    BROADCAST = "broadcast"
    #: no semantic claim — legality + deadlock checks only
    OPAQUE = "opaque"


#: name fragments -> semantics, checked in order (first match wins).  The
#: builder naming convention: ring_ar[k], tree_ar[k], partial_ar[k]+bridge,
#: subring_ar[k]+Nbridges, ring_rs[k], ring_ag[k], ring_bcast[k],
#: tree_bcast[k], tree_reduce[k], plus the program names ring_all_reduce /
#: r2ccl_all_reduce / recursive_r2ccl_all_reduce / pp_chain[n].
_NAME_RULES: tuple[tuple[str, Semantics], ...] = (
    ("_ar[", Semantics.ALL_REDUCE),
    ("all_reduce", Semantics.ALL_REDUCE),
    ("allreduce", Semantics.ALL_REDUCE),
    ("_rs[", Semantics.REDUCE_SCATTER),
    ("reduce_scatter", Semantics.REDUCE_SCATTER),
    ("_ag[", Semantics.ALL_GATHER),
    ("all_gather", Semantics.ALL_GATHER),
    ("bcast", Semantics.BROADCAST),
    ("broadcast", Semantics.BROADCAST),
    ("chain", Semantics.BROADCAST),
    ("_reduce[", Semantics.REDUCE),
)


def infer_semantics(name: str) -> Semantics:
    """Collective semantics a schedule/program name claims (the builder
    naming convention); :attr:`Semantics.OPAQUE` when it claims nothing."""
    low = name.lower()
    for frag, sem in _NAME_RULES:
        if frag in low:
            return sem
    return Semantics.OPAQUE


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """What the verifier proved about one schedule."""

    schedule: str
    semantics: Semantics
    #: ranks contributing data (every rank touched by any perm edge)
    contributors: tuple[int, ...]
    #: ranks proven to hold the result (the schedule's result_ranks)
    result_ranks: tuple[int, ...]
    steps: int
    transfers: int
    #: root of a Broadcast/Reduce, when that semantics applied
    root: int | None = None


# abstract value of one (rank, chunk): multiset of (origin_rank, origin_chunk)
# atoms, or None = stale (never written, garbage on the wire if sent)
_Value = "dict[tuple[int, int], int] | None"


def _participants(sched: ChunkSchedule) -> tuple[int, ...]:
    return tuple(sorted({r for st in sched.steps for e in st.perm for r in e}))


def _infer_root(sched: ChunkSchedule, *, segment: int | None) -> int:
    """Root of a broadcast: the unique rank that sources data but never
    receives any (its buffer is the only defined initial state)."""
    sources = {s for st in sched.steps for s, _ in st.perm}
    dests = {d for st in sched.steps for _, d in st.perm}
    candidates = sorted(sources - dests)
    if len(candidates) != 1:
        raise ResultError(
            f"cannot infer broadcast root of {sched.name!r}: "
            f"source-only ranks {candidates} (need exactly one)",
            Provenance(schedule=sched.name, segment=segment))
    return candidates[0]


def _fmt_value(v) -> str:
    if v is None:
        return "<stale>"
    return "{" + ", ".join(
        f"r{r}@c{c}" + (f"x{m}" if m > 1 else "")
        for (r, c), m in sorted(v.items())) + "}"


def _symbolic_execute(
    sched: ChunkSchedule,
    init: "list[list[_Value]]",
    *,
    segment: int | None,
    track_stale: bool,
):
    """Run every step over the abstract state (snapshot reads, then write —
    the ppermute round semantics shared by the numpy executor, the JAX
    backend, and the event engine's per-step release).  Raises
    :class:`StaleReadError` on a send of a never-written chunk (when
    ``track_stale``) and :class:`DoubleReduceError` on an accumulate whose
    contribution multiset already holds any incoming atom."""
    state = init
    for i, st in enumerate(sched.steps):
        # snapshot phase: all sends read pre-step values
        payloads: list[tuple[int, int, list]] = []   # (dst, chunk|-1, values)
        for src, dst in st.perm:
            if st.whole_buffer:
                vals = []
                for c in range(sched.num_chunks):
                    v = state[src][c]
                    if v is None and track_stale:
                        raise StaleReadError(
                            f"rank {src} sends chunk {c} of {sched.name!r} "
                            f"before any write reaches it",
                            Provenance(schedule=sched.name, segment=segment,
                                       step=i, rank=src, chunk=c))
                    vals.append(dict(v) if v is not None else None)
                payloads.append((dst, -1, vals))
            else:
                c = st.send_chunk[src]
                v = state[src][c]
                if v is None and track_stale:
                    raise StaleReadError(
                        f"rank {src} sends chunk {c} of {sched.name!r} "
                        f"before any write reaches it",
                        Provenance(schedule=sched.name, segment=segment,
                                   step=i, rank=src, chunk=c))
                payloads.append(
                    (dst, st.recv_chunk[dst],
                     [dict(v) if v is not None else None]))
        # write phase
        for dst, chunk, vals in payloads:
            chunks = (range(sched.num_chunks) if chunk < 0 else (chunk,))
            for c, val in zip(chunks, vals):
                if not st.accumulate:
                    state[dst][c] = val
                    continue
                cur = state[dst][c]
                if val is None:
                    continue                     # accumulating stale: caught
                if cur is None:                  # above when track_stale
                    state[dst][c] = val
                    continue
                merged = dict(cur)
                for atom, m in val.items():
                    if atom in merged:
                        raise DoubleReduceError(
                            f"accumulate at rank {dst} chunk {c} of "
                            f"{sched.name!r} double-counts contribution "
                            f"r{atom[0]}@c{atom[1]} (already held: "
                            f"{_fmt_value(cur)})",
                            Provenance(schedule=sched.name, segment=segment,
                                       step=i, rank=dst, chunk=c))
                    merged[atom] = m
                state[dst][c] = merged
    return state


def _full_set(contributors: Sequence[int], chunk: int) -> dict:
    return {(r, chunk): 1 for r in contributors}


# Structural proof cache: two structurally identical schedules verify
# identically, so a successful proof is keyed by the schedule's full
# semantic content (name, shape, steps, result ranks) plus the semantics/
# root overrides.  Only successes are cached — a failing schedule re-runs
# and re-raises with fresh provenance.  This makes hot-path re-verification
# (every replan of a campaign builds structurally equal programs) cost a
# tuple hash instead of a symbolic execution.
#
# Eviction is LRU: under cache pressure the least-recently-proved entry is
# dropped (the earlier cap behavior — clearing the whole memo — silently
# stopped caching the hot entries a long campaign re-proves every replan).
# Counters are exposed (``memo_stats``) so tests can assert both that
# eviction happened and that results never change under pressure.
_MEMO_CAP = 4096


class _ProofMemo:
    """Bounded LRU map of successful proofs, with observable counters."""

    def __init__(self, cap: int = _MEMO_CAP):
        self.cap = cap
        self._entries: dict = {}          # insertion order = recency order
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key):
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        # refresh recency: move to the most-recently-used end
        del self._entries[key]
        self._entries[key] = entry
        self.hits += 1
        return entry

    def put(self, key, value) -> None:
        if key in self._entries:
            del self._entries[key]
        elif len(self._entries) >= self.cap > 0:
            oldest = next(iter(self._entries))
            del self._entries[oldest]
            self.evictions += 1
        self._entries[key] = value

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = self.evictions = 0

    def stats(self) -> dict:
        return {"size": len(self._entries), "cap": self.cap,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


_SCHED_MEMO = _ProofMemo()
_PROG_MEMO = _ProofMemo()


def memo_stats() -> dict:
    """Counters of both proof memos (schedule- and program-level), for
    tests and diagnostics: size/cap/hits/misses/evictions each."""
    return {"schedule": _SCHED_MEMO.stats(), "program": _PROG_MEMO.stats()}


def clear_memos() -> None:
    """Drop all cached proofs and reset the counters (test isolation)."""
    _SCHED_MEMO.clear()
    _PROG_MEMO.clear()


def _sched_key(sched: ChunkSchedule):
    return (sched.name, sched.n, sched.num_chunks,
            tuple(sched.result_ranks), tuple(sched.steps))


def verify_schedule(
    sched: ChunkSchedule,
    *,
    semantics: Semantics | None = None,
    root: int | None = None,
    segment: int | None = None,
    _structural: bool = True,
) -> VerifyReport:
    """Statically prove ``sched`` computes its claimed collective.

    Runs the legality pass, the deadlock-freedom proof, then the abstract
    interpretation matching ``semantics`` (inferred from the schedule name
    when not given).  Raises a :class:`ScheduleError` subclass on the first
    violation; returns a :class:`VerifyReport` of what was proved.
    (``_structural=False`` skips legality + deadlock when the caller —
    :func:`verify_program` — already proved them at program level.)
    """
    memo_key = (_sched_key(sched), semantics, root)
    cached = _SCHED_MEMO.get(memo_key)
    if cached is not None:
        return cached
    rep = _verify_schedule_impl(sched, semantics=semantics, root=root,
                                segment=segment, _structural=_structural)
    _SCHED_MEMO.put(memo_key, rep)
    return rep


def _verify_schedule_impl(
    sched: ChunkSchedule,
    *,
    semantics: Semantics | None,
    root: int | None,
    segment: int | None,
    _structural: bool,
) -> VerifyReport:
    if _structural:
        check_schedule(sched, segment=segment)
        transfers = check_deadlock_free(sched)
    else:
        transfers = sum(len(st.perm) for st in sched.steps)
    sem = infer_semantics(sched.name) if semantics is None else semantics
    contributors = _participants(sched)

    def where(rank=None, chunk=None):
        return Provenance(schedule=sched.name, segment=segment,
                          rank=rank, chunk=chunk)

    if sem is Semantics.OPAQUE:
        return VerifyReport(sched.name, sem, contributors,
                            tuple(sched.result_ranks), len(sched.steps),
                            transfers)

    if not sched.result_ranks:
        raise ResultRanksError(
            f"{sched.name!r} claims {sem.value} semantics but declares no "
            f"result_ranks — nothing to prove (builders must populate it)",
            where())
    result_ranks = tuple(sched.result_ranks)
    if not contributors:
        raise ResultError(f"{sched.name!r} moves no data", where())

    n, nc = sched.n, sched.num_chunks
    if sem in (Semantics.BROADCAST,):
        bc_root = root if root is not None else _infer_root(
            sched, segment=segment)
        init: list = [
            [({(r, c): 1} if r == bc_root else None) for c in range(nc)]
            for r in range(n)]
        final = _symbolic_execute(sched, init, segment=segment,
                                  track_stale=True)
        for r in result_ranks:
            for c in range(nc):
                want = {(bc_root, c): 1}
                if final[r][c] != want:
                    raise ResultError(
                        f"broadcast incomplete: rank {r} chunk {c} of "
                        f"{sched.name!r} ends as {_fmt_value(final[r][c])}, "
                        f"want the root's value {_fmt_value(want)}",
                        where(r, c))
        return VerifyReport(sched.name, sem, contributors, result_ranks,
                            len(sched.steps), transfers, root=bc_root)

    # reduce / gather family: every rank starts holding its own
    # contribution for every chunk region
    init = [[{(r, c): 1} for c in range(nc)] for r in range(n)]
    final = _symbolic_execute(sched, init, segment=segment, track_stale=False)

    if sem is Semantics.ALL_REDUCE or sem is Semantics.REDUCE:
        targets = result_ranks
        if sem is Semantics.REDUCE and root is not None:
            targets = (root,)
        for r in targets:
            for c in range(nc):
                want = _full_set(contributors, c)
                got = final[r][c]
                if got != want:
                    missing = sorted(set(want) - set(got or {}))
                    extra = sorted(set(got or {}) - set(want))
                    raise ResultError(
                        f"{sem.value} incomplete at rank {r} chunk {c} of "
                        f"{sched.name!r}: holds {_fmt_value(got)}, want full "
                        f"contribution set of {list(contributors)}"
                        + (f"; missing {missing}" if missing else "")
                        + (f"; extra {extra}" if extra else ""),
                        where(r, c))
        return VerifyReport(sched.name, sem, contributors, result_ranks,
                            len(sched.steps), transfers,
                            root=targets[0] if sem is Semantics.REDUCE
                            else None)

    if sem is Semantics.REDUCE_SCATTER:
        for c in range(nc):
            want = _full_set(contributors, c)
            if not any(final[r][c] == want for r in result_ranks):
                raise ResultError(
                    f"reduce_scatter leaves chunk {c} of {sched.name!r} "
                    f"fully reduced at no result rank", where(chunk=c))
        return VerifyReport(sched.name, sem, contributors, result_ranks,
                            len(sched.steps), transfers)

    if sem is Semantics.ALL_GATHER:
        # unknown initial layout: prove all result ranks converge on one
        # consistent origin value per chunk, region-preserving
        for c in range(nc):
            vals = {r: final[r][c] for r in result_ranks}
            first = vals[result_ranks[0]]
            if (first is None or len(first) != 1
                    or next(iter(first.values())) != 1):
                raise ResultError(
                    f"all_gather chunk {c} of {sched.name!r} is not a "
                    f"single origin value at rank {result_ranks[0]}: "
                    f"{_fmt_value(first)}", where(result_ranks[0], c))
            (_, origin_chunk), = first.keys()
            if origin_chunk != c:
                raise ResultError(
                    f"all_gather chunk {c} of {sched.name!r} ends bound to "
                    f"region {origin_chunk} (region not preserved)",
                    where(result_ranks[0], c))
            for r, v in vals.items():
                if v != first:
                    raise ResultError(
                        f"all_gather divergence at chunk {c} of "
                        f"{sched.name!r}: rank {r} holds {_fmt_value(v)} "
                        f"but rank {result_ranks[0]} holds "
                        f"{_fmt_value(first)}", where(r, c))
        return VerifyReport(sched.name, sem, contributors, result_ranks,
                            len(sched.steps), transfers)

    raise ScheduleError(f"unhandled semantics {sem!r}", where())


def verify_program(
    prog: CollectiveProgram,
    *,
    semantics: Semantics | None = None,
) -> list[VerifyReport]:
    """Statically verify every segment of ``prog`` plus whole-program
    structure and deadlock-freedom.

    ``semantics`` overrides the per-segment name inference *only* for
    segments whose own name is opaque — the R2CCL decompositions mix
    AllReduce segments with delivery broadcasts, and each segment's name
    states which it is.  Returns one :class:`VerifyReport` per segment.
    """
    memo_key = (prog.name, prog.n, semantics,
                tuple((seg.frac, _sched_key(seg.schedule))
                      for seg in prog.segments))
    cached = _PROG_MEMO.get(memo_key)
    if cached is not None:
        return list(cached)
    check_program(prog)                  # legality of every segment schedule
    check_deadlock_free(prog)            # whole-program graph covers them all
    prog_sem = (infer_semantics(prog.name) if semantics is None
                else semantics)
    reports = []
    for i, seg in enumerate(prog.segments):
        seg_sem = infer_semantics(seg.schedule.name)
        if seg_sem is Semantics.OPAQUE:
            seg_sem = prog_sem
        reports.append(verify_schedule(
            seg.schedule, semantics=seg_sem, segment=i, _structural=False))
    _PROG_MEMO.put(memo_key, tuple(reports))
    return reports
