"""CLI: ``python -m repro.analysis [verify|lint|cost|coverage] ...``.

* ``verify [--seed S] [--max-n N]`` — run the schedule verifier over the
  full builder corpus; prints one line per entry, exits non-zero on the
  first schedule that fails to prove.
* ``lint [paths...]`` — run the determinism lint (defaults to
  ``src/repro/core``, ``src/repro/runtime``, ``src/repro/analysis`` and
  ``src/repro/serving``); exits non-zero if any finding is emitted.
* ``cost [--corpus] [--out PATH]`` — static cost analysis over the builder
  corpus; with ``--corpus``, full conformance against the event engine's
  healthy completion (bit-exact for lockstep-uniform entries, within
  ``CORPUS_COST_TOLERANCE`` everywhere), writing a JSON report.
* ``coverage [--out PATH]`` — static failure-coverage (survivability
  matrix) over the builder corpus; exits non-zero if any schedule fails to
  survive a single NIC/rail failure on the multi-rail capacity model.

With no subcommand, runs verify + lint with defaults (the CI gate).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from .corpus import builder_corpus
from .cost import (
    CONFORMANCE_CAPACITY,
    CONFORMANCE_PAYLOAD,
    CORPUS_COST_TOLERANCE,
    analyze_program,
    as_program,
)
from .coverage import analyze_coverage
from .errors import ScheduleError
from .lint import DEFAULT_LINT_TARGETS, lint_paths
from .verify import verify_program, verify_schedule
from repro.core.schedule import CollectiveProgram

#: rails per rank for the uniform conformance capacity model (multi-rail,
#: so every single-rail failure leaves residual capacity)
CONFORMANCE_RAILS = 2


def _run_verify(seed: int, max_n: int) -> int:
    n_sched = n_transfers = 0
    for label, obj in builder_corpus(seed=seed, max_n=max_n):
        try:
            if isinstance(obj, CollectiveProgram):
                reports = verify_program(obj)
            else:
                reports = [verify_schedule(obj)]
        except ScheduleError as e:
            print(f"FAIL {label}: {type(e).__name__}: {e}")
            return 1
        n_sched += len(reports)
        n_transfers += sum(r.transfers for r in reports)
        proved = ", ".join(f"{r.schedule}:{r.semantics.value}"
                           for r in reports)
        print(f"ok   {label}  [{proved}]")
    print(f"verified {n_sched} schedules ({n_transfers} transfers) clean")
    return 0


def _resolve_targets(paths: list[str]) -> list[pathlib.Path]:
    if paths:
        return [pathlib.Path(p) for p in paths]
    # default targets are repo-relative; resolve against this package's
    # location so the CLI works from any cwd
    src_root = pathlib.Path(__file__).resolve().parents[2]   # .../src
    repo_root = src_root.parent
    return [repo_root / t for t in DEFAULT_LINT_TARGETS]


def _run_lint(paths: list[str]) -> int:
    targets = _resolve_targets(paths)
    findings = lint_paths(targets)
    for f in findings:
        print(f)
    label = ", ".join(str(t) for t in targets)
    if findings:
        print(f"lint: {len(findings)} finding(s) in {label}")
        return 1
    print(f"lint clean: {label}")
    return 0


def _write_report(out: str | None, default_name: str, doc: dict) -> None:
    if out is None:
        repo_root = pathlib.Path(__file__).resolve().parents[3]
        out_path = repo_root / "experiments" / "analysis" / default_name
    else:
        out_path = pathlib.Path(out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(doc, indent=1, default=str))
    print(f"report written to {out_path}")


def _run_cost(seed: int, max_n: int, payload: float, corpus: bool,
              out: str | None) -> int:
    """Static cost analysis over the corpus; ``corpus=True`` adds the full
    engine-conformance sweep (the CI gate)."""
    entries = []
    max_rel = 0.0
    worst = None
    exact = uniform = total = 0
    rc = 0
    for label, obj in builder_corpus(seed=seed, max_n=max_n):
        prog = as_program(obj)
        caps = [CONFORMANCE_CAPACITY] * prog.n
        rep = analyze_program(prog, payload, capacities=caps)
        entry = {
            "label": label,
            "n": prog.n,
            "predicted_time": rep.predicted_time,
            "lockstep_uniform": rep.lockstep_uniform,
            "rounds": rep.rounds,
            "transfers": rep.transfers,
        }
        total += 1
        uniform += rep.lockstep_uniform
        if corpus:
            from repro.core.event_sim import healthy_completion

            engine = healthy_completion(prog, payload, capacities=caps,
                                        g=CONFORMANCE_RAILS)
            rel = (abs(rep.predicted_time - engine) / engine
                   if engine > 0 else 0.0)
            entry["engine_time"] = engine
            entry["rel_error"] = rel
            if rel > max_rel:
                max_rel, worst = rel, label
            if rep.lockstep_uniform:
                if rep.predicted_time == engine:
                    exact += 1
                else:
                    print(f"FAIL {label}: lockstep-uniform but not "
                          f"bit-exact: static={rep.predicted_time!r} "
                          f"engine={engine!r}")
                    rc = 1
            if rel > CORPUS_COST_TOLERANCE:
                print(f"FAIL {label}: rel error {rel:.4g} exceeds corpus "
                      f"tolerance {CORPUS_COST_TOLERANCE}")
                rc = 1
        entries.append(entry)

    doc = {
        "payload_bytes": payload,
        "capacity": CONFORMANCE_CAPACITY,
        "tolerance": CORPUS_COST_TOLERANCE,
        "entries_total": total,
        "lockstep_uniform": uniform,
        "conformance_ran": corpus,
        "bit_exact": exact,
        "max_rel_error": max_rel,
        "worst_entry": worst,
        "entries": entries,
    }
    _write_report(out, "cost_report.json", doc)
    if corpus:
        print(f"cost conformance: {total} entries, {uniform} lockstep-"
              f"uniform ({exact} bit-exact), max rel error {max_rel:.4g} "
              f"(tolerance {CORPUS_COST_TOLERANCE}, worst: {worst})")
    else:
        print(f"cost analysis: {total} entries, {uniform} lockstep-uniform "
              f"(pass --corpus for the engine conformance sweep)")
    return rc


def _run_coverage(seed: int, max_n: int, payload: float,
                  out: str | None) -> int:
    entries = []
    total_cells = survivable_cells = 0
    rc = 0
    for label, obj in builder_corpus(seed=seed, max_n=max_n):
        prog = as_program(obj)
        caps = [CONFORMANCE_CAPACITY] * prog.n
        rep = analyze_coverage(prog, payload, capacities=caps,
                               g=CONFORMANCE_RAILS)
        total_cells += len(rep.entries)
        survivable_cells += sum(1 for e in rep.entries if e.survivable)
        entries.append({
            "label": label,
            "n": prog.n,
            "survivable_fraction": rep.survivable_fraction,
            "worst_slowdown": rep.worst_slowdown,
            "findings": [str(f) for f in rep.findings],
        })
        for f in rep.findings:
            print(f"FAIL {label}: {type(f).__name__}: {f}")
            rc = 1
    frac = survivable_cells / total_cells if total_cells else 1.0
    doc = {
        "payload_bytes": payload,
        "capacity": CONFORMANCE_CAPACITY,
        "rails": CONFORMANCE_RAILS,
        "entries_total": len(entries),
        "failure_cells": total_cells,
        "survivable_fraction": frac,
        "entries": entries,
    }
    _write_report(out, "coverage_report.json", doc)
    print(f"coverage: {len(entries)} entries, {total_cells} single-rail "
          f"failures checked, survivable fraction {frac:.4g}")
    return rc


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="cmd")
    pv = sub.add_parser("verify", help="verify the builder corpus")
    pv.add_argument("--seed", type=int, default=0)
    pv.add_argument("--max-n", type=int, default=8)
    pl = sub.add_parser("lint", help="run the determinism lint")
    pl.add_argument("paths", nargs="*", help="files/dirs (default: "
                    + ", ".join(DEFAULT_LINT_TARGETS) + ")")
    pc = sub.add_parser("cost", help="static cost analysis over the corpus")
    pc.add_argument("--corpus", action="store_true",
                    help="full conformance sweep against the event engine")
    pc.add_argument("--seed", type=int, default=0)
    pc.add_argument("--max-n", type=int, default=8)
    pc.add_argument("--payload", type=float, default=CONFORMANCE_PAYLOAD)
    pc.add_argument("--out", default=None, metavar="PATH",
                    help="JSON report path (default: "
                         "experiments/analysis/cost_report.json)")
    pg = sub.add_parser("coverage",
                        help="static failure-coverage over the corpus")
    pg.add_argument("--seed", type=int, default=0)
    pg.add_argument("--max-n", type=int, default=8)
    pg.add_argument("--payload", type=float, default=CONFORMANCE_PAYLOAD)
    pg.add_argument("--out", default=None, metavar="PATH",
                    help="JSON report path (default: "
                         "experiments/analysis/coverage_report.json)")
    args = parser.parse_args(argv)

    if args.cmd == "verify":
        return _run_verify(args.seed, args.max_n)
    if args.cmd == "lint":
        return _run_lint(args.paths)
    if args.cmd == "cost":
        return _run_cost(args.seed, args.max_n, args.payload, args.corpus,
                         args.out)
    if args.cmd == "coverage":
        return _run_coverage(args.seed, args.max_n, args.payload, args.out)
    rc = _run_verify(seed=0, max_n=8)
    return rc or _run_lint([])


if __name__ == "__main__":
    sys.exit(main())
