"""Collective schedule IR + numpy executor: semantic correctness
(property-based over ring sizes, payloads, degraded nodes)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allreduce import (
    bottleneck_traffic,
    build_partial_all_reduce,
    build_r2ccl_all_reduce,
)
from repro.core.executor_np import (
    ExecStats,
    all_reduce_oracle,
    check_all_reduce,
    execute_chunk_schedule,
    execute_program,
)
from repro.core.recursive import build_recursive_all_reduce, spectrum_levels
from repro.core.schedule import (
    build_ring_all_gather,
    build_ring_all_reduce,
    build_ring_broadcast,
    build_ring_reduce_scatter,
    ring_program,
)


def _data(n, size, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=size) for _ in range(n)]


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 12), size=st.integers(1, 300), seed=st.integers(0, 99))
def test_ring_allreduce_correct(n, size, seed):
    prog = ring_program(list(range(n)), n)
    assert check_all_reduce(prog, _data(n, size, seed))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 12), deg=st.integers(0, 11), x=st.floats(0.05, 0.9),
       size=st.integers(2, 200), seed=st.integers(0, 99))
def test_r2ccl_allreduce_correct(n, deg, x, size, seed):
    deg = deg % n
    prog, plan = build_r2ccl_all_reduce(list(range(n)), deg, x=x, g=8)
    assert check_all_reduce(prog, _data(n, size, seed))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 10), seed=st.integers(0, 50))
def test_recursive_allreduce_correct(n, seed):
    rng = np.random.default_rng(seed)
    bw = list(rng.uniform(100, 400, size=n))
    prog, levels = build_recursive_all_reduce(bw)
    assert check_all_reduce(prog, _data(n, 64, seed))
    assert abs(sum(lv.frac for lv in levels) - 1.0) < 1e-9


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10), root=st.integers(0, 9), size=st.integers(1, 128))
def test_broadcast_correct(n, root, size):
    root = root % n
    data = _data(n, size)
    sched = build_ring_broadcast(list(range(n)), n, root=root)
    out = execute_chunk_schedule(sched, data)
    for o in out:
        assert np.allclose(o, data[root])


@given(n=st.integers(2, 10))
def test_reduce_scatter_ownership(n):
    data = _data(n, n * 8)
    sched = build_ring_reduce_scatter(list(range(n)), n)
    out = execute_chunk_schedule(sched, data)
    want = all_reduce_oracle(data).reshape(n, -1)
    for i in range(n):
        owned = (i + 1) % n
        got = out[i].reshape(n, -1)[owned]
        assert np.allclose(got, want[owned])


def test_degraded_rank_traffic_reduced():
    """Figure 5: the decomposition lowers the degraded rank's tx+rx."""
    n = 8
    prog_ring = ring_program(list(range(n)), n)
    prog_r2, plan = build_r2ccl_all_reduce(list(range(n)), 3, x=0.5, g=8)
    assert plan.use_r2ccl
    d = 1e6
    assert bottleneck_traffic(prog_r2, d, 3) < bottleneck_traffic(prog_ring, d, 3)


def test_traffic_model_matches_executor():
    """Analytic bytes_per_rank must equal the executor's measured traffic."""
    n = 6
    prog, _ = build_r2ccl_all_reduce(list(range(n)), 2, x=0.6, g=8)
    size = 120
    data = _data(n, size)
    stats = ExecStats()
    execute_program(prog, data, stats=stats)
    model = prog.bytes_per_rank(size * 8.0)
    for rank in range(n):
        tx = stats.rank_tx.get(rank, 0.0)
        assert tx == pytest.approx(model[rank]["tx"], rel=0.35), rank


def test_inflight_failover_lossless():
    """A link dying mid-round: the round replays (DMA rollback), result exact."""
    n = 8
    data = _data(n, 256)
    sched = build_ring_all_reduce(list(range(n)), n)
    stats = ExecStats()
    out = execute_chunk_schedule(sched, data, stats=stats,
                                 fail_at_round={3: (1, 2), 9: (5, 6)})
    want = all_reduce_oracle(data)
    assert stats.failovers == 2
    for o in out:
        assert np.allclose(o, want)


# ---------------------------------------------------------------------------
# Tree schedules (Table 1 latency path)
# ---------------------------------------------------------------------------

from repro.core.schedule import (  # noqa: E402
    build_tree_all_reduce,
    build_tree_broadcast,
    build_tree_reduce,
    tree_program,
)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 16), root=st.integers(0, 15), size=st.integers(1, 64),
       seed=st.integers(0, 50))
def test_tree_allreduce_correct(n, root, size, seed):
    root = root % n
    prog = tree_program(list(range(n)), n)
    assert check_all_reduce(prog, _data(n, size, seed))
    # explicit root variant
    sched = build_tree_all_reduce(list(range(n)), n, root=root)
    out = execute_chunk_schedule(sched, _data(n, size, seed))
    want = all_reduce_oracle(_data(n, size, seed))
    for o in out:
        assert np.allclose(o, want)


@given(n=st.integers(2, 16))
def test_tree_depth_logarithmic(n):
    import math
    sched = build_tree_all_reduce(list(range(n)), n)
    assert len(sched.steps) == 2 * math.ceil(math.log2(n))


@given(n=st.integers(2, 12), root=st.integers(0, 11))
def test_tree_reduce_only_root(n, root):
    root = root % n
    data = _data(n, 16)
    sched = build_tree_reduce(list(range(n)), n, root)
    out = execute_chunk_schedule(sched, data)
    assert np.allclose(out[root], all_reduce_oracle(data))
